//! Typed run configuration (reserved for the TOML config file support; the CLI currently drives ClusterConfig directly).

