//! Typed run configuration shared by the CLI and the library entry points.
//!
//! Currently hosts the leader-side aggregation tunables (the
//! [`crate::ps::Aggregator`] subsystem); the TOML config-file support the
//! module was reserved for will layer on top of these types.

/// Which leader aggregation path to run.
///
/// All paths are **bitwise-identical** in their output (the sharded and
/// streaming reductions preserve the sequential per-element addition
/// order — see `ps/aggregate.rs`), so this flag is a pure performance
/// A/B switch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggMode {
    /// Seed behavior: decode and accumulate the M payloads one after
    /// another on the leader thread.
    Sequential,
    /// Decode payloads thread-parallel across workers, then reduce
    /// cache-sized shards of the parameter vector thread-parallel.
    Sharded,
    /// Event-driven round engine: payloads are decoded **on arrival**
    /// (overlapping decode with the wait for stragglers), then the same
    /// shard-parallel reduce runs once the barrier completes.
    Streaming,
}

impl AggMode {
    /// Parse a CLI string: `sharded`/`parallel`, `sequential`/`seq` or
    /// `streaming`/`stream`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "sharded" | "parallel" => Ok(Self::Sharded),
            "sequential" | "seq" => Ok(Self::Sequential),
            "streaming" | "stream" => Ok(Self::Streaming),
            other => {
                anyhow::bail!("unknown aggregation mode '{other}' (sharded|sequential|streaming)")
            }
        }
    }
}

/// Leader aggregation configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AggregatorConfig {
    pub mode: AggMode,
    /// Pool threads for the sharded path (0 = available parallelism).
    pub threads: usize,
    /// Target elements per reduction shard. The default (16Ki f32 =
    /// 64 KiB) keeps a shard inside L2 while giving enough shards to
    /// fill the pool on DCGAN-sized vectors.
    pub shard_elems: usize,
}

impl Default for AggregatorConfig {
    fn default() -> Self {
        Self { mode: AggMode::Sharded, threads: 0, shard_elems: 16 * 1024 }
    }
}

impl AggregatorConfig {
    /// Seed-equivalent sequential configuration (the A/B baseline).
    pub fn sequential() -> Self {
        Self { mode: AggMode::Sequential, ..Self::default() }
    }

    /// Streaming (decode-on-arrival) configuration.
    pub fn streaming() -> Self {
        Self { mode: AggMode::Streaming, ..Self::default() }
    }

    /// Resolve `threads` to a concrete pool size.
    pub fn resolved_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_modes() {
        assert_eq!(AggMode::parse("sharded").unwrap(), AggMode::Sharded);
        assert_eq!(AggMode::parse("parallel").unwrap(), AggMode::Sharded);
        assert_eq!(AggMode::parse("SEQ").unwrap(), AggMode::Sequential);
        assert_eq!(AggMode::parse("sequential").unwrap(), AggMode::Sequential);
        assert_eq!(AggMode::parse("streaming").unwrap(), AggMode::Streaming);
        assert_eq!(AggMode::parse("stream").unwrap(), AggMode::Streaming);
        assert!(AggMode::parse("wat").is_err());
    }

    #[test]
    fn streaming_preset() {
        let cfg = AggregatorConfig::streaming();
        assert_eq!(cfg.mode, AggMode::Streaming);
        assert_eq!(cfg.shard_elems, AggregatorConfig::default().shard_elems);
    }

    #[test]
    fn default_is_sharded_with_auto_threads() {
        let cfg = AggregatorConfig::default();
        assert_eq!(cfg.mode, AggMode::Sharded);
        assert!(cfg.resolved_threads() >= 1);
        assert_eq!(AggregatorConfig::sequential().mode, AggMode::Sequential);
    }
}
