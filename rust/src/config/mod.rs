//! Typed run configuration shared by the CLI and the library entry points.
//!
//! Currently hosts the leader-side aggregation tunables (the
//! [`crate::ps::Aggregator`] subsystem); the TOML config-file support the
//! module was reserved for will layer on top of these types.

/// Which leader aggregation path to run.
///
/// All paths are **bitwise-identical** in their output (the sharded and
/// streaming reductions preserve the sequential per-element addition
/// order — see `ps/aggregate.rs`), so this flag is a pure performance
/// A/B switch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggMode {
    /// Seed behavior: decode and accumulate the M payloads one after
    /// another on the leader thread.
    Sequential,
    /// Decode payloads thread-parallel across workers, then reduce
    /// cache-sized shards of the parameter vector thread-parallel.
    Sharded,
    /// Event-driven round engine: payloads are decoded **on arrival**
    /// (overlapping decode with the wait for stragglers), then the same
    /// shard-parallel reduce runs once the barrier completes.
    Streaming,
    /// The streaming engine plus a fully pipelined round loop: the
    /// broadcast is queued onto per-worker writer threads
    /// (`ServerEnd::broadcast_async`) instead of written serially on the
    /// leader thread, so one slow receiver no longer delays the next
    /// round's gather, and frames for round t+1 decode on arrival (into
    /// the aggregator's second slot bank) while round t's broadcast is
    /// still in flight. Output is bitwise-identical to `Streaming` —
    /// scheduling changes only, never the reduced values.
    Pipelined,
}

impl AggMode {
    /// Parse a CLI string: `sharded`/`parallel`, `sequential`/`seq`,
    /// `streaming`/`stream` or `pipelined`/`pipeline`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "sharded" | "parallel" => Ok(Self::Sharded),
            "sequential" | "seq" => Ok(Self::Sequential),
            "streaming" | "stream" => Ok(Self::Streaming),
            "pipelined" | "pipeline" => Ok(Self::Pipelined),
            other => anyhow::bail!(
                "unknown aggregation mode '{other}' (sharded|sequential|streaming|pipelined)"
            ),
        }
    }

    /// Whether this mode runs the event-driven (decode-on-arrival) round
    /// engine — the prerequisite for partial round-completion policies.
    pub fn is_streaming(self) -> bool {
        matches!(self, Self::Streaming | Self::Pipelined)
    }
}

/// When the streaming-engine leader folds decoded payloads into the
/// round's mean (`--reduce`). Both schedules perform exactly the same
/// float additions in the same worker-id order per element, so the
/// reduced values are **bitwise identical** — this is a pure scheduling
/// switch, like [`AggMode`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceMode {
    /// Incremental windowed reduce (default): as the gather runs, the
    /// contiguous lowest-worker-id prefix of arrived+decoded slots is
    /// folded into the shard accumulators, so the close-time reduce only
    /// folds the remaining tail (empty when arrivals were in order). On
    /// the pipelined path the close-time tail fold is additionally
    /// **offloaded** to a detached pool task that the leader joins after
    /// preparing the broadcast frame.
    Windowed,
    /// Fold nothing until the round closes (the pre-windowed behavior,
    /// kept as the A/B baseline).
    Barrier,
}

impl ReduceMode {
    /// Parse a CLI string: `windowed`/`incremental` or `barrier`/`close`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "windowed" | "incremental" => Ok(Self::Windowed),
            "barrier" | "close" => Ok(Self::Barrier),
            other => anyhow::bail!("unknown reduce mode '{other}' (windowed|barrier)"),
        }
    }
}

/// Which hot-path kernel implementations to run (`--kernels`).
///
/// Like [`AggMode`] and [`ReduceMode`] this is a pure performance A/B
/// switch: the SIMD kernels are **bitwise-identical** to the scalar
/// baseline (same per-element expressions, same add order, same rounding
/// sites — see `kernels/`), so CI can diff `broadcast_fnv` across the two
/// settings forever. The process-global mode lives in [`crate::kernels`];
/// this type is just its parse/label surface.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelMode {
    /// Lane-chunked kernels (portable 8-wide unrolling, plus runtime-
    /// detected AVX2 on x86-64 where it wins). The default.
    #[default]
    Simd,
    /// The original element-at-a-time loops, kept reachable as the
    /// baseline arm of the scalar-vs-SIMD checksum A/B.
    Scalar,
}

impl KernelMode {
    /// Parse a CLI string: `simd`/`vector` or `scalar`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "simd" | "vector" => Ok(Self::Simd),
            "scalar" => Ok(Self::Scalar),
            other => anyhow::bail!("unknown kernel mode '{other}' (simd|scalar)"),
        }
    }

    /// Display label for logs and bench case names.
    pub fn label(self) -> &'static str {
        match self {
            Self::Simd => "simd",
            Self::Scalar => "scalar",
        }
    }
}

/// Which transport engine carries the leader ⇄ worker frames
/// (`--transport`).
///
/// Like [`AggMode`]/[`ReduceMode`]/[`KernelMode`] this is a pure
/// scheduling switch: the broadcasts are **bitwise-identical** across the
/// two engines (CI diffs `broadcast_fnv` between them), only the thread
/// structure and flow-control mechanism differ.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportMode {
    /// One readiness-loop delivery thread serves every worker (O(1)
    /// leader threads in M), and `--pipeline-depth` bounds *applied*
    /// broadcasts per worker via `Ack` control frames. The default.
    #[default]
    EvLoop,
    /// The per-worker reader/writer thread army (O(M) leader threads,
    /// depth bounds *written* broadcasts), kept as the A/B baseline for
    /// one release.
    Threads,
}

impl TransportMode {
    /// Parse a CLI string: `evloop`/`poll` or `threads`/`threaded`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "evloop" | "poll" => Ok(Self::EvLoop),
            "threads" | "threaded" => Ok(Self::Threads),
            other => anyhow::bail!("unknown transport '{other}' (evloop|threads)"),
        }
    }

    /// Display label for logs and bench case names.
    pub fn label(self) -> &'static str {
        match self {
            Self::EvLoop => "evloop",
            Self::Threads => "threads",
        }
    }
}

/// Round-completion policy: after each accepted arrival the streaming
/// leader asks "does this round close now, or keep waiting?". The
/// runtime engine is built from this in `ps/policy.rs`; anything other
/// than [`PolicyConfig::Full`] requires [`AggMode::Streaming`] (the
/// barrier paths have no per-arrival hook to consult).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyConfig {
    /// Synchronous barrier semantics: wait for all M payloads (default).
    Full,
    /// Close as soon as `k` of the M payloads have been accepted. The
    /// remaining workers are skipped for the round; the broadcast's
    /// inclusion bitmap tells them to fold their entire sent payload
    /// back into local error memory, so nothing is lost — only delayed.
    KofM { k: usize },
    /// Arm a grace timer when the `arm_at`-th payload is accepted; the
    /// round closes when all M have landed or the timer expires,
    /// whichever comes first (skipping whoever is still in flight).
    Deadline { grace_ms: u64, arm_at: usize },
}

impl PolicyConfig {
    /// Parse a CLI string: `full`, `kofm:K` or `deadline:MS[,K]` (grace
    /// of MS milliseconds armed at the K-th arrival; K defaults to 1).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let lowered = s.trim().to_ascii_lowercase();
        let (name, arg) = match lowered.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (lowered.as_str(), None),
        };
        match (name, arg) {
            ("full" | "all", None) => Ok(Self::Full),
            ("kofm", Some(k)) => {
                let k: usize =
                    k.parse().map_err(|e| anyhow::anyhow!("bad K in 'kofm:{k}': {e}"))?;
                anyhow::ensure!(k >= 1, "kofm needs K >= 1");
                Ok(Self::KofM { k })
            }
            ("deadline", Some(a)) => {
                let (ms, arm_at) = match a.split_once(',') {
                    Some((ms, k)) => {
                        let k: usize = k
                            .parse()
                            .map_err(|e| anyhow::anyhow!("bad K in 'deadline:{a}': {e}"))?;
                        (ms, k)
                    }
                    None => (a, 1),
                };
                let grace_ms: u64 = ms
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad MS in 'deadline:{a}': {e}"))?;
                anyhow::ensure!(arm_at >= 1, "deadline needs K >= 1");
                Ok(Self::Deadline { grace_ms, arm_at })
            }
            _ => anyhow::bail!("unknown round policy '{s}' (full|kofm:K|deadline:MS[,K])"),
        }
    }

    /// Display label for logs and error messages.
    pub fn label(&self) -> String {
        match self {
            Self::Full => "full".into(),
            Self::KofM { k } => format!("kofm:{k}"),
            Self::Deadline { grace_ms, arm_at } => format!("deadline:{grace_ms},{arm_at}"),
        }
    }
}

/// What the leader does when a worker is lost mid-run
/// (`--on-worker-loss`): a liveness-ledger violation, an `AckLedger`
/// stall, or a dead socket/channel all funnel into this one decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WorkerLossMode {
    /// Fail the run with a worker error naming the lost worker — the
    /// historical behavior, and the default: surviving a loss changes
    /// the quorum semantics, so it stays opt-in.
    #[default]
    Abort,
    /// Evict the worker: reclaim its parked frames, drain its late
    /// ledger, shrink the quorum to the survivors and keep training.
    /// Sound because error-feedback state is worker-local (the
    /// δ-compressor contract never crosses the membership boundary —
    /// see `docs/adr/005-elastic-membership.md`). Requires a
    /// streaming-engine mode (the barrier paths have no per-arrival
    /// hook to observe the loss from).
    Evict,
}

impl WorkerLossMode {
    /// Parse a CLI string: `evict` or `abort`/`fail`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "evict" => Ok(Self::Evict),
            "abort" | "fail" => Ok(Self::Abort),
            other => anyhow::bail!("unknown worker-loss mode '{other}' (evict|abort)"),
        }
    }

    /// Display label for logs and error messages.
    pub fn label(self) -> &'static str {
        match self {
            Self::Evict => "evict",
            Self::Abort => "abort",
        }
    }
}

/// Elastic-membership / fault-recovery knobs (`--on-worker-loss`,
/// `--replay-depth`, `--ckpt-dir`, `--ckpt-every`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RecoveryConfig {
    /// Evict or abort on worker loss (default abort).
    pub on_worker_loss: WorkerLossMode,
    /// How many recent broadcast frames the leader retains for rejoin
    /// replay (round-stamped; one retained message per round, shared
    /// `Arc` wire bytes at send time, so memory is O(depth) not
    /// O(depth × M)). A worker reconnecting within this many rounds
    /// replays the missed broadcasts in order and rejoins the quorum;
    /// 0 disables the ledger. Only maintained under
    /// [`WorkerLossMode::Evict`].
    pub replay_depth: usize,
    /// Content-addressed checkpoint directory: broadcast frames that
    /// rotate out of the in-memory replay ledger spill here (so rejoin
    /// works beyond `replay_depth`), and the model snapshots taken
    /// every [`Self::ckpt_every`] rounds land here too. `None` disables
    /// checkpointing.
    pub ckpt_dir: Option<std::path::PathBuf>,
    /// Take a round-stamped model snapshot every this many rounds
    /// (0 = never). Parameters are identical across workers by
    /// construction, so one snapshot per interval captures the cluster
    /// model state.
    pub ckpt_every: u64,
}

impl Default for RecoveryConfig {
    fn default() -> Self {
        Self {
            on_worker_loss: WorkerLossMode::Abort,
            replay_depth: 8,
            ckpt_dir: None,
            ckpt_every: 0,
        }
    }
}

/// Leader aggregation configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AggregatorConfig {
    pub mode: AggMode,
    /// Pool threads for the sharded path (0 = available parallelism).
    pub threads: usize,
    /// Target elements per reduction shard. The default (16Ki f32 =
    /// 64 KiB) keeps a shard inside L2 while giving enough shards to
    /// fill the pool on DCGAN-sized vectors.
    pub shard_elems: usize,
    /// Round-completion policy ([`PolicyConfig::Full`] = today's
    /// barrier; anything else needs a streaming-engine mode —
    /// [`AggMode::Streaming`] or [`AggMode::Pipelined`]).
    pub policy: PolicyConfig,
    /// [`AggMode::Pipelined`] only: bound on the per-worker queue of
    /// not-yet-delivered broadcasts (`--pipeline-depth`). Depth D lets up
    /// to D broadcast frames stack up behind a slow receiver (plus the
    /// one its writer is delivering) before the leader blocks; it also
    /// sizes the aggregator's slot banks (capped at two — one gathering
    /// round plus one round whose broadcast is still in flight).
    pub pipeline_depth: usize,
    /// Reduce schedule of the streaming-engine modes (`--reduce`):
    /// windowed incremental folds during the gather (default) or the
    /// close-time barrier fold. Ignored by the batch modes
    /// ([`AggMode::Sequential`]/[`AggMode::Sharded`], whose reduce is
    /// inherently close-time). Bitwise-identical output either way.
    pub reduce: ReduceMode,
    /// Liveness bound for partial round-completion policies: if a
    /// skipped worker's oldest undrained late round (`pending_late`
    /// front) is more than this many rounds behind the leader, the
    /// worker is presumed dead (not merely slow) and the run fails with
    /// a worker error instead of stalling its ledger forever. 0 disables
    /// the check (default). A late frame only drains when it pops out of
    /// a later round's gather, so scheduling jitter can add a round of
    /// apparent staleness — on fast-round workloads prefer R ≥ 2.
    pub liveness_rounds: u64,
    /// Elastic-membership / fault-recovery configuration: what happens
    /// on worker loss, how deep the rejoin replay ledger is, and where
    /// checkpoints land.
    pub recovery: RecoveryConfig,
}

impl Default for AggregatorConfig {
    fn default() -> Self {
        Self {
            mode: AggMode::Sharded,
            threads: 0,
            shard_elems: 16 * 1024,
            policy: PolicyConfig::Full,
            pipeline_depth: 2,
            reduce: ReduceMode::Windowed,
            liveness_rounds: 0,
            recovery: RecoveryConfig::default(),
        }
    }
}

impl AggregatorConfig {
    /// Seed-equivalent sequential configuration (the A/B baseline).
    pub fn sequential() -> Self {
        Self { mode: AggMode::Sequential, ..Self::default() }
    }

    /// Streaming (decode-on-arrival) configuration.
    pub fn streaming() -> Self {
        Self { mode: AggMode::Streaming, ..Self::default() }
    }

    /// Streaming configuration with a round-completion policy.
    pub fn streaming_with_policy(policy: PolicyConfig) -> Self {
        Self { mode: AggMode::Streaming, policy, ..Self::default() }
    }

    /// Pipelined (async-broadcast, double-buffered) configuration.
    pub fn pipelined() -> Self {
        Self { mode: AggMode::Pipelined, ..Self::default() }
    }

    /// Pipelined configuration with an explicit depth.
    pub fn pipelined_with_depth(depth: usize) -> Self {
        Self { mode: AggMode::Pipelined, pipeline_depth: depth.max(1), ..Self::default() }
    }

    /// Resolve `threads` to a concrete pool size.
    pub fn resolved_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_modes() {
        assert_eq!(AggMode::parse("sharded").unwrap(), AggMode::Sharded);
        assert_eq!(AggMode::parse("parallel").unwrap(), AggMode::Sharded);
        assert_eq!(AggMode::parse("SEQ").unwrap(), AggMode::Sequential);
        assert_eq!(AggMode::parse("sequential").unwrap(), AggMode::Sequential);
        assert_eq!(AggMode::parse("streaming").unwrap(), AggMode::Streaming);
        assert_eq!(AggMode::parse("stream").unwrap(), AggMode::Streaming);
        assert_eq!(AggMode::parse("pipelined").unwrap(), AggMode::Pipelined);
        assert_eq!(AggMode::parse("PIPELINE").unwrap(), AggMode::Pipelined);
        assert!(AggMode::parse("wat").is_err());
        assert!(AggMode::Streaming.is_streaming());
        assert!(AggMode::Pipelined.is_streaming());
        assert!(!AggMode::Sharded.is_streaming());
        assert!(!AggMode::Sequential.is_streaming());
    }

    #[test]
    fn pipelined_presets() {
        let cfg = AggregatorConfig::pipelined();
        assert_eq!(cfg.mode, AggMode::Pipelined);
        assert_eq!(cfg.pipeline_depth, 2);
        assert_eq!(cfg.liveness_rounds, 0, "liveness is opt-in");
        let deep = AggregatorConfig::pipelined_with_depth(0);
        assert_eq!(deep.pipeline_depth, 1, "depth is clamped to at least 1");
        assert_eq!(AggregatorConfig::pipelined_with_depth(4).pipeline_depth, 4);
    }

    #[test]
    fn streaming_preset() {
        let cfg = AggregatorConfig::streaming();
        assert_eq!(cfg.mode, AggMode::Streaming);
        assert_eq!(cfg.shard_elems, AggregatorConfig::default().shard_elems);
    }

    #[test]
    fn default_is_sharded_with_auto_threads() {
        let cfg = AggregatorConfig::default();
        assert_eq!(cfg.mode, AggMode::Sharded);
        assert_eq!(cfg.policy, PolicyConfig::Full);
        assert!(cfg.resolved_threads() >= 1);
        assert_eq!(AggregatorConfig::sequential().mode, AggMode::Sequential);
    }

    #[test]
    fn parses_reduce_modes() {
        assert_eq!(ReduceMode::parse("windowed").unwrap(), ReduceMode::Windowed);
        assert_eq!(ReduceMode::parse("INCREMENTAL").unwrap(), ReduceMode::Windowed);
        assert_eq!(ReduceMode::parse("barrier").unwrap(), ReduceMode::Barrier);
        assert_eq!(ReduceMode::parse("close").unwrap(), ReduceMode::Barrier);
        assert!(ReduceMode::parse("wat").is_err());
        // Windowed is the default: the fast path is on unless opted out.
        assert_eq!(AggregatorConfig::default().reduce, ReduceMode::Windowed);
    }

    #[test]
    fn parses_kernel_modes() {
        assert_eq!(KernelMode::parse("simd").unwrap(), KernelMode::Simd);
        assert_eq!(KernelMode::parse("VECTOR").unwrap(), KernelMode::Simd);
        assert_eq!(KernelMode::parse("scalar").unwrap(), KernelMode::Scalar);
        assert!(KernelMode::parse("wat").is_err());
        // SIMD is the default: the fast path is on unless opted out.
        assert_eq!(KernelMode::default(), KernelMode::Simd);
        for m in [KernelMode::Simd, KernelMode::Scalar] {
            assert_eq!(KernelMode::parse(m.label()).unwrap(), m);
        }
    }

    #[test]
    fn parses_transport_modes() {
        assert_eq!(TransportMode::parse("evloop").unwrap(), TransportMode::EvLoop);
        assert_eq!(TransportMode::parse("POLL").unwrap(), TransportMode::EvLoop);
        assert_eq!(TransportMode::parse("threads").unwrap(), TransportMode::Threads);
        assert_eq!(TransportMode::parse("threaded").unwrap(), TransportMode::Threads);
        assert!(TransportMode::parse("wat").is_err());
        // The readiness loop is the default; threads is the A/B baseline.
        assert_eq!(TransportMode::default(), TransportMode::EvLoop);
        for m in [TransportMode::EvLoop, TransportMode::Threads] {
            assert_eq!(TransportMode::parse(m.label()).unwrap(), m);
        }
    }

    #[test]
    fn parses_policies() {
        assert_eq!(PolicyConfig::parse("full").unwrap(), PolicyConfig::Full);
        assert_eq!(PolicyConfig::parse("ALL").unwrap(), PolicyConfig::Full);
        assert_eq!(PolicyConfig::parse("kofm:3").unwrap(), PolicyConfig::KofM { k: 3 });
        assert_eq!(
            PolicyConfig::parse("deadline:50").unwrap(),
            PolicyConfig::Deadline { grace_ms: 50, arm_at: 1 }
        );
        assert_eq!(
            PolicyConfig::parse("deadline:50,2").unwrap(),
            PolicyConfig::Deadline { grace_ms: 50, arm_at: 2 }
        );
        assert!(PolicyConfig::parse("kofm:0").is_err());
        assert!(PolicyConfig::parse("kofm").is_err());
        assert!(PolicyConfig::parse("deadline:abc").is_err());
        assert!(PolicyConfig::parse("deadline:10,0").is_err());
        assert!(PolicyConfig::parse("wat").is_err());
    }

    #[test]
    fn policy_labels_round_trip_through_parse() {
        for p in [
            PolicyConfig::Full,
            PolicyConfig::KofM { k: 4 },
            PolicyConfig::Deadline { grace_ms: 25, arm_at: 2 },
        ] {
            assert_eq!(PolicyConfig::parse(&p.label()).unwrap(), p);
        }
    }

    #[test]
    fn parses_worker_loss_modes() {
        assert_eq!(WorkerLossMode::parse("evict").unwrap(), WorkerLossMode::Evict);
        assert_eq!(WorkerLossMode::parse("ABORT").unwrap(), WorkerLossMode::Abort);
        assert_eq!(WorkerLossMode::parse("fail").unwrap(), WorkerLossMode::Abort);
        assert!(WorkerLossMode::parse("wat").is_err());
        // Abort stays the default: surviving a loss is opt-in.
        assert_eq!(WorkerLossMode::default(), WorkerLossMode::Abort);
        for m in [WorkerLossMode::Evict, WorkerLossMode::Abort] {
            assert_eq!(WorkerLossMode::parse(m.label()).unwrap(), m);
        }
    }

    #[test]
    fn recovery_defaults_are_abort_with_a_small_ledger() {
        let r = RecoveryConfig::default();
        assert_eq!(r.on_worker_loss, WorkerLossMode::Abort);
        assert_eq!(r.replay_depth, 8);
        assert!(r.ckpt_dir.is_none());
        assert_eq!(r.ckpt_every, 0);
        assert_eq!(AggregatorConfig::default().recovery, r);
    }

    #[test]
    fn streaming_with_policy_preset() {
        let cfg = AggregatorConfig::streaming_with_policy(PolicyConfig::KofM { k: 2 });
        assert_eq!(cfg.mode, AggMode::Streaming);
        assert_eq!(cfg.policy, PolicyConfig::KofM { k: 2 });
    }
}
