//! Single-binary cluster driver: spawns the leader + M worker threads over
//! the in-process transport and runs a full training job. This is the
//! entry point used by the CLI, the experiment harnesses and the examples.

use super::aggregate::Decoder;
use super::server::{is_snapshot_round, serve_rounds_session, ServeSession};
use super::worker::{apply_broadcast, worker_loop_resumable, EvalHook, SnapHook, WorkerSummary};
use super::RoundRecord;
use crate::algo::AlgoKind;
use crate::ckpt::{decode_worker_state, encode_worker_state, CkptStore, RunManifest};
use crate::comm::{
    inproc_cluster, inproc_cluster_evloop, Message, MsgKind, RetryPolicy, ServerEnd,
};
use crate::config::{AggregatorConfig, TransportMode};
use crate::grad::GradientSource;
use crate::optim::LrSchedule;
use crate::util::bytes::{fnv1a64, put_f32_slice};
use crate::util::rng::Pcg32;
use crate::util::timer::Stopwatch;
use std::sync::{Arc, Mutex};

/// Cluster configuration for one training run.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub algo: AlgoKind,
    /// Number of workers M.
    pub workers: usize,
    /// Mini-batch size B per worker.
    pub batch: usize,
    /// Total synchronous rounds T.
    pub rounds: u64,
    pub lr: LrSchedule,
    /// Base RNG seed (worker m uses seed+m+1; init uses seed).
    pub seed: u64,
    /// Invoke the eval hook on worker 0 every `eval_every` rounds (0 = never).
    pub eval_every: u64,
    /// Keep per-round worker stats on worker 0 (memory vs detail).
    pub keep_stats: bool,
    /// Leader aggregation path (sharded by default; the sequential
    /// baseline is bitwise-identical and kept for A/B verification).
    pub agg: AggregatorConfig,
    /// Transport engine (readiness loop by default; the per-worker
    /// thread army is kept as the A/B baseline). Broadcasts are
    /// bitwise-identical across the two — CI diffs `broadcast_fnv`
    /// between them every run.
    pub transport: TransportMode,
    /// Fault injection (`--chaos-kill W@R`): worker W participates
    /// normally for R rounds and then dies abruptly — its transport end
    /// drops with no Shutdown handshake, like a SIGKILL mid-run. The
    /// run only survives this under `--on-worker-loss evict`; the CI
    /// chaos job drives it and diffs the survivor broadcasts against a
    /// run where W was absent from the start.
    pub chaos_kill: Option<(usize, u64)>,
    /// Fault injection for the *leader* (`--chaos-kill-leader R`): the
    /// serve loop returns right after round R's broadcast with no
    /// Shutdown frame and no run-end bookkeeping — a simulated
    /// `kill -9`. Workers observe a dead transport and exit cleanly;
    /// the only durable state is what the checkpoint store already
    /// holds, which is exactly what [`Self::resume`] restores from.
    pub chaos_kill_leader: Option<u64>,
    /// Resume a previously checkpointed run: load the run manifest from
    /// `agg.recovery.ckpt_dir` (`--resume DIR`), refuse loudly on a
    /// config-fingerprint mismatch, restore every worker's snapshot at
    /// the manifest round, and serve rounds `manifest.round + 1 ..
    /// rounds` under a bumped session epoch. Post-resume rounds are
    /// bitwise-identical to an undisturbed run (the recovery
    /// integration suite gates on it).
    pub resume: bool,
    /// Worker-side connect retry policy (`--connect-retry N,BASE_MS`) —
    /// consumed by the TCP session handshake
    /// ([`crate::comm::tcp::TcpWorkerEnd::connect_session`]) when a
    /// deployment dials a restarted leader over real sockets. The
    /// in-process transports never dial, so `run_cluster` itself only
    /// carries it; it lives here so one config describes the whole run
    /// (and fingerprint-relevant knobs stay in one place — this one is
    /// excluded from [`Self::fingerprint`], retry cadence never changes
    /// the trajectory).
    pub connect_retry: Option<RetryPolicy>,
}

impl ClusterConfig {
    /// 64-bit fingerprint of every configuration knob that shapes the
    /// training trajectory: algorithm, cluster shape, horizon, step
    /// size (bit-exact), seed, round policy, and the aggregation /
    /// pipeline / checkpoint-cadence knobs. Transport and kernel arms
    /// are deliberately excluded — they are bitwise-identical switches
    /// by contract, so a resume may legally change them. A manifest
    /// written under one fingerprint refuses to resume under another.
    pub fn fingerprint(&self) -> u64 {
        let lr = match &self.lr {
            LrSchedule::Constant { eta0 } => format!("const:{:08x}", eta0.to_bits()),
            LrSchedule::InvSqrt { eta0, t0 } => {
                format!("invsqrt:{:08x}:{:016x}", eta0.to_bits(), t0.to_bits())
            }
            LrSchedule::Warmup { eta0, warmup } => {
                format!("warmup:{:08x}:{warmup}", eta0.to_bits())
            }
        };
        let canon = format!(
            "algo={};workers={};batch={};rounds={};lr={lr};seed={};policy={};agg={:?};\
             reduce={:?};pipeline_depth={};ckpt_every={}",
            self.algo.label(),
            self.workers,
            self.batch,
            self.rounds,
            self.seed,
            self.agg.policy.label(),
            self.agg.mode,
            self.agg.reduce,
            self.agg.pipeline_depth,
            self.agg.recovery.ckpt_every,
        );
        fnv1a64(canon.as_bytes())
    }
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            algo: AlgoKind::CpoAdam,
            workers: 4,
            batch: 32,
            rounds: 100,
            lr: LrSchedule::constant(1e-3),
            seed: 0xD9_6A17,
            eval_every: 0,
            keep_stats: true,
            agg: AggregatorConfig::default(),
            transport: TransportMode::default(),
            chaos_kill: None,
            chaos_kill_leader: None,
            resume: false,
            connect_retry: None,
        }
    }
}

/// A snapshot the eval hook produced at some round.
#[derive(Debug, Clone)]
pub struct EvalEvent {
    pub round: u64,
    pub params: Vec<f32>,
    pub loss_g: Option<f32>,
    pub loss_d: Option<f32>,
}

/// Full-run report.
#[derive(Debug)]
pub struct TrainReport {
    pub records: Vec<RoundRecord>,
    pub worker0: WorkerSummary,
    /// Snapshots captured by the eval schedule.
    pub evals: Vec<EvalEvent>,
    /// Total uplink payload bytes across the run (sum over rounds/workers).
    pub total_bytes_up: u64,
    pub wall_secs: f64,
    /// Mean leader-side round wall time (the Fig-4 compute input).
    pub mean_round_secs: f64,
}

/// Advance the run manifest (`RUN.json`) to the newest snapshot round
/// that is *complete*: its broadcast blob AND all M worker-state blobs
/// are durably in the store. Candidates are walked newest-first down to
/// the last round already published, so a straggling worker snapshot
/// only delays the advance, never corrupts it — the manifest always
/// points at a round every party can restore from. Returns without
/// writing when no new complete round exists (`last` is the
/// half-open low-water mark; updated on publish).
fn advance_manifest(
    store: &Arc<Mutex<CkptStore>>,
    every: u64,
    workers: usize,
    epoch: u64,
    fingerprint: u64,
    last: &mut Option<u64>,
    upto: u64,
) -> anyhow::Result<()> {
    if every == 0 {
        return Ok(());
    }
    let mut k = (upto + 1) / every;
    while k > 0 {
        let r = k * every - 1;
        if last.is_some_and(|l| r <= l) {
            return Ok(());
        }
        let st = store.lock().unwrap();
        let complete = st.contains("bcast", r, 0)
            && (0..workers).all(|w| st.contains("wstate", r, w as u32));
        if complete {
            let worker_digests = (0..workers)
                .map(|w| st.entry_digest("wstate", r, w as u32).unwrap_or(0))
                .collect();
            let man = RunManifest {
                round: r,
                epoch,
                fingerprint,
                workers,
                worker_digests,
                replay_rounds: st.rounds("bcast"),
            };
            man.save(st.dir())?;
            *last = Some(r);
            return Ok(());
        }
        drop(st);
        k -= 1;
    }
    Ok(())
}

/// Run one training job: M worker threads + leader on this thread.
///
/// `make_src` builds each worker's gradient source (called once per worker,
/// on the worker's thread — sources need not be `Sync`).
pub fn run_cluster(
    cfg: &ClusterConfig,
    make_src: impl Fn(usize) -> anyhow::Result<Box<dyn GradientSource>> + Send + Sync,
) -> anyhow::Result<TrainReport> {
    anyhow::ensure!(cfg.workers > 0, "need at least one worker");
    if let Some((cw, cr)) = cfg.chaos_kill {
        anyhow::ensure!(
            cw < cfg.workers,
            "--chaos-kill worker {cw} out of range (M = {})",
            cfg.workers
        );
        anyhow::ensure!(
            cw != 0,
            "--chaos-kill cannot target worker 0 (it owns the report summary)"
        );
        anyhow::ensure!(
            cr < cfg.rounds,
            "--chaos-kill round {cr} is past the run ({} rounds)",
            cfg.rounds
        );
    }
    if let Some(cr) = cfg.chaos_kill_leader {
        anyhow::ensure!(
            cr < cfg.rounds,
            "--chaos-kill-leader round {cr} is past the run ({} rounds)",
            cfg.rounds
        );
    }
    // One content-addressed checkpoint store per run, shared by every
    // party: the leader spills snapshot-round broadcasts (kind `bcast`)
    // and rotated-out replay frames into it, workers write their
    // round-stamped state snapshots (kind `wstate`, shard = worker id)
    // and the model blobs (kind `model`), and the run manifest
    // (`RUN.json`) lives beside it. Sharing one instance is load-bearing:
    // two stores on the same directory would clobber each other's
    // store manifest on every write.
    let store: Option<Arc<Mutex<CkptStore>>> = match &cfg.agg.recovery.ckpt_dir {
        Some(dir) => Some(Arc::new(Mutex::new(CkptStore::open(dir)?))),
        None => None,
    };
    let every = cfg.agg.recovery.ckpt_every;
    let fingerprint = cfg.fingerprint();
    // `--resume DIR`: load the crash-consistent run manifest and pick up
    // at the round after the one it points at. The manifest is only ever
    // advanced to rounds whose broadcast AND all M worker snapshots are
    // durably stored, so everything restored below is guaranteed present
    // (and integrity-checked on read by the store).
    let resume_from: Option<RunManifest> = if cfg.resume {
        let dir = cfg
            .agg
            .recovery
            .ckpt_dir
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("--resume needs --ckpt-dir (or --resume DIR)"))?;
        let man = RunManifest::load(dir)?.ok_or_else(|| {
            anyhow::anyhow!(
                "--resume: no run manifest (RUN.json) in {} — nothing to resume",
                dir.display()
            )
        })?;
        anyhow::ensure!(
            man.fingerprint == fingerprint,
            "config fingerprint mismatch: the checkpointed run was {:016x}, this \
             configuration is {fingerprint:016x} — refusing to resume a run under a \
             different configuration",
            man.fingerprint
        );
        anyhow::ensure!(
            man.workers == cfg.workers,
            "--resume: manifest has {} workers, configured {}",
            man.workers,
            cfg.workers
        );
        anyhow::ensure!(
            man.round < cfg.rounds,
            "--resume: manifest round {} is already at/past the {}-round horizon",
            man.round,
            cfg.rounds
        );
        Some(man)
    } else {
        None
    };
    let start_round = resume_from.as_ref().map_or(0, |m| m.round + 1);
    // Session epoch: bumped on every resume so a fleet can tell leader
    // incarnations apart (the TCP handshake carries it; the manifest
    // records it either way).
    let epoch = resume_from.as_ref().map_or(0, |m| m.epoch + 1);
    let sw = Stopwatch::start();
    // Both transports speak the same ServerEnd/WorkerEnd contract; the
    // evloop cluster's worker ends additionally ack applied broadcasts
    // (a WorkerEnd::ack no-op on the threaded one).
    let (mut server, worker_ends): (Box<dyn ServerEnd>, _) = match cfg.transport {
        TransportMode::EvLoop => {
            let (s, w, _counter) = inproc_cluster_evloop(cfg.workers);
            (Box::new(s), w)
        }
        TransportMode::Threads => {
            let (s, w, _counter) = inproc_cluster(cfg.workers);
            (Box::new(s), w)
        }
    };

    // Initial parameters: one w₀ pushed to all workers (Algorithm 2 line 1)
    // — realized by constructing every worker from the same vector.
    let mut init_rng = Pcg32::new(cfg.seed);
    let probe_src = make_src(0)?;
    let dim = probe_src.dim();
    let w0 = probe_src.init_params(&mut init_rng);
    drop(probe_src);

    let decoder: Decoder = cfg.algo.decoder();
    let (eval_tx, eval_rx) = std::sync::mpsc::channel::<EvalEvent>();

    let report = std::thread::scope(|scope| -> anyhow::Result<TrainReport> {
        let mut handles = Vec::new();
        for (m, mut end) in worker_ends.into_iter().enumerate() {
            let algo = cfg.algo.build_worker(w0.clone(), cfg.lr.clone());
            let make_src = &make_src;
            let eval_tx = eval_tx.clone();
            let eval_every = cfg.eval_every;
            let keep = cfg.keep_stats && m == 0;
            let batch = cfg.batch;
            let rounds = cfg.rounds;
            let seed = cfg.seed;
            let chaos_rounds = match cfg.chaos_kill {
                Some((cw, cr)) if cw == m => Some(cr),
                _ => None,
            };
            let store = store.clone();
            let snap_every = every;
            let resume_round = resume_from.as_ref().map(|man| man.round);
            handles.push(scope.spawn(move || -> anyhow::Result<WorkerSummary> {
                let mut src = make_src(m)?;
                let mut rng = Pcg32::new(seed.wrapping_add(m as u64).wrapping_add(1));
                let mut algo = algo;
                if let Some(rr) = resume_round {
                    // Resume: roll this worker back to the manifest round.
                    // The snapshot restores error memory, optimizer state
                    // and the RNG position bit-exactly, so the rounds that
                    // follow are bitwise-identical to an undisturbed run.
                    let st = store.as_ref().expect("--resume validated ckpt_dir");
                    let bytes = st.lock().unwrap().get("wstate", rr, m as u32)?.ok_or_else(
                        || {
                            anyhow::anyhow!(
                                "worker {m}: no state snapshot for round {rr} in the \
                                 checkpoint store — the run manifest points at a round \
                                 the store no longer holds"
                            )
                        },
                    )?;
                    decode_worker_state(&bytes, &mut rng, algo.as_mut())?;
                }
                if let Some(cr) = chaos_rounds {
                    // Fault injection: run `cr` normal rounds, then die
                    // without any teardown handshake — the transport end
                    // just drops mid-protocol, exactly what a killed
                    // process looks like from the leader's side.
                    let dim = algo.dim();
                    for round in start_round..cr {
                        let payload = algo.produce(src.as_mut(), batch, &mut rng)?.wire.to_vec();
                        if end.send(Message::payload(m as u32, round, payload)).is_err() {
                            break;
                        }
                        loop {
                            match end.recv() {
                                Ok(msg)
                                    if msg.kind == MsgKind::Broadcast
                                        || msg.kind == MsgKind::PartialBroadcast =>
                                {
                                    apply_broadcast(
                                        algo.as_mut(),
                                        dim,
                                        m as u32,
                                        &msg,
                                        msg.round == round,
                                    )?;
                                    let _ = end.ack(msg.round);
                                    break;
                                }
                                Ok(msg) if msg.kind == MsgKind::Shutdown => {
                                    return Ok(WorkerSummary {
                                        rounds: round,
                                        final_params: algo.params().to_vec(),
                                        stats: Vec::new(),
                                    });
                                }
                                Ok(_) => {}
                                Err(_) => break,
                            }
                        }
                    }
                    drop(end);
                    return Ok(WorkerSummary {
                        rounds: cr,
                        final_params: algo.params().to_vec(),
                        stats: Vec::new(),
                    });
                }
                let model_store = if snap_every > 0 { store.clone() } else { None };
                let eval: Option<EvalHook> = if m == 0 && (eval_every > 0 || model_store.is_some())
                {
                    Some(Box::new(move |round, params, stats| {
                        if eval_every > 0 && ((round + 1) % eval_every == 0 || round == 0) {
                            let _ = eval_tx.send(EvalEvent {
                                round,
                                params: params.to_vec(),
                                loss_g: stats.loss_g,
                                loss_d: stats.loss_d,
                            });
                        }
                        if let Some(store) = &model_store {
                            if is_snapshot_round(round, Some(snap_every)) {
                                let mut bytes = Vec::with_capacity(4 * params.len());
                                put_f32_slice(&mut bytes, params);
                                // Post-apply params are identical across
                                // workers, so worker 0's copy is *the*
                                // model at this round.
                                if let Err(e) =
                                    store.lock().unwrap().put("model", round, 0, &bytes)
                                {
                                    crate::log_warn!(
                                        "model checkpoint at round {round} failed: {e:#}"
                                    );
                                }
                            }
                        }
                    }))
                } else {
                    None
                };
                // State snapshots (every worker, not just 0): error
                // memory + optimizer state + RNG cursor, round-stamped
                // under the shared store. A failed snapshot fails the
                // worker — a manifest must never be able to point at a
                // round some worker cannot actually restore from.
                let snap: Option<SnapHook> = match &store {
                    Some(st) if snap_every > 0 => {
                        let st = st.clone();
                        Some(Box::new(move |round, algo, rng| {
                            if !is_snapshot_round(round, Some(snap_every)) {
                                return Ok(());
                            }
                            let bytes = encode_worker_state(rng, algo)?;
                            st.lock().unwrap().put("wstate", round, m as u32, &bytes)
                        }))
                    }
                    _ => None,
                };
                worker_loop_resumable(
                    &mut end,
                    algo.as_mut(),
                    src.as_mut(),
                    batch,
                    start_round,
                    rounds,
                    &mut rng,
                    keep,
                    eval,
                    snap,
                )
            }));
        }
        drop(eval_tx);

        let session = ServeSession {
            start_round,
            chaos_kill_leader: cfg.chaos_kill_leader,
            store: store.clone(),
            snapshot_every: (every > 0).then_some(every),
        };
        // Manifest low-water mark: on resume the loaded manifest round,
        // else none. The on_round hook opportunistically advances it as
        // snapshot rounds become complete; misses are retried next round
        // (and once more after the join below), so a slow worker
        // snapshot costs manifest freshness, never correctness.
        let mut last_manifest = resume_from.as_ref().map(|man| man.round);
        let serve_result = match &store {
            Some(st) if every > 0 => serve_rounds_session(
                &mut server,
                decoder,
                dim,
                cfg.rounds,
                cfg.agg.clone(),
                session,
                |rec| {
                    if let Err(e) = advance_manifest(
                        st,
                        every,
                        cfg.workers,
                        epoch,
                        fingerprint,
                        &mut last_manifest,
                        rec.round,
                    ) {
                        crate::log_warn!(
                            "run manifest advance at round {} failed: {e:#}",
                            rec.round
                        );
                    }
                },
            ),
            _ => serve_rounds_session(
                &mut server,
                decoder,
                dim,
                cfg.rounds,
                cfg.agg.clone(),
                session,
                |_| {},
            ),
        };
        if serve_result.is_err() {
            // Unblock workers waiting in phase 2 so the scope join below
            // cannot hang; ignore send failures (workers may be gone).
            use crate::comm::Message;
            let _ = server.broadcast(Message::shutdown(u64::MAX));
        }
        drop(server); // close channels before joining

        let mut worker0 = None;
        let mut worker_err: Option<anyhow::Error> = None;
        for (m, h) in handles.into_iter().enumerate() {
            match h.join() {
                Err(_) => worker_err.get_or_insert(anyhow::anyhow!("worker {m} panicked")),
                Ok(Err(e)) => worker_err.get_or_insert(e),
                Ok(Ok(summary)) => {
                    if m == 0 {
                        worker0 = Some(summary);
                    }
                    continue;
                }
            };
        }
        // Prefer the leader's error (it names the failing worker); fall
        // back to a worker-local error.
        let records = match serve_result {
            Ok(r) => r,
            Err(e) => return Err(e),
        };
        if let Some(e) = worker_err {
            return Err(e);
        }
        // Final manifest advance: the workers are joined, so every
        // snapshot they will ever write is on disk — publish the newest
        // complete round the mid-run hook may have raced past. Skipped
        // when the leader "died": a killed process records nothing, and
        // the whole point of the chaos arm is resuming from exactly
        // what was durable at the moment of death.
        if cfg.chaos_kill_leader.is_none() && every > 0 {
            if let Some(st) = &store {
                advance_manifest(
                    st,
                    every,
                    cfg.workers,
                    epoch,
                    fingerprint,
                    &mut last_manifest,
                    cfg.rounds.saturating_sub(1),
                )?;
            }
        }
        let evals: Vec<EvalEvent> = eval_rx.try_iter().collect();
        let total_bytes_up: u64 = records.iter().map(|r| r.bytes_up as u64).sum();
        let mean_round_secs = if records.is_empty() {
            0.0
        } else {
            records.iter().map(|r| r.wall_secs).sum::<f64>() / records.len() as f64
        };
        Ok(TrainReport {
            records,
            worker0: worker0.expect("worker 0 summary"),
            evals,
            total_bytes_up,
            wall_secs: sw.elapsed_secs(),
            mean_round_secs,
        })
    })?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::QuadraticOperator;

    fn quad_cfg(algo: &str, rounds: u64, lr: f32) -> ClusterConfig {
        ClusterConfig {
            algo: AlgoKind::parse(algo).unwrap(),
            workers: 3,
            batch: 8,
            rounds,
            lr: LrSchedule::constant(lr),
            seed: 1234,
            eval_every: 10,
            keep_stats: true,
            agg: Default::default(),
            transport: Default::default(),
            chaos_kill: None,
            chaos_kill_leader: None,
            resume: false,
            connect_retry: None,
        }
    }

    fn target_for_seed(seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::new(seed);
        QuadraticOperator::new(10, 0.1, &mut rng).target
    }

    #[test]
    fn dqgan_cluster_converges_end_to_end() {
        let cfg = quad_cfg("dqgan:linf8", 600, 0.1);
        let report = run_cluster(&cfg, |_m| {
            let mut rng = Pcg32::new(999);
            Ok(Box::new(QuadraticOperator::new(10, 0.1, &mut rng)))
        })
        .unwrap();
        let target = {
            let mut rng = Pcg32::new(999);
            QuadraticOperator::new(10, 0.1, &mut rng).target
        };
        for (a, b) in report.worker0.final_params.iter().zip(&target) {
            assert!((a - b).abs() < 0.1, "{a} vs {b}");
        }
        assert_eq!(report.records.len(), 600);
        assert!(report.total_bytes_up > 0);
        assert!(!report.evals.is_empty());
        let _ = target_for_seed(999);
    }

    #[test]
    fn cpoadam_cluster_converges() {
        let cfg = quad_cfg("cpoadam", 500, 0.05);
        let report = run_cluster(&cfg, |_m| {
            let mut rng = Pcg32::new(555);
            Ok(Box::new(QuadraticOperator::new(10, 0.1, &mut rng)))
        })
        .unwrap();
        let target = {
            let mut rng = Pcg32::new(555);
            QuadraticOperator::new(10, 0.1, &mut rng).target
        };
        for (a, b) in report.worker0.final_params.iter().zip(&target) {
            assert!((a - b).abs() < 0.15, "{a} vs {b}");
        }
    }

    #[test]
    fn dqgan_ships_fewer_bytes_than_cpoadam() {
        let run = |algo: &str| {
            let cfg = quad_cfg(algo, 20, 0.05);
            run_cluster(&cfg, |_m| {
                let mut rng = Pcg32::new(777);
                Ok(Box::new(QuadraticOperator::new(256, 0.1, &mut rng)))
            })
            .unwrap()
            .total_bytes_up
        };
        let dq = run("dqgan:linf8");
        let cp = run("cpoadam");
        assert!(dq * 3 < cp, "dqgan={dq} cpoadam={cp}");
    }

    #[test]
    fn transports_produce_bitwise_identical_broadcasts() {
        // The readiness-loop transport is a scheduling change only: a
        // seeded pipelined run must emit the exact same per-round
        // broadcast checksums and final parameters as the threaded
        // baseline. (The M ∈ {64, 512, 4096} frame-level equivalence
        // lives in tests/integration_evloop.rs.)
        let run = |transport| {
            let mut cfg = quad_cfg("dqgan:linf8", 30, 0.05);
            cfg.agg = AggregatorConfig::pipelined();
            cfg.transport = transport;
            run_cluster(&cfg, |_m| {
                let mut rng = Pcg32::new(777);
                Ok(Box::new(QuadraticOperator::new(32, 0.1, &mut rng)))
            })
            .unwrap()
        };
        let ev = run(TransportMode::EvLoop);
        let th = run(TransportMode::Threads);
        let fnvs = |r: &TrainReport| {
            r.records.iter().map(|x| (x.round, x.broadcast_fnv)).collect::<Vec<_>>()
        };
        assert_eq!(fnvs(&ev), fnvs(&th), "broadcast checksums must match bitwise");
        assert_eq!(ev.worker0.final_params, th.worker0.final_params);
    }

    #[test]
    fn chaos_kill_under_evict_matches_the_worker_never_existing() {
        // The δ-contract identity the CI chaos job gates on: a 4-worker
        // run whose worker 3 dies at round 0 under `--on-worker-loss
        // evict` + `kofm:3` averages over the same 3 survivors — with
        // the same 1/arrived scale — as a 3-worker `kofm:3` run, so the
        // per-round broadcast checksums must be bitwise identical.
        use crate::config::{PolicyConfig, RecoveryConfig, WorkerLossMode};
        let build = |workers: usize, chaos: Option<(usize, u64)>| {
            let mut cfg = quad_cfg("dqgan:linf8", 12, 0.05);
            cfg.workers = workers;
            cfg.transport = TransportMode::EvLoop;
            cfg.chaos_kill = chaos;
            cfg.agg = AggregatorConfig {
                policy: PolicyConfig::KofM { k: 3 },
                liveness_rounds: 2,
                recovery: RecoveryConfig {
                    on_worker_loss: WorkerLossMode::Evict,
                    ..RecoveryConfig::default()
                },
                ..AggregatorConfig::pipelined()
            };
            cfg
        };
        let run = |cfg: &ClusterConfig| {
            run_cluster(cfg, |_m| {
                let mut rng = Pcg32::new(777);
                Ok(Box::new(QuadraticOperator::new(16, 0.1, &mut rng)))
            })
            .unwrap()
        };
        let chaotic = run(&build(4, Some((3, 0))));
        let baseline = run(&build(3, None));
        assert_eq!(chaotic.records.len(), 12, "run must survive the killed worker");
        let fnvs = |r: &TrainReport| {
            r.records.iter().map(|x| (x.round, x.broadcast_fnv)).collect::<Vec<_>>()
        };
        assert_eq!(fnvs(&chaotic), fnvs(&baseline), "survivor broadcasts must be bitwise equal");
        assert_eq!(chaotic.worker0.final_params, baseline.worker0.final_params);
        // The dead worker's slot is evicted (liveness bound), never folded.
        assert!(chaotic.records.iter().any(|r| r.workers_evicted == 1));
        assert!(chaotic.records.iter().all(|r| r.workers_included == 3));
    }

    #[test]
    fn leader_kill_then_resume_is_bitwise_identical() {
        // The tentpole identity: kill the leader right after round 13's
        // broadcast (`--chaos-kill-leader 13`), then resume from the
        // checkpoint dir — every post-resume round must be bitwise
        // identical to an undisturbed run, and the final params equal.
        use crate::config::RecoveryConfig;
        let dir = std::env::temp_dir().join(format!(
            "dqgan-leader-kill-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let build = |resume: bool, chaos: Option<u64>, ckpt: bool| {
            let mut cfg = quad_cfg("dqgan:linf8", 24, 0.05);
            cfg.transport = TransportMode::EvLoop;
            cfg.agg = AggregatorConfig::pipelined();
            if ckpt {
                cfg.agg.recovery = RecoveryConfig {
                    ckpt_dir: Some(dir.clone()),
                    ckpt_every: 4,
                    ..RecoveryConfig::default()
                };
            }
            cfg.chaos_kill_leader = chaos;
            cfg.resume = resume;
            cfg
        };
        let run = |cfg: &ClusterConfig| {
            run_cluster(cfg, |_m| {
                let mut rng = Pcg32::new(4242);
                Ok(Box::new(QuadraticOperator::new(12, 0.1, &mut rng)))
            })
            .unwrap()
        };
        // Undisturbed baseline — no store: checkpointing never alters
        // the math, so a storeless run is the legitimate reference.
        let baseline = run(&build(false, None, false));
        assert_eq!(baseline.records.len(), 24);
        // The doomed run: serve loop returns after round 13, no Shutdown.
        let killed = run(&build(false, Some(13), true));
        assert_eq!(killed.records.last().unwrap().round, 13);
        // Snapshot cadence 4 ⇒ restorable rounds 3, 7, 11, …; by the
        // time the leader gathered round 12 every worker had snapped
        // round 11, so the manifest deterministically points there.
        let man = RunManifest::load(&dir).unwrap().expect("manifest written before the kill");
        assert_eq!(man.round, 11);
        assert_eq!(man.epoch, 0);
        assert_eq!(man.workers, 3);
        assert!(is_snapshot_round(man.round, Some(4)));
        // Resume: picks up at manifest round + 1 under a bumped epoch.
        let resumed = run(&build(true, None, true));
        assert_eq!(resumed.records.first().unwrap().round, man.round + 1);
        assert_eq!(resumed.records.last().unwrap().round, 23);
        for rec in &resumed.records {
            let base = &baseline.records[rec.round as usize];
            assert_eq!(
                (rec.round, rec.broadcast_fnv),
                (base.round, base.broadcast_fnv),
                "post-resume round {} must be bitwise identical to the undisturbed run",
                rec.round
            );
        }
        assert_eq!(resumed.worker0.final_params, baseline.worker0.final_params);
        let man2 = RunManifest::load(&dir).unwrap().unwrap();
        assert_eq!(man2.epoch, man.epoch + 1, "resume bumps the session epoch");
        assert_eq!(man2.round, 23, "final advance publishes the last snapshot round");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_refuses_a_fingerprint_mismatch() {
        use crate::config::RecoveryConfig;
        let dir = std::env::temp_dir().join(format!(
            "dqgan-resume-fp-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let build = |lr: f32, resume: bool| {
            let mut cfg = quad_cfg("dqgan:linf8", 8, lr);
            cfg.agg.recovery = RecoveryConfig {
                ckpt_dir: Some(dir.clone()),
                ckpt_every: 4,
                ..RecoveryConfig::default()
            };
            cfg.resume = resume;
            cfg
        };
        let src = |_m: usize| -> anyhow::Result<Box<dyn GradientSource>> {
            let mut rng = Pcg32::new(99);
            Ok(Box::new(QuadraticOperator::new(8, 0.1, &mut rng)))
        };
        run_cluster(&build(0.05, false), src).unwrap();
        // Same dir, different step size: the fingerprints differ, so the
        // resume must refuse loudly rather than silently diverge.
        let err = run_cluster(&build(0.07, true), src).unwrap_err();
        assert!(
            err.to_string().contains("fingerprint mismatch"),
            "unexpected error: {err}"
        );
        // The honest fingerprint resumes cleanly — and since the run
        // already finished (manifest at the last snapshot round 7 of 8),
        // there is nothing left to serve: a completed run resumes as a
        // no-op rather than re-training or erroring.
        let done = run_cluster(&build(0.05, true), src).unwrap();
        assert!(done.records.is_empty(), "finished run must resume as a no-op");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn failure_injection_fails_fast_not_hangs() {
        struct FailingSource {
            inner: QuadraticOperator,
            countdown: u32,
        }
        impl GradientSource for FailingSource {
            fn dim(&self) -> usize {
                self.inner.dim
            }
            fn grad(
                &mut self,
                w: &[f32],
                batch: usize,
                rng: &mut Pcg32,
                out: &mut [f32],
            ) -> anyhow::Result<crate::grad::GradMeta> {
                if self.countdown == 0 {
                    anyhow::bail!("injected gradient failure");
                }
                self.countdown -= 1;
                self.inner.grad(w, batch, rng, out)
            }
            fn init_params(&self, rng: &mut Pcg32) -> Vec<f32> {
                self.inner.init_params(rng)
            }
        }
        let cfg = quad_cfg("dqgan:linf8", 100, 0.05);
        let res = run_cluster(&cfg, |m| {
            let mut rng = Pcg32::new(31);
            Ok(Box::new(FailingSource {
                inner: QuadraticOperator::new(10, 0.1, &mut rng),
                countdown: if m == 1 { 5 } else { u32::MAX },
            }))
        });
        let err = res.unwrap_err();
        assert!(err.to_string().contains("failed") || err.to_string().contains("injected"),
            "unexpected error: {err}");
    }
}
