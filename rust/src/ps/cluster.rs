//! Single-binary cluster driver: spawns the leader + M worker threads over
//! the in-process transport and runs a full training job. This is the
//! entry point used by the CLI, the experiment harnesses and the examples.

use super::aggregate::Decoder;
use super::server::serve_rounds_with;
use super::worker::{apply_broadcast, worker_loop, EvalHook, WorkerSummary};
use super::RoundRecord;
use crate::algo::AlgoKind;
use crate::ckpt::CkptStore;
use crate::comm::{inproc_cluster, inproc_cluster_evloop, Message, MsgKind, ServerEnd};
use crate::config::{AggregatorConfig, TransportMode};
use crate::grad::GradientSource;
use crate::optim::LrSchedule;
use crate::util::bytes::put_f32_slice;
use crate::util::rng::Pcg32;
use crate::util::timer::Stopwatch;
use std::sync::{Arc, Mutex};

/// Cluster configuration for one training run.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub algo: AlgoKind,
    /// Number of workers M.
    pub workers: usize,
    /// Mini-batch size B per worker.
    pub batch: usize,
    /// Total synchronous rounds T.
    pub rounds: u64,
    pub lr: LrSchedule,
    /// Base RNG seed (worker m uses seed+m+1; init uses seed).
    pub seed: u64,
    /// Invoke the eval hook on worker 0 every `eval_every` rounds (0 = never).
    pub eval_every: u64,
    /// Keep per-round worker stats on worker 0 (memory vs detail).
    pub keep_stats: bool,
    /// Leader aggregation path (sharded by default; the sequential
    /// baseline is bitwise-identical and kept for A/B verification).
    pub agg: AggregatorConfig,
    /// Transport engine (readiness loop by default; the per-worker
    /// thread army is kept as the A/B baseline). Broadcasts are
    /// bitwise-identical across the two — CI diffs `broadcast_fnv`
    /// between them every run.
    pub transport: TransportMode,
    /// Fault injection (`--chaos-kill W@R`): worker W participates
    /// normally for R rounds and then dies abruptly — its transport end
    /// drops with no Shutdown handshake, like a SIGKILL mid-run. The
    /// run only survives this under `--on-worker-loss evict`; the CI
    /// chaos job drives it and diffs the survivor broadcasts against a
    /// run where W was absent from the start.
    pub chaos_kill: Option<(usize, u64)>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            algo: AlgoKind::CpoAdam,
            workers: 4,
            batch: 32,
            rounds: 100,
            lr: LrSchedule::constant(1e-3),
            seed: 0xD9_6A17,
            eval_every: 0,
            keep_stats: true,
            agg: AggregatorConfig::default(),
            transport: TransportMode::default(),
            chaos_kill: None,
        }
    }
}

/// A snapshot the eval hook produced at some round.
#[derive(Debug, Clone)]
pub struct EvalEvent {
    pub round: u64,
    pub params: Vec<f32>,
    pub loss_g: Option<f32>,
    pub loss_d: Option<f32>,
}

/// Full-run report.
#[derive(Debug)]
pub struct TrainReport {
    pub records: Vec<RoundRecord>,
    pub worker0: WorkerSummary,
    /// Snapshots captured by the eval schedule.
    pub evals: Vec<EvalEvent>,
    /// Total uplink payload bytes across the run (sum over rounds/workers).
    pub total_bytes_up: u64,
    pub wall_secs: f64,
    /// Mean leader-side round wall time (the Fig-4 compute input).
    pub mean_round_secs: f64,
}

/// Run one training job: M worker threads + leader on this thread.
///
/// `make_src` builds each worker's gradient source (called once per worker,
/// on the worker's thread — sources need not be `Sync`).
pub fn run_cluster(
    cfg: &ClusterConfig,
    make_src: impl Fn(usize) -> anyhow::Result<Box<dyn GradientSource>> + Send + Sync,
) -> anyhow::Result<TrainReport> {
    anyhow::ensure!(cfg.workers > 0, "need at least one worker");
    if let Some((cw, cr)) = cfg.chaos_kill {
        anyhow::ensure!(
            cw < cfg.workers,
            "--chaos-kill worker {cw} out of range (M = {})",
            cfg.workers
        );
        anyhow::ensure!(
            cw != 0,
            "--chaos-kill cannot target worker 0 (it owns the report summary)"
        );
        anyhow::ensure!(
            cr < cfg.rounds,
            "--chaos-kill round {cr} is past the run ({} rounds)",
            cfg.rounds
        );
    }
    // Periodic model snapshots (`--ckpt-every`): worker 0's post-apply
    // params land in a `model/` sub-store of the checkpoint dir. Kept
    // separate from the leader's broadcast-spill store so the two
    // manifests never contend.
    let model_ckpt: Option<Arc<Mutex<CkptStore>>> =
        match (&cfg.agg.recovery.ckpt_dir, cfg.agg.recovery.ckpt_every) {
            (Some(dir), every) if every > 0 => {
                Some(Arc::new(Mutex::new(CkptStore::open(dir.join("model"))?)))
            }
            _ => None,
        };
    let sw = Stopwatch::start();
    // Both transports speak the same ServerEnd/WorkerEnd contract; the
    // evloop cluster's worker ends additionally ack applied broadcasts
    // (a WorkerEnd::ack no-op on the threaded one).
    let (mut server, worker_ends): (Box<dyn ServerEnd>, _) = match cfg.transport {
        TransportMode::EvLoop => {
            let (s, w, _counter) = inproc_cluster_evloop(cfg.workers);
            (Box::new(s), w)
        }
        TransportMode::Threads => {
            let (s, w, _counter) = inproc_cluster(cfg.workers);
            (Box::new(s), w)
        }
    };

    // Initial parameters: one w₀ pushed to all workers (Algorithm 2 line 1)
    // — realized by constructing every worker from the same vector.
    let mut init_rng = Pcg32::new(cfg.seed);
    let probe_src = make_src(0)?;
    let dim = probe_src.dim();
    let w0 = probe_src.init_params(&mut init_rng);
    drop(probe_src);

    let decoder: Decoder = cfg.algo.decoder();
    let (eval_tx, eval_rx) = std::sync::mpsc::channel::<EvalEvent>();

    let report = std::thread::scope(|scope| -> anyhow::Result<TrainReport> {
        let mut handles = Vec::new();
        for (m, mut end) in worker_ends.into_iter().enumerate() {
            let algo = cfg.algo.build_worker(w0.clone(), cfg.lr.clone());
            let make_src = &make_src;
            let eval_tx = eval_tx.clone();
            let eval_every = cfg.eval_every;
            let keep = cfg.keep_stats && m == 0;
            let batch = cfg.batch;
            let rounds = cfg.rounds;
            let seed = cfg.seed;
            let chaos_rounds = match cfg.chaos_kill {
                Some((cw, cr)) if cw == m => Some(cr),
                _ => None,
            };
            let model_ckpt = model_ckpt.clone();
            let snap_every = cfg.agg.recovery.ckpt_every;
            handles.push(scope.spawn(move || -> anyhow::Result<WorkerSummary> {
                let mut src = make_src(m)?;
                let mut rng = Pcg32::new(seed.wrapping_add(m as u64).wrapping_add(1));
                let mut algo = algo;
                if let Some(cr) = chaos_rounds {
                    // Fault injection: run `cr` normal rounds, then die
                    // without any teardown handshake — the transport end
                    // just drops mid-protocol, exactly what a killed
                    // process looks like from the leader's side.
                    let dim = algo.dim();
                    for round in 0..cr {
                        let payload = algo.produce(src.as_mut(), batch, &mut rng)?.wire.to_vec();
                        if end.send(Message::payload(m as u32, round, payload)).is_err() {
                            break;
                        }
                        loop {
                            match end.recv() {
                                Ok(msg)
                                    if msg.kind == MsgKind::Broadcast
                                        || msg.kind == MsgKind::PartialBroadcast =>
                                {
                                    apply_broadcast(
                                        algo.as_mut(),
                                        dim,
                                        m as u32,
                                        &msg,
                                        msg.round == round,
                                    )?;
                                    let _ = end.ack(msg.round);
                                    break;
                                }
                                Ok(msg) if msg.kind == MsgKind::Shutdown => {
                                    return Ok(WorkerSummary {
                                        rounds: round,
                                        final_params: algo.params().to_vec(),
                                        stats: Vec::new(),
                                    });
                                }
                                Ok(_) => {}
                                Err(_) => break,
                            }
                        }
                    }
                    drop(end);
                    return Ok(WorkerSummary {
                        rounds: cr,
                        final_params: algo.params().to_vec(),
                        stats: Vec::new(),
                    });
                }
                let eval: Option<EvalHook> = if m == 0 && (eval_every > 0 || model_ckpt.is_some())
                {
                    Some(Box::new(move |round, params, stats| {
                        if eval_every > 0 && ((round + 1) % eval_every == 0 || round == 0) {
                            let _ = eval_tx.send(EvalEvent {
                                round,
                                params: params.to_vec(),
                                loss_g: stats.loss_g,
                                loss_d: stats.loss_d,
                            });
                        }
                        if let Some(store) = &model_ckpt {
                            if (round + 1) % snap_every == 0 {
                                let mut bytes = Vec::with_capacity(4 * params.len());
                                put_f32_slice(&mut bytes, params);
                                // Post-apply params are identical across
                                // workers, so worker 0's copy is *the*
                                // model at this round.
                                if let Err(e) =
                                    store.lock().unwrap().put("model", round, 0, &bytes)
                                {
                                    crate::log_warn!(
                                        "model checkpoint at round {round} failed: {e:#}"
                                    );
                                }
                            }
                        }
                    }))
                } else {
                    None
                };
                worker_loop(
                    &mut end,
                    algo.as_mut(),
                    src.as_mut(),
                    batch,
                    rounds,
                    &mut rng,
                    keep,
                    eval,
                )
            }));
        }
        drop(eval_tx);

        let serve_result =
            serve_rounds_with(&mut server, decoder, dim, cfg.rounds, cfg.agg.clone(), |_| {});
        if serve_result.is_err() {
            // Unblock workers waiting in phase 2 so the scope join below
            // cannot hang; ignore send failures (workers may be gone).
            use crate::comm::Message;
            let _ = server.broadcast(Message::shutdown(u64::MAX));
        }
        drop(server); // close channels before joining

        let mut worker0 = None;
        let mut worker_err: Option<anyhow::Error> = None;
        for (m, h) in handles.into_iter().enumerate() {
            match h.join() {
                Err(_) => worker_err.get_or_insert(anyhow::anyhow!("worker {m} panicked")),
                Ok(Err(e)) => worker_err.get_or_insert(e),
                Ok(Ok(summary)) => {
                    if m == 0 {
                        worker0 = Some(summary);
                    }
                    continue;
                }
            };
        }
        // Prefer the leader's error (it names the failing worker); fall
        // back to a worker-local error.
        let records = match serve_result {
            Ok(r) => r,
            Err(e) => return Err(e),
        };
        if let Some(e) = worker_err {
            return Err(e);
        }
        let evals: Vec<EvalEvent> = eval_rx.try_iter().collect();
        let total_bytes_up: u64 = records.iter().map(|r| r.bytes_up as u64).sum();
        let mean_round_secs = if records.is_empty() {
            0.0
        } else {
            records.iter().map(|r| r.wall_secs).sum::<f64>() / records.len() as f64
        };
        Ok(TrainReport {
            records,
            worker0: worker0.expect("worker 0 summary"),
            evals,
            total_bytes_up,
            wall_secs: sw.elapsed_secs(),
            mean_round_secs,
        })
    })?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::QuadraticOperator;

    fn quad_cfg(algo: &str, rounds: u64, lr: f32) -> ClusterConfig {
        ClusterConfig {
            algo: AlgoKind::parse(algo).unwrap(),
            workers: 3,
            batch: 8,
            rounds,
            lr: LrSchedule::constant(lr),
            seed: 1234,
            eval_every: 10,
            keep_stats: true,
            agg: Default::default(),
            transport: Default::default(),
            chaos_kill: None,
        }
    }

    fn target_for_seed(seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::new(seed);
        QuadraticOperator::new(10, 0.1, &mut rng).target
    }

    #[test]
    fn dqgan_cluster_converges_end_to_end() {
        let cfg = quad_cfg("dqgan:linf8", 600, 0.1);
        let report = run_cluster(&cfg, |_m| {
            let mut rng = Pcg32::new(999);
            Ok(Box::new(QuadraticOperator::new(10, 0.1, &mut rng)))
        })
        .unwrap();
        let target = {
            let mut rng = Pcg32::new(999);
            QuadraticOperator::new(10, 0.1, &mut rng).target
        };
        for (a, b) in report.worker0.final_params.iter().zip(&target) {
            assert!((a - b).abs() < 0.1, "{a} vs {b}");
        }
        assert_eq!(report.records.len(), 600);
        assert!(report.total_bytes_up > 0);
        assert!(!report.evals.is_empty());
        let _ = target_for_seed(999);
    }

    #[test]
    fn cpoadam_cluster_converges() {
        let cfg = quad_cfg("cpoadam", 500, 0.05);
        let report = run_cluster(&cfg, |_m| {
            let mut rng = Pcg32::new(555);
            Ok(Box::new(QuadraticOperator::new(10, 0.1, &mut rng)))
        })
        .unwrap();
        let target = {
            let mut rng = Pcg32::new(555);
            QuadraticOperator::new(10, 0.1, &mut rng).target
        };
        for (a, b) in report.worker0.final_params.iter().zip(&target) {
            assert!((a - b).abs() < 0.15, "{a} vs {b}");
        }
    }

    #[test]
    fn dqgan_ships_fewer_bytes_than_cpoadam() {
        let run = |algo: &str| {
            let cfg = quad_cfg(algo, 20, 0.05);
            run_cluster(&cfg, |_m| {
                let mut rng = Pcg32::new(777);
                Ok(Box::new(QuadraticOperator::new(256, 0.1, &mut rng)))
            })
            .unwrap()
            .total_bytes_up
        };
        let dq = run("dqgan:linf8");
        let cp = run("cpoadam");
        assert!(dq * 3 < cp, "dqgan={dq} cpoadam={cp}");
    }

    #[test]
    fn transports_produce_bitwise_identical_broadcasts() {
        // The readiness-loop transport is a scheduling change only: a
        // seeded pipelined run must emit the exact same per-round
        // broadcast checksums and final parameters as the threaded
        // baseline. (The M ∈ {64, 512, 4096} frame-level equivalence
        // lives in tests/integration_evloop.rs.)
        let run = |transport| {
            let mut cfg = quad_cfg("dqgan:linf8", 30, 0.05);
            cfg.agg = AggregatorConfig::pipelined();
            cfg.transport = transport;
            run_cluster(&cfg, |_m| {
                let mut rng = Pcg32::new(777);
                Ok(Box::new(QuadraticOperator::new(32, 0.1, &mut rng)))
            })
            .unwrap()
        };
        let ev = run(TransportMode::EvLoop);
        let th = run(TransportMode::Threads);
        let fnvs = |r: &TrainReport| {
            r.records.iter().map(|x| (x.round, x.broadcast_fnv)).collect::<Vec<_>>()
        };
        assert_eq!(fnvs(&ev), fnvs(&th), "broadcast checksums must match bitwise");
        assert_eq!(ev.worker0.final_params, th.worker0.final_params);
    }

    #[test]
    fn chaos_kill_under_evict_matches_the_worker_never_existing() {
        // The δ-contract identity the CI chaos job gates on: a 4-worker
        // run whose worker 3 dies at round 0 under `--on-worker-loss
        // evict` + `kofm:3` averages over the same 3 survivors — with
        // the same 1/arrived scale — as a 3-worker `kofm:3` run, so the
        // per-round broadcast checksums must be bitwise identical.
        use crate::config::{PolicyConfig, RecoveryConfig, WorkerLossMode};
        let build = |workers: usize, chaos: Option<(usize, u64)>| {
            let mut cfg = quad_cfg("dqgan:linf8", 12, 0.05);
            cfg.workers = workers;
            cfg.transport = TransportMode::EvLoop;
            cfg.chaos_kill = chaos;
            cfg.agg = AggregatorConfig {
                policy: PolicyConfig::KofM { k: 3 },
                liveness_rounds: 2,
                recovery: RecoveryConfig {
                    on_worker_loss: WorkerLossMode::Evict,
                    ..RecoveryConfig::default()
                },
                ..AggregatorConfig::pipelined()
            };
            cfg
        };
        let run = |cfg: &ClusterConfig| {
            run_cluster(cfg, |_m| {
                let mut rng = Pcg32::new(777);
                Ok(Box::new(QuadraticOperator::new(16, 0.1, &mut rng)))
            })
            .unwrap()
        };
        let chaotic = run(&build(4, Some((3, 0))));
        let baseline = run(&build(3, None));
        assert_eq!(chaotic.records.len(), 12, "run must survive the killed worker");
        let fnvs = |r: &TrainReport| {
            r.records.iter().map(|x| (x.round, x.broadcast_fnv)).collect::<Vec<_>>()
        };
        assert_eq!(fnvs(&chaotic), fnvs(&baseline), "survivor broadcasts must be bitwise equal");
        assert_eq!(chaotic.worker0.final_params, baseline.worker0.final_params);
        // The dead worker's slot is evicted (liveness bound), never folded.
        assert!(chaotic.records.iter().any(|r| r.workers_evicted == 1));
        assert!(chaotic.records.iter().all(|r| r.workers_included == 3));
    }

    #[test]
    fn failure_injection_fails_fast_not_hangs() {
        struct FailingSource {
            inner: QuadraticOperator,
            countdown: u32,
        }
        impl GradientSource for FailingSource {
            fn dim(&self) -> usize {
                self.inner.dim
            }
            fn grad(
                &mut self,
                w: &[f32],
                batch: usize,
                rng: &mut Pcg32,
                out: &mut [f32],
            ) -> anyhow::Result<crate::grad::GradMeta> {
                if self.countdown == 0 {
                    anyhow::bail!("injected gradient failure");
                }
                self.countdown -= 1;
                self.inner.grad(w, batch, rng, out)
            }
            fn init_params(&self, rng: &mut Pcg32) -> Vec<f32> {
                self.inner.init_params(rng)
            }
        }
        let cfg = quad_cfg("dqgan:linf8", 100, 0.05);
        let res = run_cluster(&cfg, |m| {
            let mut rng = Pcg32::new(31);
            Ok(Box::new(FailingSource {
                inner: QuadraticOperator::new(10, 0.1, &mut rng),
                countdown: if m == 1 { 5 } else { u32::MAX },
            }))
        });
        let err = res.unwrap_err();
        assert!(err.to_string().contains("failed") || err.to_string().contains("injected"),
            "unexpected error: {err}");
    }
}
