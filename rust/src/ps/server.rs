//! The leader: per round, gather M payloads, decode, average (Algorithm 2
//! line 11: q̂ = 1/M Σ p̂^(m)), broadcast.

use super::RoundRecord;
use crate::comm::{Message, ServerEnd};
use crate::tensor::ops;
use crate::util::bytes::put_f32_slice;
use crate::util::stats::norm2_sq;
use crate::util::timer::Stopwatch;
use std::sync::Arc;

/// Server-side payload decoder (algorithm-specific; see
/// [`crate::algo::AlgoKind::decoder`]).
pub type Decoder = Arc<dyn Fn(&[u8], usize) -> anyhow::Result<Vec<f32>> + Send + Sync>;

/// Run `rounds` synchronous rounds on `transport`. Returns per-round
/// records. `dim` is the flat parameter dimension; `on_round` is invoked
/// after each broadcast (leader-side progress/telemetry hook).
pub fn serve_rounds(
    transport: &mut dyn ServerEnd,
    decoder: Decoder,
    dim: usize,
    rounds: u64,
    mut on_round: impl FnMut(&RoundRecord),
) -> anyhow::Result<Vec<RoundRecord>> {
    let m = transport.workers();
    anyhow::ensure!(m > 0, "no workers");
    let mut records = Vec::with_capacity(rounds as usize);
    let mut avg = vec![0.0f32; dim];
    for round in 0..rounds {
        let sw = Stopwatch::start();
        let msgs = transport.recv_round()?;
        anyhow::ensure!(msgs.len() == m, "expected {m} payloads, got {}", msgs.len());
        // Decode every worker's payload and validate.
        let mut decoded: Vec<Vec<f32>> = Vec::with_capacity(m);
        let mut bytes_up = 0usize;
        for msg in &msgs {
            anyhow::ensure!(msg.round == round, "round skew: {} vs {round}", msg.round);
            bytes_up += msg.payload.len();
            let v = decoder(&msg.payload, dim)?;
            anyhow::ensure!(v.len() == dim, "decoded length {} ≠ dim {dim}", v.len());
            anyhow::ensure!(
                ops::all_finite(&v),
                "worker {} sent non-finite payload at round {round}",
                msg.worker
            );
            decoded.push(v);
        }
        // Average (line 11).
        {
            let refs: Vec<&[f32]> = decoded.iter().map(|v| v.as_slice()).collect();
            ops::mean_into(&refs, &mut avg);
        }
        // Broadcast q̄ as raw f32 (the downlink is full-precision; the
        // paper quantizes the uplink only — see DESIGN.md FIG4 notes).
        let mut payload = Vec::with_capacity(4 * dim);
        put_f32_slice(&mut payload, &avg);
        transport.broadcast(Message::broadcast(round, payload))?;
        let rec = RoundRecord {
            round,
            avg_payload_norm_sq: norm2_sq(&avg),
            bytes_up,
            wall_secs: sw.elapsed_secs(),
            ..Default::default()
        };
        on_round(&rec);
        records.push(rec);
    }
    transport.broadcast(Message::shutdown(rounds))?;
    Ok(records)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::inproc_cluster;
    use crate::comm::{MsgKind, WorkerEnd};
    use crate::compress::{Compressor, Identity};

    #[test]
    fn averages_and_broadcasts() {
        let (mut server, workers, _) = inproc_cluster(2);
        let dim = 4;
        let handles: Vec<_> = workers
            .into_iter()
            .enumerate()
            .map(|(i, mut w)| {
                std::thread::spawn(move || {
                    let v = vec![i as f32; 4];
                    let mut wire = Vec::new();
                    Identity.encode(&v, &mut wire);
                    w.send(Message::payload(i as u32, 0, wire)).unwrap();
                    let b = w.recv().unwrap();
                    assert_eq!(b.kind, MsgKind::Broadcast);
                    let avg = Identity.decode(&b.payload, 4).unwrap();
                    assert_eq!(avg, vec![0.5; 4]); // mean of 0s and 1s
                    let s = w.recv().unwrap();
                    assert_eq!(s.kind, MsgKind::Shutdown);
                })
            })
            .collect();
        let decoder: Decoder = Arc::new(|b, d| Identity.decode(b, d));
        let recs = serve_rounds(&mut server, decoder, dim, 1, |_| {}).unwrap();
        assert_eq!(recs.len(), 1);
        assert!(recs[0].bytes_up > 0);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn rejects_non_finite_payloads() {
        let (mut server, mut workers, _) = inproc_cluster(1);
        let v = vec![f32::NAN; 2];
        let mut wire = Vec::new();
        Identity.encode(&v, &mut wire);
        workers[0].send(Message::payload(0, 0, wire)).unwrap();
        let decoder: Decoder = Arc::new(|b, d| Identity.decode(b, d));
        let err = serve_rounds(&mut server, decoder, 2, 1, |_| {}).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
    }
}
