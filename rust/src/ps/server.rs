//! The leader: per round, gather M payloads, decode + average through the
//! [`Aggregator`] subsystem (Algorithm 2 line 11: q̂ = 1/M Σ p̂^(m)),
//! broadcast.
//!
//! The aggregation path is selected by [`AggregatorConfig`]: the default
//! sharded pipeline decodes worker payloads thread-parallel and reduces
//! cache-sized shards of the parameter vector in worker-id order, which is
//! bitwise-identical to the sequential baseline kept behind
//! [`crate::config::AggMode::Sequential`] (see `ps/aggregate.rs` for the
//! determinism argument and `tests/integration_aggregate.rs` for the
//! regression proof). [`crate::config::AggMode::Streaming`] replaces the
//! gather-then-aggregate barrier with an event-driven round: payloads are
//! decoded as their frames arrive (off [`ServerEnd::recv_round_streaming`]),
//! so decode work overlaps the wait for stragglers instead of serializing
//! behind the slowest worker — same bits out, less wall-clock per round.
//! [`crate::config::AggMode::Pipelined`] goes one step further and makes
//! the *downlink* asynchronous too: the broadcast is queued onto the
//! transport's per-worker writer threads
//! ([`ServerEnd::broadcast_async`]) instead of written serially on this
//! thread, so one slow receiver no longer holds the whole cluster to one
//! round in flight — the leader immediately opens round t+1 (in the
//! aggregator's second slot bank) and decodes its frames on arrival
//! while round t's broadcast is still being delivered. Scheduling
//! changes only: the reduced values are bitwise-identical to streaming
//! mode (enforced by `tests/integration_pipeline.rs` across codecs,
//! cluster sizes, pipeline depths and transports).
//!
//! The reduce itself is scheduled by `--reduce` (see
//! [`crate::config::ReduceMode`]): under the default **windowed** mode
//! the streaming-engine rounds fold the contiguous arrived worker prefix
//! into shard accumulators *during* the gather, so the close only owes
//! the out-of-order tail plus the 1/M scale — and on the pipelined path
//! even that residue is offloaded to a detached pool task
//! ([`Aggregator::close_round`]/[`Aggregator::join_reduce`]) which this
//! loop overlaps with the broadcast-frame prep (payload allocation,
//! bitmap header, late-ledger bookkeeping). `--reduce barrier` keeps the
//! close-time fold as the A/B baseline. Either way the reduced values
//! are bitwise-identical — the fold order per element never changes.
//!
//! Each [`RoundRecord`] splits the leader's round time into `wait_secs`
//! (blocked on the network — arrivals plus downlink writes) and
//! `agg_secs` (compute), now further split into `decode_secs` and
//! `reduce_secs` so the windowed/offloaded overlap is visible in
//! telemetry; `overlap_secs` reports how much of a round's gather
//! overlapped the previous round's still-in-flight broadcast, and
//! `broadcast_fnv` fingerprints the broadcast values for the CI
//! reduce-drift check.

use super::aggregate::{Aggregator, Decoder, ReduceClose};
use super::policy::build_policy;
use super::RoundRecord;
use crate::ckpt::CkptStore;
use crate::comm::{BroadcastHandle, Message, MsgKind, ServerEnd, StreamDirective};
use crate::config::{AggMode, AggregatorConfig, PolicyConfig, WorkerLossMode};
use crate::util::bytes::{fnv1a64_f32, put_f32_slice};
use crate::util::stats::norm2_sq;
use crate::util::threads::live_threads;
use crate::util::timer::Stopwatch;
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Session state for a resumable serve loop — everything
/// [`serve_rounds_session`] needs beyond the per-round aggregation
/// config: where to start, when to "die", and the run's shared
/// checkpoint store. The default is a fresh, chaos-free, storeless run,
/// which is exactly [`serve_rounds_with`].
#[derive(Default)]
pub struct ServeSession {
    /// First round to serve (0 fresh; `manifest.round + 1` on resume).
    pub start_round: u64,
    /// Simulated `kill -9` at the end of round R: the serve loop
    /// returns right after round R's broadcast is handed to the
    /// transport — **no Shutdown frame, no run-end bookkeeping** — so
    /// workers experience exactly what a dead leader looks like (a
    /// closed transport), and recovery has to work from what was
    /// already durably on disk.
    pub chaos_kill_leader: Option<u64>,
    /// Shared checkpoint store for this run. When set it replaces the
    /// store the loop would otherwise open from
    /// `recovery.ckpt_dir` — two stores on one directory would clobber
    /// each other's manifest, so the cluster driver owns a single
    /// store and hands it to both the serve loop (bcast spills) and
    /// the workers (state snapshots).
    pub store: Option<Arc<Mutex<CkptStore>>>,
    /// Snapshot cadence: at every round with `(round + 1) % every == 0`
    /// the broadcast frame is spilled to the store (kind `bcast`), the
    /// durable model artifact the run manifest points at. `None`
    /// disables spilling.
    pub snapshot_every: Option<u64>,
}

/// Whether `round` is a snapshot round under cadence `every`
/// (1-indexed: `every = 5` snapshots rounds 4, 9, 14, …). Shared by the
/// leader's bcast spill, the workers' state snapshots, and the
/// manifest advance so all three always agree on the set of
/// restorable rounds.
pub fn is_snapshot_round(round: u64, every: Option<u64>) -> bool {
    match every {
        Some(k) if k > 0 => (round + 1) % k == 0,
        _ => false,
    }
}

/// Run `rounds` synchronous rounds on `transport` with the default
/// (sharded) aggregation path. Returns per-round records. `dim` is the
/// flat parameter dimension; `on_round` is invoked after each broadcast
/// (leader-side progress/telemetry hook).
pub fn serve_rounds(
    transport: &mut dyn ServerEnd,
    decoder: Decoder,
    dim: usize,
    rounds: u64,
    on_round: impl FnMut(&RoundRecord),
) -> anyhow::Result<Vec<RoundRecord>> {
    serve_rounds_with(transport, decoder, dim, rounds, AggregatorConfig::default(), on_round)
}

/// [`serve_rounds`] with an explicit aggregation configuration — the
/// entry point the cluster driver and the A/B benchmarks use.
pub fn serve_rounds_with(
    transport: &mut dyn ServerEnd,
    decoder: Decoder,
    dim: usize,
    rounds: u64,
    agg_cfg: AggregatorConfig,
    on_round: impl FnMut(&RoundRecord),
) -> anyhow::Result<Vec<RoundRecord>> {
    serve_rounds_session(transport, decoder, dim, rounds, agg_cfg, ServeSession::default(), on_round)
}

/// [`serve_rounds_with`] under a [`ServeSession`]: the resumable /
/// chaos-injectable serve loop. Serves rounds
/// `session.start_round..rounds`; spills snapshot-round broadcasts into
/// the session store; and, under `chaos_kill_leader`, returns early
/// with no Shutdown — the simulated `kill -9`.
pub fn serve_rounds_session(
    transport: &mut dyn ServerEnd,
    decoder: Decoder,
    dim: usize,
    rounds: u64,
    agg_cfg: AggregatorConfig,
    session: ServeSession,
    mut on_round: impl FnMut(&RoundRecord),
) -> anyhow::Result<Vec<RoundRecord>> {
    let m = transport.workers();
    anyhow::ensure!(m > 0, "no workers");
    let streaming = agg_cfg.mode.is_streaming();
    let pipelined = agg_cfg.mode == AggMode::Pipelined;
    let policy_cfg = agg_cfg.policy;
    anyhow::ensure!(
        policy_cfg == PolicyConfig::Full || streaming,
        "--policy {} requires the streaming engine (--agg streaming|pipelined)",
        policy_cfg.label()
    );
    if pipelined {
        // Bound the per-worker queue of undelivered broadcasts before
        // the writer threads spawn.
        transport.set_pipeline_depth(agg_cfg.pipeline_depth.max(1));
    }
    let liveness = agg_cfg.liveness_rounds;
    let recovery = agg_cfg.recovery.clone();
    let evict_mode = recovery.on_worker_loss == WorkerLossMode::Evict;
    anyhow::ensure!(
        !evict_mode || policy_cfg != PolicyConfig::Full,
        "--on-worker-loss evict requires a partial round policy (--policy kofm:K|deadline:MS)"
    );
    if evict_mode {
        // Transport-side arm: worker loss becomes an in-band Gone frame
        // (and the listener keeps accepting rejoin hellos) instead of a
        // sticky fatal error.
        transport.set_evict_on_loss(true);
    }
    // Elastic membership: an evicted worker keeps its slot (ids stay
    // stable across the run) but is excluded from gathers, quorums and
    // ledgers until it rejoins.
    let mut evicted: Vec<bool> = vec![false; m];
    // Bounded replay ledger: the last `--replay-depth` broadcast frames,
    // round-stamped. One owned Message per round — O(depth · dim), not
    // O(depth · M · dim): the transport already shares each frame's
    // encoded wire bytes across all M outboxes per send.
    let mut replay: VecDeque<(u64, Message)> = VecDeque::new();
    // Content-addressed checkpoint store: rotated-out replay frames and
    // snapshot-round broadcasts spill here (kind "bcast"), so a rejoin
    // beyond the replay window can still reconstruct history and a
    // resumed run can restore the manifest round. The session's shared
    // store wins when present — one store per directory, ever.
    let ckpt: Option<Arc<Mutex<CkptStore>>> = match session.store.clone() {
        Some(store) => Some(store),
        None => match &recovery.ckpt_dir {
            Some(dir) => Some(Arc::new(Mutex::new(CkptStore::open(dir)?))),
            None => None,
        },
    };
    // Policy engine (None = the unchanged full-barrier paths below).
    let mut policy = match policy_cfg {
        PolicyConfig::Full => None,
        other => Some(build_policy(other, m)?),
    };
    // Per worker: rounds that closed without this worker's payload and
    // whose late frame has not been drained yet (frames arrive in round
    // order per worker, so a FIFO suffices).
    let mut pending_late: Vec<VecDeque<u64>> = vec![VecDeque::new(); m];
    let mut agg = Aggregator::new(agg_cfg, dim, m);
    anyhow::ensure!(
        session.start_round <= rounds,
        "resume round {} is past the configured horizon of {rounds} rounds",
        session.start_round
    );
    let mut records = Vec::with_capacity((rounds - session.start_round) as usize);
    // Completion handle of the previous round's async broadcast
    // (pipelined mode only) — the input to `overlap_secs`.
    let mut prev_broadcast: Option<BroadcastHandle> = None;
    // Transport byte counter, when the transport exposes one: source of
    // the per-round `bytes_down` delta and the run-end obs totals.
    let byte_counter = transport.counter();
    for round in session.start_round..rounds {
        // A previous broadcast that has *completed with a failure* means
        // some worker's downlink died. Surface it now — the synchronous
        // path failed at the broadcast call itself, and blocking in a
        // gather that may never complete would turn the failure into a
        // hang. (is_done first: wait() on a still-in-flight broadcast
        // would serialize the pipeline we just built.)
        if let Some(h) = &prev_broadcast {
            if h.is_done() {
                h.wait()?;
            }
        }
        // Liveness bound: a skipped worker whose oldest late frame has
        // not drained within `liveness` rounds is presumed dead, not
        // slow — fail like a worker error instead of letting its
        // `pending_late` ledger (and the error-memory staleness it
        // stands for) stall indefinitely. Note a merely-slow worker's
        // late frame drains only when it pops out of the next round's
        // gather, so transient scheduling can add a round of apparent
        // staleness — size R accordingly (R ≥ 2 is a sane floor on
        // fast-round workloads).
        if liveness > 0 {
            for w in 0..m {
                if evicted[w] {
                    continue;
                }
                let Some(&r0) = pending_late[w].front() else { continue };
                if round.saturating_sub(r0) <= liveness {
                    continue;
                }
                anyhow::ensure!(
                    evict_mode,
                    "worker {w} failed at round {round}: liveness timeout — its round {r0} \
                     payload is still missing after {liveness} rounds (worker presumed \
                     dead, not slow)"
                );
                // `--on-worker-loss evict`: the dead worker loses its
                // membership, not the run. The transport reclaims its
                // parked outbox frames and exempts it from the ack
                // ledger; its late ledger is dropped (those frames are
                // never coming, and error-feedback keeps all compressor
                // state worker-local, so nothing leader-side dangles).
                transport.evict_worker(w)?;
                evicted[w] = true;
                pending_late[w].clear();
                crate::obs::metrics::RECOVERY_EVICTIONS.inc();
            }
        }
        if evict_mode {
            // Quorum feasibility over the survivors: a round that can
            // never close must fail loudly now, not hang in the gather.
            let live = evicted.iter().filter(|&&e| !e).count();
            anyhow::ensure!(live > 0, "all {m} workers evicted — nothing left to aggregate");
            if let Some(p) = policy.as_deref() {
                let q = p.min_quorum();
                anyhow::ensure!(
                    q <= live,
                    "round policy needs {q} workers but only {live} of {m} remain after \
                     evictions"
                );
            }
        }
        let sw = Stopwatch::start();
        let round_start = Instant::now();
        let down_at_start = byte_counter.as_ref().map(|c| c.down_total());
        // Leader-process thread census (running max over the round's
        // sample points): the O(1)-vs-O(M) evidence behind `--transport
        // evloop`, sampled where transports spawn threads — after the
        // gather (reader threads) and after the broadcast (writers).
        let mut threads_peak = live_threads();
        let mut bytes_up = 0usize;
        // Leader time inside `Aggregator::accept`: payload decode plus
        // the windowed reduce folds; the aggregator's `ReduceTiming`
        // splits the two apart after the close.
        let mut accept_secs = 0.0f64;
        let mut wait_secs;
        // Leader-clock seconds at which this round's gather completed.
        let gather_secs;
        // Inclusion set of a policy-closed round (None ⇒ full barrier,
        // every worker included).
        let mut included: Option<Vec<bool>> = None;
        // Reduce ticket of a streaming-engine round (None ⇒ batch mode,
        // which decodes and reduces inside `aggregate` below). Between
        // `close_round` and `join_reduce` the leader prepares the
        // broadcast frame — the window an offloaded close-time reduce
        // overlaps on the pipelined windowed path.
        let close: Option<ReduceClose>;
        let mut batch_msgs: Vec<Message> = Vec::new();
        // Rejoin hellos observed during this round's gather; replay +
        // readmission run after the round closes (the transport is busy
        // inside the gather callback here).
        let mut rejoins: Vec<(usize, u64)> = Vec::new();
        let gather_span = crate::obs::span("gather", crate::obs::LEADER_TID, round);
        if let Some(policy) = policy.as_deref_mut() {
            // Policy-driven round: every arrival is consulted against
            // the RoundPolicy; the round may close before all M payloads
            // land (K-of-M quorum or deadline expiry), skipping the
            // stragglers. Their frames arrive during later rounds and
            // are drained here against the `pending_late` ledger.
            agg.begin_round(round);
            policy.begin_round(round);
            let mut directive = StreamDirective::Wait;
            transport.recv_round_streaming_timed(&mut |msg| {
                if msg.kind == MsgKind::WorkerError {
                    let w = msg.worker as usize;
                    if w < m && evicted[w] {
                        // A dying evicted worker is old news — its slot
                        // is already out of the round.
                        return Ok(directive);
                    }
                    anyhow::bail!(
                        "worker {} failed at round {}: {}",
                        msg.worker,
                        msg.round,
                        String::from_utf8_lossy(&msg.payload)
                    );
                }
                if msg.kind == MsgKind::Gone {
                    // Transport-observed loss (socket death, ack-ledger
                    // stall), surfaced in-band under evict mode. The
                    // transport already reclaimed the worker's parked
                    // frames and marked it dead in the ack ledger; here
                    // membership shrinks and the quorum re-checks.
                    let w = msg.worker as usize;
                    anyhow::ensure!(w < m, "worker id {w} out of range (M = {m})");
                    if !evicted[w] {
                        evicted[w] = true;
                        pending_late[w].clear();
                        crate::obs::metrics::RECOVERY_EVICTIONS.inc();
                    }
                    let live = evicted.iter().filter(|&&e| !e).count();
                    anyhow::ensure!(
                        live > 0,
                        "all {m} workers evicted — nothing left to aggregate"
                    );
                    let q = policy.min_quorum();
                    anyhow::ensure!(
                        q <= live,
                        "round policy needs {q} workers but only {live} of {m} remain \
                         after evictions"
                    );
                    directive = policy.on_arrival(agg.arrived_count(), live);
                    return Ok(directive);
                }
                if msg.kind == MsgKind::Rejoin {
                    let w = msg.worker as usize;
                    anyhow::ensure!(w < m, "worker id {w} out of range (M = {m})");
                    rejoins.push((w, msg.round));
                    return Ok(directive);
                }
                // Every payload frame received during this round costs
                // real uplink bytes — count drained late frames (and an
                // evicted worker's in-flight frames) too, so the
                // per-round series sums to the actual wire traffic.
                if msg.kind == MsgKind::Payload {
                    bytes_up += msg.payload.len();
                }
                if msg.kind == MsgKind::Payload
                    && evicted.get(msg.worker as usize).copied().unwrap_or(false)
                {
                    // In-flight frame from a worker evicted this round:
                    // its slot is skipped, not folded, and its late
                    // ledger was dropped at eviction.
                    return Ok(directive);
                }
                if msg.kind == MsgKind::Payload && msg.round < round {
                    // Late frame from a round that closed without this
                    // worker: drain it and keep the current directive
                    // (no new arrival, so the policy state is unchanged).
                    let w = msg.worker as usize;
                    anyhow::ensure!(w < m, "worker id {w} out of range (M = {m})");
                    match pending_late[w].front().copied() {
                        Some(r) if r == msg.round => {
                            pending_late[w].pop_front();
                        }
                        _ => anyhow::bail!(
                            "worker {w}: unexpected stale frame for round {} \
                             (leader at round {round}, not a skipped round)",
                            msg.round
                        ),
                    }
                    return Ok(directive);
                }
                let t = Stopwatch::start();
                let decode_span = crate::obs::span("decode", crate::obs::LEADER_TID, round);
                let res = agg.accept(&msg, &decoder);
                drop(decode_span);
                accept_secs += t.elapsed_secs();
                res?;
                // Quorums and full-arrival closes are judged against the
                // *live* membership, not the configured M — an evicted
                // straggler must not hold a deadline/full close open.
                let live = evicted.iter().filter(|&&e| !e).count();
                directive = policy.on_arrival(agg.arrived_count(), live);
                Ok(directive)
            })?;
            gather_secs = sw.elapsed_secs();
            wait_secs = (gather_secs - accept_secs).max(0.0);
            // The inclusion set must be captured before the close: an
            // offloaded close moves the bank's arrival flags into the
            // detached task until the join.
            included = Some(agg.included().to_vec());
            close = {
                let _close_span = crate::obs::span("close", crate::obs::LEADER_TID, round);
                Some(agg.close_round(true)?)
            };
        } else if streaming {
            // Event-driven round: each payload decodes (and, under
            // `--reduce windowed`, prefix-folds) the moment its frame
            // lands, overlapping that work with the wait for the
            // remaining workers; the close only owes the leftover tail.
            agg.begin_round(round);
            transport.recv_round_streaming(&mut |msg| {
                bytes_up += msg.payload.len();
                let t = Stopwatch::start();
                let decode_span = crate::obs::span("decode", crate::obs::LEADER_TID, round);
                let res = agg.accept(&msg, &decoder);
                drop(decode_span);
                accept_secs += t.elapsed_secs();
                res
            })?;
            // Time not spent decoding during the gather was spent blocked
            // on arrivals.
            gather_secs = sw.elapsed_secs();
            wait_secs = (gather_secs - accept_secs).max(0.0);
            close = {
                let _close_span = crate::obs::span("close", crate::obs::LEADER_TID, round);
                Some(agg.close_round(false)?)
            };
        } else {
            batch_msgs = transport.recv_round()?;
            gather_secs = sw.elapsed_secs();
            wait_secs = gather_secs;
            bytes_up = batch_msgs.iter().map(|msg| msg.payload.len()).sum();
            close = None;
        }
        drop(gather_span);
        // ---- Broadcast-frame prep: runs while an offloaded close-time
        // reduce is still folding on the pool. Nothing here needs the
        // averaged values — the payload buffer (multi-MB at DCGAN dim)
        // is allocated, the partial frame's bitmap header written, and
        // the late ledger updated from the inclusion set alone.
        let workers_included = match &included {
            Some(inc) => inc.iter().filter(|&&b| b).count(),
            None => m,
        };
        // A policy round that every worker made it into broadcasts the
        // plain frame too: "all included ⇒ byte-identical to the full
        // barrier" is structural, not an accident of which code path ran
        // (deadline rounds with no straggler, kofm:M).
        let partial_frame = workers_included < m;
        let mut payload = match &included {
            Some(inc) if partial_frame => Message::partial_broadcast_prefix(inc, dim),
            _ => Vec::with_capacity(4 * dim),
        };
        if let Some(inc) = &included {
            for (w, &arrived) in inc.iter().enumerate() {
                // Evicted workers owe no late frame: their slot is
                // skipped outright, so the ledger (and the liveness
                // bound it feeds) tracks live stragglers only.
                if !arrived && !evicted[w] {
                    pending_late[w].push_back(round);
                }
            }
        }
        // ---- Join the reduce (or run the batch decode+reduce) and
        // serialize the mean into the prepared frame.
        let batch_sw = Stopwatch::start();
        let reduce_span = crate::obs::span("reduce", crate::obs::LEADER_TID, round);
        let avg: &[f32] = match close {
            Some(ticket) => agg.join_reduce(ticket)?,
            // Decode × M, validate, average (line 11) — sharded or
            // sequential.
            None => agg.aggregate(round, &batch_msgs, &decoder)?,
        };
        drop(reduce_span);
        let batch_wall = batch_sw.elapsed_secs();
        threads_peak = threads_peak.max(live_threads());
        let avg_payload_norm_sq = norm2_sq(avg);
        // Per-round fingerprint of the broadcast values (bit-pattern
        // checksum) — what the CI reduce-drift check diffs across
        // `--reduce windowed|barrier` runs.
        let broadcast_fnv = fnv1a64_f32(avg);
        // Broadcast q̄ as raw f32 (the downlink is full-precision; the
        // paper quantizes the uplink only — see DESIGN.md FIG4 notes).
        // `Message` owns its payload bytes, so the pre-sized Vec above is
        // the one unavoidable per-round allocation on the leader.
        let msg = if partial_frame {
            Message::partial_broadcast_from_prefix(round, payload, avg)
        } else {
            put_f32_slice(&mut payload, avg);
            Message::broadcast(round, payload)
        };
        // Decode/reduce split of the round's compute: reduce is the
        // windowed in-gather folds plus the close fold (task clock when
        // offloaded); decode is the remaining accept time (streaming) or
        // the non-reduce share of `aggregate` (batch). `agg_secs` stays
        // their sum, so existing consumers read unchanged.
        let timing = agg.last_reduce_timing();
        let reduce_secs = timing.total_secs();
        let decode_secs = if streaming {
            (accept_secs - timing.in_gather_secs).max(0.0)
        } else {
            (batch_wall - timing.close_secs).max(0.0)
        };
        let agg_secs = decode_secs + reduce_secs;
        // Gather/broadcast overlap: how much of this round's gather ran
        // while the previous round's broadcast was still on the writer
        // threads. (Synchronous modes completed their broadcast before
        // the round started, so this is 0 there by construction.)
        let overlap_secs = match &prev_broadcast {
            Some(h) => match h.completed_at() {
                Some(done) => done
                    .saturating_duration_since(round_start)
                    .as_secs_f64()
                    .min(gather_secs),
                // Still in flight now: the entire gather overlapped it.
                None => gather_secs,
            },
            None => 0.0,
        };
        // ---- Rejoins observed during the gather: replay the missed
        // broadcast history in round order, then readmit. The replayed
        // frames are queued before this round's broadcast, and each
        // worker's downlink is FIFO, so the rejoined worker sees rounds
        // [resume, now] exactly once and in order.
        for (w, resume) in rejoins.drain(..) {
            if !evicted[w] {
                // Duplicate hello for a slot that is already live.
                continue;
            }
            transport.rejoin_worker(w)?;
            let mut frames: Vec<Message> = Vec::new();
            let mut complete = true;
            for r in resume..round {
                if let Some((_, f)) = replay.iter().find(|(rr, _)| *rr == r) {
                    frames.push(f.clone());
                } else if let Some(store) = ckpt.as_ref() {
                    match store.lock().unwrap().get("bcast", r, 0)? {
                        Some(bytes) => frames.push(Message::decode(&bytes)?),
                        None => {
                            complete = false;
                            break;
                        }
                    }
                } else {
                    complete = false;
                    break;
                }
            }
            if !complete {
                // History is gone — older than `--replay-depth` and not
                // in the checkpoint store. A stale worker must not train
                // across a hole in the broadcast sequence: tell it to
                // exit cleanly and keep the slot evicted.
                transport.send_to(w, &Message::shutdown(round))?;
                transport.evict_worker(w)?;
                continue;
            }
            for f in &frames {
                transport.send_to(w, f)?;
                crate::obs::metrics::RECOVERY_REPLAYED_FRAMES.inc();
            }
            evicted[w] = false;
            crate::obs::metrics::RECOVERY_REJOINS.inc();
        }
        let workers_evicted = evicted.iter().filter(|&&e| e).count();
        // Record this round's broadcast into the bounded replay ledger;
        // frames rotated out of the window spill (encoded) into the
        // checkpoint store when one is configured.
        if evict_mode {
            replay.push_back((round, msg.clone()));
            while replay.len() > recovery.replay_depth {
                let (r, old) = replay.pop_front().expect("non-empty: len > depth >= 0");
                if let Some(store) = ckpt.as_ref() {
                    store.lock().unwrap().put("bcast", r, 0, &old.encode())?;
                }
            }
        }
        // Snapshot round: spill the broadcast frame durably *before* the
        // broadcast goes out, so a manifest that later points at this
        // round always finds its model artifact on disk.
        if is_snapshot_round(round, session.snapshot_every) {
            if let Some(store) = ckpt.as_ref() {
                store.lock().unwrap().put("bcast", round, 0, &msg.encode())?;
            }
        }
        let t = Stopwatch::start();
        // Ack-RTT reference point: the ledger's ack arrivals are matched
        // against this send timestamp (`worker.ack_rtt_ns`).
        crate::obs::note_broadcast_sent(round);
        let broadcast_span = crate::obs::span("broadcast", crate::obs::LEADER_TID, round);
        if pipelined {
            // Queue the frame onto the per-worker writer threads and move
            // straight on to the next round's gather: a slow receiver
            // costs its own writer time, not the cluster's.
            prev_broadcast = Some(transport.broadcast_async(msg)?);
        } else {
            transport.broadcast(msg)?;
        }
        drop(broadcast_span);
        // Time blocked pushing the downlink is network wait too: the
        // full per-socket write loop on the synchronous path, only
        // queue backpressure (a receiver `pipeline_depth` broadcasts
        // behind) on the asynchronous one.
        wait_secs += t.elapsed_secs();
        threads_peak = threads_peak.max(live_threads());
        let bytes_down = byte_counter
            .as_ref()
            .zip(down_at_start)
            .map(|(c, d0)| c.down_total().saturating_sub(d0));
        let rec = RoundRecord {
            round,
            avg_payload_norm_sq,
            bytes_up,
            wall_secs: sw.elapsed_secs(),
            wait_secs,
            agg_secs,
            decode_secs,
            reduce_secs,
            broadcast_fnv,
            overlap_secs,
            workers_included,
            workers_skipped: m - workers_included,
            workers_evicted,
            threads_peak: (threads_peak > 0).then_some(threads_peak),
            bytes_down,
            ..Default::default()
        };
        on_round(&rec);
        records.push(rec);
        if session.chaos_kill_leader == Some(round) {
            // Simulated `kill -9` after round R: return with NO Shutdown
            // broadcast and no run-end bookkeeping. The caller drops the
            // transport, workers see a dead leader, and the only state
            // that survives is what the checkpoint store already holds —
            // exactly the contract `--resume` must work from.
            return Ok(records);
        }
    }
    // The trailing Shutdown uses the blocking path: with writer threads
    // active it routes through the same per-worker queues (order
    // preserved) and waits until every queued frame — broadcasts and the
    // Shutdown itself — has been delivered, so teardown loses nothing.
    transport.broadcast(Message::shutdown(rounds))?;
    // Run-end transport totals into the obs registry (after the Shutdown
    // frame, so the control bytes include teardown).
    if let Some(c) = &byte_counter {
        crate::obs::record_transport_totals(c);
    }
    Ok(records)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::inproc_cluster;
    use crate::comm::{MsgKind, WorkerEnd};
    use crate::compress::{Compressor, Identity};
    use crate::config::AggMode;
    use std::sync::Arc;

    fn identity_decoder() -> Decoder {
        Arc::new(|bytes: &[u8], out: &mut [f32]| Identity.decode_into(bytes, out))
    }

    #[test]
    fn averages_and_broadcasts() {
        let (mut server, workers, _) = inproc_cluster(2);
        let dim = 4;
        let handles: Vec<_> = workers
            .into_iter()
            .enumerate()
            .map(|(i, mut w)| {
                std::thread::spawn(move || {
                    let v = vec![i as f32; 4];
                    let mut wire = Vec::new();
                    Identity.encode(&v, &mut wire);
                    w.send(Message::payload(i as u32, 0, wire)).unwrap();
                    let b = w.recv().unwrap();
                    assert_eq!(b.kind, MsgKind::Broadcast);
                    let avg = Identity.decode(&b.payload, 4).unwrap();
                    assert_eq!(avg, vec![0.5; 4]); // mean of 0s and 1s
                    let s = w.recv().unwrap();
                    assert_eq!(s.kind, MsgKind::Shutdown);
                })
            })
            .collect();
        let recs = serve_rounds(&mut server, identity_decoder(), dim, 1, |_| {}).unwrap();
        assert_eq!(recs.len(), 1);
        assert!(recs[0].bytes_up > 0);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn sequential_flag_produces_the_same_broadcast() {
        use crate::config::ReduceMode;
        let mut fnvs = Vec::new();
        for (mode, reduce) in [
            (AggMode::Sequential, ReduceMode::Windowed),
            (AggMode::Sharded, ReduceMode::Windowed),
            (AggMode::Streaming, ReduceMode::Windowed),
            (AggMode::Streaming, ReduceMode::Barrier),
            (AggMode::Pipelined, ReduceMode::Windowed),
            (AggMode::Pipelined, ReduceMode::Barrier),
        ] {
            let (mut server, mut workers, _) = inproc_cluster(2);
            for (i, w) in workers.iter_mut().enumerate() {
                let mut wire = Vec::new();
                Identity.encode(&[1.0 + i as f32, -2.0, 0.5], &mut wire);
                w.send(Message::payload(i as u32, 0, wire)).unwrap();
            }
            let cfg = AggregatorConfig { mode, reduce, ..Default::default() };
            let t = std::thread::spawn(move || {
                let mut avgs = Vec::new();
                for w in &mut workers {
                    let b = w.recv().unwrap();
                    assert_eq!(b.kind, MsgKind::Broadcast);
                    avgs.push(Identity.decode(&b.payload, 3).unwrap());
                    let s = w.recv().unwrap();
                    assert_eq!(s.kind, MsgKind::Shutdown);
                }
                avgs
            });
            let recs =
                serve_rounds_with(&mut server, identity_decoder(), 3, 1, cfg, |_| {}).unwrap();
            assert_eq!(recs.len(), 1);
            fnvs.push(recs[0].broadcast_fnv);
            let avgs = t.join().unwrap();
            assert_eq!(avgs[0], vec![1.5, -2.0, 0.5], "{mode:?}/{reduce:?}");
            assert_eq!(avgs[0], avgs[1]);
        }
        // Identical broadcast values ⇒ identical checksum across every
        // agg/reduce scheduling combination.
        assert!(fnvs.windows(2).all(|w| w[0] == w[1]), "{fnvs:?}");
    }

    #[test]
    fn round_records_split_wait_and_agg_time() {
        for cfg in [
            AggregatorConfig::default(),
            AggregatorConfig::streaming(),
            AggregatorConfig::pipelined(),
        ] {
            let (mut server, mut workers, _) = inproc_cluster(2);
            for (i, w) in workers.iter_mut().enumerate() {
                let mut wire = Vec::new();
                Identity.encode(&[1.0f32, 2.0], &mut wire);
                w.send(Message::payload(i as u32, 0, wire)).unwrap();
            }
            let t = std::thread::spawn(move || {
                for w in &mut workers {
                    w.recv().unwrap();
                    w.recv().unwrap();
                }
            });
            let recs =
                serve_rounds_with(&mut server, identity_decoder(), 2, 1, cfg, |_| {}).unwrap();
            t.join().unwrap();
            let r = &recs[0];
            assert!(r.wait_secs >= 0.0 && r.agg_secs >= 0.0);
            assert!(r.wall_secs >= r.wait_secs, "wall {} < wait {}", r.wall_secs, r.wait_secs);
            assert!(r.bytes_up > 0);
            assert_eq!(r.overlap_secs, 0.0, "round 0 has no previous broadcast to overlap");
            // The decode/reduce split sums to the legacy agg column.
            assert!(r.decode_secs >= 0.0 && r.reduce_secs >= 0.0);
            assert!(
                (r.decode_secs + r.reduce_secs - r.agg_secs).abs() < 1e-12,
                "agg {} != decode {} + reduce {}",
                r.agg_secs,
                r.decode_secs,
                r.reduce_secs
            );
        }
    }

    #[test]
    fn liveness_timeout_fails_instead_of_stalling_a_dead_workers_ledger() {
        // kofm:1 with M=2: worker 0 keeps the run going, worker 1 never
        // sends a single frame (died). Its pending_late ledger stalls at
        // round 0, and with --liveness 2 the leader must convert that
        // into a worker error at round 3 rather than closing partial
        // rounds forever.
        let (mut server, workers, _) = inproc_cluster(2);
        let mut it = workers.into_iter();
        let mut w0 = it.next().unwrap();
        let w1 = it.next().unwrap(); // kept alive, silent
        let t = std::thread::spawn(move || {
            for round in 0..10u64 {
                let mut wire = Vec::new();
                Identity.encode(&[1.0f32], &mut wire);
                if w0.send(Message::payload(0, round, wire)).is_err() {
                    return;
                }
                match w0.recv() {
                    Ok(msg) if msg.kind == MsgKind::Shutdown => return,
                    Ok(_) => {}
                    Err(_) => return,
                }
            }
        });
        let cfg = AggregatorConfig {
            liveness_rounds: 2,
            ..AggregatorConfig::streaming_with_policy(crate::config::PolicyConfig::KofM {
                k: 1,
            })
        };
        let err =
            serve_rounds_with(&mut server, identity_decoder(), 1, 10, cfg, |_| {}).unwrap_err();
        let text = err.to_string();
        assert!(text.contains("worker 1"), "{text}");
        assert!(text.contains("liveness timeout"), "{text}");
        assert!(text.contains("round 0"), "{text}");
        assert!(text.contains("presumed dead"), "{text}");
        drop(server); // unblock worker 0
        drop(w1);
        t.join().unwrap();
    }

    #[test]
    fn evict_mode_survives_a_silent_worker() {
        // Same dead-worker shape as the liveness test above, but with
        // --on-worker-loss evict: instead of failing the run at the
        // liveness deadline, the leader must drop worker 1 from the
        // membership and keep closing rounds over worker 0 alone.
        use crate::comm::inproc_cluster_evloop;
        use crate::config::{RecoveryConfig, WorkerLossMode};
        let (mut server, workers, _) = inproc_cluster_evloop(2);
        let mut it = workers.into_iter();
        let mut w0 = it.next().unwrap();
        let w1 = it.next().unwrap(); // kept alive, silent, then evicted
        let t = std::thread::spawn(move || {
            let mut applied = 0u64;
            for round in 0..6u64 {
                let mut wire = Vec::new();
                Identity.encode(&[1.0f32], &mut wire);
                if w0.send(Message::payload(0, round, wire)).is_err() {
                    return applied;
                }
                loop {
                    match w0.recv() {
                        Ok(msg) if msg.kind == MsgKind::Shutdown => return applied,
                        Ok(msg)
                            if msg.kind == MsgKind::Broadcast
                                || msg.kind == MsgKind::PartialBroadcast =>
                        {
                            applied += 1;
                            let _ = w0.ack(msg.round);
                            break;
                        }
                        Ok(_) => {}
                        Err(_) => return applied,
                    }
                }
            }
            applied
        });
        let cfg = AggregatorConfig {
            liveness_rounds: 1,
            recovery: RecoveryConfig {
                on_worker_loss: WorkerLossMode::Evict,
                ..Default::default()
            },
            ..AggregatorConfig::streaming_with_policy(crate::config::PolicyConfig::KofM {
                k: 1,
            })
        };
        let records =
            serve_rounds_with(&mut server, identity_decoder(), 1, 6, cfg, |_| {}).unwrap();
        assert_eq!(records.len(), 6, "the run must complete every round");
        assert!(records.iter().all(|r| r.workers_included == 1));
        let evict_round = records.iter().position(|r| r.workers_evicted == 1);
        assert!(
            evict_round.is_some(),
            "worker 1 was never evicted: {:?}",
            records.iter().map(|r| r.workers_evicted).collect::<Vec<_>>()
        );
        assert_eq!(t.join().unwrap(), 6, "worker 0 applied every broadcast");
        drop(w1);
    }

    #[test]
    fn rejects_non_finite_payloads() {
        let (mut server, mut workers, _) = inproc_cluster(1);
        let v = vec![f32::NAN; 2];
        let mut wire = Vec::new();
        Identity.encode(&v, &mut wire);
        workers[0].send(Message::payload(0, 0, wire)).unwrap();
        let err = serve_rounds(&mut server, identity_decoder(), 2, 1, |_| {}).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
    }

    #[test]
    fn round_skew_reports_worker_id() {
        // Both workers send round 7 while the leader is at round 0: the
        // transport-level mixed-round check passes (rounds agree with each
        // other), so the aggregator's skew check must fire and name the
        // worker.
        let (mut server, mut workers, _) = inproc_cluster(2);
        for (i, w) in workers.iter_mut().enumerate() {
            let mut wire = Vec::new();
            Identity.encode(&[0.0f32], &mut wire);
            w.send(Message::payload(i as u32, 7, wire)).unwrap();
        }
        let err = serve_rounds(&mut server, identity_decoder(), 1, 1, |_| {}).unwrap_err();
        let text = err.to_string();
        assert!(text.contains("round skew"), "{text}");
        assert!(text.contains("worker 0"), "{text}");
        assert!(text.contains("got round 7"), "{text}");
        assert!(text.contains("leader at round 0"), "{text}");
    }
}
