//! The leader: per round, gather M payloads, decode + average through the
//! [`Aggregator`] subsystem (Algorithm 2 line 11: q̂ = 1/M Σ p̂^(m)),
//! broadcast.
//!
//! The aggregation path is selected by [`AggregatorConfig`]: the default
//! sharded pipeline decodes worker payloads thread-parallel and reduces
//! cache-sized shards of the parameter vector in worker-id order, which is
//! bitwise-identical to the sequential baseline kept behind
//! [`crate::config::AggMode::Sequential`] (see `ps/aggregate.rs` for the
//! determinism argument and `tests/integration_aggregate.rs` for the
//! regression proof). [`crate::config::AggMode::Streaming`] replaces the
//! gather-then-aggregate barrier with an event-driven round: payloads are
//! decoded as their frames arrive (off [`ServerEnd::recv_round_streaming`]),
//! so decode work overlaps the wait for stragglers instead of serializing
//! behind the slowest worker — same bits out, less wall-clock per round.
//! Each [`RoundRecord`] splits the leader's round time into `wait_secs`
//! (blocked on the network) and `agg_secs` (decode + reduce) so the A/B
//! benchmarks can show the overlap directly.

use super::aggregate::{Aggregator, Decoder};
use super::policy::build_policy;
use super::RoundRecord;
use crate::comm::{Message, MsgKind, ServerEnd, StreamDirective};
use crate::config::{AggMode, AggregatorConfig, PolicyConfig};
use crate::util::bytes::put_f32_slice;
use crate::util::stats::norm2_sq;
use crate::util::timer::Stopwatch;
use std::collections::VecDeque;

/// Run `rounds` synchronous rounds on `transport` with the default
/// (sharded) aggregation path. Returns per-round records. `dim` is the
/// flat parameter dimension; `on_round` is invoked after each broadcast
/// (leader-side progress/telemetry hook).
pub fn serve_rounds(
    transport: &mut dyn ServerEnd,
    decoder: Decoder,
    dim: usize,
    rounds: u64,
    on_round: impl FnMut(&RoundRecord),
) -> anyhow::Result<Vec<RoundRecord>> {
    serve_rounds_with(transport, decoder, dim, rounds, AggregatorConfig::default(), on_round)
}

/// [`serve_rounds`] with an explicit aggregation configuration — the
/// entry point the cluster driver and the A/B benchmarks use.
pub fn serve_rounds_with(
    transport: &mut dyn ServerEnd,
    decoder: Decoder,
    dim: usize,
    rounds: u64,
    agg_cfg: AggregatorConfig,
    mut on_round: impl FnMut(&RoundRecord),
) -> anyhow::Result<Vec<RoundRecord>> {
    let m = transport.workers();
    anyhow::ensure!(m > 0, "no workers");
    let streaming = agg_cfg.mode == AggMode::Streaming;
    let policy_cfg = agg_cfg.policy;
    anyhow::ensure!(
        policy_cfg == PolicyConfig::Full || streaming,
        "--policy {} requires the streaming engine (--agg streaming)",
        policy_cfg.label()
    );
    // Policy engine (None = the unchanged full-barrier paths below).
    let mut policy = match policy_cfg {
        PolicyConfig::Full => None,
        other => Some(build_policy(other, m)?),
    };
    // Per worker: rounds that closed without this worker's payload and
    // whose late frame has not been drained yet (frames arrive in round
    // order per worker, so a FIFO suffices).
    let mut pending_late: Vec<VecDeque<u64>> = vec![VecDeque::new(); m];
    let mut agg = Aggregator::new(agg_cfg, dim, m);
    let mut records = Vec::with_capacity(rounds as usize);
    for round in 0..rounds {
        let sw = Stopwatch::start();
        let mut bytes_up = 0usize;
        let mut agg_secs = 0.0f64;
        let wait_secs;
        // Inclusion set of a policy-closed round (None ⇒ full barrier,
        // every worker included).
        let mut included: Option<Vec<bool>> = None;
        let avg: &[f32] = if let Some(policy) = policy.as_deref_mut() {
            // Policy-driven round: every arrival is consulted against
            // the RoundPolicy; the round may close before all M payloads
            // land (K-of-M quorum or deadline expiry), skipping the
            // stragglers. Their frames arrive during later rounds and
            // are drained here against the `pending_late` ledger.
            agg.begin_round(round);
            policy.begin_round(round);
            let mut directive = StreamDirective::Wait;
            transport.recv_round_streaming_timed(&mut |msg| {
                if msg.kind == MsgKind::WorkerError {
                    anyhow::bail!(
                        "worker {} failed at round {}: {}",
                        msg.worker,
                        msg.round,
                        String::from_utf8_lossy(&msg.payload)
                    );
                }
                // Every payload frame received during this round costs
                // real uplink bytes — count drained late frames too, so
                // the per-round series sums to the actual wire traffic.
                if msg.kind == MsgKind::Payload {
                    bytes_up += msg.payload.len();
                }
                if msg.kind == MsgKind::Payload && msg.round < round {
                    // Late frame from a round that closed without this
                    // worker: drain it and keep the current directive
                    // (no new arrival, so the policy state is unchanged).
                    let w = msg.worker as usize;
                    anyhow::ensure!(w < m, "worker id {w} out of range (M = {m})");
                    match pending_late[w].front().copied() {
                        Some(r) if r == msg.round => {
                            pending_late[w].pop_front();
                        }
                        _ => anyhow::bail!(
                            "worker {w}: unexpected stale frame for round {} \
                             (leader at round {round}, not a skipped round)",
                            msg.round
                        ),
                    }
                    return Ok(directive);
                }
                let t = Stopwatch::start();
                let res = agg.accept(&msg, &decoder);
                agg_secs += t.elapsed_secs();
                res?;
                directive = policy.on_arrival(agg.arrived_count(), m);
                Ok(directive)
            })?;
            wait_secs = (sw.elapsed_secs() - agg_secs).max(0.0);
            let inc = agg.included().to_vec();
            let t = Stopwatch::start();
            let avg = agg.finish_partial()?;
            agg_secs += t.elapsed_secs();
            included = Some(inc);
            avg
        } else if streaming {
            // Event-driven round: each payload decodes the moment its
            // frame lands, overlapping decode with the wait for the
            // remaining workers; the reduce runs once the barrier is full.
            agg.begin_round(round);
            transport.recv_round_streaming(&mut |msg| {
                bytes_up += msg.payload.len();
                let t = Stopwatch::start();
                let res = agg.accept(&msg, &decoder);
                agg_secs += t.elapsed_secs();
                res
            })?;
            // Time not spent decoding during the gather was spent blocked
            // on arrivals.
            wait_secs = (sw.elapsed_secs() - agg_secs).max(0.0);
            let t = Stopwatch::start();
            let avg = agg.finish_round()?;
            agg_secs += t.elapsed_secs();
            avg
        } else {
            let msgs = transport.recv_round()?;
            wait_secs = sw.elapsed_secs();
            bytes_up = msgs.iter().map(|msg| msg.payload.len()).sum();
            // Decode × M, validate, average (line 11) — sharded or
            // sequential.
            let t = Stopwatch::start();
            let avg = agg.aggregate(round, &msgs, &decoder)?;
            agg_secs = t.elapsed_secs();
            avg
        };
        let avg_payload_norm_sq = norm2_sq(avg);
        // Broadcast q̄ as raw f32 (the downlink is full-precision; the
        // paper quantizes the uplink only — see DESIGN.md FIG4 notes).
        // `Message` owns its payload bytes, so this exact-sized Vec is
        // the one unavoidable per-round allocation on the leader. Under
        // a partial policy the frame additionally carries the inclusion
        // bitmap so skipped workers re-absorb their sent payloads.
        let workers_included;
        let msg = match &included {
            // A policy round that every worker made it into broadcasts
            // the plain frame too: "all included ⇒ byte-identical to the
            // full barrier" is structural, not an accident of which code
            // path ran (deadline rounds with no straggler, kofm:M).
            Some(inc) if !inc.iter().all(|&b| b) => {
                workers_included = inc.iter().filter(|&&b| b).count();
                Message::partial_broadcast(round, inc, avg)
            }
            _ => {
                workers_included = m;
                let mut payload = Vec::with_capacity(4 * dim);
                put_f32_slice(&mut payload, avg);
                Message::broadcast(round, payload)
            }
        };
        transport.broadcast(msg)?;
        if let Some(inc) = &included {
            for (w, &arrived) in inc.iter().enumerate() {
                if !arrived {
                    pending_late[w].push_back(round);
                }
            }
        }
        let rec = RoundRecord {
            round,
            avg_payload_norm_sq,
            bytes_up,
            wall_secs: sw.elapsed_secs(),
            wait_secs,
            agg_secs,
            workers_included,
            workers_skipped: m - workers_included,
            ..Default::default()
        };
        on_round(&rec);
        records.push(rec);
    }
    transport.broadcast(Message::shutdown(rounds))?;
    Ok(records)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::inproc_cluster;
    use crate::comm::{MsgKind, WorkerEnd};
    use crate::compress::{Compressor, Identity};
    use crate::config::AggMode;
    use std::sync::Arc;

    fn identity_decoder() -> Decoder {
        Arc::new(|bytes: &[u8], out: &mut [f32]| Identity.decode_into(bytes, out))
    }

    #[test]
    fn averages_and_broadcasts() {
        let (mut server, workers, _) = inproc_cluster(2);
        let dim = 4;
        let handles: Vec<_> = workers
            .into_iter()
            .enumerate()
            .map(|(i, mut w)| {
                std::thread::spawn(move || {
                    let v = vec![i as f32; 4];
                    let mut wire = Vec::new();
                    Identity.encode(&v, &mut wire);
                    w.send(Message::payload(i as u32, 0, wire)).unwrap();
                    let b = w.recv().unwrap();
                    assert_eq!(b.kind, MsgKind::Broadcast);
                    let avg = Identity.decode(&b.payload, 4).unwrap();
                    assert_eq!(avg, vec![0.5; 4]); // mean of 0s and 1s
                    let s = w.recv().unwrap();
                    assert_eq!(s.kind, MsgKind::Shutdown);
                })
            })
            .collect();
        let recs = serve_rounds(&mut server, identity_decoder(), dim, 1, |_| {}).unwrap();
        assert_eq!(recs.len(), 1);
        assert!(recs[0].bytes_up > 0);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn sequential_flag_produces_the_same_broadcast() {
        for mode in [AggMode::Sequential, AggMode::Sharded, AggMode::Streaming] {
            let (mut server, mut workers, _) = inproc_cluster(2);
            for (i, w) in workers.iter_mut().enumerate() {
                let mut wire = Vec::new();
                Identity.encode(&[1.0 + i as f32, -2.0, 0.5], &mut wire);
                w.send(Message::payload(i as u32, 0, wire)).unwrap();
            }
            let cfg = AggregatorConfig { mode, ..Default::default() };
            let t = std::thread::spawn(move || {
                let mut avgs = Vec::new();
                for w in &mut workers {
                    let b = w.recv().unwrap();
                    assert_eq!(b.kind, MsgKind::Broadcast);
                    avgs.push(Identity.decode(&b.payload, 3).unwrap());
                    let s = w.recv().unwrap();
                    assert_eq!(s.kind, MsgKind::Shutdown);
                }
                avgs
            });
            let recs =
                serve_rounds_with(&mut server, identity_decoder(), 3, 1, cfg, |_| {}).unwrap();
            assert_eq!(recs.len(), 1);
            let avgs = t.join().unwrap();
            assert_eq!(avgs[0], vec![1.5, -2.0, 0.5], "{mode:?}");
            assert_eq!(avgs[0], avgs[1]);
        }
    }

    #[test]
    fn round_records_split_wait_and_agg_time() {
        for cfg in [AggregatorConfig::default(), AggregatorConfig::streaming()] {
            let (mut server, mut workers, _) = inproc_cluster(2);
            for (i, w) in workers.iter_mut().enumerate() {
                let mut wire = Vec::new();
                Identity.encode(&[1.0f32, 2.0], &mut wire);
                w.send(Message::payload(i as u32, 0, wire)).unwrap();
            }
            let t = std::thread::spawn(move || {
                for w in &mut workers {
                    w.recv().unwrap();
                    w.recv().unwrap();
                }
            });
            let recs =
                serve_rounds_with(&mut server, identity_decoder(), 2, 1, cfg, |_| {}).unwrap();
            t.join().unwrap();
            let r = &recs[0];
            assert!(r.wait_secs >= 0.0 && r.agg_secs >= 0.0);
            assert!(r.wall_secs >= r.wait_secs, "wall {} < wait {}", r.wall_secs, r.wait_secs);
            assert!(r.bytes_up > 0);
        }
    }

    #[test]
    fn rejects_non_finite_payloads() {
        let (mut server, mut workers, _) = inproc_cluster(1);
        let v = vec![f32::NAN; 2];
        let mut wire = Vec::new();
        Identity.encode(&v, &mut wire);
        workers[0].send(Message::payload(0, 0, wire)).unwrap();
        let err = serve_rounds(&mut server, identity_decoder(), 2, 1, |_| {}).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
    }

    #[test]
    fn round_skew_reports_worker_id() {
        // Both workers send round 7 while the leader is at round 0: the
        // transport-level mixed-round check passes (rounds agree with each
        // other), so the aggregator's skew check must fire and name the
        // worker.
        let (mut server, mut workers, _) = inproc_cluster(2);
        for (i, w) in workers.iter_mut().enumerate() {
            let mut wire = Vec::new();
            Identity.encode(&[0.0f32], &mut wire);
            w.send(Message::payload(i as u32, 7, wire)).unwrap();
        }
        let err = serve_rounds(&mut server, identity_decoder(), 1, 1, |_| {}).unwrap_err();
        let text = err.to_string();
        assert!(text.contains("round skew"), "{text}");
        assert!(text.contains("worker 0"), "{text}");
        assert!(text.contains("got round 7"), "{text}");
        assert!(text.contains("leader at round 0"), "{text}");
    }
}
