//! Parameter-server runtime (paper Fig. 1): the leader (server) and the M
//! worker loops, wired over any [`crate::comm`] transport, driving any
//! [`crate::algo::WorkerAlgo`] against any [`crate::grad::GradientSource`].
//!
//! One synchronous round:
//!
//! ```text
//! worker m: produce()  ──payload──▶  server: decode × M, average
//! worker m: apply(q̄)   ◀─broadcast──          broadcast(q̄)
//! ```
//!
//! The leader owns round progression, byte/time accounting, evaluation
//! scheduling and shutdown; workers are stateless loops around their
//! algorithm object.

mod aggregate;
mod cluster;
mod policy;
mod server;
mod worker;

pub use aggregate::{Aggregator, Decoder, ReduceClose, ReduceTiming};
pub use cluster::{run_cluster, ClusterConfig, EvalEvent, TrainReport};
pub use policy::{build_policy, RoundPolicy};
pub use server::{
    is_snapshot_round, serve_rounds, serve_rounds_session, serve_rounds_with, ServeSession,
};
pub use worker::{worker_loop, worker_loop_resumable, SnapHook};

/// Per-round record the leader accumulates (averaged across workers).
#[derive(Debug, Clone, Default)]
pub struct RoundRecord {
    pub round: u64,
    /// Mean over workers of ‖F(w_{t−½}; ξ)‖² — Theorem 3's quantity is the
    /// norm² of the mean; we track both.
    pub mean_grad_norm_sq: f32,
    /// ‖(1/M)Σ_m F^(m)‖² (computed on the averaged payload, η-scaled for
    /// DQGAN; see `exp/thm3.rs` for the exact Theorem-3 accounting).
    pub avg_payload_norm_sq: f32,
    /// Mean over workers of ‖e_t‖² (Lemma 1).
    pub mean_err_norm_sq: f32,
    /// Uplink payload bytes this round (sum over workers).
    pub bytes_up: usize,
    /// Wall-clock of the round as seen by the leader.
    pub wall_secs: f64,
    /// Leader time spent blocked waiting on worker payloads (the network/
    /// straggler component of `wall_secs`). Under the streaming engine,
    /// decode work overlaps this wait, so `wait_secs + agg_secs` shrinks
    /// relative to the barrier paths on skewed arrivals.
    pub wait_secs: f64,
    /// Leader time spent in decode + reduce (the compute component):
    /// kept as the sum `decode_secs + reduce_secs` now that the split is
    /// recorded, so existing consumers of the column read unchanged.
    pub agg_secs: f64,
    /// Payload-decode component of `agg_secs` (frame bytes → dense f32
    /// slots, measured inside the gather).
    pub decode_secs: f64,
    /// Reduce component of `agg_secs`: the windowed folds that ran during
    /// the gather plus the close-time tail fold + scale. When the close
    /// was offloaded (`--reduce windowed` on the pipelined path) the
    /// close part runs on a pool task's own clock and overlaps leader
    /// wall time instead of adding to it — which is exactly the overlap
    /// the split exists to make visible.
    pub reduce_secs: f64,
    /// FNV-style 64-bit checksum of the broadcast values' f32 bit
    /// patterns — the per-round fingerprint the CI drift check diffs
    /// between `--reduce windowed` and `--reduce barrier` runs (equal
    /// checksums ⇔ bit-equal broadcasts, modulo 64-bit collisions).
    pub broadcast_fnv: u64,
    /// Seconds of this round's gather that ran while the **previous**
    /// round's broadcast was still in flight on the writer threads —
    /// the gather/broadcast overlap the pipelined engine
    /// (`--agg pipelined`) exists to create. 0 under every synchronous
    /// broadcast mode (the previous broadcast completed before the round
    /// started) and for round 0.
    pub overlap_secs: f64,
    /// Workers whose payloads entered this round's average (= M under
    /// the full barrier; < M when a `--policy kofm`/`deadline` round
    /// closed early).
    pub workers_included: usize,
    /// Workers the round-completion policy skipped this round (their
    /// payloads fold back into local error memory via the broadcast's
    /// inclusion bitmap). Evicted workers count here too — an evicted
    /// slot is a permanently skipped one until its owner rejoins.
    pub workers_skipped: usize,
    /// Workers evicted from the membership as of this round's close
    /// (`--on-worker-loss evict`): presumed-dead slots excluded from
    /// gathers, quorums and the ack ledger until they rejoin. Always 0
    /// under the default abort mode.
    pub workers_evicted: usize,
    /// Mean losses (when the model reports them).
    pub loss_g: Option<f32>,
    pub loss_d: Option<f32>,
    /// Peak live OS threads in the leader process observed during this
    /// round (`/proc/self/task`; `None` on platforms without procfs).
    /// The telemetry behind the readiness-loop transport's O(1)-threads
    /// claim: flat in M under `--transport evloop`, O(M) under
    /// `--transport threads`.
    pub threads_peak: Option<usize>,
    /// Downlink bytes broadcast this round, when the transport exposes a
    /// byte counter (difference of `ByteCounter::down_total` snapshots
    /// taken around the round). `None` on counterless transports. Under
    /// `--agg pipelined` the broadcast issued this round drains on the
    /// writer threads, so the bytes land in the round whose gather
    /// overlapped the send — totals across a run are exact, per-round
    /// attribution is flow-aligned rather than issue-aligned.
    pub bytes_down: Option<u64>,
}
