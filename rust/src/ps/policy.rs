//! Round-completion policy engine: after each accepted arrival the
//! streaming leader asks its [`RoundPolicy`] whether the round closes
//! now, keeps waiting, or keeps waiting with a deadline armed.
//!
//! Why skipping a worker is sound: the error-feedback machinery (paper
//! Lemma 1; EF-SGD, arXiv:1806.08054) already absorbs arbitrary
//! per-round compression residue into the worker-local error memory
//! `e`. A skipped worker is told so by the broadcast's inclusion bitmap
//! and folds its **entire** sent payload back (`e ← e + p̂ = p`,
//! exactly as if the δ-approximate compressor had returned 0 — a legal
//! output of a 0-approximate round that the next round's transmission
//! compensates). The leader therefore never biases the update by
//! closing early; it only trades one round of staleness for the
//! straggler's wall-clock, which is where the linear-speedup claim
//! (Theorem 3) is won or lost on real clusters.
//!
//! Three policies ship behind `--policy`:
//!
//! | policy          | closes when…                                        |
//! |-----------------|-----------------------------------------------------|
//! | `full`          | all M payloads accepted (today's barrier, default)  |
//! | `kofm:K`        | K payloads accepted                                 |
//! | `deadline:MS,K` | all M accepted, or MS ms after the K-th acceptance  |
//!
//! The engine runs inside `ps/server.rs`'s policy-driven round loop on
//! top of [`crate::comm::ServerEnd::recv_round_streaming_timed`]; the
//! decisions are expressed directly as [`StreamDirective`]s so the
//! transport can bound its blocking waits.
//!
//! Partial closes compose with the windowed incremental reduce
//! (`--reduce windowed`, `ps/aggregate.rs`) by construction: the window
//! only ever folds the **contiguous arrived** worker-id prefix, so a
//! worker this policy skips can never have been folded early — the
//! close-time subset fold sees exactly the included slots, bitwise
//! identical to the barrier-reduce partial close (property-tested in
//! `tests/integration_aggregate.rs`).

use crate::comm::StreamDirective;
use crate::config::PolicyConfig;
use std::time::{Duration, Instant};

/// Leader-side round-completion policy, consulted once per accepted
/// arrival. Implementations are stateful per round (deadlines arm once)
/// and are reset by [`RoundPolicy::begin_round`].
pub trait RoundPolicy: Send {
    /// A new round opened; reset any per-round state.
    fn begin_round(&mut self, round: u64);
    /// The `arrived`-th payload (1-based) of `workers` total was just
    /// accepted: close now, keep waiting, or keep waiting with a
    /// deadline armed. Under elastic membership (`--on-worker-loss
    /// evict`) the leader passes the **live** worker count, so barrier
    /// and deadline closes are judged against the survivors.
    fn on_arrival(&mut self, arrived: usize, workers: usize) -> StreamDirective;
    /// The smallest live membership under which a round can still close
    /// (quorum feasibility): a hard quorum for `kofm:K`, otherwise 1 —
    /// the full-barrier and deadline policies close over whatever
    /// membership remains. The leader fails the run the moment evictions
    /// push the live count below this, instead of hanging in a gather
    /// that can never complete.
    fn min_quorum(&self) -> usize {
        1
    }
}

/// Barrier semantics: close only when every worker has arrived.
struct FullPolicy;

impl RoundPolicy for FullPolicy {
    fn begin_round(&mut self, _round: u64) {}

    fn on_arrival(&mut self, arrived: usize, workers: usize) -> StreamDirective {
        if arrived >= workers {
            StreamDirective::Close
        } else {
            StreamDirective::Wait
        }
    }
}

/// Close as soon as `k` payloads have been accepted.
struct KofMPolicy {
    k: usize,
}

impl RoundPolicy for KofMPolicy {
    fn begin_round(&mut self, _round: u64) {}

    fn on_arrival(&mut self, arrived: usize, _workers: usize) -> StreamDirective {
        if arrived >= self.k {
            StreamDirective::Close
        } else {
            StreamDirective::Wait
        }
    }

    fn min_quorum(&self) -> usize {
        self.k
    }
}

/// Grace-period policy: arm a timer at the `arm_at`-th acceptance; the
/// round closes at M arrivals or when the timer expires (the transport
/// reports the expiry as `StreamOutcome::DeadlineExpired`).
struct DeadlinePolicy {
    grace: Duration,
    arm_at: usize,
    armed: Option<Instant>,
}

impl RoundPolicy for DeadlinePolicy {
    fn begin_round(&mut self, _round: u64) {
        self.armed = None;
    }

    fn on_arrival(&mut self, arrived: usize, workers: usize) -> StreamDirective {
        if arrived >= workers {
            return StreamDirective::Close;
        }
        if arrived >= self.arm_at {
            // Arm exactly once: later arrivals inside the grace window
            // must not push the deadline out.
            let dl = *self.armed.get_or_insert_with(|| Instant::now() + self.grace);
            StreamDirective::WaitUntil(dl)
        } else {
            StreamDirective::Wait
        }
    }
}

/// Build the runtime policy for a cluster of `workers`, validating the
/// configuration against M (a quorum larger than the cluster can never
/// be reached and would hang every round).
pub fn build_policy(cfg: PolicyConfig, workers: usize) -> anyhow::Result<Box<dyn RoundPolicy>> {
    anyhow::ensure!(workers > 0, "no workers");
    match cfg {
        PolicyConfig::Full => Ok(Box::new(FullPolicy)),
        PolicyConfig::KofM { k } => {
            anyhow::ensure!(
                (1..=workers).contains(&k),
                "kofm:{k} needs 1 <= K <= M (M = {workers})"
            );
            Ok(Box::new(KofMPolicy { k }))
        }
        PolicyConfig::Deadline { grace_ms, arm_at } => {
            anyhow::ensure!(
                (1..=workers).contains(&arm_at),
                "deadline arm count {arm_at} needs 1 <= K <= M (M = {workers})"
            );
            Ok(Box::new(DeadlinePolicy {
                grace: Duration::from_millis(grace_ms),
                arm_at,
                armed: None,
            }))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_policy_closes_only_at_m() {
        let mut p = build_policy(PolicyConfig::Full, 3).unwrap();
        p.begin_round(0);
        assert_eq!(p.on_arrival(1, 3), StreamDirective::Wait);
        assert_eq!(p.on_arrival(2, 3), StreamDirective::Wait);
        assert_eq!(p.on_arrival(3, 3), StreamDirective::Close);
    }

    #[test]
    fn kofm_closes_at_the_quorum() {
        let mut p = build_policy(PolicyConfig::KofM { k: 2 }, 4).unwrap();
        p.begin_round(0);
        assert_eq!(p.on_arrival(1, 4), StreamDirective::Wait);
        assert_eq!(p.on_arrival(2, 4), StreamDirective::Close);
        // kofm:M degenerates to the full barrier.
        let mut p = build_policy(PolicyConfig::KofM { k: 4 }, 4).unwrap();
        p.begin_round(0);
        assert_eq!(p.on_arrival(3, 4), StreamDirective::Wait);
        assert_eq!(p.on_arrival(4, 4), StreamDirective::Close);
    }

    #[test]
    fn deadline_arms_once_per_round_and_closes_at_m() {
        let cfg = PolicyConfig::Deadline { grace_ms: 60_000, arm_at: 2 };
        let mut p = build_policy(cfg, 4).unwrap();
        p.begin_round(0);
        assert_eq!(p.on_arrival(1, 4), StreamDirective::Wait);
        let dl1 = match p.on_arrival(2, 4) {
            StreamDirective::WaitUntil(dl) => dl,
            other => panic!("expected WaitUntil, got {other:?}"),
        };
        // Subsequent arrivals must not extend the armed deadline.
        match p.on_arrival(3, 4) {
            StreamDirective::WaitUntil(dl2) => assert_eq!(dl1, dl2),
            other => panic!("expected WaitUntil, got {other:?}"),
        }
        assert_eq!(p.on_arrival(4, 4), StreamDirective::Close);
        // A new round re-arms from scratch.
        p.begin_round(1);
        assert_eq!(p.on_arrival(1, 4), StreamDirective::Wait);
        match p.on_arrival(2, 4) {
            StreamDirective::WaitUntil(dl) => assert!(dl >= dl1),
            other => panic!("expected WaitUntil, got {other:?}"),
        }
    }

    #[test]
    fn min_quorum_is_hard_only_for_kofm() {
        assert_eq!(build_policy(PolicyConfig::KofM { k: 3 }, 4).unwrap().min_quorum(), 3);
        assert_eq!(build_policy(PolicyConfig::Full, 4).unwrap().min_quorum(), 1);
        let cfg = PolicyConfig::Deadline { grace_ms: 1, arm_at: 2 };
        assert_eq!(build_policy(cfg, 4).unwrap().min_quorum(), 1);
    }

    #[test]
    fn build_rejects_unreachable_quorums() {
        assert!(build_policy(PolicyConfig::KofM { k: 5 }, 4).is_err());
        assert!(build_policy(PolicyConfig::KofM { k: 0 }, 4).is_err());
        assert!(build_policy(PolicyConfig::Deadline { grace_ms: 1, arm_at: 9 }, 4).is_err());
        assert!(build_policy(PolicyConfig::Full, 0).is_err());
        assert!(build_policy(PolicyConfig::KofM { k: 4 }, 4).is_ok());
    }
}
