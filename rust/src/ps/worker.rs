//! The worker loop: drive a [`WorkerAlgo`] against a [`GradientSource`]
//! over a transport for a known number of rounds.
//!
//! The round count is distributed to every node up front (as in the
//! paper's Algorithm 2, "for t = 1..T"), which keeps the protocol strictly
//! two-phase and hang-free: per round exactly one Payload up and one
//! Broadcast down, then one trailing Shutdown frame.
//!
//! Under a partial round-completion policy (`--policy kofm:K` /
//! `deadline:MS`) the downlink frame may be a
//! [`MsgKind::PartialBroadcast`]: its inclusion bitmap tells this worker
//! whether the leader's average contains its payload. A skipped worker
//! still applies the broadcast (parameters stay in lockstep across the
//! cluster) and additionally folds its entire sent payload back into
//! local error memory ([`WorkerAlgo::absorb_skipped`]), so the skipped
//! contribution is delayed — never lost or double-counted.

use crate::algo::{RoundStats, WorkerAlgo};
use crate::comm::{bitmap_included, read_inclusion_bitmap, Message, MsgKind, WorkerEnd};
use crate::grad::GradientSource;
use crate::util::bytes::Reader;
use crate::util::rng::Pcg32;

/// Per-worker result summary.
#[derive(Debug, Clone)]
pub struct WorkerSummary {
    /// Rounds whose broadcast this worker applied — fewer than requested
    /// when the server shuts the run down early. Under a partial policy's
    /// teardown this can include trailing rounds the leader closed
    /// without this worker's payload (applied to stay in lockstep), so it
    /// counts parameter updates, not gradient contributions.
    pub rounds: u64,
    /// Final parameter vector (identical across workers by construction).
    pub final_params: Vec<f32>,
    /// Round stats history (empty unless `keep_stats`).
    pub stats: Vec<RoundStats>,
}

/// Hook invoked on a worker after each `apply` with (round, params, stats).
pub type EvalHook = Box<dyn FnMut(u64, &[f32], &RoundStats) + Send>;

/// Snapshot hook: invoked at the round boundary — after the round's
/// broadcast is applied (and acked/evaled), before the next `produce` —
/// with the algorithm and RNG exactly as the next round will see them.
/// That boundary is the one point where a worker's state is closed
/// under restore: scratch buffers are dead, error memory is post-absorb,
/// and the RNG sits at the position round+1 draws from. The hook decides
/// its own cadence (checking `is_snapshot_round` internally) and
/// typically writes `ckpt::encode_worker_state` into the run's store.
pub type SnapHook = Box<dyn FnMut(u64, &dyn WorkerAlgo, &Pcg32) -> anyhow::Result<()> + Send>;

/// Parse and apply one (possibly partial) broadcast frame: when the
/// inclusion bitmap says the leader skipped this worker, re-absorb the
/// round's sent payload into error memory after applying the average.
/// `allow_absorb` is false for trailing broadcasts of rounds this worker
/// never produced a payload for (teardown drain) — there is nothing of
/// ours to fold back there, and re-absorbing the previous round's buffer
/// again would double-count it. Returns whether the skipped-round absorb
/// path ran (feeds the `worker.absorbed_skips` obs counter).
pub(super) fn apply_broadcast(
    algo: &mut dyn WorkerAlgo,
    dim: usize,
    id: u32,
    msg: &Message,
    allow_absorb: bool,
) -> anyhow::Result<bool> {
    let mut r = Reader::new(&msg.payload);
    let included = match msg.kind {
        MsgKind::PartialBroadcast => {
            let bitmap = read_inclusion_bitmap(&mut r)?;
            bitmap_included(bitmap, id)
        }
        _ => true,
    };
    let avg = r.f32_vec(dim)?;
    algo.apply(&avg);
    let absorbed = !included && allow_absorb;
    if absorbed {
        algo.absorb_skipped();
    }
    Ok(absorbed)
}

/// [`apply_broadcast`] under the worker-side observability hooks: the
/// apply is spanned on this worker's trace lane and its latency plus the
/// absorbed flag feed `worker.apply_ns` / `worker.absorbed_skips` and
/// the `--worker-csv` row for (worker, round). With obs off this is the
/// bare apply plus two relaxed loads.
fn apply_broadcast_observed(
    algo: &mut dyn WorkerAlgo,
    dim: usize,
    id: u32,
    msg: &Message,
    allow_absorb: bool,
) -> anyhow::Result<()> {
    let t0 = crate::obs::maybe_now();
    let span = crate::obs::span("apply", crate::obs::worker_tid(id as usize), msg.round);
    let absorbed = apply_broadcast(algo, dim, id, msg, allow_absorb)?;
    drop(span);
    if let Some(t0) = t0 {
        crate::obs::worker_apply(id as usize, msg.round, t0.elapsed().as_nanos() as u64, absorbed);
    }
    Ok(())
}

/// Run at most `rounds` rounds, then consume the trailing Shutdown.
///
/// On a local error the worker sends a `WorkerError` frame before
/// returning, so the server's barrier fails fast instead of hanging
/// (failure-injection tests exercise this).
#[allow(clippy::too_many_arguments)]
pub fn worker_loop(
    transport: &mut dyn WorkerEnd,
    algo: &mut dyn WorkerAlgo,
    src: &mut dyn GradientSource,
    batch: usize,
    rounds: u64,
    rng: &mut Pcg32,
    keep_stats: bool,
    eval: Option<EvalHook>,
) -> anyhow::Result<WorkerSummary> {
    worker_loop_resumable(transport, algo, src, batch, 0, rounds, rng, keep_stats, eval, None)
}

/// [`worker_loop`] for resumable sessions: starts at `start_round`
/// (the algorithm, RNG, and data cursor must already be positioned
/// there — restored from a snapshot via `ckpt::decode_worker_state`)
/// and invokes `snap` at every completed round boundary so the worker's
/// state can be re-snapshotted under the run's checkpoint cadence. The
/// teardown-drain path (leader died or closed the run early) applies
/// trailing broadcasts but takes no snapshots: a manifest can never
/// legitimately point at a round the leader did not live to record.
#[allow(clippy::too_many_arguments)]
pub fn worker_loop_resumable(
    transport: &mut dyn WorkerEnd,
    algo: &mut dyn WorkerAlgo,
    src: &mut dyn GradientSource,
    batch: usize,
    start_round: u64,
    rounds: u64,
    rng: &mut Pcg32,
    keep_stats: bool,
    mut eval: Option<EvalHook>,
    mut snap: Option<SnapHook>,
) -> anyhow::Result<WorkerSummary> {
    let dim = algo.dim();
    let id = transport.id();
    let mut stats_hist = Vec::new();
    // Rounds actually completed — reported instead of the requested
    // count when the server shuts down early.
    let mut completed = 0u64;
    for round in start_round..rounds {
        // Phase 1: produce and push. `produce` returns views into the
        // worker's reused buffers; the one owned copy happens here, at the
        // transport boundary, because `Message` owns its payload bytes.
        let produce_span = crate::obs::span("produce", crate::obs::worker_tid(id as usize), round);
        let (payload, stats) = match algo.produce(src, batch, rng) {
            Ok(p) => (p.wire.to_vec(), p.stats),
            Err(e) => {
                let _ = transport.send(Message::worker_error(id, round, &format!("{e:#}")));
                return Err(e);
            }
        };
        drop(produce_span);
        crate::obs::worker_produce(id as usize, round, stats.err_norm_sq);
        if let Err(send_err) = transport.send(Message::payload(id, round, payload)) {
            // Partial-policy teardown race: a leader running `--policy
            // kofm`/`deadline` may have closed its remaining rounds
            // without this worker's frames and already torn the
            // transport down. The queued downlink frames are still
            // readable and arrive in round order — apply every trailing
            // broadcast (keeps parameters in lockstep with the
            // survivors; only the current round's payload exists to
            // re-absorb) and exit cleanly on Shutdown; anything else
            // surfaces the send error.
            let mut clean = false;
            loop {
                let msg = match transport.recv() {
                    Ok(msg) => msg,
                    // Transport died underneath us (leader gone, or this
                    // worker evicted under `--on-worker-loss evict` and
                    // its socket closed): same contract as the phase-2
                    // recv below — no Shutdown is coming, exit cleanly
                    // with whatever broadcasts drained so far.
                    Err(_) => {
                        clean = true;
                        break;
                    }
                };
                match msg.kind {
                    MsgKind::Shutdown => {
                        clean = true;
                        break;
                    }
                    MsgKind::Broadcast | MsgKind::PartialBroadcast if msg.round >= round => {
                        apply_broadcast_observed(algo, dim, id, &msg, msg.round == round)?;
                        // Ack the APPLY (ack-based transports only; no-op
                        // elsewhere). Errors are ignored: the leader that
                        // would consume this ack is already tearing down.
                        let _ = transport.ack(msg.round);
                        completed = completed.max(msg.round + 1);
                        if msg.round == round {
                            if let Some(cb) = eval.as_deref_mut() {
                                cb(round, algo.params(), &stats);
                            }
                            if keep_stats {
                                stats_hist.push(stats.clone());
                            }
                        }
                    }
                    _ => break,
                }
            }
            if !clean {
                return Err(send_err);
            }
            break;
        }
        // Phase 2: await broadcast, apply.
        let recv_span = crate::obs::span("recv", crate::obs::worker_tid(id as usize), round);
        let msg = match transport.recv() {
            Ok(msg) => msg,
            // An evicted worker's downlink dies mid-run (`--on-worker-loss
            // evict`: the leader closed this socket / muted this channel
            // and the run continues without us) — no Shutdown frame is
            // coming, so waiting for one would hang forever. The payload
            // already sent this round is skipped leader-side, never
            // folded, so exiting here leaves the survivors' state
            // untouched. Exit cleanly with the rounds completed so far.
            Err(_) => break,
        };
        drop(recv_span);
        match msg.kind {
            MsgKind::Broadcast | MsgKind::PartialBroadcast => {
                anyhow::ensure!(msg.round == round, "broadcast round skew");
                apply_broadcast_observed(algo, dim, id, &msg, true)?;
                // Ack the APPLY — this is what `--pipeline-depth` bounds
                // on ack-based transports (Lemma-1 staleness), and a
                // default no-op on the threaded ones. Errors are ignored:
                // they only occur when the leader is already gone, where
                // flow control is moot.
                let _ack_span = crate::obs::span("ack", crate::obs::worker_tid(id as usize), round);
                let _ = transport.ack(round);
            }
            MsgKind::Shutdown => break, // server aborted early
            other => anyhow::bail!("unexpected message kind {other:?}"),
        }
        completed = round + 1;
        if let Some(cb) = eval.as_deref_mut() {
            cb(round, algo.params(), &stats);
        }
        if keep_stats {
            stats_hist.push(stats);
        }
        // Round boundary: the one place worker state is closed under
        // restore (see [`SnapHook`]). A snapshot failure is this
        // worker's failure — tell the leader before bailing so its next
        // gather fails fast instead of hanging on our missing payload.
        if let Some(cb) = snap.as_deref_mut() {
            if let Err(e) = cb(round, &*algo, &*rng) {
                let _ = transport.send(Message::worker_error(
                    id,
                    round,
                    &format!("state snapshot at round {round} failed: {e:#}"),
                ));
                return Err(e);
            }
        }
    }
    // Drain the trailing Shutdown so the transport closes cleanly.
    match transport.recv() {
        Ok(msg) if msg.kind == MsgKind::Shutdown => {}
        Ok(other) => anyhow::bail!("expected shutdown, got {:?}", other.kind),
        Err(_) => {} // server already gone — fine at teardown
    }
    Ok(WorkerSummary {
        rounds: completed,
        final_params: algo.params().to_vec(),
        stats: stats_hist,
    })
}
