//! The worker loop: drive a [`WorkerAlgo`] against a [`GradientSource`]
//! over a transport for a known number of rounds.
//!
//! The round count is distributed to every node up front (as in the
//! paper's Algorithm 2, "for t = 1..T"), which keeps the protocol strictly
//! two-phase and hang-free: per round exactly one Payload up and one
//! Broadcast down, then one trailing Shutdown frame.

use crate::algo::{RoundStats, WorkerAlgo};
use crate::comm::{Message, MsgKind, WorkerEnd};
use crate::grad::GradientSource;
use crate::util::bytes::Reader;
use crate::util::rng::Pcg32;

/// Per-worker result summary.
#[derive(Debug, Clone)]
pub struct WorkerSummary {
    pub rounds: u64,
    /// Final parameter vector (identical across workers by construction).
    pub final_params: Vec<f32>,
    /// Round stats history (empty unless `keep_stats`).
    pub stats: Vec<RoundStats>,
}

/// Hook invoked on a worker after each `apply` with (round, params, stats).
pub type EvalHook = Box<dyn FnMut(u64, &[f32], &RoundStats) + Send>;

/// Run exactly `rounds` rounds, then consume the trailing Shutdown.
///
/// On a local error the worker sends a `WorkerError` frame before
/// returning, so the server's barrier fails fast instead of hanging
/// (failure-injection tests exercise this).
#[allow(clippy::too_many_arguments)]
pub fn worker_loop(
    transport: &mut dyn WorkerEnd,
    algo: &mut dyn WorkerAlgo,
    src: &mut dyn GradientSource,
    batch: usize,
    rounds: u64,
    rng: &mut Pcg32,
    keep_stats: bool,
    mut eval: Option<EvalHook>,
) -> anyhow::Result<WorkerSummary> {
    let dim = algo.dim();
    let id = transport.id();
    let mut stats_hist = Vec::new();
    for round in 0..rounds {
        // Phase 1: produce and push. `produce` returns views into the
        // worker's reused buffers; the one owned copy happens here, at the
        // transport boundary, because `Message` owns its payload bytes.
        let (payload, stats) = match algo.produce(src, batch, rng) {
            Ok(p) => (p.wire.to_vec(), p.stats),
            Err(e) => {
                let _ = transport.send(Message::worker_error(id, round, &format!("{e:#}")));
                return Err(e);
            }
        };
        transport.send(Message::payload(id, round, payload))?;
        // Phase 2: await broadcast, apply.
        let msg = transport.recv()?;
        match msg.kind {
            MsgKind::Broadcast => {
                anyhow::ensure!(msg.round == round, "broadcast round skew");
                let mut r = Reader::new(&msg.payload);
                let avg = r.f32_vec(dim)?;
                algo.apply(&avg);
            }
            MsgKind::Shutdown => break, // server aborted early
            other => anyhow::bail!("unexpected message kind {other:?}"),
        }
        if let Some(cb) = eval.as_deref_mut() {
            cb(round, algo.params(), &stats);
        }
        if keep_stats {
            stats_hist.push(stats);
        }
    }
    // Drain the trailing Shutdown so the transport closes cleanly.
    match transport.recv() {
        Ok(msg) if msg.kind == MsgKind::Shutdown => {}
        Ok(other) => anyhow::bail!("expected shutdown, got {:?}", other.kind),
        Err(_) => {} // server already gone — fine at teardown
    }
    Ok(WorkerSummary { rounds, final_params: algo.params().to_vec(), stats: stats_hist })
}
