//! Sharded, thread-parallel, allocation-free leader aggregation
//! (Algorithm 2 line 11: q̄ = 1/M Σ_m p̂^(m)).
//!
//! The seed leader decoded and averaged the M worker payloads strictly
//! sequentially, materializing a fresh `Vec<f32>` per worker per round —
//! O(M·d) allocation traffic and a single core doing all the work. This
//! subsystem replaces that loop with a two-stage pipeline over the
//! existing [`crate::util::threadpool::ThreadPool`]:
//!
//! 1. **Decode stage** (parallel over workers): worker m's wire payload is
//!    decoded *into* a preallocated per-worker dense buffer
//!    ([`crate::compress::Compressor::decode_into`] — no intermediate
//!    `Vec`), and validated (finiteness, round id) in the same pass.
//! 2. **Reduce stage** (parallel over shards): the flat `dim` vector is
//!    split into cache-sized shards; each shard task owns a disjoint
//!    `&mut` range of the output and accumulates the M decoded buffers
//!    **in worker-id order** before scaling by 1/M.
//!
//! A third mode, [`AggMode::Streaming`], drives the same buffers through
//! an **event-driven round**: [`Aggregator::begin_round`] opens the
//! barrier, [`Aggregator::accept`] decodes each payload the moment its
//! frame arrives (any arrival order — decode overlaps the wait for
//! stragglers), and [`Aggregator::finish_round`] runs the shard reduce
//! once all M inputs are in. See `ps/server.rs` for the leader loop that
//! feeds it from [`crate::comm::ServerEnd::recv_round_streaming`].
//!
//! [`AggMode::Pipelined`] extends the streaming engine with **double
//! round-state**: the per-worker decode buffers live in rotating *slot
//! banks* (two of them at `--pipeline-depth` ≥ 2), each independently
//! `begin_round`-able. [`Aggregator::accept`] routes every frame to the
//! open bank whose round id matches, so frames for round t+1 can decode
//! on arrival while round t's bank is still referenced — which is what
//! lets the pipelined leader loop in `ps/server.rs` queue round t's
//! broadcast onto the transport's writer threads and immediately open
//! round t+1 instead of holding the whole cluster to one round in
//! flight. Closing (`finish_round` / [`Aggregator::finish_partial`])
//! always applies to the *oldest* open bank, preserving round order.
//!
//! ## Windowed incremental reduce (`--reduce windowed`, the default)
//!
//! The streaming-engine rounds no longer have to run the whole reduce
//! *after* the last payload lands. Each bank tracks, per reduction
//! shard, how many workers are already folded into a per-bank shard
//! accumulator, plus the length of the **contiguous lowest-worker-id
//! prefix** of arrived+decoded slots. Every [`Aggregator::accept`] that
//! extends that prefix folds the newly covered slots into the
//! accumulators — strictly in worker-id order per shard, on the pool —
//! so by close time only the out-of-order tail (empty when arrivals were
//! in order) plus the final 1/M scale remain. Only the contiguous prefix
//! is ever folded early, which is what makes partial (K-of-M/deadline)
//! closes safe: a slot that never arrived can never have been folded, so
//! the skipped-worker filter of [`Aggregator::finish_partial`] still
//! holds exactly.
//!
//! On the pipelined path the close-time tail fold + scale is additionally
//! **offloaded**: [`Aggregator::close_round`] submits it to the pool as a
//! detached task (the rotating banks isolate its inputs — the buffers are
//! moved into the task and moved back at join), and
//! [`Aggregator::join_reduce`] joins it through a completion latch. The
//! leader uses the window in between to prepare the broadcast frame (see
//! `ps/server.rs`), so the residual close work runs off the leader
//! thread instead of serializing in front of the broadcast. The offload
//! is gated to small residues (at most one unfolded worker): the
//! detached task folds sequentially, so a short-prefix close — worker 0
//! arriving last leaves the whole fold in the tail — takes the inline
//! shard-parallel path instead.
//!
//! ## Determinism contract
//!
//! The reduce stage adds workers in exactly the order the sequential path
//! does (`((0 + v⁰ᵢ) + v¹ᵢ) + … ) · (1/M)` per element), so the sharded
//! result is **bitwise identical** to [`AggMode::Sequential`] — float
//! addition is non-associative, which is precisely why the design shards
//! over *dimension* rather than accumulating per-thread partial sums over
//! worker subsets (those would regroup the additions and break the A/B
//! guarantee the regression tests enforce). The streaming mode decodes in
//! arrival order but each payload lands in its own per-worker slot, and
//! the reduce only ever reads the slots in worker-id order — so arrival
//! order cannot affect a single bit of the output. The windowed schedule
//! changes *when* additions run, never their per-element order or
//! grouping: prefix folds add workers 0..p in id order, the close fold
//! continues with the remaining (included) ids, and the scale multiplies
//! the same sums by the same 1/M — so `--reduce windowed|barrier` is
//! bitwise-invisible too, over full and partial closes alike (enforced by
//! `tests/integration_aggregate.rs`).
//!
//! ## Buffer reuse
//!
//! All round state — the M decode buffers and the averaged output — is
//! allocated once in [`Aggregator::new`] and reused every round. The only
//! per-round heap traffic left is bookkeeping-sized: the shard-reference
//! `Vec` handed to the pool (≤ `num_shards` fat pointers) and the boxed
//! per-chunk jobs inside `parallel_for_mut` — nothing proportional to
//! `M·d`. Jobs run on the pool's persistent workers; no threads are
//! spawned per round. Rounds whose total decode work is tiny (small `d` —
//! the bilinear/synthetic sweeps) skip dispatch entirely and run the
//! sequential body, which is output-identical by construction.

use crate::comm::Message;
use crate::config::{AggMode, AggregatorConfig, ReduceMode};
use crate::tensor::ops;
use crate::util::threadpool::{TaskDone, ThreadPool};
use crate::util::timer::Stopwatch;
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

/// Server-side payload decoder: decode `bytes` into the dense `out`
/// buffer (length = flat parameter dimension). Algorithm-specific; see
/// [`crate::algo::AlgoKind::decoder`].
pub type Decoder = Arc<dyn Fn(&[u8], &mut [f32]) -> anyhow::Result<()> + Send + Sync>;

/// Per-worker round state: the reused decode buffer and the outcome of
/// this round's decode+validate pass (checked after the parallel stage so
/// the first failure *by worker id* is reported, deterministically).
struct WorkerSlot {
    buf: Vec<f32>,
    err: Option<anyhow::Error>,
}

/// One round's worth of slot state: the M decode buffers plus the
/// arrival bookkeeping of a single streaming round. The pipelined engine
/// rotates between two of these so a new round's decodes never touch the
/// bank a still-in-flight round occupies; every other mode owns exactly
/// one.
struct RoundBank {
    /// Round id this bank is (or was last) assigned to.
    round: u64,
    /// Whether the bank is currently accepting arrivals.
    open: bool,
    slots: Vec<WorkerSlot>,
    arrived: Vec<bool>,
    arrived_count: usize,
    /// Windowed-reduce accumulator: the running per-element sum of the
    /// folded worker prefix (zeroed lazily by each shard's first fold).
    acc: Vec<f32>,
    /// Per reduction shard: the lowest worker-id prefix already folded
    /// into that shard of `acc`.
    folded: Vec<usize>,
    /// Length of the contiguous arrived prefix (workers `0..prefix` have
    /// all arrived+decoded) — the fold window's high-water mark.
    prefix: usize,
    /// Leader seconds spent in incremental window folds this round.
    fold_secs: f64,
    /// Buffers currently moved into a detached close-time reduce task
    /// (the bank must not be reopened until [`Aggregator::join_reduce`]
    /// moves them back).
    detached: bool,
}

impl RoundBank {
    fn new(dim: usize, workers: usize, shards: usize, windowed: bool) -> Self {
        Self {
            round: 0,
            open: false,
            slots: (0..workers).map(|_| WorkerSlot { buf: vec![0.0; dim], err: None }).collect(),
            arrived: vec![false; workers],
            arrived_count: 0,
            // The dim-sized accumulator only exists for configurations
            // that can actually fold into it — under `--reduce barrier`
            // and the batch modes it would be dead weight (~1.6 MB per
            // bank at DCGAN dim).
            acc: if windowed { vec![0.0; dim] } else { Vec::new() },
            folded: vec![0; shards],
            prefix: 0,
            fold_secs: 0.0,
            detached: false,
        }
    }

    fn reset(&mut self, round: u64) {
        self.round = round;
        self.open = true;
        self.arrived.fill(false);
        self.arrived_count = 0;
        self.folded.fill(0);
        self.prefix = 0;
        self.fold_secs = 0.0;
    }
}

/// Fold workers `*folded..upto` of the per-worker slots into one shard
/// accumulator, strictly in worker-id order. A shard's first fold zeroes
/// it first, replicating the barrier reduce's `0.0 + v⁰ᵢ` opening
/// addition exactly (a plain copy would differ on −0.0 inputs).
/// The per-worker additions run through [`crate::kernels::add_assign`] —
/// 8 lanes per iteration under `--kernels simd`, the element loop under
/// `--kernels scalar` — but always one slot at a time over the full shard
/// (the per-element add order is part of the bitwise contract; lanes only
/// batch *independent* elements of the same (acc, slot) pair).
fn fold_shard(acc: &mut [f32], off: usize, slots: &[WorkerSlot], folded: &mut usize, upto: usize) {
    if *folded >= upto {
        return;
    }
    if *folded == 0 {
        for x in acc.iter_mut() {
            *x = 0.0;
        }
    }
    for slot in &slots[*folded..upto] {
        let src = &slot.buf[off..off + acc.len()];
        crate::kernels::add_assign(acc, src);
    }
    *folded = upto;
}

/// Close-time fold + scale for one shard: continue the worker-id-order
/// fold past the already-folded prefix — skipping never-arrived slots
/// when `partial` (their buffers hold stale bytes that must not leak
/// into the mean) — then write `out = acc · inv`.
fn close_shard(
    acc: &mut [f32],
    out: &mut [f32],
    off: usize,
    slots: &[WorkerSlot],
    arrived: &[bool],
    folded: &mut usize,
    partial: bool,
    inv: f32,
) {
    if *folded == 0 {
        for x in acc.iter_mut() {
            *x = 0.0;
        }
    }
    for (w, slot) in slots.iter().enumerate().skip(*folded) {
        if partial && !arrived[w] {
            continue;
        }
        let src = &slot.buf[off..off + acc.len()];
        crate::kernels::add_assign(acc, src);
    }
    *folded = slots.len();
    crate::kernels::scale_into(out, acc, inv);
}

/// Sequentially run [`close_shard`] over every shard — the one walk the
/// no-pool inline close and the detached close task share, so the shard
/// offset arithmetic and inclusion filter exist exactly once outside the
/// pool dispatch.
#[allow(clippy::too_many_arguments)]
fn close_all_shards(
    acc: &mut [f32],
    out: &mut [f32],
    shard_elems: usize,
    slots: &[WorkerSlot],
    arrived: &[bool],
    folded: &mut [usize],
    partial: bool,
    inv: f32,
) {
    for (s, ((ac, f), o)) in acc
        .chunks_mut(shard_elems)
        .zip(folded.iter_mut())
        .zip(out.chunks_mut(shard_elems))
        .enumerate()
    {
        close_shard(ac, o, s * shard_elems, slots, arrived, f, partial, inv);
    }
}

/// Split of one round's reduce time, feeding the `decode_secs` /
/// `reduce_secs` telemetry columns.
#[derive(Debug, Clone, Copy, Default)]
pub struct ReduceTiming {
    /// Seconds of incremental window folds that ran *during* the gather
    /// (inside [`Aggregator::accept`], on the leader clock).
    pub in_gather_secs: f64,
    /// Seconds of the close-time fold + scale — on the detached task's
    /// own clock when the close was offloaded, so this can overlap
    /// leader wall time instead of adding to it.
    pub close_secs: f64,
}

impl ReduceTiming {
    /// Total reduce seconds of the round.
    pub fn total_secs(&self) -> f64 {
        self.in_gather_secs + self.close_secs
    }
}

/// Buffers of a close-time fold in flight on the pool: moved out of the
/// bank for the task's lifetime, moved back at join, so the leader can
/// keep decoding the *other* bank meanwhile without aliasing.
struct ReduceJob {
    slots: Vec<WorkerSlot>,
    arrived: Vec<bool>,
    acc: Vec<f32>,
    folded: Vec<usize>,
    out: Vec<f32>,
    close_secs: f64,
}

struct DetachedReduce {
    done: TaskDone,
    cell: Arc<Mutex<Option<ReduceJob>>>,
}

/// Ticket returned by [`Aggregator::close_round`]; redeem it with
/// [`Aggregator::join_reduce`] to obtain the round's mean. The window in
/// between is where an offloaded close overlaps leader-side work.
#[must_use = "join_reduce must be called to complete the round"]
pub struct ReduceClose {
    bank: usize,
    detached: Option<DetachedReduce>,
}

/// Reusable leader-side aggregation state for one training run.
pub struct Aggregator {
    cfg: AggregatorConfig,
    dim: usize,
    workers: usize,
    shard_elems: usize,
    /// Pool for the sharded/streaming reduce (absent in sequential mode).
    pool: Option<ThreadPool>,
    /// Slot banks: one for every mode but pipelined, up to two there
    /// (`pipeline_depth` ≥ 2 — one bank gathering, one whose round is
    /// still in flight on the downlink).
    banks: Vec<RoundBank>,
    /// Indices of the currently-open banks, oldest round first — closes
    /// always pop the front.
    open_order: VecDeque<usize>,
    /// Bank most recently begun, accepted-into or closed: the one
    /// [`Self::arrived_count`] / [`Self::included`] report on.
    active: usize,
    avg: Vec<f32>,
    /// Reduce-time split of the most recently closed (joined) round.
    timing: ReduceTiming,
}

impl Aggregator {
    /// Below this much total decode work (`dim · workers` elements) the
    /// sharded mode runs the sequential body — output-identical by
    /// construction — and spawns no pool at all (the small-d theory
    /// sweeps construct many short-lived clusters).
    const SMALL_WORK_ELEMS: usize = 4096;

    /// Allocate all round buffers for `workers` payloads of dimension
    /// `dim` up front (two slot banks in pipelined mode with depth ≥ 2,
    /// one otherwise).
    pub fn new(cfg: AggregatorConfig, dim: usize, workers: usize) -> Self {
        assert!(workers > 0, "aggregator needs at least one worker");
        let small = dim * workers < Self::SMALL_WORK_ELEMS;
        let pool = match cfg.mode {
            AggMode::Sequential => None,
            _ if small => None,
            _ => Some(ThreadPool::new(cfg.resolved_threads())),
        };
        let shard_elems = cfg.shard_elems.max(1);
        let n_banks = match cfg.mode {
            AggMode::Pipelined => cfg.pipeline_depth.clamp(1, 2),
            _ => 1,
        };
        let shards = dim.div_ceil(shard_elems).max(1);
        let windowed = cfg.mode.is_streaming() && cfg.reduce == ReduceMode::Windowed;
        Self {
            dim,
            workers,
            shard_elems,
            pool,
            banks: (0..n_banks)
                .map(|_| RoundBank::new(dim, workers, shards, windowed))
                .collect(),
            open_order: VecDeque::with_capacity(n_banks),
            active: 0,
            avg: vec![0.0; dim],
            timing: ReduceTiming::default(),
            cfg,
        }
    }

    /// Number of slot banks (2 ⇔ pipelined double-buffering is active).
    pub fn num_banks(&self) -> usize {
        self.banks.len()
    }

    /// Active mode (for logs/benches).
    pub fn mode(&self) -> AggMode {
        self.cfg.mode
    }

    /// Number of reduction shards the sharded path uses.
    pub fn num_shards(&self) -> usize {
        self.dim.div_ceil(self.shard_elems).max(1)
    }

    /// Decode, validate and average one round's payloads. `msgs` must be
    /// sorted by worker id (the [`crate::comm::ServerEnd`] contract).
    /// Returns the averaged vector, valid until the next call.
    pub fn aggregate(
        &mut self,
        round: u64,
        msgs: &[Message],
        decoder: &Decoder,
    ) -> anyhow::Result<&[f32]> {
        anyhow::ensure!(
            msgs.len() == self.workers,
            "expected {} payloads, got {}",
            self.workers,
            msgs.len()
        );
        for msg in msgs {
            anyhow::ensure!(
                msg.round == round,
                "worker {}: round skew: got round {}, leader at round {round}",
                msg.worker,
                msg.round
            );
        }
        match self.cfg.mode {
            AggMode::Sequential => self.run_sequential(round, msgs, decoder)?,
            AggMode::Sharded => self.run_sharded(round, msgs, decoder)?,
            AggMode::Streaming | AggMode::Pipelined => {
                // Batch entry point for the streaming engine: feed the
                // payloads through the same begin/accept/finish path the
                // event-driven leader uses (order-invariant by design).
                self.begin_round(round);
                for msg in msgs {
                    self.accept(msg, decoder)?;
                }
                self.finish_round()?;
            }
        }
        Ok(&self.avg)
    }

    /// Open a streaming round in a free slot bank: arrivals are then fed
    /// through [`Self::accept`] in **any order** and the average produced
    /// by [`Self::finish_round`]. With every bank already open (an
    /// aborted round, or a pipelined caller past its depth) the *oldest*
    /// open bank is recycled — which for the single-bank modes preserves
    /// the original "begin resets any aborted previous round" semantics.
    pub fn begin_round(&mut self, round: u64) {
        let n = self.banks.len();
        let idx = if self.open_order.len() < n {
            // Rotate away from the most recently touched bank, so with
            // two banks a new round never decodes over the one the round
            // just closed occupied — genuine double-buffering. A bank
            // whose buffers are inside a detached reduce task is not a
            // candidate: its close must be joined first.
            (1..=n)
                .map(|k| (self.active + k) % n)
                .find(|&i| !self.banks[i].open && !self.banks[i].detached)
                .expect("no free bank: join_reduce the detached close before begin_round")
        } else {
            self.open_order.pop_front().expect("all banks open")
        };
        self.banks[idx].reset(round);
        self.open_order.push_back(idx);
        self.active = idx;
    }

    /// Decode one arrived payload into its worker slot immediately (the
    /// decode-on-arrival half of the streaming pipeline). The frame is
    /// routed to the **open bank whose round id matches** — with two
    /// banks open, round t and round t+1 frames interleave freely. Fails
    /// fast on round skew (no open bank matches), out-of-range /
    /// duplicate worker ids, decode errors and non-finite values — the
    /// arrival itself carries the failure, so the barrier aborts without
    /// waiting for stragglers.
    pub fn accept(&mut self, msg: &Message, decoder: &Decoder) -> anyhow::Result<()> {
        anyhow::ensure!(!self.open_order.is_empty(), "accept called outside an open round");
        let Some(idx) =
            self.open_order.iter().copied().find(|&i| self.banks[i].round == msg.round)
        else {
            let newest = *self.open_order.back().expect("checked non-empty");
            anyhow::bail!(
                "worker {}: round skew: got round {}, leader at round {}",
                msg.worker,
                msg.round,
                self.banks[newest].round
            );
        };
        let round = msg.round;
        let w = msg.worker as usize;
        anyhow::ensure!(w < self.workers, "worker id {w} out of range (M = {})", self.workers);
        let bank = &mut self.banks[idx];
        anyhow::ensure!(!bank.arrived[w], "duplicate payload from worker {w} at round {round}");
        let slot = &mut bank.slots[w];
        decode_and_validate(round, msg, decoder, slot);
        if let Some(e) = slot.err.take() {
            return Err(e);
        }
        bank.arrived[w] = true;
        bank.arrived_count += 1;
        self.active = idx;
        if self.windowed_reduce() {
            self.extend_fold_window(idx);
        }
        Ok(())
    }

    /// Whether this aggregator runs the windowed incremental reduce:
    /// `--reduce windowed` on a streaming-engine mode. Batch-mode
    /// aggregators driven through the streaming API directly fall back
    /// to the barrier fold (their banks carry no accumulator).
    fn windowed_reduce(&self) -> bool {
        self.cfg.mode.is_streaming() && self.cfg.reduce == ReduceMode::Windowed
    }

    /// Windowed reduce: advance the bank's contiguous-arrived prefix and
    /// fold the newly covered slots into the shard accumulators (strictly
    /// in worker-id order per shard — shard-parallel on the pool). The
    /// elapsed time is charged to the bank's fold clock so telemetry can
    /// split the gather into decode and reduce components.
    fn extend_fold_window(&mut self, idx: usize) {
        let workers = self.workers;
        let shard_elems = self.shard_elems;
        let bank = &mut self.banks[idx];
        let mut upto = bank.prefix;
        while upto < workers && bank.arrived[upto] {
            upto += 1;
        }
        if upto == bank.prefix {
            return;
        }
        let extension = upto - bank.prefix;
        bank.prefix = upto;
        let t = Stopwatch::start();
        let RoundBank { slots, acc, folded, .. } = &mut *bank;
        let slots: &[WorkerSlot] = slots;
        // A one-worker extension over a smallish dim is less work than a
        // pool dispatch + latch round trip: fold it on the caller thread
        // (same adds, same order — scheduling only).
        let inline = extension * self.dim < Self::SMALL_WORK_ELEMS;
        crate::obs::metrics::AGG_FOLD_BATCH_ELEMS.record((extension * self.dim) as u64);
        match &self.pool {
            Some(pool) if !inline => {
                let mut units: Vec<(&mut [f32], &mut usize)> =
                    acc.chunks_mut(shard_elems).zip(folded.iter_mut()).collect();
                // With small shards each unit is little work: batch
                // enough shards per job that a job folds at least
                // SMALL_WORK_ELEMS element-adds (scheduling only —
                // shard order and add order are unchanged).
                let min_per_job =
                    Self::SMALL_WORK_ELEMS.div_ceil(extension * shard_elems).max(1);
                crate::obs::metrics::AGG_FOLD_POOL_DISPATCH.inc();
                pool.parallel_for_mut_min_chunk(&mut units, min_per_job, |s, (chunk, f)| {
                    fold_shard(chunk, s * shard_elems, slots, f, upto);
                });
            }
            _ => {
                crate::obs::metrics::AGG_FOLD_CALLER_INLINE.inc();
                for (s, (chunk, f)) in
                    acc.chunks_mut(shard_elems).zip(folded.iter_mut()).enumerate()
                {
                    fold_shard(chunk, s * shard_elems, slots, f, upto);
                }
            }
        }
        bank.fold_secs += t.elapsed_secs();
    }

    /// Close the **oldest** open streaming round and start its reduce:
    /// every worker must have arrived (`partial = false`) or at least one
    /// (`partial = true`). Under `--reduce barrier` the whole fold runs
    /// here; under `--reduce windowed` only the unfolded tail + the 1/M
    /// scale remain — and on the pipelined path with a pool, a *small*
    /// residue (≤ 1 unfolded worker) is **offloaded** as a detached pool
    /// task whose completion the returned ticket carries, while larger
    /// tails run inline shard-parallel. Redeem the ticket with
    /// [`Self::join_reduce`]; the window in between is free leader time.
    pub fn close_round(&mut self, partial: bool) -> anyhow::Result<ReduceClose> {
        let idx = self.open_order.pop_front().ok_or_else(|| {
            anyhow::anyhow!("close_round called outside an open streaming round")
        })?;
        self.banks[idx].open = false;
        self.active = idx;
        if partial {
            anyhow::ensure!(
                self.banks[idx].arrived_count > 0,
                "cannot close a round with zero payloads"
            );
        } else {
            anyhow::ensure!(
                self.banks[idx].arrived_count == self.workers,
                "expected {} payloads, got {}",
                self.workers,
                self.banks[idx].arrived_count
            );
        }
        self.timing =
            ReduceTiming { in_gather_secs: self.banks[idx].fold_secs, close_secs: 0.0 };
        if self.windowed_reduce() {
            let count = if partial { self.banks[idx].arrived_count } else { self.workers };
            let inv = 1.0 / count as f32;
            // Workers still unfolded at close: every id < prefix is
            // folded and arrived, so the selected tail is count − prefix.
            let tail_workers = count.saturating_sub(self.banks[idx].prefix);
            // Offload only when the residue is genuinely small (at most
            // one fold + the scale — the in-order common case): the
            // detached task folds sequentially on one pool worker, which
            // overlaps the leader's O(dim) frame prep nicely but would
            // serialize a many-worker tail that the inline close runs
            // shard-parallel (e.g. worker 0 arriving last keeps the
            // prefix at 0 and the whole fold in the tail).
            let offload =
                self.cfg.mode == AggMode::Pipelined && self.pool.is_some() && tail_workers <= 1;
            if offload {
                crate::obs::metrics::AGG_CLOSE_OFFLOADED.inc();
                Ok(self.spawn_detached_close(idx, partial, inv))
            } else {
                crate::obs::metrics::AGG_CLOSE_INLINE.inc();
                let t = Stopwatch::start();
                self.close_windowed_inline(idx, partial, inv);
                self.timing.close_secs = t.elapsed_secs();
                Ok(ReduceClose { bank: idx, detached: None })
            }
        } else {
            crate::obs::metrics::AGG_CLOSE_INLINE.inc();
            let t = Stopwatch::start();
            self.reduce_mean(idx, partial);
            self.timing.close_secs = t.elapsed_secs();
            Ok(ReduceClose { bank: idx, detached: None })
        }
    }

    /// Join the reduce a [`Self::close_round`] ticket stands for and
    /// return the round's mean, valid until the next close. Inline closes
    /// return immediately; detached ones block on the task's completion
    /// latch, move the bank's buffers back, and install the task's output
    /// as the current average.
    pub fn join_reduce(&mut self, close: ReduceClose) -> anyhow::Result<&[f32]> {
        let ReduceClose { bank, detached } = close;
        if let Some(task) = detached {
            // Generous anti-hang bound: converts a lost task (a panicked
            // pool worker) into an error instead of a deadlock.
            anyhow::ensure!(
                task.done.wait_timeout(std::time::Duration::from_secs(300)),
                "offloaded reduce task did not complete within 300s"
            );
            let job = task.cell.lock().unwrap().take();
            let Some(mut job) = job else {
                anyhow::bail!("offloaded reduce task panicked before depositing its result");
            };
            self.timing.close_secs = job.close_secs;
            let b = &mut self.banks[bank];
            b.slots = std::mem::take(&mut job.slots);
            b.arrived = std::mem::take(&mut job.arrived);
            b.acc = std::mem::take(&mut job.acc);
            b.folded = std::mem::take(&mut job.folded);
            b.detached = false;
            self.avg = job.out;
        }
        Ok(&self.avg)
    }

    /// Inline windowed close: fold each shard's unfolded (included) tail
    /// and scale into `avg`, shard-parallel on the pool when present.
    fn close_windowed_inline(&mut self, idx: usize, partial: bool, inv: f32) {
        let shard_elems = self.shard_elems;
        let RoundBank { slots, arrived, acc, folded, .. } = &mut self.banks[idx];
        let slots: &[WorkerSlot] = slots;
        let arrived: &[bool] = arrived;
        match &self.pool {
            None => {
                close_all_shards(
                    acc, &mut self.avg, shard_elems, slots, arrived, folded, partial, inv,
                );
            }
            Some(pool) => {
                let mut units: Vec<((&mut [f32], &mut usize), &mut [f32])> = acc
                    .chunks_mut(shard_elems)
                    .zip(folded.iter_mut())
                    .zip(self.avg.chunks_mut(shard_elems))
                    .collect();
                // Tail folds touch at most a worker or two per shard:
                // floor the per-job shard count so small-shard configs
                // don't pay one dispatch per tiny close.
                let min_per_job = Self::SMALL_WORK_ELEMS.div_ceil(shard_elems).max(1);
                pool.parallel_for_mut_min_chunk(&mut units, min_per_job, |s, ((ac, f), out)| {
                    close_shard(ac, out, s * shard_elems, slots, arrived, f, partial, inv);
                });
            }
        }
    }

    /// Offloaded windowed close: move the bank's buffers (and the output
    /// vector) into a detached pool task that folds the tail and scales,
    /// then deposits everything for [`Self::join_reduce`] to move back.
    /// The fold runs sequentially on its worker — the caller only
    /// detaches closes whose tail is at most one worker (the in-order
    /// common case), so the task is O(dim) and overlaps the leader's
    /// broadcast-frame prep rather than serializing in front of it.
    fn spawn_detached_close(&mut self, idx: usize, partial: bool, inv: f32) -> ReduceClose {
        let shard_elems = self.shard_elems;
        let bank = &mut self.banks[idx];
        bank.detached = true;
        let mut job = ReduceJob {
            slots: std::mem::take(&mut bank.slots),
            arrived: std::mem::take(&mut bank.arrived),
            acc: std::mem::take(&mut bank.acc),
            folded: std::mem::take(&mut bank.folded),
            out: std::mem::take(&mut self.avg),
            close_secs: 0.0,
        };
        let cell = Arc::new(Mutex::new(None));
        let deposit = Arc::clone(&cell);
        let pool = self.pool.as_ref().expect("detached close requires a pool");
        let done = pool.submit(move || {
            let t = Stopwatch::start();
            {
                let ReduceJob { slots, arrived, acc, folded, out, .. } = &mut job;
                close_all_shards(acc, out, shard_elems, slots, arrived, folded, partial, inv);
            }
            job.close_secs = t.elapsed_secs();
            *deposit.lock().unwrap() = Some(job);
        });
        ReduceClose { bank: idx, detached: Some(DetachedReduce { done, cell }) }
    }

    /// Close the **oldest** open streaming round: every worker must have
    /// arrived; runs (or joins) the reduce and returns the average, valid
    /// until the next close. Equivalent to `close_round(false)` +
    /// `join_reduce` back to back.
    pub fn finish_round(&mut self) -> anyhow::Result<&[f32]> {
        let close = self.close_round(false)?;
        self.join_reduce(close)
    }

    /// Reduce-time split of the most recently closed-and-joined round
    /// (how much fold work ran inside the gather vs at close time).
    pub fn last_reduce_timing(&self) -> ReduceTiming {
        self.timing
    }

    /// Number of payloads accepted into the most recently touched (open
    /// or just-closed) streaming round.
    pub fn arrived_count(&self) -> usize {
        self.banks[self.active].arrived_count
    }

    /// Per-worker arrival flags of the most recently touched (open or
    /// just-closed) streaming round — the inclusion set a partial
    /// broadcast carries. Valid until that bank's next
    /// [`Self::begin_round`]. Panics (rather than silently returning an
    /// empty slice) while the bank's buffers are inside a detached
    /// close-time reduce: capture the inclusion set **before**
    /// [`Self::close_round`], as the leader loop does.
    pub fn included(&self) -> &[bool] {
        let bank = &self.banks[self.active];
        assert!(
            !bank.detached,
            "included() while the close is detached — capture it before close_round"
        );
        &bank.arrived
    }

    /// Round id of the oldest open streaming round, if any.
    pub fn oldest_open_round(&self) -> Option<u64> {
        self.open_order.front().map(|&i| self.banks[i].round)
    }

    /// Close the **oldest** open streaming round over **the subset of
    /// workers that arrived** (K-of-M / deadline partial aggregation):
    /// averages the included slots only, added in worker-id order and
    /// scaled by 1/#included. At least one payload must have arrived.
    /// With every worker arrived the subset reduce performs exactly
    /// [`Self::finish_round`]'s adds in the same order — bitwise
    /// identical, so `kofm:M` degenerates to the full barrier exactly
    /// (the integration property test covers the all-arrived draw too).
    pub fn finish_partial(&mut self) -> anyhow::Result<&[f32]> {
        let close = self.close_round(true)?;
        self.join_reduce(close)
    }

    /// The one reduce every mode shares: zero `avg`, add the selected
    /// slots of bank `idx` **in worker-id order**, scale by 1/#selected —
    /// on the pool (disjoint shards) when present, else via
    /// `ops::mean_into`. With `partial = false` every slot is selected
    /// (the full-barrier 1/M mean); with `partial = true` only the slots
    /// whose payload arrived this round are. The inclusion filter skips
    /// whole slots, never reorders element additions, so the full-barrier
    /// output is bitwise-independent of which body runs and a partial
    /// round's output is exactly `mean_into` over the included payloads
    /// (both properties are pinned by the regression tests). Which bank
    /// the slots live in cannot affect a bit either: banks are identical
    /// buffers, only the decode destination rotates.
    fn reduce_mean(&mut self, idx: usize, partial: bool) {
        let bank = &self.banks[idx];
        let count = if partial { bank.arrived_count } else { self.workers };
        let inv = 1.0 / count as f32;
        let slots = &bank.slots;
        let arrived = &bank.arrived;
        match &self.pool {
            None => {
                let refs: Vec<&[f32]> = slots
                    .iter()
                    .zip(arrived)
                    .filter(|(_, &inc)| !partial || inc)
                    .map(|(s, _)| s.buf.as_slice())
                    .collect();
                ops::mean_into(&refs, &mut self.avg);
            }
            Some(pool) => {
                let shard_elems = self.shard_elems;
                let mut shards: Vec<&mut [f32]> = self.avg.chunks_mut(shard_elems).collect();
                let min_per_job = Self::SMALL_WORK_ELEMS.div_ceil(shard_elems).max(1);
                pool.parallel_for_mut_min_chunk(&mut shards, min_per_job, |s, shard| {
                    let off = s * shard_elems;
                    for x in shard.iter_mut() {
                        *x = 0.0;
                    }
                    for (slot, &inc) in slots.iter().zip(arrived) {
                        if partial && !inc {
                            continue;
                        }
                        let src = &slot.buf[off..off + shard.len()];
                        crate::kernels::add_assign(shard, src);
                    }
                    crate::kernels::scale_in_place(shard, inv);
                });
            }
        }
    }

    /// Seed-equivalent path: decode and validate worker by worker on the
    /// caller thread, then average — kept behind the config flag as the
    /// A/B baseline (buffers are still reused, arithmetic is unchanged).
    fn run_sequential(
        &mut self,
        round: u64,
        msgs: &[Message],
        decoder: &Decoder,
    ) -> anyhow::Result<()> {
        for (slot, msg) in self.banks[0].slots.iter_mut().zip(msgs) {
            decode_and_validate(round, msg, decoder, slot);
            if let Some(e) = slot.err.take() {
                return Err(e);
            }
        }
        let t = Stopwatch::start();
        self.reduce_mean(0, false);
        self.timing = ReduceTiming { in_gather_secs: 0.0, close_secs: t.elapsed_secs() };
        Ok(())
    }

    /// The parallel pipeline: worker-parallel decode, shard-parallel
    /// reduce in worker-id order.
    fn run_sharded(
        &mut self,
        round: u64,
        msgs: &[Message],
        decoder: &Decoder,
    ) -> anyhow::Result<()> {
        // No pool ⇒ the workload was below SMALL_WORK_ELEMS at
        // construction: run the sequential body (bitwise-identical).
        if self.pool.is_none() {
            return self.run_sequential(round, msgs, decoder);
        }
        let pool = self.pool.as_ref().expect("checked above");
        // Stage 1: each worker's payload decodes into its own slot.
        pool.parallel_for_mut(&mut self.banks[0].slots, |m, slot| {
            decode_and_validate(round, &msgs[m], decoder, slot);
        });
        for slot in &mut self.banks[0].slots {
            if let Some(e) = slot.err.take() {
                return Err(e);
            }
        }
        // Stage 2: disjoint output shards, each reduced in worker order.
        let t = Stopwatch::start();
        self.reduce_mean(0, false);
        self.timing = ReduceTiming { in_gather_secs: 0.0, close_secs: t.elapsed_secs() };
        Ok(())
    }
}

/// Decode one payload into `slot.buf` and validate it, recording any
/// failure (with the worker id) in `slot.err`.
fn decode_and_validate(round: u64, msg: &Message, decoder: &Decoder, slot: &mut WorkerSlot) {
    slot.err = None;
    if let Err(e) = decoder(&msg.payload, &mut slot.buf) {
        slot.err = Some(e.context(format!(
            "worker {}: payload decode failed at round {round}",
            msg.worker
        )));
        return;
    }
    if !ops::all_finite(&slot.buf) {
        slot.err = Some(anyhow::anyhow!(
            "worker {} sent non-finite payload at round {round}",
            msg.worker
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, Identity, LinfStochastic};
    use crate::util::rng::Pcg32;

    fn identity_decoder() -> Decoder {
        Arc::new(|bytes: &[u8], out: &mut [f32]| Identity.decode_into(bytes, out))
    }

    fn payload_of(worker: u32, round: u64, v: &[f32]) -> Message {
        let mut wire = Vec::new();
        Identity.encode(v, &mut wire);
        Message::payload(worker, round, wire)
    }

    fn sharded_cfg(threads: usize, shard_elems: usize) -> AggregatorConfig {
        AggregatorConfig { mode: AggMode::Sharded, threads, shard_elems, ..Default::default() }
    }

    #[test]
    fn sharded_averages_match_hand_computation() {
        let d = 5;
        let msgs = vec![
            payload_of(0, 0, &[1.0, 2.0, 3.0, 4.0, 5.0]),
            payload_of(1, 0, &[3.0, 2.0, 1.0, 0.0, -1.0]),
        ];
        let mut agg = Aggregator::new(sharded_cfg(2, 2), d, 2);
        assert_eq!(agg.num_shards(), 3); // 2 + 2 + 1 elements
        let avg = agg.aggregate(0, &msgs, &identity_decoder()).unwrap();
        assert_eq!(avg, &[2.0, 2.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn sequential_and_sharded_agree_bitwise_on_stochastic_payloads() {
        let d = 1234;
        let m = 7;
        let c = LinfStochastic::with_bits(8);
        let mut rng = Pcg32::new(42);
        let msgs: Vec<Message> = (0..m)
            .map(|w| {
                let v = rng.normal_vec(d);
                let mut wire = Vec::new();
                c.compress_encoded(&v, &mut rng, &mut wire);
                Message::payload(w as u32, 9, wire)
            })
            .collect();
        let decoder: Decoder = Arc::new(move |b: &[u8], out: &mut [f32]| c.decode_into(b, out));
        let mut seq = Aggregator::new(AggregatorConfig::sequential(), d, m);
        let mut shd = Aggregator::new(sharded_cfg(3, 100), d, m);
        let a = seq.aggregate(9, &msgs, &decoder).unwrap().to_vec();
        let b = shd.aggregate(9, &msgs, &decoder).unwrap();
        for i in 0..d {
            assert_eq!(a[i].to_bits(), b[i].to_bits(), "element {i} differs");
        }
    }

    #[test]
    fn streaming_accepts_any_arrival_order_bitwise_identically() {
        let d = 999;
        let m = 5;
        let c = LinfStochastic::with_bits(8);
        let mut rng = Pcg32::new(0xFEED);
        let msgs: Vec<Message> = (0..m)
            .map(|w| {
                let v = rng.normal_vec(d);
                let mut wire = Vec::new();
                c.compress_encoded(&v, &mut rng, &mut wire);
                Message::payload(w as u32, 4, wire)
            })
            .collect();
        let decoder: Decoder = Arc::new(move |b: &[u8], out: &mut [f32]| c.decode_into(b, out));
        let mut seq = Aggregator::new(AggregatorConfig::sequential(), d, m);
        let oracle = seq.aggregate(4, &msgs, &decoder).unwrap().to_vec();
        // Worst-case arrival order: straggler-first reversal.
        let mut agg = Aggregator::new(
            AggregatorConfig {
                mode: AggMode::Streaming,
                threads: 3,
                shard_elems: 128,
                ..Default::default()
            },
            d,
            m,
        );
        agg.begin_round(4);
        for msg in msgs.iter().rev() {
            agg.accept(msg, &decoder).unwrap();
        }
        let avg = agg.finish_round().unwrap();
        for i in 0..d {
            assert_eq!(oracle[i].to_bits(), avg[i].to_bits(), "element {i}");
        }
    }

    #[test]
    fn streaming_guards_the_barrier_invariants() {
        let dec = identity_decoder();
        let mut agg = Aggregator::new(AggregatorConfig::streaming(), 2, 2);
        // accept/finish outside an open round.
        assert!(agg.accept(&payload_of(0, 0, &[1.0, 2.0]), &dec).is_err());
        assert!(agg.finish_round().is_err());
        agg.begin_round(0);
        agg.accept(&payload_of(1, 0, &[1.0, 2.0]), &dec).unwrap();
        // Duplicate arrival, round skew, out-of-range id.
        assert!(agg.accept(&payload_of(1, 0, &[1.0, 2.0]), &dec).is_err());
        let skew = agg.accept(&payload_of(0, 3, &[1.0, 2.0]), &dec).unwrap_err();
        assert!(skew.to_string().contains("round skew"), "{skew}");
        assert!(agg.accept(&payload_of(9, 0, &[1.0, 2.0]), &dec).is_err());
        // Missing a worker: finish fails and closes the round.
        let err = agg.finish_round().unwrap_err();
        assert!(err.to_string().contains("expected 2 payloads, got 1"), "{err}");
        // A fresh round recovers cleanly after the abort.
        agg.begin_round(7);
        agg.accept(&payload_of(0, 7, &[2.0, 4.0]), &dec).unwrap();
        agg.accept(&payload_of(1, 7, &[4.0, 2.0]), &dec).unwrap();
        assert_eq!(agg.finish_round().unwrap(), &[3.0, 3.0]);
    }

    #[test]
    fn finish_partial_averages_only_the_arrived_slots() {
        let dec = identity_decoder();
        // Small-d (no pool) regime.
        let mut agg = Aggregator::new(AggregatorConfig::streaming(), 2, 3);
        agg.begin_round(0);
        agg.accept(&payload_of(2, 0, &[4.0, 8.0]), &dec).unwrap();
        agg.accept(&payload_of(0, 0, &[2.0, 2.0]), &dec).unwrap();
        assert_eq!(agg.arrived_count(), 2);
        assert_eq!(agg.included(), &[true, false, true]);
        let avg = agg.finish_partial().unwrap();
        assert_eq!(avg, &[3.0, 5.0], "mean over workers {{0, 2}} only");
        // Zero arrivals is an error; a fresh round recovers.
        agg.begin_round(1);
        assert!(agg.finish_partial().is_err());
        // All-arrived partial close equals the full-barrier close.
        agg.begin_round(2);
        for w in 0..3u32 {
            agg.accept(&payload_of(w, 2, &[w as f32, 1.0]), &dec).unwrap();
        }
        assert_eq!(agg.finish_partial().unwrap(), &[1.0, 1.0]);
    }

    #[test]
    fn finish_partial_runs_the_pool_path_above_the_small_work_cutoff() {
        // dim · workers above SMALL_WORK_ELEMS ⇒ the shard-parallel
        // subset reduce really runs on the pool.
        let d = Aggregator::SMALL_WORK_ELEMS;
        let dec = identity_decoder();
        let mut agg = Aggregator::new(
            AggregatorConfig {
                mode: AggMode::Streaming,
                threads: 3,
                shard_elems: 512,
                ..Default::default()
            },
            d,
            2,
        );
        agg.begin_round(0);
        agg.accept(&payload_of(1, 0, &vec![2.5; d]), &dec).unwrap();
        let avg = agg.finish_partial().unwrap();
        assert!(avg.iter().all(|&x| x == 2.5), "single included worker is its own mean");
    }

    #[test]
    fn pipelined_banks_accept_two_interleaved_rounds() {
        // Double round-state: rounds 4 and 5 are both open; frames for
        // the two rounds interleave in arrival order and each decodes
        // into its own bank. Closes apply oldest-first.
        let dec = identity_decoder();
        let mut agg = Aggregator::new(AggregatorConfig::pipelined_with_depth(2), 2, 2);
        assert_eq!(agg.num_banks(), 2);
        agg.begin_round(4);
        agg.accept(&payload_of(0, 4, &[1.0, 1.0]), &dec).unwrap();
        agg.begin_round(5);
        assert_eq!(agg.oldest_open_round(), Some(4));
        // Interleaved: round-5 frame, then the round-4 straggler, then
        // the rest of round 5 — routing is by round id, not recency.
        agg.accept(&payload_of(1, 5, &[8.0, 2.0]), &dec).unwrap();
        agg.accept(&payload_of(1, 4, &[3.0, 5.0]), &dec).unwrap();
        agg.accept(&payload_of(0, 5, &[2.0, 4.0]), &dec).unwrap();
        assert_eq!(agg.finish_round().unwrap(), &[2.0, 3.0], "round 4 closes first");
        assert_eq!(agg.oldest_open_round(), Some(5));
        assert_eq!(agg.finish_round().unwrap(), &[5.0, 3.0], "then round 5");
        assert_eq!(agg.oldest_open_round(), None);
        // A frame for neither open round is skew against the newest.
        agg.begin_round(6);
        let err = agg.accept(&payload_of(0, 9, &[0.0, 0.0]), &dec).unwrap_err();
        assert!(err.to_string().contains("round skew"), "{err}");
        assert!(err.to_string().contains("leader at round 6"), "{err}");
    }

    #[test]
    fn pipelined_single_depth_keeps_one_bank() {
        let mut agg = Aggregator::new(AggregatorConfig::pipelined_with_depth(1), 2, 1);
        assert_eq!(agg.num_banks(), 1);
        let dec = identity_decoder();
        agg.begin_round(0);
        agg.accept(&payload_of(0, 0, &[2.0, 6.0]), &dec).unwrap();
        assert_eq!(agg.finish_round().unwrap(), &[2.0, 6.0]);
    }

    #[test]
    fn pipelined_output_is_bitwise_identical_to_streaming_across_banks() {
        // The bank a round decodes into must not affect a single bit:
        // run the same payload stream through streaming (one bank) and
        // pipelined (rotating banks) and compare outputs per round.
        let d = 777;
        let m = 4;
        let c = LinfStochastic::with_bits(8);
        let mut rng = Pcg32::new(0xABBA);
        let rounds: Vec<Vec<Message>> = (0..4u64)
            .map(|r| {
                (0..m)
                    .map(|w| {
                        let v = rng.normal_vec(d);
                        let mut wire = Vec::new();
                        c.compress_encoded(&v, &mut rng, &mut wire);
                        Message::payload(w as u32, r, wire)
                    })
                    .collect()
            })
            .collect();
        let decoder: Decoder = Arc::new(move |b: &[u8], out: &mut [f32]| c.decode_into(b, out));
        let mut stream = Aggregator::new(AggregatorConfig::streaming(), d, m);
        let mut pipe = Aggregator::new(AggregatorConfig::pipelined_with_depth(2), d, m);
        for (r, msgs) in rounds.iter().enumerate() {
            let a = stream.aggregate(r as u64, msgs, &decoder).unwrap().to_vec();
            // Reversed arrival order on the pipelined side for good
            // measure — order-invariance composes with bank rotation.
            pipe.begin_round(r as u64);
            for msg in msgs.iter().rev() {
                pipe.accept(msg, &decoder).unwrap();
            }
            let b = pipe.finish_round().unwrap();
            for i in 0..d {
                assert_eq!(a[i].to_bits(), b[i].to_bits(), "round {r} element {i}");
            }
        }
    }

    fn streaming_with_reduce(
        reduce: ReduceMode,
        threads: usize,
        shard_elems: usize,
    ) -> AggregatorConfig {
        AggregatorConfig {
            mode: AggMode::Streaming,
            reduce,
            threads,
            shard_elems,
            ..Default::default()
        }
    }

    #[test]
    fn windowed_reduce_matches_barrier_bitwise_in_every_arrival_order() {
        // Same payloads, every rotation of the arrival order, windowed vs
        // barrier — must agree to the bit, in both the no-pool (small d)
        // and pool regimes.
        let c = LinfStochastic::with_bits(8);
        let decoder: Decoder = Arc::new(move |b: &[u8], out: &mut [f32]| c.decode_into(b, out));
        for &(d, threads, shard) in
            &[(17usize, 0usize, 4usize), (Aggregator::SMALL_WORK_ELEMS, 3, 512)]
        {
            let m = 5;
            let mut rng = Pcg32::new(0xD1CE ^ d as u64);
            let msgs: Vec<Message> = (0..m)
                .map(|w| {
                    let v = rng.normal_vec(d);
                    let mut wire = Vec::new();
                    c.compress_encoded(&v, &mut rng, &mut wire);
                    Message::payload(w as u32, 0, wire)
                })
                .collect();
            for rot in 0..m {
                let barrier_cfg = streaming_with_reduce(ReduceMode::Barrier, threads, shard);
                let windowed_cfg = streaming_with_reduce(ReduceMode::Windowed, threads, shard);
                let mut oracle = Aggregator::new(barrier_cfg, d, m);
                let mut windowed = Aggregator::new(windowed_cfg, d, m);
                for agg in [&mut oracle, &mut windowed] {
                    agg.begin_round(0);
                    for i in 0..m {
                        agg.accept(&msgs[(i + rot) % m], &decoder).unwrap();
                    }
                }
                let a = oracle.finish_round().unwrap().to_vec();
                let b = windowed.finish_round().unwrap();
                for i in 0..d {
                    assert_eq!(a[i].to_bits(), b[i].to_bits(), "d={d} rot={rot} element {i}");
                }
            }
        }
    }

    #[test]
    fn windowed_partial_close_never_folds_a_skipped_slot() {
        // Poison the skipped worker's slot with a previous round's data:
        // if the windowed fold ever touched a never-arrived slot, the
        // stale bytes would leak into the mean and diverge from a fresh
        // barrier oracle that never saw them.
        let dec = identity_decoder();
        let (d, m) = (6usize, 4usize);
        let mut windowed =
            Aggregator::new(streaming_with_reduce(ReduceMode::Windowed, 0, 2), d, m);
        // Round 0: everyone (including the soon-to-be-skipped worker 1)
        // sends large junk that must not survive into round 1.
        windowed.begin_round(0);
        for w in 0..m as u32 {
            windowed.accept(&payload_of(w, 0, &[1e6; 6]), &dec).unwrap();
        }
        windowed.finish_round().unwrap();
        // Round 1: workers {0, 2, 3} arrive (prefix stops at 1), kofm
        // closes without worker 1.
        let vecs: Vec<Vec<f32>> =
            (0..m).map(|w| (0..d).map(|i| (w * 10 + i) as f32).collect()).collect();
        windowed.begin_round(1);
        for &w in &[0usize, 2, 3] {
            windowed.accept(&payload_of(w as u32, 1, &vecs[w]), &dec).unwrap();
        }
        assert_eq!(windowed.included(), &[true, false, true, true]);
        let got = windowed.finish_partial().unwrap().to_vec();
        let mut fresh = Aggregator::new(
            streaming_with_reduce(ReduceMode::Barrier, 0, 2),
            d,
            m,
        );
        fresh.begin_round(1);
        for &w in &[0usize, 2, 3] {
            fresh.accept(&payload_of(w as u32, 1, &vecs[w]), &dec).unwrap();
        }
        let want = fresh.finish_partial().unwrap();
        for i in 0..d {
            assert_eq!(want[i].to_bits(), got[i].to_bits(), "element {i}");
        }
    }

    #[test]
    fn offloaded_pipelined_close_matches_inline_across_rounds_and_partials() {
        // d · m above the small-work cutoff so the pipelined aggregator
        // really owns a pool; rotate the banks over several rounds,
        // ending on a partial close. In-order arrivals leave the tail
        // empty, so close_round really detaches (the offload is gated to
        // tail_workers ≤ 1); reversed arrivals keep the prefix short and
        // take the inline shard-parallel close — both must match the
        // barrier oracle to the bit.
        let d = Aggregator::SMALL_WORK_ELEMS;
        let m = 3;
        let c = LinfStochastic::with_bits(8);
        let decoder: Decoder = Arc::new(move |b: &[u8], out: &mut [f32]| c.decode_into(b, out));
        for reversed in [false, true] {
            let mut rng = Pcg32::new(0x0FF1_0AD);
            let rounds: Vec<Vec<Message>> = (0..4u64)
                .map(|r| {
                    (0..m)
                        .map(|w| {
                            let v = rng.normal_vec(d);
                            let mut wire = Vec::new();
                            c.compress_encoded(&v, &mut rng, &mut wire);
                            Message::payload(w as u32, r, wire)
                        })
                        .collect()
                })
                .collect();
            let mut pipe = Aggregator::new(
                AggregatorConfig {
                    threads: 3,
                    shard_elems: 512,
                    ..AggregatorConfig::pipelined()
                },
                d,
                m,
            );
            let mut oracle =
                Aggregator::new(streaming_with_reduce(ReduceMode::Barrier, 3, 512), d, m);
            for (r, msgs) in rounds.iter().enumerate() {
                let full = r + 1 < rounds.len();
                let take = if full { m } else { m - 1 };
                let want: Vec<f32> = {
                    oracle.begin_round(r as u64);
                    for msg in msgs.iter().take(take) {
                        oracle.accept(msg, &decoder).unwrap();
                    }
                    if full {
                        oracle.finish_round().unwrap().to_vec()
                    } else {
                        oracle.finish_partial().unwrap().to_vec()
                    }
                };
                pipe.begin_round(r as u64);
                let order: Vec<usize> =
                    if reversed { (0..take).rev().collect() } else { (0..take).collect() };
                for &j in &order {
                    pipe.accept(&msgs[j], &decoder).unwrap();
                }
                let close = pipe.close_round(!full).unwrap();
                let got = pipe.join_reduce(close).unwrap();
                for i in 0..d {
                    assert_eq!(
                        want[i].to_bits(),
                        got[i].to_bits(),
                        "reversed={reversed} round {r} element {i}"
                    );
                }
                let timing = pipe.last_reduce_timing();
                assert!(timing.in_gather_secs >= 0.0 && timing.close_secs >= 0.0);
                assert!(timing.total_secs() >= timing.close_secs);
            }
        }
    }

    #[test]
    #[should_panic(expected = "join_reduce the detached close")]
    fn begin_round_refuses_a_bank_whose_reduce_is_still_detached() {
        let d = Aggregator::SMALL_WORK_ELEMS;
        let dec = identity_decoder();
        let mut agg = Aggregator::new(
            AggregatorConfig { threads: 2, shard_elems: 512, ..AggregatorConfig::pipelined() },
            d,
            1,
        );
        agg.begin_round(0);
        agg.accept(&payload_of(0, 0, &vec![1.0; d]), &dec).unwrap();
        let _close = agg.close_round(false).unwrap(); // bank 0 detached
        agg.begin_round(1); // bank 1 is free
        agg.begin_round(2); // no free bank: must panic, not recycle
    }

    #[test]
    fn streaming_batch_aggregate_matches_sequential() {
        let d = 6;
        let msgs = vec![
            payload_of(0, 0, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            payload_of(1, 0, &[6.0, 5.0, 4.0, 3.0, 2.0, 1.0]),
        ];
        let mut agg = Aggregator::new(AggregatorConfig::streaming(), d, 2);
        let avg = agg.aggregate(0, &msgs, &identity_decoder()).unwrap();
        assert_eq!(avg, &[3.5; 6]);
    }

    #[test]
    fn round_skew_error_names_the_worker() {
        let msgs = vec![payload_of(0, 3, &[1.0]), payload_of(1, 4, &[1.0])];
        let mut agg = Aggregator::new(AggregatorConfig::default(), 1, 2);
        let err = agg.aggregate(3, &msgs, &identity_decoder()).unwrap_err();
        let text = err.to_string();
        assert!(text.contains("worker 1"), "{text}");
        assert!(text.contains("round skew"), "{text}");
        assert!(text.contains("got round 4"), "{text}");
        assert!(text.contains("leader at round 3"), "{text}");
    }

    #[test]
    fn decode_failures_name_the_worker_deterministically() {
        // Both payloads are truncated garbage; the error must cite the
        // lowest worker id regardless of thread scheduling. dim is above
        // SMALL_WORK_ELEMS so the sharded case really runs the pool.
        let d = Aggregator::SMALL_WORK_ELEMS;
        let msgs = vec![
            Message::payload(0, 0, vec![1, 2]),
            Message::payload(1, 0, vec![3]),
        ];
        for cfg in [AggregatorConfig::sequential(), sharded_cfg(4, 512)] {
            let mut agg = Aggregator::new(cfg, d, 2);
            let err = agg.aggregate(0, &msgs, &identity_decoder()).unwrap_err();
            assert!(format!("{err:#}").contains("worker 0"), "{err:#}");
        }
    }

    #[test]
    fn non_finite_payloads_are_rejected_in_both_modes() {
        // dim above SMALL_WORK_ELEMS so the sharded case runs the pool.
        let d = Aggregator::SMALL_WORK_ELEMS;
        let mut v = vec![1.0f32; d];
        v[17] = f32::NAN;
        let msgs = vec![payload_of(0, 0, &v)];
        for cfg in [AggregatorConfig::sequential(), sharded_cfg(2, 512)] {
            let mut agg = Aggregator::new(cfg, d, 1);
            let err = agg.aggregate(0, &msgs, &identity_decoder()).unwrap_err();
            assert!(err.to_string().contains("non-finite"), "{err}");
            assert!(err.to_string().contains("worker 0"), "{err}");
        }
    }

    #[test]
    fn buffers_are_reused_across_rounds() {
        let d = 64;
        let mut agg = Aggregator::new(sharded_cfg(2, 16), d, 1);
        let dec = identity_decoder();
        let v: Vec<f32> = (0..d).map(|i| i as f32).collect();
        let p0 = {
            let avg = agg.aggregate(0, &[payload_of(0, 0, &v)], &dec).unwrap();
            assert_eq!(avg, &v[..]);
            avg.as_ptr()
        };
        let p1 = {
            let avg = agg.aggregate(1, &[payload_of(0, 1, &v)], &dec).unwrap();
            assert_eq!(avg, &v[..]);
            avg.as_ptr()
        };
        assert_eq!(p0, p1, "output buffer must not be reallocated per round");
    }

    #[test]
    fn shard_sizing_covers_every_regime() {
        for (d, shard) in [(1usize, 1usize), (10, 3), (10, 100), (4096, 4096)] {
            let msgs = vec![payload_of(0, 0, &vec![1.5; d])];
            let mut agg = Aggregator::new(sharded_cfg(3, shard), d, 1);
            let avg = agg.aggregate(0, &msgs, &identity_decoder()).unwrap();
            assert!(avg.iter().all(|&x| x == 1.5), "d={d} shard={shard}");
        }
    }

    #[test]
    fn payload_count_mismatch_is_an_error() {
        let msgs = vec![payload_of(0, 0, &[1.0])];
        let mut agg = Aggregator::new(AggregatorConfig::default(), 1, 2);
        let err = agg.aggregate(0, &msgs, &identity_decoder()).unwrap_err();
        assert!(err.to_string().contains("expected 2 payloads"), "{err}");
    }
}
