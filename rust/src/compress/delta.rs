//! Empirical verification of Definition 1 (δ-approximate compressor):
//! estimate δ̂ = 1 − E[‖Q(v)−v‖²/‖v‖²] over sampled inputs, used by the
//! `validate-compressors` CLI command and the Theorem 1/2 property tests.

use super::Compressor;
use crate::util::rng::Pcg32;
use crate::util::stats::norm2_sq;

/// Result of an empirical δ estimation.
#[derive(Debug, Clone)]
pub struct DeltaEstimate {
    /// Mean of 1 − ‖Q(v)−v‖²/‖v‖² across trials — the empirical δ.
    pub mean_delta: f64,
    /// Worst (smallest) per-trial δ observed.
    pub worst_delta: f64,
    /// Number of trials where the contraction held per-sample
    /// (biased compressors must satisfy it on *every* sample;
    /// unbiased ones only in expectation).
    pub per_sample_holds: usize,
    pub trials: usize,
}

impl DeltaEstimate {
    /// Whether the *expected* contraction holds with any δ ∈ (0,1]
    /// (i.e. E ratio < 1).
    pub fn is_delta_approximate(&self) -> bool {
        self.mean_delta > 0.0
    }
}

/// Estimate δ for `c` over `trials` vectors of dimension `d`, drawn from
/// `sample` (e.g. Gaussian, heavy-tailed, sparse). Each trial averages
/// `reps` independent quantizations so stochastic compressors are judged
/// in expectation, per Definition 1's reading for unbiased Q.
pub fn empirical_delta(
    c: &dyn Compressor,
    d: usize,
    trials: usize,
    reps: usize,
    rng: &mut Pcg32,
    mut sample: impl FnMut(&mut Pcg32, usize) -> Vec<f32>,
) -> DeltaEstimate {
    assert!(trials > 0 && reps > 0 && d > 0);
    let mut sum_delta = 0.0f64;
    let mut worst = f64::INFINITY;
    let mut holds = 0usize;
    for _ in 0..trials {
        let v = sample(rng, d);
        let denom = norm2_sq(&v) as f64;
        if denom == 0.0 {
            // Q(0) must be 0 for the contraction to hold trivially.
            sum_delta += 1.0;
            worst = worst.min(1.0);
            holds += 1;
            continue;
        }
        let mut mean_ratio = 0.0f64;
        let mut every_sample_ok = true;
        for _ in 0..reps {
            let q = c.compress_vec(&v, rng);
            let err: f64 =
                v.iter().zip(&q).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
            let ratio = err / denom;
            mean_ratio += ratio;
            if ratio > 1.0 + 1e-6 {
                every_sample_ok = false;
            }
        }
        mean_ratio /= reps as f64;
        let delta = 1.0 - mean_ratio;
        sum_delta += delta;
        worst = worst.min(delta);
        if every_sample_ok {
            holds += 1;
        }
    }
    DeltaEstimate {
        mean_delta: sum_delta / trials as f64,
        worst_delta: worst,
        per_sample_holds: holds,
        trials,
    }
}

/// Standard Gaussian sampler for [`empirical_delta`].
pub fn gaussian_sampler(rng: &mut Pcg32, d: usize) -> Vec<f32> {
    rng.normal_vec(d)
}

/// Heavy-tailed sampler (Gaussian cubed) — stresses ‖·‖∞-scaled schemes.
pub fn heavy_tail_sampler(rng: &mut Pcg32, d: usize) -> Vec<f32> {
    (0..d)
        .map(|_| {
            let g = rng.normal();
            g * g * g
        })
        .collect()
}

/// Sparse sampler: ~10% nonzero — stresses ‖·‖₂-scaled schemes.
pub fn sparse_sampler(rng: &mut Pcg32, d: usize) -> Vec<f32> {
    (0..d)
        .map(|_| if rng.uniform() < 0.1 { rng.normal() } else { 0.0 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Identity, LinfStochastic, Qsgd, SignScale, TopK};

    #[test]
    fn identity_has_delta_one() {
        let mut rng = Pcg32::new(1);
        let est = empirical_delta(&Identity, 64, 20, 1, &mut rng, gaussian_sampler);
        assert!((est.mean_delta - 1.0).abs() < 1e-9);
        assert_eq!(est.per_sample_holds, 20);
    }

    #[test]
    fn topk_matches_theorem1() {
        // δ̂ ≥ k/d always, and per-sample contraction holds (biased, exact).
        let c = TopK::new(0.25);
        let mut rng = Pcg32::new(2);
        let d = 200;
        let est = empirical_delta(&c, d, 50, 1, &mut rng, gaussian_sampler);
        let guaranteed = c.delta(d).unwrap();
        assert!(est.worst_delta >= guaranteed - 1e-6, "{} < {}", est.worst_delta, guaranteed);
        assert_eq!(est.per_sample_holds, 50);
    }

    #[test]
    fn qsgd_and_linf_are_delta_approximate_in_expectation() {
        let mut rng = Pcg32::new(3);
        for c in [&Qsgd::with_bits(8) as &dyn Compressor, &LinfStochastic::with_bits(8)] {
            let est = empirical_delta(c, 512, 10, 20, &mut rng, gaussian_sampler);
            assert!(est.is_delta_approximate(), "{}: {est:?}", c.name());
            // At 8 bits both should be close to lossless on Gaussians.
            assert!(est.mean_delta > 0.9, "{}: {est:?}", c.name());
        }
    }

    #[test]
    fn sign_worst_case_is_one_over_d() {
        // One-hot vector achieves δ = 1/d exactly.
        let d = 16;
        let mut v = vec![0.0f32; d];
        v[3] = 2.0;
        let q = SignScale.compress_vec(&v, &mut Pcg32::new(4));
        let err: f64 = v.iter().zip(&q).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let ratio = err / (4.0);
        assert!((ratio - (1.0 - 1.0 / d as f64)).abs() < 1e-5, "ratio={ratio}");
    }
}
