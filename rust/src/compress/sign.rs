//! Sign compression with ℓ₁ scaling (signSGD with majority-vote scale à la
//! Bernstein et al. [3] / Karimireddy et al. [14]):
//!
//!   Q(v) = (‖v‖₁ / d) · sign(v)
//!
//! This choice of scale minimizes ‖Q(v) − v‖² among all c·sign(v) and gives
//! the identity ‖Q(v)−v‖² = ‖v‖² − ‖v‖₁²/d, i.e. a δ-approximate
//! compressor with the **input-dependent** δ = ‖v‖₁²/(d·‖v‖₂²) ∈ [1/d, 1].
//! The guaranteed worst case is δ = 1/d (one-hot input).
//!
//! Wire: `[scale:f32]` + 1 bit/element — a 32× reduction vs f32.

use super::codec::{BitReader, BitWriter};
use super::Compressor;
use crate::util::bytes::{put_f32, Reader};
use crate::util::rng::Pcg32;

/// `Q(v) = (‖v‖₁/d)·sign(v)`.
#[derive(Debug, Clone, Copy, Default)]
pub struct SignScale;

impl SignScale {
    fn scale_of(v: &[f32]) -> f32 {
        if v.is_empty() {
            return 0.0;
        }
        let l1: f64 = v.iter().map(|&x| x.abs() as f64).sum();
        (l1 / v.len() as f64) as f32
    }
}

impl Compressor for SignScale {
    fn name(&self) -> String {
        "sign".to_string()
    }

    fn compress(&self, v: &[f32], out: &mut [f32], _rng: &mut Pcg32) {
        assert_eq!(v.len(), out.len());
        let scale = Self::scale_of(v);
        for (o, &x) in out.iter_mut().zip(v) {
            // sign(0) = +1 here (the wire has no zero symbol); with the
            // l1 scale this is the standard convention.
            *o = if x < 0.0 { -scale } else { scale };
        }
    }

    fn encode(&self, quantized: &[f32], buf: &mut Vec<u8>) {
        let scale = quantized.first().map(|x| x.abs()).unwrap_or(0.0);
        put_f32(buf, scale);
        let mut w = BitWriter::with_capacity_bits(quantized.len());
        for &q in quantized {
            w.write(u32::from(q < 0.0), 1);
        }
        w.append_to(buf);
    }

    fn decode(&self, bytes: &[u8], d: usize) -> anyhow::Result<Vec<f32>> {
        let mut out = vec![0.0; d];
        self.decode_into(bytes, &mut out)?;
        Ok(out)
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) -> anyhow::Result<()> {
        let mut r = Reader::new(bytes);
        let scale = r.f32()?;
        let rest = r.bytes(bytes.len() - 4)?;
        let mut br = BitReader::new(rest);
        for o in out.iter_mut() {
            let neg = br.read(1)? == 1;
            *o = if neg { -scale } else { scale };
        }
        Ok(())
    }

    fn delta(&self, d: usize) -> Option<f64> {
        // Worst case over inputs: one-hot vector ⇒ δ = 1/d.
        Some(1.0 / d.max(1) as f64)
    }

    fn encoded_size(&self, d: usize) -> usize {
        4 + d.div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{norm2_sq};

    #[test]
    fn optimal_scale_identity() {
        // ‖Q(v)−v‖² = ‖v‖² − ‖v‖₁²/d exactly.
        let mut rng = Pcg32::new(31);
        for _ in 0..50 {
            let d = 1 + rng.below(100) as usize;
            let v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let q = SignScale.compress_vec(&v, &mut rng);
            let err: f64 =
                v.iter().zip(&q).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
            let l1: f64 = v.iter().map(|&x| x.abs() as f64).sum();
            let want = norm2_sq(&v) as f64 - l1 * l1 / d as f64;
            assert!((err - want).abs() < 1e-3 * want.abs().max(1.0), "err={err} want={want}");
        }
    }

    #[test]
    fn round_trip_bit_exact() {
        let mut rng = Pcg32::new(37);
        let v: Vec<f32> = (0..777).map(|_| rng.normal()).collect();
        let mut buf = Vec::new();
        let q = SignScale.compress_encoded(&v, &mut rng, &mut buf);
        assert_eq!(buf.len(), SignScale.encoded_size(v.len()));
        let back = SignScale.decode(&buf, v.len()).unwrap();
        for (a, b) in q.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn wire_is_32x_smaller() {
        let d = 1_000_000;
        let ratio = (4 * d) as f64 / SignScale.encoded_size(d) as f64;
        assert!(ratio > 31.0, "ratio={ratio}");
    }
}
