//! Sign compression with ℓ₁ scaling (signSGD with majority-vote scale à la
//! Bernstein et al. [3] / Karimireddy et al. [14]):
//!
//!   Q(v) = (‖v‖₁ / d) · sign(v)
//!
//! This choice of scale minimizes ‖Q(v) − v‖² among all c·sign(v) and gives
//! the identity ‖Q(v)−v‖² = ‖v‖² − ‖v‖₁²/d, i.e. a δ-approximate
//! compressor with the **input-dependent** δ = ‖v‖₁²/(d·‖v‖₂²) ∈ [1/d, 1].
//! The guaranteed worst case is δ = 1/d (one-hot input).
//!
//! Wire: `[scale:f32]` + 1 bit/element — a 32× reduction vs f32.

use super::codec::{BitReader, BitWriter};
use super::Compressor;
use crate::config::KernelMode;
use crate::kernels::{self, LANES};
use crate::util::bytes::{put_f32, Reader};
use crate::util::rng::Pcg32;

/// `Q(v) = (‖v‖₁/d)·sign(v)`.
#[derive(Debug, Clone, Copy, Default)]
pub struct SignScale;

impl SignScale {
    /// ‖v‖₁/d. The f64 accumulation is a strict sequential fold — it must
    /// not be reassociated (the f64 rounding order is part of the bitwise
    /// contract), so this stays scalar under both kernel modes.
    fn scale_of(v: &[f32]) -> f32 {
        if v.is_empty() {
            return 0.0;
        }
        let l1: f64 = v.iter().map(|&x| x.abs() as f64).sum();
        (l1 / v.len() as f64) as f32
    }

    /// SIMD arm of the sign select: 8 lanes per iteration of the same
    /// `if x < 0.0 { -scale } else { scale }` expression.
    fn select_simd(scale: f32, v: &[f32], out: &mut [f32]) {
        let mut oc = out.chunks_exact_mut(LANES);
        let mut vc = v.chunks_exact(LANES);
        for (o, x) in (&mut oc).zip(&mut vc) {
            let o: &mut [f32; LANES] = o.try_into().expect("exact chunk");
            let x: &[f32; LANES] = x.try_into().expect("exact chunk");
            for i in 0..LANES {
                o[i] = if x[i] < 0.0 { -scale } else { scale };
            }
        }
        for (o, &x) in oc.into_remainder().iter_mut().zip(vc.remainder()) {
            *o = if x < 0.0 { -scale } else { scale };
        }
    }

    /// SIMD arm of [`Compressor::decode_into`]: 32 sign bits arrive as
    /// one LE word (exactly the bytes 32 single-bit reads consume), the
    /// select runs over lanes, and the ragged tail reads a zero-padded
    /// word. Values are the same ±scale constants as the scalar loop.
    fn decode_into_simd(scale: f32, rest: &[u8], out: &mut [f32]) -> anyhow::Result<()> {
        let need_bits = out.len();
        if need_bits > rest.len() * 8 {
            anyhow::bail!("bit reader overrun: need {need_bits} bits, have {}", rest.len() * 8);
        }
        let mut pos = 0usize;
        let mut chunks = out.chunks_exact_mut(32);
        for chunk in &mut chunks {
            let w = u32::from_le_bytes(rest[pos..pos + 4].try_into().expect("4-byte slice"));
            pos += 4;
            let chunk: &mut [f32; 32] = chunk.try_into().expect("exact chunk");
            for j in 0..32 {
                chunk[j] = if (w >> j) & 1 == 1 { -scale } else { scale };
            }
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let mut tmp = [0u8; 4];
            let n = (rest.len() - pos).min(4);
            tmp[..n].copy_from_slice(&rest[pos..pos + n]);
            let w = u32::from_le_bytes(tmp);
            for (j, o) in rem.iter_mut().enumerate() {
                *o = if (w >> j) & 1 == 1 { -scale } else { scale };
            }
        }
        Ok(())
    }
}

impl Compressor for SignScale {
    fn name(&self) -> String {
        "sign".to_string()
    }

    fn compress(&self, v: &[f32], out: &mut [f32], _rng: &mut Pcg32) {
        assert_eq!(v.len(), out.len());
        let scale = Self::scale_of(v);
        match kernels::mode() {
            KernelMode::Simd => Self::select_simd(scale, v, out),
            KernelMode::Scalar => {
                for (o, &x) in out.iter_mut().zip(v) {
                    // sign(0) = +1 here (the wire has no zero symbol);
                    // with the l1 scale this is the standard convention.
                    *o = if x < 0.0 { -scale } else { scale };
                }
            }
        }
    }

    fn encode(&self, quantized: &[f32], buf: &mut Vec<u8>) {
        let scale = quantized.first().map(|x| x.abs()).unwrap_or(0.0);
        put_f32(buf, scale);
        let mut w = BitWriter::with_capacity_bits(quantized.len());
        match kernels::mode() {
            KernelMode::Simd => {
                // Batch 32 sign bits into one word write: bit j of the
                // word is sign j of the chunk — exactly the global bit
                // position the single-bit writes produce, so the wire
                // bytes are unchanged.
                let mut chunks = quantized.chunks_exact(32);
                for chunk in &mut chunks {
                    let chunk: &[f32; 32] = chunk.try_into().expect("exact chunk");
                    let mut word = 0u32;
                    for (j, &q) in chunk.iter().enumerate() {
                        word |= u32::from(q < 0.0) << j;
                    }
                    w.write(word, 32);
                }
                for &q in chunks.remainder() {
                    w.write(u32::from(q < 0.0), 1);
                }
            }
            KernelMode::Scalar => {
                for &q in quantized {
                    w.write(u32::from(q < 0.0), 1);
                }
            }
        }
        w.append_to(buf);
    }

    fn decode(&self, bytes: &[u8], d: usize) -> anyhow::Result<Vec<f32>> {
        let mut out = vec![0.0; d];
        self.decode_into(bytes, &mut out)?;
        Ok(out)
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) -> anyhow::Result<()> {
        let mut r = Reader::new(bytes);
        let scale = r.f32()?;
        let rest = r.bytes(bytes.len() - 4)?;
        if kernels::mode() == KernelMode::Simd {
            return Self::decode_into_simd(scale, rest, out);
        }
        let mut br = BitReader::new(rest);
        for o in out.iter_mut() {
            let neg = br.read(1)? == 1;
            *o = if neg { -scale } else { scale };
        }
        Ok(())
    }

    fn delta(&self, d: usize) -> Option<f64> {
        // Worst case over inputs: one-hot vector ⇒ δ = 1/d.
        Some(1.0 / d.max(1) as f64)
    }

    fn encoded_size(&self, d: usize) -> usize {
        4 + d.div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::norm2_sq;

    #[test]
    fn optimal_scale_identity() {
        // ‖Q(v)−v‖² = ‖v‖² − ‖v‖₁²/d exactly.
        let mut rng = Pcg32::new(31);
        for _ in 0..50 {
            let d = 1 + rng.below(100) as usize;
            let v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let q = SignScale.compress_vec(&v, &mut rng);
            let err: f64 = v.iter().zip(&q).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
            let l1: f64 = v.iter().map(|&x| x.abs() as f64).sum();
            let want = norm2_sq(&v) as f64 - l1 * l1 / d as f64;
            assert!((err - want).abs() < 1e-3 * want.abs().max(1.0), "err={err} want={want}");
        }
    }

    #[test]
    fn round_trip_bit_exact() {
        let mut rng = Pcg32::new(37);
        let v: Vec<f32> = (0..777).map(|_| rng.normal()).collect();
        let mut buf = Vec::new();
        let q = SignScale.compress_encoded(&v, &mut rng, &mut buf);
        assert_eq!(buf.len(), SignScale.encoded_size(v.len()));
        let back = SignScale.decode(&buf, v.len()).unwrap();
        for (a, b) in q.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn wire_is_32x_smaller() {
        let d = 1_000_000;
        let ratio = (4 * d) as f64 / SignScale.encoded_size(d) as f64;
        assert!(ratio > 31.0, "ratio={ratio}");
    }
}
