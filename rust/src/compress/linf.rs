//! The ‖·‖∞-scaled stochastic quantizer of Hou et al. [12] — the compressor
//! the paper's experiments use at 8 bits. Identical grid scheme to QSGD but
//! the scale is the max-magnitude (paper §2.4: "Hou et al. replaced the
//! ‖v‖₂ … with ‖v‖∞"), which wastes no levels when the vector is dense.
//!
//! Supports optional **blockwise** scaling (a scale per `block` elements),
//! mirroring the Pallas `quantize_ef` kernel's VMEM tiling: each block is
//! quantized against its own ‖·‖∞, which tightens the grid on heavy-tailed
//! gradients at a cost of one extra f32 per block on the wire.
//!
//! Wire: per block `[scale:f32]` + per element `1 sign bit + (bits−1)
//! level bits`. At 8 bits (s = 127) that is 8 bits/element + scales — the
//! paper's "1/4 full precision" setting.

use super::codec::{bits_for, BitReader, BitWriter, FixedWidthReader};
use super::Compressor;
use crate::config::KernelMode;
use crate::kernels::{self, LANES};
use crate::util::bytes::{put_f32, Reader};
use crate::util::rng::Pcg32;

/// ‖·‖∞-scaled stochastic quantizer with `s` levels and optional blocking.
#[derive(Debug, Clone, Copy)]
pub struct LinfStochastic {
    pub levels: u32,
    /// Elements per scale block (`usize::MAX` = one scale for the vector).
    pub block: usize,
}

impl LinfStochastic {
    pub fn new(levels: u32) -> Self {
        assert!(levels >= 1);
        Self { levels, block: usize::MAX }
    }

    /// m-bit budget: sign + (m−1) level bits, s = 2^(m−1) − 1 levels.
    pub fn with_bits(bits: u8) -> Self {
        assert!((2..=16).contains(&bits));
        Self::new((1u32 << (bits - 1)) - 1)
    }

    /// Blockwise variant (scale per `block` elements).
    pub fn with_block(mut self, block: usize) -> Self {
        assert!(block > 0);
        self.block = block;
        self
    }

    fn level_bits(&self) -> u8 {
        bits_for(self.levels)
    }

    fn block_len(&self, d: usize) -> usize {
        self.block.min(d.max(1))
    }

    fn num_blocks(&self, d: usize) -> usize {
        if d == 0 {
            0
        } else {
            d.div_ceil(self.block_len(d))
        }
    }

    /// Quantize one block to integer levels against its own ‖·‖∞.
    /// §Perf: one division per *block* (reciprocal-scaled multiply per
    /// element), branch-light stochastic rounding. Dispatches between the
    /// scalar baseline and the lane-chunked arm on the global
    /// [`crate::kernels`] mode; both draw one uniform per element in
    /// element order and evaluate identical per-element expressions, so
    /// the levels (and wire bits) are bitwise-equal.
    fn quantize_block(&self, v: &[f32], rng: &mut Pcg32) -> (f32, Vec<i32>) {
        let scale = v.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        if scale == 0.0 {
            return (0.0, vec![0; v.len()]);
        }
        let levels = match kernels::mode() {
            KernelMode::Simd => self.quantize_block_simd(scale, v, rng),
            KernelMode::Scalar => self.quantize_block_scalar(scale, v, rng),
        };
        (scale, levels)
    }

    /// Scalar arm of [`Self::quantize_block`] (`scale` is nonzero).
    fn quantize_block_scalar(&self, scale: f32, v: &[f32], rng: &mut Pcg32) -> Vec<i32> {
        let s = self.levels as f32;
        let k = s / scale;
        v.iter()
            .map(|&x| {
                let u = (x.abs() * k).min(s);
                let l = u.floor();
                // stochastic round up with prob (u − l)
                let level = (l + f32::from(rng.uniform() < u - l)) as i32;
                if x < 0.0 {
                    -level
                } else {
                    level
                }
            })
            .collect()
    }

    /// SIMD arm of [`Self::quantize_block`]: the float pipeline (scale,
    /// clamp, floor) chunks 8 lanes at a time; the stochastic finalize
    /// walks lanes sequentially because the per-element RNG draw order is
    /// part of the bitwise contract with the scalar arm.
    fn quantize_block_simd(&self, scale: f32, v: &[f32], rng: &mut Pcg32) -> Vec<i32> {
        let s = self.levels as f32;
        let k = s / scale;
        let mut out = Vec::with_capacity(v.len());
        let mut vc = v.chunks_exact(LANES);
        for x in &mut vc {
            let x: &[f32; LANES] = x.try_into().expect("exact chunk");
            let mut u = [0.0f32; LANES];
            let mut l = [0.0f32; LANES];
            for i in 0..LANES {
                u[i] = (x[i].abs() * k).min(s);
            }
            for i in 0..LANES {
                l[i] = u[i].floor();
            }
            for i in 0..LANES {
                let level = (l[i] + f32::from(rng.uniform() < u[i] - l[i])) as i32;
                out.push(if x[i] < 0.0 { -level } else { level });
            }
        }
        for &x in vc.remainder() {
            let u = (x.abs() * k).min(s);
            let l = u.floor();
            let level = (l + f32::from(rng.uniform() < u - l)) as i32;
            out.push(if x < 0.0 { -level } else { level });
        }
        out
    }

    fn reconstruct_block(&self, scale: f32, levels: &[i32], out: &mut [f32]) {
        // NOTE: must stay exactly `scale * (l / s)` — decode uses the same
        // expression, and the EF state requires bit-identical round trips.
        // Both kernel arms evaluate exactly that expression per lane.
        let s = self.levels as f32;
        kernels::grid_reconstruct(out, levels, scale, s);
    }

    /// SIMD arm of the per-block decode body: fixed-width gather of 8
    /// packed values per iteration plus the lane grid reconstruction —
    /// same bits consumed and produced as the [`BitReader`] loop.
    fn decode_block_simd(
        &self,
        packed_bytes: &[u8],
        scale: f32,
        width: u8,
        ob: &mut [f32],
    ) -> anyhow::Result<()> {
        let s = self.levels as f32;
        let fr = FixedWidthReader::new(packed_bytes, width, ob.len())?;
        let mut base = 0usize;
        let mut oc = ob.chunks_exact_mut(LANES);
        for o in &mut oc {
            let o: &mut [f32; LANES] = o.try_into().expect("exact chunk");
            let mut lv = [0i32; LANES];
            for i in 0..LANES {
                let packed = fr.get(base + i);
                let mag = (packed >> 1) as i32;
                lv[i] = if packed & 1 == 1 { -mag } else { mag };
            }
            kernels::grid_reconstruct_simd(o, &lv, scale, s);
            base += LANES;
        }
        for (i, o) in oc.into_remainder().iter_mut().enumerate() {
            let packed = fr.get(base + i);
            let mag = (packed >> 1) as i32;
            let l = if packed & 1 == 1 { -mag } else { mag };
            *o = scale * (l as f32 / s);
        }
        Ok(())
    }
}

impl Compressor for LinfStochastic {
    fn name(&self) -> String {
        if self.block == usize::MAX {
            format!("linf(s={})", self.levels)
        } else {
            format!("linf(s={},block={})", self.levels, self.block)
        }
    }

    fn compress(&self, v: &[f32], out: &mut [f32], rng: &mut Pcg32) {
        assert_eq!(v.len(), out.len());
        if v.is_empty() {
            return;
        }
        let bl = self.block_len(v.len());
        for (vb, ob) in v.chunks(bl).zip(out.chunks_mut(bl)) {
            let (scale, levels) = self.quantize_block(vb, rng);
            self.reconstruct_block(scale, &levels, ob);
        }
    }

    fn compress_encoded_into(
        &self,
        v: &[f32],
        rng: &mut Pcg32,
        buf: &mut Vec<u8>,
        q_out: &mut [f32],
    ) {
        assert_eq!(v.len(), q_out.len());
        if v.is_empty() {
            return;
        }
        let bl = self.block_len(v.len());
        let lb = self.level_bits();
        // Same combined `sign | level << 1` single-write trick as QSGD
        // (sign stays in the lower bit position, so the packed stream is
        // unchanged); this is the per-element hot loop of the paper's
        // experimental codec. Fallback pair only for degenerate s ≥ 2³¹.
        let width = 1 + lb;
        for (vb, ob) in v.chunks(bl).zip(q_out.chunks_mut(bl)) {
            let (scale, levels) = self.quantize_block(vb, rng);
            put_f32(buf, scale);
            let mut w = BitWriter::with_capacity_bits(vb.len() * width as usize);
            if width <= 32 {
                for &l in &levels {
                    let mag = l.unsigned_abs().min(self.levels);
                    w.write(u32::from(l < 0) | (mag << 1), width);
                }
            } else {
                for &l in &levels {
                    w.write(u32::from(l < 0), 1);
                    w.write(l.unsigned_abs().min(self.levels), lb);
                }
            }
            w.append_to(buf);
            self.reconstruct_block(scale, &levels, ob);
        }
    }

    fn encode(&self, quantized: &[f32], buf: &mut Vec<u8>) {
        // Dense grid values are scale·k/s; within each block the max |q|
        // is at the top occupied level. Unlike the ‖·‖₂ case, scale ≥
        // max|q| with equality iff some element hit level s; recover by
        // grid search from level s downward (test/tooling path — the hot
        // path uses compress_encoded).
        if quantized.is_empty() {
            return;
        }
        let bl = self.block_len(quantized.len());
        let s = self.levels as f32;
        let lb = self.level_bits();
        for qb in quantized.chunks(bl) {
            let max_abs = qb.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
            if max_abs == 0.0 {
                put_f32(buf, 0.0);
                let mut w = BitWriter::with_capacity_bits(qb.len() * (1 + lb as usize));
                for _ in qb {
                    w.write(0, 1);
                    w.write(0, lb);
                }
                w.append_to(buf);
                continue;
            }
            let mut found: Option<(f32, Vec<i32>)> = None;
            'cand: for l_max in (1..=self.levels).rev() {
                let scale = max_abs * s / l_max as f32;
                let mut levels = Vec::with_capacity(qb.len());
                for &q in qb {
                    let u = q.abs() / scale * s;
                    let j = u.round();
                    if (u - j).abs() > 1e-3 * j.max(1.0) || j > s {
                        continue 'cand;
                    }
                    levels.push(if q < 0.0 { -(j as i32) } else { j as i32 });
                }
                found = Some((scale, levels));
                break;
            }
            let (scale, levels) = found.unwrap_or_else(|| {
                let scale = max_abs;
                let levels = qb
                    .iter()
                    .map(|&q| {
                        let j = (q.abs() / scale * s).round().min(s) as i32;
                        if q < 0.0 {
                            -j
                        } else {
                            j
                        }
                    })
                    .collect();
                (scale, levels)
            });
            put_f32(buf, scale);
            let mut w = BitWriter::with_capacity_bits(qb.len() * (1 + lb as usize));
            for &l in &levels {
                w.write(u32::from(l < 0), 1);
                w.write(l.unsigned_abs().min(self.levels), lb);
            }
            w.append_to(buf);
        }
    }

    fn decode(&self, bytes: &[u8], d: usize) -> anyhow::Result<Vec<f32>> {
        let mut out = vec![0.0; d];
        self.decode_into(bytes, &mut out)?;
        Ok(out)
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) -> anyhow::Result<()> {
        let d = out.len();
        if d == 0 {
            return Ok(());
        }
        let bl = self.block_len(d);
        let lb = self.level_bits();
        let s = self.levels as f32;
        let mut pos = 0usize;
        for ob in out.chunks_mut(bl) {
            let mut r = Reader::new(&bytes[pos..]);
            let scale = r.f32()?;
            pos += 4;
            let packed_bytes = (ob.len() * (1 + lb as usize)).div_ceil(8);
            if pos + packed_bytes > bytes.len() {
                anyhow::bail!("linf decode: truncated block");
            }
            let block_bytes = &bytes[pos..pos + packed_bytes];
            pos += packed_bytes;
            // Mirror of the combined-write encode: one read per element.
            let width = 1 + lb;
            if width <= 32 && kernels::mode() == KernelMode::Simd {
                self.decode_block_simd(block_bytes, scale, width, ob)?;
                continue;
            }
            let mut br = BitReader::new(block_bytes);
            for o in ob.iter_mut() {
                let (sign, mag) = if width <= 32 {
                    let packed = br.read(width)?;
                    (packed & 1, (packed >> 1) as i32)
                } else {
                    (br.read(1)?, br.read(lb)? as i32)
                };
                let l = if sign == 1 { -mag } else { mag };
                // NOTE: must stay exactly `scale * (l / s)` — see
                // `reconstruct_block`; the EF state requires bit-identical
                // round trips.
                *o = scale * (l as f32 / s);
            }
        }
        Ok(())
    }

    fn delta(&self, d: usize) -> Option<f64> {
        // Per-element stochastic rounding on a grid of spacing scale/s has
        // conditional variance ≤ (scale/s)²/4; summed over a block of b
        // elements: E‖Q(v)−v‖² ≤ b·scale²/(4s²) ≤ (b/(4s²))·‖v_block‖²·…
        // only bounded relative to ‖v‖² when scale² ≤ ‖v‖² (true since
        // scale = ‖v‖∞ ≤ ‖v‖₂). Hence δ ≥ 1 − b/(4s²) when positive.
        let b = self.block_len(d) as f64;
        let s = self.levels as f64;
        let var = b / (4.0 * s * s);
        if var < 1.0 {
            Some(1.0 - var)
        } else {
            None
        }
    }

    fn encoded_size(&self, d: usize) -> usize {
        let bl = self.block_len(d);
        let lb = 1 + self.level_bits() as usize;
        let mut size = 0;
        let mut rem = d;
        for _ in 0..self.num_blocks(d) {
            let n = bl.min(rem);
            size += 4 + (n * lb).div_ceil(8);
            rem -= n;
        }
        size
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbiasedness() {
        let c = LinfStochastic::new(4);
        let v = [0.3f32, -0.7, 0.05, 1.0];
        let mut rng = Pcg32::new(5);
        let trials = 20_000;
        let mut acc = [0.0f64; 4];
        for _ in 0..trials {
            let q = c.compress_vec(&v, &mut rng);
            for i in 0..4 {
                acc[i] += q[i] as f64;
            }
        }
        for i in 0..4 {
            let mean = acc[i] / trials as f64;
            assert!((mean - v[i] as f64).abs() < 0.02, "i={i} mean={mean}");
        }
    }

    #[test]
    fn max_element_is_representable_exactly_in_expectation() {
        // With ‖·‖∞ scaling the max element sits exactly on the top level.
        let c = LinfStochastic::with_bits(8);
        let v = [0.1f32, -2.0, 0.5];
        let q = c.compress_vec(&v, &mut Pcg32::new(3));
        assert_eq!(q[1], -2.0);
    }

    #[test]
    fn fused_round_trip_bit_exact_various_blocks() {
        let mut rng = Pcg32::new(17);
        for block in [usize::MAX, 8, 64, 100] {
            let c = LinfStochastic::with_bits(8).with_block(block);
            for _ in 0..10 {
                let d = 1 + rng.below(400) as usize;
                let v: Vec<f32> = (0..d).map(|_| rng.normal() * 2.0).collect();
                let mut buf = Vec::new();
                let q = c.compress_encoded(&v, &mut rng, &mut buf);
                assert_eq!(buf.len(), c.encoded_size(d), "block={block} d={d}");
                let back = c.decode(&buf, d).unwrap();
                for (a, b) in q.iter().zip(&back) {
                    assert_eq!(a.to_bits(), b.to_bits(), "block={block}");
                }
            }
        }
    }

    #[test]
    fn generic_encode_round_trips() {
        let c = LinfStochastic::with_bits(6).with_block(32);
        let mut rng = Pcg32::new(23);
        let v: Vec<f32> = (0..150).map(|_| rng.normal()).collect();
        let q = c.compress_vec(&v, &mut rng);
        let mut buf = Vec::new();
        c.encode(&q, &mut buf);
        let back = c.decode(&buf, q.len()).unwrap();
        for (a, b) in q.iter().zip(&back) {
            assert!((a - b).abs() <= 1e-4 * a.abs().max(1e-3), "{a} vs {b}");
        }
    }

    #[test]
    fn eight_bit_wire_is_quarter_of_f32() {
        let c = LinfStochastic::with_bits(8);
        let d = 1_000_000;
        let ratio = (4 * d) as f64 / c.encoded_size(d) as f64;
        assert!(ratio > 3.9 && ratio <= 4.0, "ratio={ratio}");
    }

    #[test]
    fn delta_closed_form() {
        let c = LinfStochastic::with_bits(8); // s=127
        let delta = c.delta(1000).unwrap();
        // blockless: b=d=1000, 1 - 1000/(4·127²) ≈ 0.9845
        assert!(delta > 0.98, "delta={delta}");
        let cb = LinfStochastic::with_bits(8).with_block(128);
        assert!(cb.delta(100_000).unwrap() > 0.99);
    }

    #[test]
    fn zero_vector() {
        let c = LinfStochastic::with_bits(8).with_block(4);
        let mut buf = Vec::new();
        let q = c.compress_encoded(&[0.0; 10], &mut Pcg32::new(1), &mut buf);
        assert_eq!(q, vec![0.0; 10]);
        assert_eq!(c.decode(&buf, 10).unwrap(), vec![0.0; 10]);
    }
}
