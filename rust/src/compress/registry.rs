//! Config-string → compressor factory, e.g. `"linf8"`, `"qsgd(s=63)"`,
//! `"topk(f=0.1)"`, `"identity"`. Used by the CLI and the config system so
//! every experiment can select its compressor from a flag.

use super::{Compressor, Identity, LinfStochastic, Qsgd, SignScale, TernGrad, TopK};

/// Parsed compressor specification.
#[derive(Debug, Clone, PartialEq)]
pub enum CompressorSpec {
    Identity,
    TopK { fraction: f64 },
    Qsgd { levels: u32 },
    Linf { levels: u32, block: Option<usize> },
    Sign,
    TernGrad,
}

impl CompressorSpec {
    /// Parse `"name"` or `"name(arg=val,...)"`; also accepts the
    /// shorthands `qsgd8` / `linf8` (m-bit budget).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let s = s.trim();
        let (name, args) = match s.find('(') {
            Some(i) => {
                let name = &s[..i];
                let rest = s[i + 1..]
                    .strip_suffix(')')
                    .ok_or_else(|| anyhow::anyhow!("missing ')' in compressor spec '{s}'"))?;
                (name, Some(rest))
            }
            None => (s, None),
        };
        let kv = |args: Option<&str>| -> anyhow::Result<Vec<(String, String)>> {
            let mut out = Vec::new();
            if let Some(a) = args {
                for part in a.split(',').filter(|p| !p.trim().is_empty()) {
                    let (k, v) = part
                        .split_once('=')
                        .ok_or_else(|| anyhow::anyhow!("bad arg '{part}' in '{s}'"))?;
                    out.push((k.trim().to_string(), v.trim().to_string()));
                }
            }
            Ok(out)
        };
        // m-bit shorthands.
        if let Some(bits) = name.strip_prefix("qsgd").and_then(|b| b.parse::<u8>().ok()) {
            return Ok(Self::Qsgd { levels: (1u32 << (bits - 1)) - 1 });
        }
        if let Some(bits) = name.strip_prefix("linf").and_then(|b| b.parse::<u8>().ok()) {
            return Ok(Self::Linf { levels: (1u32 << (bits - 1)) - 1, block: None });
        }
        match name {
            "identity" | "none" | "fp32" => Ok(Self::Identity),
            "sign" => Ok(Self::Sign),
            "terngrad" | "tern" => Ok(Self::TernGrad),
            "topk" => {
                let mut fraction = 0.1f64;
                for (k, v) in kv(args)? {
                    match k.as_str() {
                        "f" | "fraction" => fraction = v.parse()?,
                        "k" => anyhow::bail!("topk takes a fraction 'f=', not absolute 'k='"),
                        _ => anyhow::bail!("unknown topk arg '{k}'"),
                    }
                }
                Ok(Self::TopK { fraction })
            }
            "qsgd" => {
                let mut levels = 127u32;
                for (k, v) in kv(args)? {
                    match k.as_str() {
                        "s" | "levels" => levels = v.parse()?,
                        "bits" => levels = (1u32 << (v.parse::<u8>()? - 1)) - 1,
                        _ => anyhow::bail!("unknown qsgd arg '{k}'"),
                    }
                }
                Ok(Self::Qsgd { levels })
            }
            "linf" | "hou" => {
                let mut levels = 127u32;
                let mut block = None;
                for (k, v) in kv(args)? {
                    match k.as_str() {
                        "s" | "levels" => levels = v.parse()?,
                        "bits" => levels = (1u32 << (v.parse::<u8>()? - 1)) - 1,
                        "block" => block = Some(v.parse()?),
                        _ => anyhow::bail!("unknown linf arg '{k}'"),
                    }
                }
                Ok(Self::Linf { levels, block })
            }
            other => anyhow::bail!(
                "unknown compressor '{other}' (expected identity|topk|qsgd|linf|sign|terngrad)"
            ),
        }
    }

    /// Instantiate the compressor.
    pub fn build(&self) -> Box<dyn Compressor> {
        match *self {
            Self::Identity => Box::new(Identity),
            Self::TopK { fraction } => Box::new(TopK::new(fraction)),
            Self::Qsgd { levels } => Box::new(Qsgd::new(levels)),
            Self::Linf { levels, block } => {
                let c = LinfStochastic::new(levels);
                Box::new(match block {
                    Some(b) => c.with_block(b),
                    None => c,
                })
            }
            Self::Sign => Box::new(SignScale),
            Self::TernGrad => Box::new(TernGrad),
        }
    }
}

/// One-shot: parse + build.
pub fn compressor_from_spec(s: &str) -> anyhow::Result<Box<dyn Compressor>> {
    Ok(CompressorSpec::parse(s)?.build())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_shorthands() {
        assert_eq!(CompressorSpec::parse("linf8").unwrap(), CompressorSpec::Linf {
            levels: 127,
            block: None
        });
        assert_eq!(CompressorSpec::parse("qsgd4").unwrap(), CompressorSpec::Qsgd { levels: 7 });
        assert_eq!(CompressorSpec::parse("identity").unwrap(), CompressorSpec::Identity);
        assert_eq!(CompressorSpec::parse("fp32").unwrap(), CompressorSpec::Identity);
    }

    #[test]
    fn parses_args() {
        assert_eq!(
            CompressorSpec::parse("topk(f=0.05)").unwrap(),
            CompressorSpec::TopK { fraction: 0.05 }
        );
        assert_eq!(
            CompressorSpec::parse("linf(bits=8, block=128)").unwrap(),
            CompressorSpec::Linf { levels: 127, block: Some(128) }
        );
        assert_eq!(
            CompressorSpec::parse("qsgd(s=63)").unwrap(),
            CompressorSpec::Qsgd { levels: 63 }
        );
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(CompressorSpec::parse("bogus").is_err());
        assert!(CompressorSpec::parse("topk(k=5)").is_err());
        assert!(CompressorSpec::parse("linf(bits=8").is_err());
        assert!(CompressorSpec::parse("qsgd(wat=1)").is_err());
    }

    #[test]
    fn builds_working_compressors() {
        for s in ["identity", "topk(f=0.2)", "qsgd8", "linf8", "sign", "terngrad"] {
            let c = compressor_from_spec(s).unwrap();
            let v = [1.0f32, -2.0, 3.0, -4.0];
            let mut rng = crate::util::rng::Pcg32::new(5);
            let mut buf = Vec::new();
            let q = c.compress_encoded(&v, &mut rng, &mut buf);
            assert_eq!(q.len(), 4, "{s}");
            assert_eq!(buf.len(), c.encoded_size(4), "{s}");
            let back = c.decode(&buf, 4).unwrap();
            assert_eq!(q, back, "{s}");
        }
    }
}
