//! Bit-level packing for sub-byte quantization levels.
//!
//! QSGD/linf with `s` levels need ⌈log2(2s+1)⌉ bits per element (sign +
//! level); 8-bit mode is the paper's experimental setting. The writer packs
//! little-endian within each byte (LSB first), the reader mirrors it.

/// Append-only bit writer (LSB-first within bytes).
///
/// Implementation: a 64-bit accumulator drains **whole 32-bit words**
/// into the buffer — one shift/or per `write`, a single branch, and one
/// amortized 4-byte store per 32 bits written (§Perf: the word-level
/// drain replaces the original per-byte push loop; only the final
/// partial word is flushed byte-wise in [`Self::flush`]). The byte
/// layout is unchanged: flushing the low 32 bits as one little-endian
/// word emits exactly the four bytes the per-byte loop would have.
#[derive(Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    acc: u64,
    /// Bits currently buffered in `acc` (invariant: < 32 between writes,
    /// so a ≤ 32-bit value always fits the 64-bit accumulator).
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity_bits(bits: usize) -> Self {
        Self { buf: Vec::with_capacity(bits.div_ceil(8)), acc: 0, nbits: 0 }
    }

    /// Write the low `n` bits of `v` (n ≤ 32).
    #[inline]
    pub fn write(&mut self, v: u32, n: u8) {
        debug_assert!(n <= 32);
        debug_assert!(n == 32 || v < (1u32 << n), "value {v} exceeds {n} bits");
        self.acc |= (v as u64) << self.nbits;
        self.nbits += n as u32;
        if self.nbits >= 32 {
            // Drain one whole word: the low 32 bits are the earliest
            // bits, so the LE word equals the four bytes the per-byte
            // drain produced.
            self.buf.extend_from_slice(&(self.acc as u32).to_le_bytes());
            self.acc >>= 32;
            self.nbits -= 32;
        }
    }

    /// Total bits written.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    fn flush(&mut self) {
        // Unaligned tail only: up to 31 bits remain after the word-level
        // drain; the final partial byte is zero-padded as before.
        while self.nbits > 0 {
            self.buf.push(self.acc as u8);
            self.acc >>= 8;
            self.nbits = self.nbits.saturating_sub(8);
        }
    }

    /// Finish and return the byte buffer (final partial byte zero-padded).
    pub fn into_bytes(mut self) -> Vec<u8> {
        self.flush();
        self.buf
    }

    /// Append the packed bits onto an existing Vec<u8>.
    pub fn append_to(mut self, out: &mut Vec<u8>) {
        self.flush();
        out.extend_from_slice(&self.buf);
    }
}

/// Bit reader matching [`BitWriter`]'s layout (accumulator-based, with a
/// word-level refill: four wire bytes enter the accumulator at once while
/// at least a whole word remains, and the byte-at-a-time path only ever
/// runs on the unaligned tail at the very end of the buffer).
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// Next byte index to refill from.
    pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0, acc: 0, nbits: 0 }
    }

    /// Refill the accumulator until it holds ≥ `n` bits: whole 32-bit LE
    /// words while the buffer has them (`nbits < n ≤ 32` implies ≤ 31
    /// buffered bits, so a fresh word always fits the u64), then single
    /// bytes for the tail of the buffer only.
    #[inline]
    fn refill(&mut self, n: u32) -> anyhow::Result<()> {
        while self.nbits < n && self.pos + 4 <= self.buf.len() {
            let w = u32::from_le_bytes(
                self.buf[self.pos..self.pos + 4].try_into().expect("4-byte slice"),
            );
            self.acc |= (w as u64) << self.nbits;
            self.pos += 4;
            self.nbits += 32;
        }
        while self.nbits < n {
            if self.pos >= self.buf.len() {
                anyhow::bail!(
                    "bit reader overrun: need {n} bits, have {} (+{} unread bytes)",
                    self.nbits,
                    self.buf.len() - self.pos
                );
            }
            self.acc |= (self.buf[self.pos] as u64) << self.nbits;
            self.pos += 1;
            self.nbits += 8;
        }
        Ok(())
    }

    /// Read `n` bits (n ≤ 32); errors on overrun.
    #[inline]
    pub fn read(&mut self, n: u8) -> anyhow::Result<u32> {
        debug_assert!(n <= 32);
        let n = n as u32;
        if self.nbits < n {
            self.refill(n)?;
        }
        let mask = if n == 32 { u32::MAX as u64 } else { (1u64 << n) - 1 };
        let out = (self.acc & mask) as u32;
        self.acc >>= n;
        self.nbits -= n;
        Ok(out)
    }

    pub fn bits_remaining(&self) -> usize {
        (self.buf.len() - self.pos) * 8 + self.nbits as usize
    }
}

/// Branch-light random-access reader over a **fixed-width** packed
/// stream — the common case of the codecs, where every element is written
/// with the same width `w`. Element `i` occupies bits `[i·w, (i+1)·w)` of
/// the buffer, LSB-first: exactly [`BitWriter`]'s layout when all writes
/// share one width. The SIMD decode arms use this to gather 8 packed
/// values per iteration with unaligned u64 loads instead of the
/// per-element refill branch of [`BitReader`]; truncation is checked
/// once, up front, so extraction itself never fails.
#[derive(Debug)]
pub struct FixedWidthReader<'a> {
    buf: &'a [u8],
    width: usize,
    mask: u64,
}

impl<'a> FixedWidthReader<'a> {
    /// Build a reader for `count` elements of `width` bits (1 ≤ width ≤
    /// 32); errors if the buffer cannot hold them.
    pub fn new(buf: &'a [u8], width: u8, count: usize) -> anyhow::Result<Self> {
        anyhow::ensure!((1..=32).contains(&width), "fixed width {width} out of range");
        let need_bits = count * width as usize;
        let have_bits = buf.len() * 8;
        if need_bits > have_bits {
            anyhow::bail!("bit reader overrun: need {need_bits} bits, have {have_bits}");
        }
        Ok(Self { buf, width: width as usize, mask: (1u64 << width) - 1 })
    }

    /// Packed value of element `i` (i < the `count` passed to `new`; a
    /// larger `i` reads zero-padding or panics on the slice bound).
    #[inline]
    pub fn get(&self, i: usize) -> u32 {
        let bit = i * self.width;
        let byte = bit >> 3;
        let shift = bit & 7;
        // shift ≤ 7 and width ≤ 32, so the value always sits inside the
        // 64-bit window starting at `byte`; near the end of the buffer
        // the window is topped up with zero padding (never read past the
        // slice).
        let word = if byte + 8 <= self.buf.len() {
            u64::from_le_bytes(self.buf[byte..byte + 8].try_into().expect("8-byte slice"))
        } else {
            let mut tmp = [0u8; 8];
            let n = self.buf.len() - byte;
            tmp[..n].copy_from_slice(&self.buf[byte..]);
            u64::from_le_bytes(tmp)
        };
        ((word >> shift) & self.mask) as u32
    }
}

/// Bits needed to represent values 0..=max_value.
pub fn bits_for(max_value: u32) -> u8 {
    if max_value == 0 {
        1
    } else {
        (32 - max_value.leading_zeros()) as u8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn single_bits_round_trip() {
        let mut w = BitWriter::new();
        let pattern = [1u32, 0, 1, 1, 0, 1, 0, 0, 1, 1];
        for &b in &pattern {
            w.write(b, 1);
        }
        assert_eq!(w.bit_len(), pattern.len());
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &b in &pattern {
            assert_eq!(r.read(1).unwrap(), b);
        }
    }

    #[test]
    fn mixed_widths_round_trip() {
        let mut w = BitWriter::new();
        w.write(5, 3);
        w.write(255, 8);
        w.write(0b1011, 4);
        w.write(1, 1);
        w.write(123_456, 17);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(3).unwrap(), 5);
        assert_eq!(r.read(8).unwrap(), 255);
        assert_eq!(r.read(4).unwrap(), 0b1011);
        assert_eq!(r.read(1).unwrap(), 1);
        assert_eq!(r.read(17).unwrap(), 123_456);
    }

    #[test]
    fn random_round_trip() {
        let mut rng = Pcg32::new(99);
        for _ in 0..50 {
            let n = 1 + rng.below(200) as usize;
            let widths: Vec<u8> = (0..n).map(|_| 1 + rng.below(24) as u8).collect();
            let values: Vec<u32> = widths
                .iter()
                .map(|&w| {
                    let max = if w == 32 { u32::MAX } else { (1u32 << w) - 1 };
                    rng.below(max.max(1)).min(max)
                })
                .collect();
            let mut w = BitWriter::new();
            for (v, &width) in values.iter().zip(&widths) {
                w.write(*v, width);
            }
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            for (v, &width) in values.iter().zip(&widths) {
                assert_eq!(r.read(width).unwrap(), *v);
            }
        }
    }

    #[test]
    fn word_drain_and_word_refill_round_trip() {
        // Widths that straddle the 32-bit drain boundary on almost every
        // write (31-bit values) plus full-word writes, ending on an
        // unaligned tail — exercises the word-level fast paths and the
        // byte-wise tail flush/refill together.
        let mut w = BitWriter::new();
        let mut expect = Vec::new();
        for i in 0..100u32 {
            let v = (0x55AA_33CC ^ i.wrapping_mul(0x9E37_79B9)) & 0x7FFF_FFFF;
            w.write(v, 31);
            expect.push((v, 31u8));
        }
        for i in 0..8u32 {
            let v = 0xDEAD_BEEF ^ i;
            w.write(v, 32);
            expect.push((v, 32));
        }
        w.write(0b101, 3); // unaligned tail
        expect.push((0b101, 3));
        let total_bits: usize = expect.iter().map(|&(_, n)| n as usize).sum();
        assert_eq!(w.bit_len(), total_bits);
        let bytes = w.into_bytes();
        assert_eq!(bytes.len(), total_bits.div_ceil(8));
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &expect {
            assert_eq!(r.read(n).unwrap(), v, "width {n}");
        }
        assert!(r.bits_remaining() < 8);
    }

    #[test]
    fn fixed_width_reader_matches_bit_reader() {
        // For every width and count straddling word/byte boundaries, a
        // stream of width-w writes must read back identically through
        // the random-access fixed-width path.
        let mut rng = Pcg32::new(7);
        for width in 1..=32u8 {
            for count in [0usize, 1, 7, 8, 9, 15, 16, 17, 33] {
                let max = if width == 32 { u32::MAX } else { (1u32 << width) - 1 };
                let values: Vec<u32> =
                    (0..count).map(|_| rng.below(max.max(1)).min(max)).collect();
                let mut w = BitWriter::new();
                for &v in &values {
                    w.write(v, width);
                }
                let bytes = w.into_bytes();
                let f = FixedWidthReader::new(&bytes, width, count).unwrap();
                let mut r = BitReader::new(&bytes);
                for (i, &v) in values.iter().enumerate() {
                    assert_eq!(f.get(i), v, "width={width} count={count} i={i}");
                    assert_eq!(r.read(width).unwrap(), v);
                }
            }
        }
    }

    #[test]
    fn fixed_width_reader_rejects_truncation() {
        let bytes = [0xFFu8; 2]; // 16 bits
        assert!(FixedWidthReader::new(&bytes, 8, 2).is_ok());
        assert!(FixedWidthReader::new(&bytes, 8, 3).is_err());
        assert!(FixedWidthReader::new(&bytes, 5, 3).is_ok()); // 15 ≤ 16
        assert!(FixedWidthReader::new(&bytes, 0, 1).is_err());
        assert!(FixedWidthReader::new(&bytes, 33, 0).is_err());
    }

    #[test]
    fn overrun_is_error() {
        let mut w = BitWriter::new();
        w.write(3, 2);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert!(r.read(2).is_ok());
        // The partial byte has 6 padding bits; reading past them errors.
        assert!(r.read(7).is_err());
    }

    #[test]
    fn bits_for_values() {
        assert_eq!(bits_for(0), 1);
        assert_eq!(bits_for(1), 1);
        assert_eq!(bits_for(2), 2);
        assert_eq!(bits_for(255), 8);
        assert_eq!(bits_for(256), 9);
    }
}
