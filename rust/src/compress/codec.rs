//! Bit-level packing for sub-byte quantization levels.
//!
//! QSGD/linf with `s` levels need ⌈log2(2s+1)⌉ bits per element (sign +
//! level); 8-bit mode is the paper's experimental setting. The writer packs
//! little-endian within each byte (LSB first), the reader mirrors it.

/// Append-only bit writer (LSB-first within bytes).
///
/// Implementation: a 64-bit accumulator drains whole bytes into the
/// buffer — one branchless shift/or per `write` plus amortized byte
/// stores (§Perf: ~3× over the original per-byte loop).
#[derive(Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    acc: u64,
    /// Bits currently buffered in `acc` (0..8 after each write drain).
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity_bits(bits: usize) -> Self {
        Self { buf: Vec::with_capacity(bits.div_ceil(8)), acc: 0, nbits: 0 }
    }

    /// Write the low `n` bits of `v` (n ≤ 32).
    #[inline]
    pub fn write(&mut self, v: u32, n: u8) {
        debug_assert!(n <= 32);
        debug_assert!(n == 32 || v < (1u32 << n), "value {v} exceeds {n} bits");
        self.acc |= (v as u64) << self.nbits;
        self.nbits += n as u32;
        while self.nbits >= 8 {
            self.buf.push(self.acc as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    /// Total bits written.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    fn flush(&mut self) {
        if self.nbits > 0 {
            self.buf.push(self.acc as u8);
            self.acc = 0;
            self.nbits = 0;
        }
    }

    /// Finish and return the byte buffer (final partial byte zero-padded).
    pub fn into_bytes(mut self) -> Vec<u8> {
        self.flush();
        self.buf
    }

    /// Append the packed bits onto an existing Vec<u8>.
    pub fn append_to(mut self, out: &mut Vec<u8>) {
        self.flush();
        out.extend_from_slice(&self.buf);
    }
}

/// Bit reader matching [`BitWriter`]'s layout (accumulator-based).
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// Next byte index to refill from.
    pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0, acc: 0, nbits: 0 }
    }

    /// Read `n` bits (n ≤ 32); errors on overrun.
    #[inline]
    pub fn read(&mut self, n: u8) -> anyhow::Result<u32> {
        debug_assert!(n <= 32);
        let n = n as u32;
        while self.nbits < n {
            if self.pos >= self.buf.len() {
                anyhow::bail!(
                    "bit reader overrun: need {n} bits, have {} (+{} unread bytes)",
                    self.nbits,
                    self.buf.len() - self.pos
                );
            }
            self.acc |= (self.buf[self.pos] as u64) << self.nbits;
            self.pos += 1;
            self.nbits += 8;
        }
        let mask = if n == 32 { u32::MAX as u64 } else { (1u64 << n) - 1 };
        let out = (self.acc & mask) as u32;
        self.acc >>= n;
        self.nbits -= n;
        Ok(out)
    }

    pub fn bits_remaining(&self) -> usize {
        (self.buf.len() - self.pos) * 8 + self.nbits as usize
    }
}

/// Bits needed to represent values 0..=max_value.
pub fn bits_for(max_value: u32) -> u8 {
    if max_value == 0 {
        1
    } else {
        (32 - max_value.leading_zeros()) as u8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn single_bits_round_trip() {
        let mut w = BitWriter::new();
        let pattern = [1u32, 0, 1, 1, 0, 1, 0, 0, 1, 1];
        for &b in &pattern {
            w.write(b, 1);
        }
        assert_eq!(w.bit_len(), pattern.len());
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &b in &pattern {
            assert_eq!(r.read(1).unwrap(), b);
        }
    }

    #[test]
    fn mixed_widths_round_trip() {
        let mut w = BitWriter::new();
        w.write(5, 3);
        w.write(255, 8);
        w.write(0b1011, 4);
        w.write(1, 1);
        w.write(123_456, 17);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(3).unwrap(), 5);
        assert_eq!(r.read(8).unwrap(), 255);
        assert_eq!(r.read(4).unwrap(), 0b1011);
        assert_eq!(r.read(1).unwrap(), 1);
        assert_eq!(r.read(17).unwrap(), 123_456);
    }

    #[test]
    fn random_round_trip() {
        let mut rng = Pcg32::new(99);
        for _ in 0..50 {
            let n = 1 + rng.below(200) as usize;
            let widths: Vec<u8> = (0..n).map(|_| 1 + rng.below(24) as u8).collect();
            let values: Vec<u32> = widths
                .iter()
                .map(|&w| {
                    let max = if w == 32 { u32::MAX } else { (1u32 << w) - 1 };
                    rng.below(max.max(1)).min(max)
                })
                .collect();
            let mut w = BitWriter::new();
            for (v, &width) in values.iter().zip(&widths) {
                w.write(*v, width);
            }
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            for (v, &width) in values.iter().zip(&widths) {
                assert_eq!(r.read(width).unwrap(), *v);
            }
        }
    }

    #[test]
    fn overrun_is_error() {
        let mut w = BitWriter::new();
        w.write(3, 2);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert!(r.read(2).is_ok());
        // The partial byte has 6 padding bits; reading past them errors.
        assert!(r.read(7).is_err());
    }

    #[test]
    fn bits_for_values() {
        assert_eq!(bits_for(0), 1);
        assert_eq!(bits_for(1), 1);
        assert_eq!(bits_for(2), 2);
        assert_eq!(bits_for(255), 8);
        assert_eq!(bits_for(256), 9);
    }
}
