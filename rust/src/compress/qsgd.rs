//! QSGD (Alistarh et al. [1]): unbiased stochastic quantization onto a
//! uniform grid of `s` levels scaled by ‖v‖₂ (paper eq. 19–20).
//!
//!   Q(v_i) = sign(v_i) · ‖v‖₂ · ξ_i(v, s),
//!   ξ_i = l/s w.p. 1 − (|v_i|/‖v‖₂·s − l), else (l+1)/s.
//!
//! E[Q(v)] = v; the classical variance bound gives
//! E‖Q(v)−v‖² ≤ min(d/s², √d/s)·‖v‖², so QSGD is a δ-approximate
//! compressor with δ = 1 − min(d/s², √d/s) whenever that is positive
//! (the paper's Theorem 2 asserts existence of such δ in general; for
//! small s and large d use [`super::empirical_delta`]).
//!
//! Wire format: `[norm:f32]` then per element `1 sign bit + ⌈log2(s+1)⌉
//! level bits`, bit-packed. For s = 255 that is 9 bits/element — a 3.6×
//! reduction vs f32. The dense quantized values are *reconstructed from
//! the integer levels*, so `compress`/`compress_encoded`/`decode` agree
//! bit-exactly (required by the error-feedback state).

use super::codec::{bits_for, BitReader, BitWriter, FixedWidthReader};
use super::Compressor;
use crate::config::KernelMode;
use crate::kernels::{self, LANES};
use crate::util::bytes::{put_f32, Reader};
use crate::util::rng::Pcg32;
use crate::util::stats::norm2;

/// QSGD with `s` quantization levels.
#[derive(Debug, Clone, Copy)]
pub struct Qsgd {
    pub levels: u32,
}

impl Qsgd {
    pub fn new(levels: u32) -> Self {
        assert!(levels >= 1, "need at least one level");
        Self { levels }
    }

    /// The s for an m-bit budget (sign + m−1 level bits): s = 2^(m−1) − 1.
    pub fn with_bits(bits: u8) -> Self {
        assert!((2..=16).contains(&bits), "bits must be in 2..=16");
        Self::new((1u32 << (bits - 1)) - 1)
    }

    fn level_bits(&self) -> u8 {
        bits_for(self.levels)
    }

    /// Stochastically round each element to an integer level in 0..=s.
    /// Returns (norm, signed level per element). Dispatches between the
    /// scalar baseline and the lane-chunked arm on the global
    /// [`crate::kernels`] mode; both draw **one uniform per element in
    /// element order** and evaluate the identical per-element
    /// expressions, so the levels (and thus the wire bits) are equal.
    fn quantize_levels(&self, v: &[f32], rng: &mut Pcg32) -> (f32, Vec<i32>) {
        let norm = norm2(v);
        if norm == 0.0 {
            return (0.0, vec![0; v.len()]);
        }
        let levels = match kernels::mode() {
            KernelMode::Simd => self.quantize_levels_simd(norm, v, rng),
            KernelMode::Scalar => self.quantize_levels_scalar(norm, v, rng),
        };
        (norm, levels)
    }

    /// Scalar arm of [`Self::quantize_levels`] (`norm` is nonzero).
    fn quantize_levels_scalar(&self, norm: f32, v: &[f32], rng: &mut Pcg32) -> Vec<i32> {
        let s = self.levels as f32;
        v.iter()
            .map(|&x| {
                let u = (x.abs() / norm).min(1.0) * s;
                let l = u.floor();
                let p = u - l;
                let level = if rng.uniform() < p { l + 1.0 } else { l } as i32;
                if x < 0.0 {
                    -level
                } else {
                    level
                }
            })
            .collect()
    }

    /// SIMD arm of [`Self::quantize_levels`]: the pure float pipeline
    /// (normalize, clamp, floor) chunks 8 lanes at a time; the stochastic
    /// finalize then walks the lanes **sequentially**, because the RNG
    /// draw order — one `uniform()` per element, in element order — is
    /// part of the bitwise contract with the scalar arm.
    fn quantize_levels_simd(&self, norm: f32, v: &[f32], rng: &mut Pcg32) -> Vec<i32> {
        let s = self.levels as f32;
        let mut out = Vec::with_capacity(v.len());
        let mut vc = v.chunks_exact(LANES);
        for x in &mut vc {
            let x: &[f32; LANES] = x.try_into().expect("exact chunk");
            let mut u = [0.0f32; LANES];
            let mut l = [0.0f32; LANES];
            for i in 0..LANES {
                u[i] = (x[i].abs() / norm).min(1.0) * s;
            }
            for i in 0..LANES {
                l[i] = u[i].floor();
            }
            for i in 0..LANES {
                let level = if rng.uniform() < u[i] - l[i] { l[i] + 1.0 } else { l[i] } as i32;
                out.push(if x[i] < 0.0 { -level } else { level });
            }
        }
        for &x in vc.remainder() {
            let u = (x.abs() / norm).min(1.0) * s;
            let l = u.floor();
            let level = if rng.uniform() < u - l { l + 1.0 } else { l } as i32;
            out.push(if x < 0.0 { -level } else { level });
        }
        out
    }

    /// Dense reconstruction from (norm, levels) — shared by every path so
    /// the f32 values are identical everywhere (the kernel arms both
    /// evaluate exactly `norm * (l as f32 / s)`).
    fn reconstruct(&self, norm: f32, levels: &[i32], out: &mut [f32]) {
        let s = self.levels as f32;
        kernels::grid_reconstruct(out, levels, norm, s);
    }

    fn encode_levels(&self, norm: f32, levels: &[i32], buf: &mut Vec<u8>) {
        put_f32(buf, norm);
        if norm == 0.0 {
            return;
        }
        let lb = self.level_bits();
        // One combined `sign | level << 1` write per element instead of
        // two: the sign bit stays in the lower position, so the packed
        // stream is bit-identical to the old write(sign,1)+write(level,lb)
        // pair — half the writer calls through the word-level drain.
        let width = 1 + lb;
        let mut w = BitWriter::with_capacity_bits(levels.len() * width as usize);
        if width <= 32 {
            for &l in levels {
                let mag = l.unsigned_abs().min(self.levels);
                w.write(u32::from(l < 0) | (mag << 1), width);
            }
        } else {
            // Degenerate s ≥ 2³¹ (not reachable via with_bits): the
            // combined value would not fit one write, so keep the pair.
            for &l in levels {
                w.write(u32::from(l < 0), 1);
                w.write(l.unsigned_abs().min(self.levels), lb);
            }
        }
        w.append_to(buf);
    }
}

impl Compressor for Qsgd {
    fn name(&self) -> String {
        format!("qsgd(s={})", self.levels)
    }

    fn compress(&self, v: &[f32], out: &mut [f32], rng: &mut Pcg32) {
        assert_eq!(v.len(), out.len());
        let (norm, levels) = self.quantize_levels(v, rng);
        self.reconstruct(norm, &levels, out);
    }

    fn compress_encoded_into(
        &self,
        v: &[f32],
        rng: &mut Pcg32,
        buf: &mut Vec<u8>,
        q_out: &mut [f32],
    ) {
        assert_eq!(v.len(), q_out.len());
        let (norm, levels) = self.quantize_levels(v, rng);
        self.encode_levels(norm, &levels, buf);
        self.reconstruct(norm, &levels, q_out);
    }

    fn encode(&self, quantized: &[f32], buf: &mut Vec<u8>) {
        // Recover (norm, level) from dense grid values: every nonzero is
        // ±norm·k/s with integer k, so norm = s · gcd-like smallest grid
        // step. The smallest positive |q| is norm·k_min/s; dividing all
        // magnitudes by it yields integers/k_min. We find the step as the
        // positive minimum and refine by checking grid consistency against
        // the implied level of the max element.
        let s = self.levels as f32;
        let mut max_abs = 0.0f32;
        for &q in quantized {
            max_abs = max_abs.max(q.abs());
        }
        if max_abs == 0.0 {
            self.encode_levels(0.0, &vec![0; quantized.len()], buf);
            return;
        }
        // The max element sits at some level L ∈ 1..=s: norm = max_abs·s/L.
        // Accept the largest L whose implied grid fits all elements.
        let mut best: Option<(f32, Vec<i32>)> = None;
        'cand: for l_max in (1..=self.levels).rev() {
            let norm = max_abs * s / l_max as f32;
            let mut levels = Vec::with_capacity(quantized.len());
            for &q in quantized {
                let u = q.abs() / norm * s;
                let j = u.round();
                if (u - j).abs() > 1e-3 * (j.max(1.0)) || j > s {
                    continue 'cand;
                }
                levels.push(if q < 0.0 { -(j as i32) } else { j as i32 });
            }
            best = Some((norm, levels));
            break;
        }
        let (norm, levels) = best.unwrap_or_else(|| {
            // Not on any grid (caller passed a non-compress output):
            // round onto the max_abs grid as a fallback.
            let norm = max_abs;
            let levels = quantized
                .iter()
                .map(|&q| {
                    let j = (q.abs() / norm * s).round().min(s) as i32;
                    if q < 0.0 {
                        -j
                    } else {
                        j
                    }
                })
                .collect();
            (norm, levels)
        });
        self.encode_levels(norm, &levels, buf);
    }

    fn decode(&self, bytes: &[u8], d: usize) -> anyhow::Result<Vec<f32>> {
        let mut out = vec![0.0; d];
        self.decode_into(bytes, &mut out)?;
        Ok(out)
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) -> anyhow::Result<()> {
        let mut r = Reader::new(bytes);
        let norm = r.f32()?;
        if norm == 0.0 {
            out.fill(0.0);
            return Ok(());
        }
        let rest = r.bytes(bytes.len() - 4)?;
        let lb = self.level_bits();
        let s = self.levels as f32;
        // Mirror of `encode_levels`: one combined read per element, sign
        // in the low bit — same bits consumed as the old 1+lb read pair.
        let width = 1 + lb;
        if width <= 32 && kernels::mode() == KernelMode::Simd {
            return self.decode_into_simd(rest, norm, width, out);
        }
        let mut br = BitReader::new(rest);
        for o in out.iter_mut() {
            let (sign, mag) = if width <= 32 {
                let packed = br.read(width)?;
                (packed & 1, (packed >> 1) as i32)
            } else {
                // Mirror of the degenerate-s encode fallback.
                (br.read(1)?, br.read(lb)? as i32)
            };
            let level = if sign == 1 { -mag } else { mag };
            // NOTE: must stay exactly `norm * (l / s)` — `reconstruct`
            // uses the same expression and the EF state requires
            // bit-identical round trips.
            *o = norm * (level as f32 / s);
        }
        Ok(())
    }

    fn delta(&self, d: usize) -> Option<f64> {
        Self::delta_impl(self.levels, d)
    }

    fn encoded_size(&self, d: usize) -> usize {
        4 + (d * (1 + self.level_bits() as usize)).div_ceil(8)
    }
}

impl Qsgd {
    /// SIMD arm of [`Compressor::decode_into`]: the packed stream is
    /// fixed-width, so a [`FixedWidthReader`] gathers 8 packed values per
    /// iteration (no per-element refill branch), the sign/magnitude split
    /// chunks over lanes, and the grid reconstruction runs through the
    /// lane kernel — evaluating exactly the scalar `norm * (l as f32 / s)`.
    fn decode_into_simd(
        &self,
        rest: &[u8],
        norm: f32,
        width: u8,
        out: &mut [f32],
    ) -> anyhow::Result<()> {
        let s = self.levels as f32;
        let fr = FixedWidthReader::new(rest, width, out.len())?;
        let mut base = 0usize;
        let mut oc = out.chunks_exact_mut(LANES);
        for o in &mut oc {
            let o: &mut [f32; LANES] = o.try_into().expect("exact chunk");
            let mut lv = [0i32; LANES];
            for i in 0..LANES {
                let packed = fr.get(base + i);
                let mag = (packed >> 1) as i32;
                lv[i] = if packed & 1 == 1 { -mag } else { mag };
            }
            kernels::grid_reconstruct_simd(o, &lv, norm, s);
            base += LANES;
        }
        for (i, o) in oc.into_remainder().iter_mut().enumerate() {
            let packed = fr.get(base + i);
            let mag = (packed >> 1) as i32;
            let level = if packed & 1 == 1 { -mag } else { mag };
            *o = norm * (level as f32 / s);
        }
        Ok(())
    }

    fn delta_impl(levels: u32, d: usize) -> Option<f64> {
        let s = levels as f64;
        let d = d as f64;
        let var = (d / (s * s)).min(d.sqrt() / s);
        if var < 1.0 {
            Some(1.0 - var)
        } else {
            None // Theorem 2 asserts existence; measure empirically.
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_vector_stays_zero() {
        let c = Qsgd::with_bits(8);
        let q = c.compress_vec(&[0.0; 16], &mut Pcg32::new(1));
        assert!(q.iter().all(|&x| x == 0.0));
        let mut buf = Vec::new();
        let q2 = c.compress_encoded(&[0.0; 16], &mut Pcg32::new(1), &mut buf);
        assert_eq!(q2, vec![0.0; 16]);
        assert_eq!(c.decode(&buf, 16).unwrap(), vec![0.0; 16]);
    }

    #[test]
    fn unbiasedness() {
        // E[Q(v)] = v: average many independent quantizations.
        let c = Qsgd::new(4); // coarse grid to stress the stochastic part
        let v = [0.3f32, -0.7, 0.05, 0.9];
        let mut rng = Pcg32::new(5);
        let trials = 20_000;
        let mut acc = [0.0f64; 4];
        for _ in 0..trials {
            let q = c.compress_vec(&v, &mut rng);
            for i in 0..4 {
                acc[i] += q[i] as f64;
            }
        }
        for i in 0..4 {
            let mean = acc[i] / trials as f64;
            assert!(
                (mean - v[i] as f64).abs() < 0.02,
                "i={i} mean={mean} want={}",
                v[i]
            );
        }
    }

    #[test]
    fn outputs_lie_on_grid() {
        let c = Qsgd::new(8);
        let mut rng = Pcg32::new(9);
        let v: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let q = c.compress_vec(&v, &mut rng);
        let norm = norm2(&v);
        for &x in &q {
            let u = x.abs() / norm * 8.0;
            assert!((u - u.round()).abs() < 1e-4, "off grid: {x}");
        }
    }

    #[test]
    fn fused_path_round_trips_bit_exact() {
        let c = Qsgd::with_bits(8);
        let mut rng = Pcg32::new(11);
        for _ in 0..20 {
            let d = 1 + rng.below(500) as usize;
            let v: Vec<f32> = (0..d).map(|_| rng.normal() * 3.0).collect();
            let mut buf = Vec::new();
            let q = c.compress_encoded(&v, &mut rng, &mut buf);
            assert_eq!(buf.len(), c.encoded_size(d));
            let back = c.decode(&buf, d).unwrap();
            for (a, b) in q.iter().zip(&back) {
                assert_eq!(a.to_bits(), b.to_bits(), "bit mismatch {a} vs {b}");
            }
        }
    }

    #[test]
    fn generic_encode_round_trips_compress_output() {
        let c = Qsgd::with_bits(6);
        let mut rng = Pcg32::new(13);
        let v: Vec<f32> = (0..100).map(|_| rng.normal()).collect();
        let q = c.compress_vec(&v, &mut rng);
        let mut buf = Vec::new();
        c.encode(&q, &mut buf);
        let back = c.decode(&buf, q.len()).unwrap();
        for (a, b) in q.iter().zip(&back) {
            assert!((a - b).abs() <= 1e-4 * a.abs().max(1e-3), "{a} vs {b}");
        }
    }

    #[test]
    fn eight_bit_is_about_3_6x_smaller_than_f32() {
        let c = Qsgd::with_bits(8);
        let raw = 4 * 100_000;
        let enc = c.encoded_size(100_000);
        let ratio = raw as f64 / enc as f64;
        assert!(ratio > 3.4 && ratio < 4.0, "ratio={ratio}");
    }

    #[test]
    fn delta_closed_form_when_s_large() {
        let c = Qsgd::new(1000);
        let delta = c.delta(100).unwrap();
        assert!(delta > 0.98, "delta={delta}");
    }
}
