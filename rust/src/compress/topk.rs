//! Top-k sparsification (Stich et al. [41]): keep the k largest-magnitude
//! entries, zero the rest. **Theorem 1**: this is a δ-approximate
//! compressor with δ = k/d.
//!
//! Wire format: `[k:u32][indices:u32×k][values:f32×k]` — 8 bytes per kept
//! element (index compression is possible but the paper doesn't assume it).

use super::Compressor;
use crate::util::bytes::{put_f32, put_u32, Reader};
use crate::util::rng::Pcg32;

/// Top-k by a fixed fraction of the dimension (so the same spec works for
/// any model size), with an absolute floor of 1 element.
#[derive(Debug, Clone, Copy)]
pub struct TopK {
    /// Fraction of entries kept, in (0, 1].
    pub fraction: f64,
}

impl TopK {
    pub fn new(fraction: f64) -> Self {
        assert!(fraction > 0.0 && fraction <= 1.0, "fraction must be in (0,1]");
        Self { fraction }
    }

    /// k for dimension d (≥ 1, ≤ d).
    pub fn k(&self, d: usize) -> usize {
        ((self.fraction * d as f64).round() as usize).clamp(1, d.max(1))
    }
}

impl Compressor for TopK {
    fn name(&self) -> String {
        format!("topk(f={})", self.fraction)
    }

    fn compress(&self, v: &[f32], out: &mut [f32], _rng: &mut Pcg32) {
        assert_eq!(v.len(), out.len());
        let d = v.len();
        if d == 0 {
            return;
        }
        let k = self.k(d);
        // Partial select: indices of the k largest |v_i|.
        let mut idx: Vec<u32> = (0..d as u32).collect();
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            v[b as usize]
                .abs()
                .partial_cmp(&v[a as usize].abs())
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        out.fill(0.0);
        for &i in &idx[..k] {
            out[i as usize] = v[i as usize];
        }
    }

    fn encode(&self, quantized: &[f32], buf: &mut Vec<u8>) {
        // Collect the non-zeros (exactly the kept entries).
        let nz: Vec<(u32, f32)> = quantized
            .iter()
            .enumerate()
            .filter(|(_, &x)| x != 0.0)
            .map(|(i, &x)| (i as u32, x))
            .collect();
        put_u32(buf, nz.len() as u32);
        for &(i, _) in &nz {
            put_u32(buf, i);
        }
        for &(_, x) in &nz {
            put_f32(buf, x);
        }
    }

    fn decode(&self, bytes: &[u8], d: usize) -> anyhow::Result<Vec<f32>> {
        let mut out = vec![0.0; d];
        self.decode_into(bytes, &mut out)?;
        Ok(out)
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) -> anyhow::Result<()> {
        let d = out.len();
        let mut r = Reader::new(bytes);
        let k = r.u32()? as usize;
        if k > d {
            anyhow::bail!("topk decode: k={k} exceeds d={d}");
        }
        out.fill(0.0);
        // Two cursors — `r` walks the index block, `vr` the value block —
        // so the sparse scatter needs no intermediate index Vec.
        let mut vr = Reader::new(bytes);
        let _ = vr.bytes(4 + 4 * k)?;
        for _ in 0..k {
            let i = r.u32()? as usize;
            if i >= d {
                anyhow::bail!("topk decode: index {i} out of bounds d={d}");
            }
            out[i] = vr.f32()?;
        }
        Ok(())
    }

    fn delta(&self, d: usize) -> Option<f64> {
        // Theorem 1: δ = k/d.
        Some(self.k(d) as f64 / d.max(1) as f64)
    }

    fn encoded_size(&self, d: usize) -> usize {
        4 + 8 * self.k(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::norm2_sq;

    #[test]
    fn keeps_largest_magnitudes() {
        let v = [0.1f32, -5.0, 0.2, 3.0, -0.05];
        let c = TopK::new(0.4); // k = 2 of 5
        let mut out = [0.0; 5];
        c.compress(&v, &mut out, &mut Pcg32::new(1));
        assert_eq!(out, [0.0, -5.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn theorem1_delta_holds_deterministically() {
        // ‖Q(v)−v‖² ≤ (1−k/d)‖v‖² — for top-k this holds per-vector.
        let mut rng = Pcg32::new(7);
        let c = TopK::new(0.25);
        for _ in 0..100 {
            let d = 1 + rng.below(300) as usize;
            let v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let q = c.compress_vec(&v, &mut rng);
            let err: f32 = v.iter().zip(&q).map(|(a, b)| (a - b) * (a - b)).sum();
            let bound = (1.0 - c.delta(d).unwrap() as f32) * norm2_sq(&v);
            assert!(err <= bound + 1e-5, "err={err} bound={bound} d={d}");
        }
    }

    #[test]
    fn encode_round_trips() {
        let v = [0.0f32, -5.0, 0.0, 3.0, 0.0];
        let c = TopK::new(0.4);
        let mut buf = Vec::new();
        c.encode(&v, &mut buf);
        let back = c.decode(&buf, 5).unwrap();
        assert_eq!(back, v.to_vec());
    }

    #[test]
    fn wire_is_smaller_than_raw_for_sparse_fraction() {
        let c = TopK::new(0.1);
        assert!(c.encoded_size(10_000) < 4 * 10_000);
    }

    #[test]
    fn decode_rejects_corrupt() {
        let c = TopK::new(0.5);
        // k larger than d
        let mut buf = Vec::new();
        put_u32(&mut buf, 100);
        assert!(c.decode(&buf, 4).is_err());
    }

    #[test]
    fn full_fraction_is_lossless() {
        let v = [1.0f32, -2.0, 3.0];
        let c = TopK::new(1.0);
        let q = c.compress_vec(&v, &mut Pcg32::new(3));
        assert_eq!(q, v.to_vec());
        assert_eq!(c.delta(3), Some(1.0));
    }
}
