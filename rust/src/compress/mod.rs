//! Gradient compression: δ-approximate compressors (paper Definition 1),
//! their wire codecs, and verification tooling for Theorems 1–2.
//!
//! **Definition 1** (δ-approximate compressor): `Q` with δ ∈ (0,1] such that
//! `‖Q(v) − v‖² ≤ (1−δ)‖v‖²` for all `v` (in expectation for stochastic Q).
//!
//! Implemented compressors:
//!
//! | name        | type      | δ                         | paper ref |
//! |-------------|-----------|---------------------------|-----------|
//! | identity    | exact     | 1                          | —         |
//! | top-k       | biased    | k/d (Theorem 1)            | [41]      |
//! | qsgd        | unbiased  | Theorem 2 (‖·‖₂ scale)     | [1]       |
//! | linf (Hou)  | unbiased  | Theorem 2 (‖·‖∞ scale)     | [12]      |
//! | sign+scale  | biased    | ‖v‖₁²/(d‖v‖₂²)             | [3,14]    |
//! | terngrad    | unbiased  | **not δ-approximate**¹     | [48]      |
//!
//! ¹ TernGrad is unbiased but its error E‖Q(v)−v‖² = Σ|v_i|(‖v‖∞−|v_i|)
//! exceeds ‖v‖² on typical dense vectors, so Definition 1 fails (verified
//! by `prop_terngrad_is_not_delta_approximate`). It ships as a comparison
//! codec; DQGAN's convergence guarantee requires one of the others.
//!
//! Every compressor also implements a byte-exact [`encode`](Compressor::encode)
//! so the transport layer can account *real* wire bytes — the quantity
//! driving the paper's Figure 4 speedup.

mod codec;
mod delta;
mod identity;
mod linf;
mod qsgd;
mod registry;
mod sign;
mod terngrad;
mod topk;

pub use codec::{BitReader, BitWriter, FixedWidthReader};
pub use delta::{
    empirical_delta, gaussian_sampler, heavy_tail_sampler, sparse_sampler, DeltaEstimate,
};
pub use identity::Identity;
pub use linf::LinfStochastic;
pub use qsgd::Qsgd;
pub use registry::{compressor_from_spec, CompressorSpec};
pub use sign::SignScale;
pub use terngrad::TernGrad;
pub use topk::TopK;

use crate::util::rng::Pcg32;

/// A δ-approximate gradient compressor with a byte-exact wire format.
///
/// Contract:
/// - [`compress`](Self::compress) maps `v ∈ R^d` to its quantized form
///   `Q(v) ∈ R^d` (dense f32, same length). Stochastic compressors draw
///   from the supplied RNG — determinism given the RNG state is required
///   (tests and the replay tooling rely on it).
/// - [`encode`](Self::encode) produces the wire bytes for `Q(v)` such that
///   [`decode`](Self::decode) reconstructs `Q(v)` exactly (bit-exact f32).
/// - [`delta`](Self::delta) returns the *guaranteed* δ for dimension `d`
///   (`None` if input-dependent; use [`empirical_delta`] then).
pub trait Compressor: Send + Sync {
    /// Short identifier, e.g. `"qsgd(s=255)"`.
    fn name(&self) -> String;

    /// Quantize `v` into `out` (same length). Stochastic methods use `rng`.
    fn compress(&self, v: &[f32], out: &mut [f32], rng: &mut Pcg32);

    /// Serialize the *quantized* vector (as produced by `compress`) into
    /// wire bytes. Implementations must round-trip via `decode`.
    fn encode(&self, quantized: &[f32], buf: &mut Vec<u8>);

    /// Inverse of `encode`. `d` is the vector dimension.
    fn decode(&self, bytes: &[u8], d: usize) -> anyhow::Result<Vec<f32>>;

    /// Decode into a caller-provided buffer (`out.len()` is the vector
    /// dimension) — the server aggregation hot path, which reuses one
    /// dense buffer per worker across rounds instead of allocating a
    /// fresh `Vec` per decode. Must produce exactly `decode`'s output
    /// bit-for-bit; the in-tree codecs override the default with direct
    /// in-place decoders.
    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) -> anyhow::Result<()> {
        let v = self.decode(bytes, out.len())?;
        anyhow::ensure!(
            v.len() == out.len(),
            "decode returned {} elements, expected {}",
            v.len(),
            out.len()
        );
        out.copy_from_slice(&v);
        Ok(())
    }

    /// Guaranteed compression quality δ ∈ (0,1] for dimension `d`, when
    /// known in closed form.
    fn delta(&self, d: usize) -> Option<f64>;

    /// Exact wire size in bytes for a vector of dimension `d`.
    fn encoded_size(&self, d: usize) -> usize;

    /// Convenience: compress into a fresh Vec.
    fn compress_vec(&self, v: &[f32], rng: &mut Pcg32) -> Vec<f32> {
        let mut out = vec![0.0; v.len()];
        self.compress(v, &mut out, rng);
        out
    }

    /// Fused quantize + encode into **caller-provided** buffers — the
    /// allocation-free worker hot path (`q_out.len() == v.len()`): `Q(v)`
    /// is written into `q_out` and the wire bytes appended to `buf`. The
    /// dense output and the wire bytes are guaranteed mutually
    /// consistent: `decode(bytes, d)` reproduces `q_out` **bit-exactly**,
    /// so worker-local error `e = p − Q(p)` and the server's decoded
    /// `Q(p)` never diverge.
    ///
    /// The default composes `compress` + `encode`; scale-based compressors
    /// override it to avoid re-deriving their scale from the dense output.
    fn compress_encoded_into(
        &self,
        v: &[f32],
        rng: &mut Pcg32,
        buf: &mut Vec<u8>,
        q_out: &mut [f32],
    ) {
        self.compress(v, q_out, rng);
        self.encode(q_out, buf);
    }

    /// [`compress_encoded_into`](Self::compress_encoded_into) with codec
    /// observability — the form the worker round loops call. When metrics
    /// are enabled, the fused quantize+encode is timed into
    /// `codec.encode_ns` and the payload sizes feed the observed
    /// compression ratio (`codec.bytes_pre_total` = 4·d raw f32 bytes,
    /// `codec.bytes_post_total` totals and `codec.bytes_wire` histograms
    /// the encoded bytes). When disabled this is exactly
    /// `compress_encoded_into` plus one relaxed load; the numerics are
    /// untouched either way.
    fn compress_encoded_observed(
        &self,
        v: &[f32],
        rng: &mut Pcg32,
        buf: &mut Vec<u8>,
        q_out: &mut [f32],
    ) {
        if !crate::obs::metrics_enabled() {
            self.compress_encoded_into(v, rng, buf, q_out);
            return;
        }
        let before = buf.len();
        let t0 = std::time::Instant::now();
        self.compress_encoded_into(v, rng, buf, q_out);
        crate::obs::metrics::CODEC_ENCODE_NS.record(t0.elapsed().as_nanos() as u64);
        let wire = (buf.len() - before) as u64;
        crate::obs::metrics::CODEC_BYTES_PRE_TOTAL.add(4 * v.len() as u64);
        crate::obs::metrics::CODEC_BYTES_POST_TOTAL.add(wire);
        crate::obs::metrics::CODEC_BYTES_WIRE.record(wire);
    }

    /// [`compress_encoded_into`](Self::compress_encoded_into) returning a
    /// fresh dense Vec — convenience for tests/tooling; the worker round
    /// loop uses the `_into` form with reused buffers.
    fn compress_encoded(&self, v: &[f32], rng: &mut Pcg32, buf: &mut Vec<u8>) -> Vec<f32> {
        let mut q = vec![0.0; v.len()];
        self.compress_encoded_into(v, rng, buf, &mut q);
        q
    }
}

/// Compression ratio vs raw f32 (4·d bytes).
pub fn compression_ratio(c: &dyn Compressor, d: usize) -> f64 {
    (4 * d) as f64 / c.encoded_size(d) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_of_identity_is_about_one() {
        let c = Identity;
        let r = compression_ratio(&c, 1024);
        assert!(r <= 1.0 + 1e-6, "r={r}");
        assert!(r > 0.9, "r={r}");
    }
}
