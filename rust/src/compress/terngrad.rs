//! TernGrad (Wen et al. [48]): unbiased stochastic ternarization,
//!
//!   Q(v_i) = ‖v‖∞ · sign(v_i) · b_i,   b_i ~ Bernoulli(|v_i| / ‖v‖∞).
//!
//! E[Q(v)] = v. Wire: `[scale:f32]` + 2 bits/element (00 zero, 01 +, 10 −)
//! — a 16× reduction vs f32.

use super::codec::{BitReader, BitWriter};
use super::Compressor;
use crate::config::KernelMode;
use crate::kernels::{self, LANES};
use crate::util::bytes::{put_f32, Reader};
use crate::util::rng::Pcg32;

/// Stochastic ternary quantizer.
#[derive(Debug, Clone, Copy, Default)]
pub struct TernGrad;

impl TernGrad {
    /// Ternary symbols for each element: -1, 0, +1 (and the scale).
    /// Dispatches between the scalar baseline and the lane-chunked arm on
    /// the global [`crate::kernels`] mode; both draw one uniform per
    /// element in element order, so the symbols are identical.
    fn ternarize(&self, v: &[f32], rng: &mut Pcg32) -> (f32, Vec<i8>) {
        let scale = v.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        if scale == 0.0 {
            return (0.0, vec![0; v.len()]);
        }
        let syms = match kernels::mode() {
            KernelMode::Simd => Self::ternarize_simd(scale, v, rng),
            KernelMode::Scalar => Self::ternarize_scalar(scale, v, rng),
        };
        (scale, syms)
    }

    /// Scalar arm of [`Self::ternarize`] (`scale` is nonzero).
    fn ternarize_scalar(scale: f32, v: &[f32], rng: &mut Pcg32) -> Vec<i8> {
        v.iter()
            .map(|&x| {
                let p = x.abs() / scale;
                if rng.uniform() < p {
                    if x < 0.0 {
                        -1
                    } else {
                        1
                    }
                } else {
                    0
                }
            })
            .collect()
    }

    /// SIMD arm of [`Self::ternarize`]: the Bernoulli probabilities chunk
    /// 8 lanes at a time; the draws stay sequential (RNG order is part of
    /// the bitwise contract).
    fn ternarize_simd(scale: f32, v: &[f32], rng: &mut Pcg32) -> Vec<i8> {
        let mut out = Vec::with_capacity(v.len());
        let mut vc = v.chunks_exact(LANES);
        for x in &mut vc {
            let x: &[f32; LANES] = x.try_into().expect("exact chunk");
            let mut p = [0.0f32; LANES];
            for i in 0..LANES {
                p[i] = x[i].abs() / scale;
            }
            for i in 0..LANES {
                out.push(if rng.uniform() < p[i] {
                    if x[i] < 0.0 {
                        -1
                    } else {
                        1
                    }
                } else {
                    0
                });
            }
        }
        for &x in vc.remainder() {
            let p = x.abs() / scale;
            out.push(if rng.uniform() < p {
                if x < 0.0 {
                    -1
                } else {
                    1
                }
            } else {
                0
            });
        }
        out
    }

    fn reconstruct(scale: f32, syms: &[i8], out: &mut [f32]) {
        match kernels::mode() {
            KernelMode::Simd => Self::reconstruct_simd(scale, syms, out),
            KernelMode::Scalar => Self::reconstruct_scalar(scale, syms, out),
        }
    }

    /// Scalar arm: one multiply per element.
    fn reconstruct_scalar(scale: f32, syms: &[i8], out: &mut [f32]) {
        for (o, &s) in out.iter_mut().zip(syms) {
            *o = scale * s as f32;
        }
    }

    /// SIMD arm: the same `scale * sym as f32` per lane, 8 at a time.
    fn reconstruct_simd(scale: f32, syms: &[i8], out: &mut [f32]) {
        let mut oc = out.chunks_exact_mut(LANES);
        let mut sc = syms.chunks_exact(LANES);
        for (o, s) in (&mut oc).zip(&mut sc) {
            let o: &mut [f32; LANES] = o.try_into().expect("exact chunk");
            let s: &[i8; LANES] = s.try_into().expect("exact chunk");
            for i in 0..LANES {
                o[i] = scale * s[i] as f32;
            }
        }
        for (o, &s) in oc.into_remainder().iter_mut().zip(sc.remainder()) {
            *o = scale * s as f32;
        }
    }

    /// SIMD arm of [`Compressor::decode_into`]: the packed stream is four
    /// wire bytes per 16 symbols, so full chunks load directly as LE
    /// words (no bit-reader state), a word-wide bit trick rejects 0b11
    /// symbols, and the select runs over lanes. The produced values are
    /// the same `0.0 / scale / -scale` constants the scalar match emits.
    fn decode_into_simd(scale: f32, rest: &[u8], out: &mut [f32]) -> anyhow::Result<()> {
        let need_bits = out.len() * 2;
        if need_bits > rest.len() * 8 {
            anyhow::bail!("bit reader overrun: need {need_bits} bits, have {}", rest.len() * 8);
        }
        let lut = [0.0f32, scale, -scale];
        let mut pos = 0usize;
        let mut chunks = out.chunks_exact_mut(16);
        for chunk in &mut chunks {
            let w = u32::from_le_bytes(rest[pos..pos + 4].try_into().expect("4-byte slice"));
            pos += 4;
            // A 0b11 pair has both bits set: mask pairs where bit 2j and
            // bit 2j+1 are both 1.
            if w & (w >> 1) & 0x5555_5555 != 0 {
                anyhow::bail!("terngrad decode: bad symbol 0b11");
            }
            let chunk: &mut [f32; 16] = chunk.try_into().expect("exact chunk");
            for j in 0..16 {
                chunk[j] = lut[((w >> (2 * j)) & 0b11) as usize];
            }
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let mut tmp = [0u8; 4];
            let n = (rest.len() - pos).min(4);
            tmp[..n].copy_from_slice(&rest[pos..pos + n]);
            let w = u32::from_le_bytes(tmp);
            for (j, o) in rem.iter_mut().enumerate() {
                let code = (w >> (2 * j)) & 0b11;
                if code == 0b11 {
                    anyhow::bail!("terngrad decode: bad symbol 0b11");
                }
                *o = lut[code as usize];
            }
        }
        Ok(())
    }

    /// 2-bit wire code of one ternary symbol (00 zero, 01 +, 10 −).
    #[inline]
    fn sym_code(s: i8) -> u32 {
        match s {
            0 => 0b00,
            1 => 0b01,
            _ => 0b10,
        }
    }

    fn encode_syms(scale: f32, syms: &[i8], buf: &mut Vec<u8>) {
        put_f32(buf, scale);
        let mut w = BitWriter::with_capacity_bits(syms.len() * 2);
        // Batch 16 symbols into one 32-bit write: symbol j of a chunk
        // lands at bits 2j of the word, which is exactly the global bit
        // position the per-symbol writes produced — identical wire bytes,
        // 16× fewer writer calls. Only the < 16-symbol tail goes one at
        // a time.
        let mut chunks = syms.chunks_exact(16);
        for chunk in &mut chunks {
            let mut word = 0u32;
            for (j, &s) in chunk.iter().enumerate() {
                word |= Self::sym_code(s) << (2 * j);
            }
            w.write(word, 32);
        }
        for &s in chunks.remainder() {
            w.write(Self::sym_code(s), 2);
        }
        w.append_to(buf);
    }
}

impl Compressor for TernGrad {
    fn name(&self) -> String {
        "terngrad".to_string()
    }

    fn compress(&self, v: &[f32], out: &mut [f32], rng: &mut Pcg32) {
        assert_eq!(v.len(), out.len());
        let (scale, syms) = self.ternarize(v, rng);
        Self::reconstruct(scale, &syms, out);
    }

    fn compress_encoded_into(
        &self,
        v: &[f32],
        rng: &mut Pcg32,
        buf: &mut Vec<u8>,
        q_out: &mut [f32],
    ) {
        assert_eq!(v.len(), q_out.len());
        let (scale, syms) = self.ternarize(v, rng);
        Self::encode_syms(scale, &syms, buf);
        Self::reconstruct(scale, &syms, q_out);
    }

    fn encode(&self, quantized: &[f32], buf: &mut Vec<u8>) {
        let scale = quantized.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let syms: Vec<i8> = quantized
            .iter()
            .map(|&q| {
                if q == 0.0 {
                    0
                } else if q < 0.0 {
                    -1
                } else {
                    1
                }
            })
            .collect();
        Self::encode_syms(scale, &syms, buf);
    }

    fn decode(&self, bytes: &[u8], d: usize) -> anyhow::Result<Vec<f32>> {
        let mut out = vec![0.0; d];
        self.decode_into(bytes, &mut out)?;
        Ok(out)
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) -> anyhow::Result<()> {
        let mut r = Reader::new(bytes);
        let scale = r.f32()?;
        let rest = r.bytes(bytes.len() - 4)?;
        if kernels::mode() == KernelMode::Simd {
            return Self::decode_into_simd(scale, rest, out);
        }
        let mut br = BitReader::new(rest);
        // Mirror of `encode_syms`: 16 symbols per 32-bit read (a full
        // chunk consumes exactly four wire bytes, so batched reads can
        // never overrun into the zero-padded tail), per-symbol reads for
        // the remainder only.
        let mut chunks = out.chunks_exact_mut(16);
        for chunk in &mut chunks {
            let mut word = br.read(32)?;
            for o in chunk.iter_mut() {
                *o = match word & 0b11 {
                    0b00 => 0.0,
                    0b01 => scale,
                    0b10 => -scale,
                    other => anyhow::bail!("terngrad decode: bad symbol {other:#b}"),
                };
                word >>= 2;
            }
        }
        for o in chunks.into_remainder() {
            let code = br.read(2)?;
            *o = match code {
                0b00 => 0.0,
                0b01 => scale,
                0b10 => -scale,
                other => anyhow::bail!("terngrad decode: bad symbol {other:#b}"),
            };
        }
        Ok(())
    }

    fn delta(&self, _d: usize) -> Option<f64> {
        // Input-dependent (E‖Q−v‖² = Σ|v_i|(‖v‖∞−|v_i|) relative to ‖v‖²);
        // no uniform closed form — use empirical_delta.
        None
    }

    fn encoded_size(&self, d: usize) -> usize {
        4 + (2 * d).div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbiasedness() {
        let v = [0.5f32, -0.25, 1.0, 0.1];
        let mut rng = Pcg32::new(41);
        let trials = 40_000;
        let mut acc = [0.0f64; 4];
        for _ in 0..trials {
            let q = TernGrad.compress_vec(&v, &mut rng);
            for i in 0..4 {
                acc[i] += q[i] as f64;
            }
        }
        for i in 0..4 {
            let mean = acc[i] / trials as f64;
            assert!((mean - v[i] as f64).abs() < 0.02, "i={i} mean={mean}");
        }
    }

    #[test]
    fn outputs_are_ternary() {
        let mut rng = Pcg32::new(43);
        let v: Vec<f32> = (0..128).map(|_| rng.normal()).collect();
        let scale = v.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let q = TernGrad.compress_vec(&v, &mut rng);
        for &x in &q {
            assert!(x == 0.0 || x == scale || x == -scale, "not ternary: {x}");
        }
    }

    #[test]
    fn fused_round_trip_bit_exact() {
        let mut rng = Pcg32::new(47);
        for _ in 0..10 {
            let d = 1 + rng.below(300) as usize;
            let v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let mut buf = Vec::new();
            let q = TernGrad.compress_encoded(&v, &mut rng, &mut buf);
            assert_eq!(buf.len(), TernGrad.encoded_size(d));
            let back = TernGrad.decode(&buf, d).unwrap();
            for (a, b) in q.iter().zip(&back) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn decode_rejects_bad_symbols_in_batch_and_tail() {
        // d = 20: one full 16-symbol batched chunk + a 4-symbol tail.
        let d = 20;
        let mut buf = Vec::new();
        put_f32(&mut buf, 1.0);
        buf.extend_from_slice(&[0u8; 5]); // 2·20 bits of 00 symbols
        assert_eq!(TernGrad.decode(&buf, d).unwrap(), vec![0.0; d]);
        // 0b11 at symbol 3 (bits 6..8 of packed byte 0 — inside the chunk).
        let mut bad = buf.clone();
        bad[4] = 0b1100_0000;
        assert!(TernGrad.decode(&bad, d).is_err());
        // 0b11 at symbol 17 (bits 2..4 of packed byte 4 — inside the tail).
        let mut bad = buf.clone();
        bad[4 + 4] = 0b0000_1100;
        assert!(TernGrad.decode(&bad, d).is_err());
    }

    #[test]
    fn wire_is_16x_smaller() {
        let d = 1_000_000;
        let ratio = (4 * d) as f64 / TernGrad.encoded_size(d) as f64;
        assert!(ratio > 15.0, "ratio={ratio}");
    }
}
