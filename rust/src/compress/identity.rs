//! The identity "compressor" (δ = 1): transmits raw f32. This is what the
//! CPOAdam baseline ships over the wire; having it behind the same trait
//! keeps the transport byte accounting uniform.

use super::Compressor;
use crate::util::bytes::{put_f32_slice, Reader};
use crate::util::rng::Pcg32;

/// No-op compressor: `Q(v) = v`, wire = 4·d bytes.
#[derive(Debug, Clone, Copy, Default)]
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> String {
        "identity".to_string()
    }

    fn compress(&self, v: &[f32], out: &mut [f32], _rng: &mut Pcg32) {
        out.copy_from_slice(v);
    }

    fn encode(&self, quantized: &[f32], buf: &mut Vec<u8>) {
        put_f32_slice(buf, quantized);
    }

    fn decode(&self, bytes: &[u8], d: usize) -> anyhow::Result<Vec<f32>> {
        let mut out = vec![0.0; d];
        self.decode_into(bytes, &mut out)?;
        Ok(out)
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) -> anyhow::Result<()> {
        let mut r = Reader::new(bytes);
        let raw = r.bytes(out.len() * 4)?;
        for (o, c) in out.iter_mut().zip(raw.chunks_exact(4)) {
            *o = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        Ok(())
    }

    fn delta(&self, _d: usize) -> Option<f64> {
        Some(1.0)
    }

    fn encoded_size(&self, d: usize) -> usize {
        4 * d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_exact() {
        let v = [1.5f32, -2.25, 0.0, 1e-7];
        let mut out = [0.0; 4];
        let mut rng = Pcg32::new(1);
        Identity.compress(&v, &mut out, &mut rng);
        assert_eq!(out, v);
    }

    #[test]
    fn encode_round_trips_bit_exact() {
        let v = [f32::MIN_POSITIVE, -0.0, 3.14159, -1e30];
        let mut buf = Vec::new();
        Identity.encode(&v, &mut buf);
        assert_eq!(buf.len(), Identity.encoded_size(v.len()));
        let back = Identity.decode(&buf, v.len()).unwrap();
        for (a, b) in v.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
