//! Flat f32 tensors + parameter layouts.
//!
//! The coordinator treats a model's parameters/gradients as one flat `f32`
//! vector (Algorithm 2 operates on `w ∈ R^d`), but the XLA artifacts take
//! and return *per-parameter* arrays. [`ParamLayout`] records the shapes of
//! each named parameter so the runtime can flatten/unflatten losslessly.

mod layout;
pub mod ops;

pub use layout::{ParamLayout, ParamSpec};
pub use ops::*;

/// A dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Build from shape + data (length must match the shape product).
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} ≠ data len {}", data.len());
        Self { shape, data }
    }

    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// 1-D view over a Vec.
    pub fn from_vec(data: Vec<f32>) -> Self {
        let n = data.len();
        Self { shape: vec![n], data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reshape (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape to {shape:?} from len {}", self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// 2-D indexing helper (row-major).
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_shape() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.at2(1, 2), 6.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0; 3]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(vec![1., 2., 3., 4.]).reshape(&[2, 2]);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.at2(0, 1), 2.0);
    }
}
