//! Elementwise vector ops on flat f32 slices — the hot-path primitives of
//! the coordinator (OMD updates, error feedback, server aggregation). These
//! are written as simple indexed loops the compiler auto-vectorizes; the
//! §Perf pass benchmarks them in `benches/bench_aggregation.rs`.

/// out[i] = a[i] + b[i]
pub fn add(a: &[f32], b: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), out.len());
    for i in 0..a.len() {
        out[i] = a[i] + b[i];
    }
}

/// a[i] += b[i]
pub fn add_assign(a: &mut [f32], b: &[f32]) {
    assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        a[i] += b[i];
    }
}

/// a[i] -= b[i]
pub fn sub_assign(a: &mut [f32], b: &[f32]) {
    assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        a[i] -= b[i];
    }
}

/// out[i] = a[i] - b[i]
pub fn sub(a: &[f32], b: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), out.len());
    for i in 0..a.len() {
        out[i] = a[i] - b[i];
    }
}

/// a[i] *= s
pub fn scale_assign(a: &mut [f32], s: f32) {
    for v in a.iter_mut() {
        *v *= s;
    }
}

/// out[i] = s * a[i]
pub fn scale(a: &[f32], s: f32, out: &mut [f32]) {
    assert_eq!(a.len(), out.len());
    for i in 0..a.len() {
        out[i] = s * a[i];
    }
}

/// y[i] += alpha * x[i]  (the BLAS axpy)
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// out[i] = alpha * x[i] + e[i] — the DQGAN "p = ηF + e" step, fused.
pub fn scaled_add(alpha: f32, x: &[f32], e: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), e.len());
    assert_eq!(x.len(), out.len());
    for i in 0..x.len() {
        out[i] = alpha * x[i] + e[i];
    }
}

/// Zero a slice.
pub fn zero(a: &mut [f32]) {
    for v in a.iter_mut() {
        *v = 0.0;
    }
}

/// Mean of `vs` (all same length) into `out` — the server aggregation
/// `q̄ = 1/M Σ q̂^(m)`.
pub fn mean_into(vs: &[&[f32]], out: &mut [f32]) {
    assert!(!vs.is_empty());
    let n = out.len();
    for v in vs {
        assert_eq!(v.len(), n);
    }
    zero(out);
    for v in vs {
        add_assign(out, v);
    }
    scale_assign(out, 1.0 / vs.len() as f32);
}

/// Elementwise clamp.
pub fn clamp_assign(a: &mut [f32], lo: f32, hi: f32) {
    for v in a.iter_mut() {
        *v = v.clamp(lo, hi);
    }
}

/// True iff every element is finite — failure-injection guard used by the
/// server to reject NaN/Inf gradients.
pub fn all_finite(a: &[f32]) -> bool {
    a.iter().all(|v| v.is_finite())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_arithmetic() {
        let a = [1.0, 2.0];
        let b = [10.0, 20.0];
        let mut out = [0.0; 2];
        add(&a, &b, &mut out);
        assert_eq!(out, [11.0, 22.0]);
        sub(&b, &a, &mut out);
        assert_eq!(out, [9.0, 18.0]);
        scale(&a, 3.0, &mut out);
        assert_eq!(out, [3.0, 6.0]);
    }

    #[test]
    fn fused_scaled_add_matches_composition() {
        let x = [1.0, -2.0, 3.0];
        let e = [0.5, 0.5, -0.5];
        let mut fused = [0.0; 3];
        scaled_add(0.1, &x, &e, &mut fused);
        let mut manual = e;
        axpy(0.1, &x, &mut manual);
        assert_eq!(fused, manual);
    }

    #[test]
    fn mean_into_averages() {
        let a = [1.0, 2.0];
        let b = [3.0, 6.0];
        let mut out = [0.0; 2];
        mean_into(&[&a, &b], &mut out);
        assert_eq!(out, [2.0, 4.0]);
    }

    #[test]
    fn finite_guard() {
        assert!(all_finite(&[1.0, -2.0]));
        assert!(!all_finite(&[1.0, f32::NAN]));
        assert!(!all_finite(&[f32::INFINITY]));
    }

    #[test]
    fn clamp_works() {
        let mut a = [-2.0, 0.5, 7.0];
        clamp_assign(&mut a, -1.0, 1.0);
        assert_eq!(a, [-1.0, 0.5, 1.0]);
    }
}
