//! Named-parameter layouts: map between the flat `w ∈ R^d` vector the
//! DQGAN algorithm manipulates and the per-parameter tensors the XLA
//! artifacts consume/produce.

use super::Tensor;

/// One named parameter: shape + (derived) flat offset/length.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Ordered collection of [`ParamSpec`]s with contiguous flat offsets.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ParamLayout {
    specs: Vec<ParamSpec>,
    total: usize,
}

impl ParamLayout {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build a layout from (name, shape) pairs.
    pub fn from_shapes(shapes: &[(&str, &[usize])]) -> Self {
        let mut l = Self::new();
        for (name, shape) in shapes {
            l.push(name, shape);
        }
        l
    }

    /// Append a parameter; returns its index.
    pub fn push(&mut self, name: &str, shape: &[usize]) -> usize {
        let spec =
            ParamSpec { name: name.to_string(), shape: shape.to_vec(), offset: self.total };
        self.total += spec.numel();
        self.specs.push(spec);
        self.specs.len() - 1
    }

    /// Total flat dimension d.
    pub fn total_len(&self) -> usize {
        self.total
    }

    /// Number of parameters.
    pub fn num_params(&self) -> usize {
        self.specs.len()
    }

    pub fn specs(&self) -> &[ParamSpec] {
        &self.specs
    }

    pub fn spec(&self, i: usize) -> &ParamSpec {
        &self.specs[i]
    }

    /// Find by name.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.specs.iter().position(|s| s.name == name)
    }

    /// Flat-slice view of parameter `i` inside `flat`.
    pub fn slice<'a>(&self, flat: &'a [f32], i: usize) -> &'a [f32] {
        let s = &self.specs[i];
        &flat[s.offset..s.offset + s.numel()]
    }

    /// Mutable flat-slice view of parameter `i`.
    pub fn slice_mut<'a>(&self, flat: &'a mut [f32], i: usize) -> &'a mut [f32] {
        let s = &self.specs[i];
        &mut flat[s.offset..s.offset + s.numel()]
    }

    /// Split a flat vector into per-parameter tensors (copies).
    pub fn unflatten(&self, flat: &[f32]) -> Vec<Tensor> {
        assert_eq!(flat.len(), self.total, "flat len mismatch");
        self.specs
            .iter()
            .map(|s| Tensor::new(s.shape.clone(), flat[s.offset..s.offset + s.numel()].to_vec()))
            .collect()
    }

    /// Concatenate per-parameter tensors into one flat vector.
    pub fn flatten(&self, tensors: &[Tensor]) -> Vec<f32> {
        assert_eq!(tensors.len(), self.specs.len(), "tensor count mismatch");
        let mut flat = vec![0.0; self.total];
        for (t, s) in tensors.iter().zip(&self.specs) {
            assert_eq!(t.shape(), &s.shape[..], "shape mismatch for {}", s.name);
            flat[s.offset..s.offset + s.numel()].copy_from_slice(t.data());
        }
        flat
    }

    /// Concatenate raw slices (same order as the layout) into a flat vector.
    pub fn flatten_slices(&self, slices: &[&[f32]]) -> Vec<f32> {
        assert_eq!(slices.len(), self.specs.len());
        let mut flat = vec![0.0; self.total];
        for (sl, s) in slices.iter().zip(&self.specs) {
            assert_eq!(sl.len(), s.numel(), "slice len mismatch for {}", s.name);
            flat[s.offset..s.offset + s.numel()].copy_from_slice(sl);
        }
        flat
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> ParamLayout {
        ParamLayout::from_shapes(&[("w1", &[2, 3]), ("b1", &[3]), ("w2", &[3, 1])])
    }

    #[test]
    fn offsets_are_contiguous() {
        let l = layout();
        assert_eq!(l.total_len(), 6 + 3 + 3);
        assert_eq!(l.spec(0).offset, 0);
        assert_eq!(l.spec(1).offset, 6);
        assert_eq!(l.spec(2).offset, 9);
        assert_eq!(l.index_of("b1"), Some(1));
        assert_eq!(l.index_of("nope"), None);
    }

    #[test]
    fn flatten_unflatten_round_trip() {
        let l = layout();
        let flat: Vec<f32> = (0..l.total_len()).map(|i| i as f32).collect();
        let tensors = l.unflatten(&flat);
        assert_eq!(tensors[1].data(), &[6.0, 7.0, 8.0]);
        let back = l.flatten(&tensors);
        assert_eq!(back, flat);
    }

    #[test]
    fn slice_views() {
        let l = layout();
        let mut flat: Vec<f32> = vec![0.0; l.total_len()];
        l.slice_mut(&mut flat, 1).copy_from_slice(&[9.0, 8.0, 7.0]);
        assert_eq!(l.slice(&flat, 1), &[9.0, 8.0, 7.0]);
        assert_eq!(l.slice(&flat, 0), &[0.0; 6]);
    }

    #[test]
    #[should_panic]
    fn flatten_wrong_shape_panics() {
        let l = layout();
        let bad = vec![
            Tensor::zeros(&[2, 3]),
            Tensor::zeros(&[4]), // wrong
            Tensor::zeros(&[3, 1]),
        ];
        l.flatten(&bad);
    }
}
