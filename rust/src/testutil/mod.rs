//! Mini property-testing framework (no `proptest` offline).
//!
//! [`forall`] runs a property over `cases` seeded random inputs; on failure
//! it performs shrinking-lite (retry the failing case with progressively
//! "simpler" regenerated inputs using the same seed lineage) and panics
//! with the seed so the case is replayable:
//!
//! ```ignore
//! forall("qsgd is delta-approx", 200, |g| {
//!     let v = g.vec_f32(1..=4096, -10.0..10.0);
//!     prop_assert!(check(&v), "failed on {v:?}");
//!     prop_pass!()
//! });
//! ```
//!
//! Set `DQGAN_PROP_SEED` (hex or decimal) to replay a reported failure.

use crate::util::rng::Pcg32;

/// Default base seed for property generation.
const DEFAULT_SEED: u64 = 0x5EED_D06A;

/// Random input generator handed to properties.
pub struct Gen {
    rng: Pcg32,
    /// Size dial in (0,1]: shrink attempts re-run with smaller values.
    size: f64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Self {
        Self { rng: Pcg32::new(seed), size }
    }

    /// Direct RNG access.
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }

    /// usize in [lo, hi], upper end scaled down by the shrink dial.
    pub fn usize_in(&mut self, range: std::ops::RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        assert!(lo <= hi);
        let span = (hi - lo) as f64 * self.size;
        let hi_eff = lo + span.round() as usize;
        if hi_eff <= lo {
            lo
        } else {
            lo + self.rng.below((hi_eff - lo + 1) as u32) as usize
        }
    }

    /// f32 in [lo, hi), magnitudes scaled by the shrink dial.
    pub fn f32_in(&mut self, range: std::ops::Range<f32>) -> f32 {
        let v = self.rng.uniform_range(range.start, range.end);
        (v as f64 * self.size) as f32
    }

    /// Standard normal scaled by the shrink dial.
    pub fn normal(&mut self) -> f32 {
        (self.rng.normal() as f64 * self.size) as f32
    }

    /// Bool with probability p of true.
    pub fn bool_p(&mut self, p: f32) -> bool {
        self.rng.uniform() < p
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.rng.below(xs.len() as u32) as usize]
    }

    /// Vec of f32 with random length in `len` and values in `vals`.
    pub fn vec_f32(
        &mut self,
        len: std::ops::RangeInclusive<usize>,
        vals: std::ops::Range<f32>,
    ) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(vals.clone())).collect()
    }

    /// Vec of standard normals with random length.
    pub fn vec_normal(&mut self, len: std::ops::RangeInclusive<usize>) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.normal()).collect()
    }
}

/// Outcome of a single property case.
pub enum CaseResult {
    Pass,
    Fail(String),
}

fn env_seed() -> Option<u64> {
    std::env::var("DQGAN_PROP_SEED").ok().and_then(|s| {
        let t = s.trim();
        if let Some(hex) = t.strip_prefix("0x") {
            u64::from_str_radix(hex, 16).ok()
        } else {
            t.parse().ok()
        }
    })
}

/// Run `prop` on `cases` random inputs. On failure, retries with 8 shrink
/// sizes and panics reporting the smallest failing size and the seed.
pub fn forall(name: &str, cases: u64, prop: impl Fn(&mut Gen) -> CaseResult) {
    let base_seed = env_seed().unwrap_or(DEFAULT_SEED);
    for case in 0..cases {
        let seed = base_seed ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen::new(seed, 1.0);
        if let CaseResult::Fail(msg) = prop(&mut g) {
            // shrink-lite: same seed, smaller size dial.
            let mut best = (1.0f64, msg);
            for k in 1..=8 {
                let size = 1.0 / (1u64 << k) as f64;
                let mut g = Gen::new(seed, size);
                if let CaseResult::Fail(m) = prop(&mut g) {
                    best = (size, m);
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, size {:.4}):\n  {}\n  \
                 replay with DQGAN_PROP_SEED={base_seed:#x}",
                best.0, best.1
            );
        }
    }
}

/// Assert inside a property, returning a failure message on violation.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr) => {
        if !($cond) {
            return $crate::testutil::CaseResult::Fail(format!(
                "assertion failed: {} ({}:{})",
                stringify!($cond),
                file!(),
                line!()
            ));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return $crate::testutil::CaseResult::Fail(format!($($arg)*));
        }
    };
}

/// Finish a property successfully.
#[macro_export]
macro_rules! prop_pass {
    () => {
        return $crate::testutil::CaseResult::Pass
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs() {
        forall("abs is non-negative", 64, |g| {
            let x = g.normal();
            if x.abs() >= 0.0 {
                CaseResult::Pass
            } else {
                CaseResult::Fail(format!("x={x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        forall("always fails", 4, |_g| CaseResult::Fail("nope".into()));
    }

    #[test]
    fn generators_respect_ranges() {
        forall("gen ranges", 128, |g| {
            let n = g.usize_in(3..=10);
            if !(3..=10).contains(&n) {
                return CaseResult::Fail(format!("n={n}"));
            }
            let v = g.vec_f32(1..=16, -2.0..2.0);
            if v.is_empty() || v.len() > 16 {
                return CaseResult::Fail(format!("len={}", v.len()));
            }
            if v.iter().any(|x| !(-2.0..2.0).contains(x)) {
                return CaseResult::Fail(format!("out of range: {v:?}"));
            }
            CaseResult::Pass
        });
    }
}
