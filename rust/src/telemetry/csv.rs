//! Minimal CSV writer (quoting for strings containing separators).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Buffered CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    columns: usize,
    path: String,
}

impl CsvWriter {
    /// Create/truncate `path` and write the header row.
    pub fn create(path: &Path, header: &[&str]) -> anyhow::Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(Self { out, columns: header.len(), path: path.display().to_string() })
    }

    /// Write one row of already-formatted cells.
    pub fn row(&mut self, cells: &[String]) -> anyhow::Result<()> {
        anyhow::ensure!(
            cells.len() == self.columns,
            "csv {}: row has {} cells, header has {}",
            self.path,
            cells.len(),
            self.columns
        );
        let quoted: Vec<String> = cells
            .iter()
            .map(|c| {
                if c.contains(',') || c.contains('"') || c.contains('\n') {
                    format!("\"{}\"", c.replace('"', "\"\""))
                } else {
                    c.clone()
                }
            })
            .collect();
        writeln!(self.out, "{}", quoted.join(","))?;
        Ok(())
    }

    /// Convenience: a row of f64s (formatted with 6 significant digits).
    pub fn row_f64(&mut self, cells: &[f64]) -> anyhow::Result<()> {
        let formatted: Vec<String> = cells.iter().map(|v| format!("{v:.6}")).collect();
        self.row(&formatted)
    }

    /// Flush and report the path.
    pub fn finish(mut self) -> anyhow::Result<String> {
        self.out.flush()?;
        Ok(self.path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_quotes() {
        let path = std::env::temp_dir().join("dqgan_csv_test.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&["1".into(), "x,y".into()]).unwrap();
        w.row_f64(&[1.5, -2.25]).unwrap();
        let p = w.finish().unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,\"x,y\"\n1.500000,-2.250000\n");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn wrong_arity_errors() {
        let path = std::env::temp_dir().join("dqgan_csv_test2.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        assert!(w.row(&["only-one".into()]).is_err());
        std::fs::remove_file(&path).ok();
    }
}
