//! Result recording: CSV series (one file per figure, regenerable) and
//! aligned console tables.

mod csv;
mod rounds;
mod table;

pub use csv::CsvWriter;
pub use rounds::{write_round_records, ROUND_CSV_HEADER};
pub use table::Table;

use std::path::PathBuf;

/// Results directory (`results/` or `$DQGAN_RESULTS`), created on demand.
pub fn results_dir() -> anyhow::Result<PathBuf> {
    let dir = std::env::var("DQGAN_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("results"));
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}
