//! Aligned console tables for the figure harnesses' printed output.

/// Column-aligned table builder.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "table arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (cols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("  name  value"));
        assert!(s.lines().count() == 4);
    }
}
