//! CSV sink for per-round leader telemetry ([`crate::ps::RoundRecord`]):
//! one row per synchronous round, including the `wait_secs`/`agg_secs`
//! wall-clock split — `agg_secs` further split into `decode_secs` +
//! `reduce_secs` so the windowed/offloaded reduce's overlap win is
//! visible (the old column stays as their sum) — the pipelined engine's
//! gather/broadcast `overlap_secs`, the round-completion policy's
//! `workers_included`/`workers_skipped` counts, and the
//! `broadcast_fnv` bit-pattern checksum the CI reduce-drift check diffs
//! between `--reduce windowed` and `--reduce barrier` runs, the
//! `threads_peak` live-OS-thread high-water mark, and the transport's
//! per-round downlink byte count `bytes_down` (new columns are appended
//! **after** `broadcast_fnv` only, so the CI `cut -d, -f1,12` checksum
//! greps keep their column numbers). Unknown quantities — no procfs for
//! `threads_peak`, a counterless transport for `bytes_down` — serialize
//! as the empty cell, never a fake zero.

use super::CsvWriter;
use crate::ps::RoundRecord;
use std::path::Path;

/// Column order of [`write_round_records`] output.
pub const ROUND_CSV_HEADER: [&str; 15] = [
    "round",
    "wall_secs",
    "wait_secs",
    "agg_secs",
    "decode_secs",
    "reduce_secs",
    "overlap_secs",
    "bytes_up",
    "workers_included",
    "workers_skipped",
    "avg_payload_norm_sq",
    "broadcast_fnv",
    "threads_peak",
    "bytes_down",
    "workers_evicted",
];

/// Write one row per [`RoundRecord`] to `path` (creating parent
/// directories as needed) and return the written path.
pub fn write_round_records(path: &Path, records: &[RoundRecord]) -> anyhow::Result<String> {
    let mut csv = CsvWriter::create(path, &ROUND_CSV_HEADER)?;
    for r in records {
        csv.row(&[
            r.round.to_string(),
            format!("{:.6}", r.wall_secs),
            format!("{:.6}", r.wait_secs),
            format!("{:.6}", r.agg_secs),
            format!("{:.6}", r.decode_secs),
            format!("{:.6}", r.reduce_secs),
            format!("{:.6}", r.overlap_secs),
            r.bytes_up.to_string(),
            r.workers_included.to_string(),
            r.workers_skipped.to_string(),
            format!("{:.6e}", r.avg_payload_norm_sq),
            format!("{:016x}", r.broadcast_fnv),
            r.threads_peak.map(|n| n.to_string()).unwrap_or_default(),
            r.bytes_down.map(|n| n.to_string()).unwrap_or_default(),
            r.workers_evicted.to_string(),
        ])?;
    }
    csv.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_one_row_per_round_with_policy_columns() {
        let path = std::env::temp_dir().join("dqgan_round_csv_test.csv");
        let records = vec![
            RoundRecord {
                round: 0,
                wall_secs: 0.25,
                wait_secs: 0.2,
                agg_secs: 0.05,
                decode_secs: 0.03,
                reduce_secs: 0.02,
                broadcast_fnv: 0xDEAD_BEEF_0BAD_F00D,
                overlap_secs: 0.125,
                bytes_up: 1024,
                workers_included: 3,
                workers_skipped: 1,
                workers_evicted: 1,
                threads_peak: Some(7),
                bytes_down: Some(4096),
                ..Default::default()
            },
            RoundRecord { round: 1, workers_included: 4, ..Default::default() },
        ];
        let p = write_round_records(&path, &records).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let mut lines = text.lines();
        assert_eq!(lines.next().unwrap(), ROUND_CSV_HEADER.join(","));
        let row0: Vec<&str> = lines.next().unwrap().split(',').collect();
        assert_eq!(row0.len(), ROUND_CSV_HEADER.len());
        assert_eq!(row0[0], "0");
        assert_eq!(row0[3], "0.050000");
        assert_eq!(row0[4], "0.030000", "decode_secs follows agg_secs");
        assert_eq!(row0[5], "0.020000", "reduce_secs follows decode_secs");
        assert_eq!(row0[6], "0.125000");
        assert_eq!(row0[7], "1024");
        assert_eq!(row0[8], "3");
        assert_eq!(row0[9], "1");
        assert_eq!(row0[11], "deadbeef0badf00d", "fixed-width hex checksum");
        assert_eq!(row0[12], "7", "threads_peak after broadcast_fnv");
        assert_eq!(row0[13], "4096", "bytes_down after threads_peak");
        assert_eq!(row0[14], "1", "workers_evicted appended last");
        let row1: Vec<&str> = lines.next().unwrap().split(',').collect();
        assert_eq!(row1[6], "0.000000");
        assert_eq!(row1[8], "4");
        assert_eq!(row1[9], "0");
        assert_eq!(row1[11], &"0".repeat(16));
        assert_eq!(row1[12], "", "unknown thread count serializes as the empty cell");
        assert_eq!(row1[13], "", "counterless transport leaves bytes_down empty");
        assert_eq!(row1[14], "0", "no evictions under the default abort mode");
        assert!(lines.next().is_none());
        std::fs::remove_file(&p).ok();
    }
}
