//! CSV sink for per-round leader telemetry ([`crate::ps::RoundRecord`]):
//! one row per synchronous round, including the `wait_secs`/`agg_secs`
//! wall-clock split, the pipelined engine's gather/broadcast
//! `overlap_secs`, and the round-completion policy's
//! `workers_included`/`workers_skipped` counts — the series the
//! straggler and pipelining A/Bs plot.

use super::CsvWriter;
use crate::ps::RoundRecord;
use std::path::Path;

/// Column order of [`write_round_records`] output.
pub const ROUND_CSV_HEADER: [&str; 9] = [
    "round",
    "wall_secs",
    "wait_secs",
    "agg_secs",
    "overlap_secs",
    "bytes_up",
    "workers_included",
    "workers_skipped",
    "avg_payload_norm_sq",
];

/// Write one row per [`RoundRecord`] to `path` (creating parent
/// directories as needed) and return the written path.
pub fn write_round_records(path: &Path, records: &[RoundRecord]) -> anyhow::Result<String> {
    let mut csv = CsvWriter::create(path, &ROUND_CSV_HEADER)?;
    for r in records {
        csv.row(&[
            r.round.to_string(),
            format!("{:.6}", r.wall_secs),
            format!("{:.6}", r.wait_secs),
            format!("{:.6}", r.agg_secs),
            format!("{:.6}", r.overlap_secs),
            r.bytes_up.to_string(),
            r.workers_included.to_string(),
            r.workers_skipped.to_string(),
            format!("{:.6e}", r.avg_payload_norm_sq),
        ])?;
    }
    csv.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_one_row_per_round_with_policy_columns() {
        let path = std::env::temp_dir().join("dqgan_round_csv_test.csv");
        let records = vec![
            RoundRecord {
                round: 0,
                wall_secs: 0.25,
                wait_secs: 0.2,
                agg_secs: 0.05,
                overlap_secs: 0.125,
                bytes_up: 1024,
                workers_included: 3,
                workers_skipped: 1,
                ..Default::default()
            },
            RoundRecord { round: 1, workers_included: 4, ..Default::default() },
        ];
        let p = write_round_records(&path, &records).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let mut lines = text.lines();
        assert_eq!(lines.next().unwrap(), ROUND_CSV_HEADER.join(","));
        let row0: Vec<&str> = lines.next().unwrap().split(',').collect();
        assert_eq!(row0[0], "0");
        assert_eq!(row0[4], "0.125000");
        assert_eq!(row0[5], "1024");
        assert_eq!(row0[6], "3");
        assert_eq!(row0[7], "1");
        let row1: Vec<&str> = lines.next().unwrap().split(',').collect();
        assert_eq!(row1[4], "0.000000");
        assert_eq!(row1[6], "4");
        assert_eq!(row1[7], "0");
        assert!(lines.next().is_none());
        std::fs::remove_file(&p).ok();
    }
}
