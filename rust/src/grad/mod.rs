//! The gradient operator `F(w; ξ)` abstraction.
//!
//! The paper's algorithms act on the joint operator
//! `F(w) = [∇_θ L_G(θ,φ), ∇_φ L_D(θ,φ)]` over the stacked parameter vector
//! `w = [θ, φ]`. Everything above this trait (OMD, DQGAN, the PS runtime)
//! is model-agnostic; implementations are:
//!
//! - [`crate::model::MlpGan`] / [`crate::model::BilinearGame`] — native
//!   Rust, analytic gradients (fast sweeps, tests, theory experiments);
//! - [`crate::runtime::XlaGradSource`] — the production path: the JAX/
//!   Pallas model AOT-compiled to an XLA executable.

use crate::util::rng::Pcg32;

/// Diagnostics attached to a gradient evaluation.
#[derive(Debug, Clone, Default)]
pub struct GradMeta {
    /// Generator loss L_G at the evaluation point (if the model reports it).
    pub loss_g: Option<f32>,
    /// Discriminator loss L_D at the evaluation point.
    pub loss_d: Option<f32>,
}

/// A stochastic gradient oracle for the joint GAN operator.
pub trait GradientSource: Send {
    /// Flat parameter dimension d (θ and φ stacked).
    fn dim(&self) -> usize;

    /// Evaluate the minibatch gradient `F(w; ξ)` with batch size `batch`,
    /// sampling ξ from `rng`, writing into `out` (length `dim()`).
    fn grad(
        &mut self,
        w: &[f32],
        batch: usize,
        rng: &mut Pcg32,
        out: &mut [f32],
    ) -> anyhow::Result<GradMeta>;

    /// Initial parameter vector w₀ (same for every worker — Algorithm 2
    /// line 1 pushes one w₀ to all).
    fn init_params(&self, rng: &mut Pcg32) -> Vec<f32>;

    /// Human-readable name for logs.
    fn name(&self) -> String {
        "grad-source".to_string()
    }
}

/// A deterministic quadratic test operator: F(w) = A·(w − w*) + noise.
/// Strongly monotone, so every sane algorithm must converge to w* — used
/// by the integration tests to validate algorithm plumbing.
pub struct QuadraticOperator {
    pub dim: usize,
    pub target: Vec<f32>,
    /// Diagonal of the (PSD) matrix A.
    pub diag: Vec<f32>,
    /// Per-sample noise std (simulates minibatch variance σ²).
    pub noise: f32,
}

impl QuadraticOperator {
    pub fn new(dim: usize, noise: f32, rng: &mut Pcg32) -> Self {
        let target = rng.normal_vec(dim);
        let diag = (0..dim).map(|_| 0.5 + rng.uniform()).collect();
        Self { dim, target, diag, noise }
    }
}

impl GradientSource for QuadraticOperator {
    fn dim(&self) -> usize {
        self.dim
    }

    fn grad(
        &mut self,
        w: &[f32],
        batch: usize,
        rng: &mut Pcg32,
        out: &mut [f32],
    ) -> anyhow::Result<GradMeta> {
        assert_eq!(w.len(), self.dim);
        // Minibatch of B i.i.d. noisy evaluations = exact gradient + noise/√B.
        let eff_noise = self.noise / (batch.max(1) as f32).sqrt();
        for i in 0..self.dim {
            out[i] = self.diag[i] * (w[i] - self.target[i]) + eff_noise * rng.normal();
        }
        Ok(GradMeta::default())
    }

    fn init_params(&self, rng: &mut Pcg32) -> Vec<f32> {
        rng.normal_vec(self.dim)
    }

    fn name(&self) -> String {
        format!("quadratic(d={})", self.dim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_gradient_vanishes_at_target() {
        let mut rng = Pcg32::new(3);
        let mut op = QuadraticOperator::new(8, 0.0, &mut rng);
        let target = op.target.clone();
        let mut g = vec![0.0; 8];
        op.grad(&target, 4, &mut rng, &mut g).unwrap();
        assert!(g.iter().all(|&x| x.abs() < 1e-6));
    }

    #[test]
    fn batch_size_reduces_noise() {
        let mut rng = Pcg32::new(5);
        let mut op = QuadraticOperator::new(4, 1.0, &mut rng);
        let w = vec![0.0; 4];
        let mut var_of = |op: &mut QuadraticOperator, b: usize, rng: &mut Pcg32| {
            let mut g = vec![0.0; 4];
            let mut acc = 0.0f64;
            let n = 2000;
            for _ in 0..n {
                op.grad(&w, b, rng, &mut g).unwrap();
                acc += (g[0] as f64 - (op.diag[0] * (0.0 - op.target[0])) as f64).powi(2);
            }
            acc / n as f64
        };
        let v1 = var_of(&mut op, 1, &mut rng);
        let v16 = var_of(&mut op, 16, &mut rng);
        assert!(v16 < v1, "v1={v1} v16={v16}");
    }
}
