//! Ring-of-K 2-D Gaussian mixture — the classic GAN mode-coverage toy.

use crate::util::rng::Pcg32;

/// K Gaussians evenly spaced on a circle.
#[derive(Debug, Clone)]
pub struct GaussianMixture2D {
    pub modes: Vec<[f32; 2]>,
    pub std: f32,
}

impl GaussianMixture2D {
    /// K modes on a circle of the given radius.
    pub fn ring(k: usize, radius: f32, std: f32) -> Self {
        assert!(k > 0);
        let modes = (0..k)
            .map(|i| {
                let ang = 2.0 * std::f32::consts::PI * i as f32 / k as f32;
                [radius * ang.cos(), radius * ang.sin()]
            })
            .collect();
        Self { modes, std }
    }

    /// Draw one sample.
    pub fn sample(&self, rng: &mut Pcg32) -> [f32; 2] {
        let m = &self.modes[rng.below(self.modes.len() as u32) as usize];
        [m[0] + self.std * rng.normal(), m[1] + self.std * rng.normal()]
    }

    /// Draw `n` samples as a flat [n×2] buffer.
    pub fn sample_flat(&self, n: usize, rng: &mut Pcg32) -> Vec<f32> {
        let mut out = Vec::with_capacity(2 * n);
        for _ in 0..n {
            let s = self.sample(rng);
            out.push(s[0]);
            out.push(s[1]);
        }
        out
    }

    /// Fraction of modes that have at least one of `points` within
    /// `3·std` — the mode-coverage metric of SYN-A.
    pub fn mode_coverage(&self, points: &[[f32; 2]]) -> f32 {
        let thr = 3.0 * self.std;
        let covered = self
            .modes
            .iter()
            .filter(|m| {
                points.iter().any(|p| {
                    let dx = p[0] - m[0];
                    let dy = p[1] - m[1];
                    (dx * dx + dy * dy).sqrt() < thr
                })
            })
            .count();
        covered as f32 / self.modes.len() as f32
    }

    /// Symmetrized proxy for distribution distance: mean distance from
    /// each point to its nearest mode (quality) plus the coverage deficit.
    pub fn quality_score(&self, points: &[[f32; 2]]) -> f32 {
        if points.is_empty() {
            return f32::INFINITY;
        }
        let mean_dist: f32 = points
            .iter()
            .map(|p| {
                self.modes
                    .iter()
                    .map(|m| {
                        let dx = p[0] - m[0];
                        let dy = p[1] - m[1];
                        (dx * dx + dy * dy).sqrt()
                    })
                    .fold(f32::INFINITY, f32::min)
            })
            .sum::<f32>()
            / points.len() as f32;
        mean_dist + (1.0 - self.mode_coverage(points))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_cluster_near_modes() {
        let gm = GaussianMixture2D::ring(8, 2.0, 0.05);
        let mut rng = Pcg32::new(5);
        for _ in 0..200 {
            let s = gm.sample(&mut rng);
            let min_d = gm
                .modes
                .iter()
                .map(|m| ((s[0] - m[0]).powi(2) + (s[1] - m[1]).powi(2)).sqrt())
                .fold(f32::INFINITY, f32::min);
            assert!(min_d < 0.5, "sample {s:?} too far from any mode");
        }
    }

    #[test]
    fn true_samples_cover_all_modes() {
        let gm = GaussianMixture2D::ring(8, 2.0, 0.05);
        let mut rng = Pcg32::new(7);
        let pts: Vec<[f32; 2]> = (0..500).map(|_| gm.sample(&mut rng)).collect();
        assert_eq!(gm.mode_coverage(&pts), 1.0);
        assert!(gm.quality_score(&pts) < 0.2);
    }

    #[test]
    fn collapsed_samples_score_poorly() {
        let gm = GaussianMixture2D::ring(8, 2.0, 0.05);
        // All samples at a single mode: coverage 1/8.
        let pts = vec![[2.0, 0.0]; 100];
        assert!((gm.mode_coverage(&pts) - 0.125).abs() < 1e-6);
        assert!(gm.quality_score(&pts) > 0.8);
    }
}
