//! Procedural 32×32×3 image distributions — the CIFAR-10 / CelebA
//! stand-ins (DESIGN.md §5).
//!
//! Each dataset is a 10-class mixture. A class is a deterministic template
//! built from a few oriented sinusoid + radial components (`cifar_like`)
//! or an ellipse-face composition with attribute variation (`faces_like`);
//! a sample is its class template warped by per-sample phase/position
//! jitter plus pixel noise. Pixels are in [−1, 1] (tanh range), the
//! convention the DCGAN generator uses.
//!
//! The distributions are multi-modal, class-labelled (for the proxy
//! Inception Score) and non-trivial for a GAN to fit, while being exactly
//! reproducible from a seed.

use crate::util::rng::Pcg32;

pub const IMG_H: usize = 32;
pub const IMG_W: usize = 32;
pub const IMG_C: usize = 3;

/// Pixels per image.
pub const IMG_LEN: usize = IMG_H * IMG_W * IMG_C;

/// Which procedural family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SynthKind {
    /// Frequency/orientation textures — 10 "object" classes (CIFAR-ish).
    CifarLike,
    /// Ellipse "portraits" with attribute variation (CelebA-ish).
    FacesLike,
}

/// A procedural labelled image distribution.
#[derive(Debug, Clone)]
pub struct SynthImages {
    pub kind: SynthKind,
    pub classes: usize,
    /// Per-sample additive pixel noise std.
    pub noise: f32,
    /// Per-class template parameters (deterministic from the seed).
    params: Vec<ClassParams>,
}

#[derive(Debug, Clone)]
struct ClassParams {
    // sinusoid components: (fx, fy, phase, amp) × 3
    waves: [(f32, f32, f32, f32); 3],
    // radial blob: (cx, cy, radius, amp)
    blob: (f32, f32, f32, f32),
    // base color per channel
    color: [f32; 3],
}

impl SynthImages {
    pub fn cifar_like(seed: u64) -> Self {
        Self::new(SynthKind::CifarLike, 10, 0.08, seed)
    }

    pub fn faces_like(seed: u64) -> Self {
        Self::new(SynthKind::FacesLike, 10, 0.05, seed)
    }

    fn new(kind: SynthKind, classes: usize, noise: f32, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed ^ 0x5717_11AC);
        let params = (0..classes)
            .map(|_| ClassParams {
                waves: [
                    wave(&mut rng),
                    wave(&mut rng),
                    wave(&mut rng),
                ],
                blob: (
                    rng.uniform_range(0.25, 0.75),
                    rng.uniform_range(0.25, 0.75),
                    rng.uniform_range(0.1, 0.3),
                    rng.uniform_range(0.3, 0.9),
                ),
                color: [
                    rng.uniform_range(-0.5, 0.5),
                    rng.uniform_range(-0.5, 0.5),
                    rng.uniform_range(-0.5, 0.5),
                ],
            })
            .collect();
        Self { kind, classes, noise, params }
    }

    /// Render one sample of class `label` into `out` (length IMG_LEN,
    /// CHW layout, pixels in [−1,1]).
    pub fn render(&self, label: usize, rng: &mut Pcg32, out: &mut [f32]) {
        assert_eq!(out.len(), IMG_LEN);
        let p = &self.params[label % self.classes];
        // per-sample jitter
        let dx = rng.uniform_range(-0.08, 0.08);
        let dy = rng.uniform_range(-0.08, 0.08);
        let dphase = rng.uniform_range(-0.6, 0.6);
        let scale = rng.uniform_range(0.85, 1.15);
        for y in 0..IMG_H {
            for x in 0..IMG_W {
                let u = x as f32 / IMG_W as f32 + dx;
                let v = y as f32 / IMG_H as f32 + dy;
                let mut base = 0.0f32;
                for &(fx, fy, ph, amp) in &p.waves {
                    base += amp * (2.0 * std::f32::consts::PI * (fx * u + fy * v) + ph + dphase)
                        .sin();
                }
                // radial component
                let (cx, cy, r, amp) = p.blob;
                let dist = (((u - cx) * (u - cx) + (v - cy) * (v - cy)).sqrt() / r).min(4.0);
                let blob = amp * (-dist * dist).exp();
                let face = match self.kind {
                    SynthKind::CifarLike => 0.0,
                    SynthKind::FacesLike => face_component(u, v, label, scale),
                };
                let lum = (base * 0.4 + blob + face).clamp(-1.0, 1.0);
                for c in 0..IMG_C {
                    let px = (lum + p.color[c]).clamp(-1.0, 1.0)
                        + self.noise * rng.normal();
                    out[c * IMG_H * IMG_W + y * IMG_W + x] = px.clamp(-1.0, 1.0);
                }
            }
        }
    }

    /// Sample a batch: returns (flat [n×IMG_LEN] pixels, labels).
    pub fn sample_batch(&self, n: usize, rng: &mut Pcg32) -> (Vec<f32>, Vec<usize>) {
        let mut pixels = vec![0.0f32; n * IMG_LEN];
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let label = rng.below(self.classes as u32) as usize;
            self.render(label, rng, &mut pixels[i * IMG_LEN..(i + 1) * IMG_LEN]);
            labels.push(label);
        }
        (pixels, labels)
    }
}

fn wave(rng: &mut Pcg32) -> (f32, f32, f32, f32) {
    (
        rng.uniform_range(0.5, 6.0),
        rng.uniform_range(0.5, 6.0),
        rng.uniform_range(0.0, std::f32::consts::TAU),
        rng.uniform_range(0.3, 1.0),
    )
}

/// Ellipse-face component: head outline + eyes + mouth, parameterized by
/// the class label ("identity") and a per-sample scale ("expression").
fn face_component(u: f32, v: f32, label: usize, scale: f32) -> f32 {
    let l = label as f32 / 10.0;
    // head: ellipse centered slightly above middle
    let (hu, hv) = ((u - 0.5) / (0.32 * scale), (v - 0.45) / (0.40 * scale));
    let head = 1.0 - (hu * hu + hv * hv);
    let mut val = if head > 0.0 { 0.8 * head.min(0.4) / 0.4 } else { -0.3 };
    // eyes: two small blobs whose spacing encodes identity
    let eye_dx = 0.10 + 0.06 * l;
    for sgn in [-1.0f32, 1.0] {
        let (eu, ev) = (u - (0.5 + sgn * eye_dx), v - 0.38);
        if (eu * eu + ev * ev).sqrt() < 0.035 * scale {
            val -= 1.2;
        }
    }
    // mouth: horizontal bar, vertical position encodes identity
    let mv = 0.60 + 0.05 * l;
    if (v - mv).abs() < 0.02 * scale && (u - 0.5).abs() < 0.10 * scale {
        val -= 0.9;
    }
    val
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::dist2_sq;

    #[test]
    fn pixels_are_bounded() {
        for ds in [SynthImages::cifar_like(1), SynthImages::faces_like(1)] {
            let mut rng = Pcg32::new(2);
            let (px, labels) = ds.sample_batch(8, &mut rng);
            assert_eq!(px.len(), 8 * IMG_LEN);
            assert_eq!(labels.len(), 8);
            assert!(px.iter().all(|&p| (-1.0..=1.0).contains(&p)));
        }
    }

    #[test]
    fn classes_are_distinguishable() {
        // Same-class pairs must be closer than cross-class pairs on average.
        let ds = SynthImages::cifar_like(3);
        let mut rng = Pcg32::new(4);
        let mut a0 = vec![0.0; IMG_LEN];
        let mut b0 = vec![0.0; IMG_LEN];
        let mut a1 = vec![0.0; IMG_LEN];
        let mut intra = 0.0f64;
        let mut inter = 0.0f64;
        let trials = 20;
        for _ in 0..trials {
            ds.render(0, &mut rng, &mut a0);
            ds.render(0, &mut rng, &mut b0);
            ds.render(1, &mut rng, &mut a1);
            intra += dist2_sq(&a0, &b0) as f64;
            inter += dist2_sq(&a0, &a1) as f64;
        }
        assert!(
            inter > intra * 1.5,
            "classes not separable: intra={intra} inter={inter}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let ds1 = SynthImages::faces_like(9);
        let ds2 = SynthImages::faces_like(9);
        let mut r1 = Pcg32::new(10);
        let mut r2 = Pcg32::new(10);
        let (p1, l1) = ds1.sample_batch(4, &mut r1);
        let (p2, l2) = ds2.sample_batch(4, &mut r2);
        assert_eq!(p1, p2);
        assert_eq!(l1, l2);
    }

    #[test]
    fn cifar_and_faces_differ() {
        let c = SynthImages::cifar_like(5);
        let f = SynthImages::faces_like(5);
        let mut r1 = Pcg32::new(6);
        let mut r2 = Pcg32::new(6);
        let mut a = vec![0.0; IMG_LEN];
        let mut b = vec![0.0; IMG_LEN];
        c.render(0, &mut r1, &mut a);
        f.render(0, &mut r2, &mut b);
        assert!(dist2_sq(&a, &b) > 1.0);
    }
}
