//! Synthetic datasets (DESIGN.md §5 substitutions).
//!
//! - [`GaussianMixture2D`] — the ring-of-K-Gaussians used by the SYN-A
//!   mode-coverage experiment (the standard GAN toy distribution);
//! - [`SynthImages`] — procedural 32×32×3 image distributions standing in
//!   for CIFAR-10 (`SynthImages::cifar_like`) and CelebA
//!   (`SynthImages::faces_like`): per-class template patterns + per-sample
//!   jitter, exercising exactly the code paths the paper's Figures 2–3
//!   exercise (multi-modal image distribution → conv GAN → IS/FID).

mod gaussian_mixture;
mod synth_images;

pub use gaussian_mixture::GaussianMixture2D;
pub use synth_images::{SynthImages, SynthKind, IMG_C, IMG_H, IMG_LEN, IMG_W};
