//! Content-addressed checkpoint store for elastic recovery.
//!
//! The leader's replay ledger (`ps/server.rs`) keeps only the last
//! `--replay-depth` broadcast frames in memory; anything older — and any
//! periodic model/error-memory snapshot — lands here when `--ckpt-dir`
//! is set. Blobs are **content-addressed**: a blob's filename embeds the
//! byte-wise FNV-1a digest of its contents
//! (`<kind>-r<round>-s<shard>-<fnv:016x>.bin`), so
//!
//! * a re-put of identical content is a no-op (the file already exists
//!   under the same name — crash-and-retry is idempotent),
//! * a read verifies the digest before returning, turning silent disk
//!   corruption into a loud error instead of a diverged rejoin.
//!
//! A small JSON manifest (`MANIFEST.json`, via the zero-dep
//! [`crate::util::json`] writer) maps the logical key `(kind, round,
//! shard)` to the blob's digest and length; it is rewritten atomically
//! (temp file + rename) after every put, so a torn write leaves the
//! previous manifest intact. The store deliberately has no notion of
//! "latest" — callers address snapshots by round, which is the unit of
//! consistency in a synchronous parameter-server run.

mod manifest;

pub use manifest::{decode_worker_state, encode_worker_state, RunManifest};

use crate::util::bytes::fnv1a64;
use crate::util::json::Json;
use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Manifest filename inside the store directory.
const MANIFEST: &str = "MANIFEST.json";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Entry {
    fnv: u64,
    len: usize,
}

/// A directory of content-addressed, round-stamped blobs plus a JSON
/// manifest. One store per run (leader-side); readers and writers go
/// through the same instance, so no cross-process locking is needed.
#[derive(Debug)]
pub struct CkptStore {
    dir: PathBuf,
    /// Logical key `"<kind>-r<round>-s<shard>"` → blob identity.
    entries: BTreeMap<String, Entry>,
}

fn key(kind: &str, round: u64, shard: u32) -> String {
    format!("{kind}-r{round}-s{shard}")
}

fn blob_name(kind: &str, round: u64, shard: u32, fnv: u64) -> String {
    format!("{kind}-r{round}-s{shard}-{fnv:016x}.bin")
}

impl CkptStore {
    /// Open (or create) the store at `dir`, loading the manifest if one
    /// exists. Fails on an unreadable or malformed manifest rather than
    /// silently starting empty — an operator pointing `--ckpt-dir` at a
    /// corrupt store should hear about it before the run depends on it.
    pub fn open(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir)
            .map_err(|e| anyhow::anyhow!("ckpt dir {}: {e}", dir.display()))?;
        let mut entries = BTreeMap::new();
        let manifest = dir.join(MANIFEST);
        if manifest.exists() {
            let text = fs::read_to_string(&manifest)
                .map_err(|e| anyhow::anyhow!("ckpt manifest {}: {e}", manifest.display()))?;
            let doc = Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("ckpt manifest {}: {e}", manifest.display()))?;
            let obj = doc
                .get("entries")
                .and_then(Json::as_obj)
                .ok_or_else(|| anyhow::anyhow!("ckpt manifest: missing \"entries\" object"))?;
            for (k, v) in obj {
                let fnv_hex = v
                    .get("fnv")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("ckpt manifest entry {k}: missing fnv"))?;
                let fnv = u64::from_str_radix(fnv_hex, 16)
                    .map_err(|_| anyhow::anyhow!("ckpt manifest entry {k}: bad fnv hex"))?;
                let len = v
                    .get("bytes")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("ckpt manifest entry {k}: missing bytes"))?;
                entries.insert(k.clone(), Entry { fnv, len });
            }
        }
        Ok(Self { dir, entries })
    }

    /// The store's root directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Number of blobs the manifest knows about.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Whether a blob exists for `(kind, round, shard)`.
    pub fn contains(&self, kind: &str, round: u64, shard: u32) -> bool {
        self.entries.contains_key(&key(kind, round, shard))
    }

    /// The stored digest for `(kind, round, shard)`, if any — lets the
    /// run manifest record per-worker state digests without re-reading
    /// the blob bytes.
    pub fn entry_digest(&self, kind: &str, round: u64, shard: u32) -> Option<u64> {
        self.entries.get(&key(kind, round, shard)).map(|e| e.fnv)
    }

    /// Sorted distinct rounds that have at least one blob of `kind`.
    pub fn rounds(&self, kind: &str) -> Vec<u64> {
        let prefix = format!("{kind}-r");
        let mut rounds = BTreeSet::new();
        for k in self.entries.keys() {
            if let Some(rest) = k.strip_prefix(&prefix) {
                if let Some((r, _)) = rest.split_once("-s") {
                    if let Ok(r) = r.parse::<u64>() {
                        rounds.insert(r);
                    }
                }
            }
        }
        rounds.into_iter().collect()
    }

    /// Retention sweep: keep the blobs of the newest `keep` distinct
    /// rounds (per the union of all kinds) plus `protect` (the round the
    /// run manifest points at — never pruned regardless of age); drop
    /// every older round's entries and delete their blob files. The
    /// manifest is rewritten atomically once at the end, so a crash
    /// mid-sweep leaves at worst already-deleted blobs that the next
    /// `open` + gc pass will drop from the manifest again. Returns the
    /// number of blobs pruned.
    pub fn gc_keep(&mut self, keep: usize, protect: Option<u64>) -> anyhow::Result<usize> {
        anyhow::ensure!(keep >= 1, "ckpt-gc: --keep must be at least 1");
        let mut all_rounds = BTreeSet::new();
        let mut parsed: BTreeMap<String, u64> = BTreeMap::new();
        for k in self.entries.keys() {
            // Key shape is "<kind>-r<round>-s<shard>"; kinds are
            // [A-Za-z0-9_] so the first "-r" is unambiguous.
            let Some((_, rest)) = k.split_once("-r") else { continue };
            let Some((r, _)) = rest.split_once("-s") else { continue };
            let Ok(r) = r.parse::<u64>() else { continue };
            all_rounds.insert(r);
            parsed.insert(k.clone(), r);
        }
        let rounds: Vec<u64> = all_rounds.into_iter().collect();
        if rounds.len() <= keep {
            return Ok(0);
        }
        let cutoff = rounds[rounds.len() - keep]; // keep rounds >= cutoff
        let doomed: Vec<String> = parsed
            .iter()
            .filter(|(_, &r)| r < cutoff && Some(r) != protect)
            .map(|(k, _)| k.clone())
            .collect();
        let mut pruned = 0usize;
        for k in &doomed {
            let entry = self.entries.remove(k).expect("doomed key came from entries");
            let path = self.dir.join(format!("{k}-{:016x}.bin", entry.fnv));
            match fs::remove_file(&path) {
                Ok(()) => pruned += 1,
                // A superseded key's old blob may already be gone (it was
                // manifest garbage); missing files are not an error.
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => pruned += 1,
                Err(e) => anyhow::bail!("ckpt-gc remove {}: {e}", path.display()),
            }
        }
        if !doomed.is_empty() {
            self.write_manifest()?;
        }
        Ok(pruned)
    }

    /// Store `bytes` under `(kind, round, shard)`. Content-addressed:
    /// re-putting identical bytes skips the data write entirely, and
    /// putting *different* bytes for the same key supersedes the old
    /// blob in the manifest (the old file stays on disk as garbage — a
    /// deliberate trade: recovery never deletes data it might be asked
    /// to trust again).
    pub fn put(&mut self, kind: &str, round: u64, shard: u32, bytes: &[u8]) -> anyhow::Result<()> {
        anyhow::ensure!(
            !kind.is_empty() && kind.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'_'),
            "ckpt kind {kind:?} must be non-empty [A-Za-z0-9_] (it names files)"
        );
        let fnv = fnv1a64(bytes);
        let entry = Entry { fnv, len: bytes.len() };
        let k = key(kind, round, shard);
        if self.entries.get(&k) == Some(&entry) {
            return Ok(()); // idempotent re-put of identical content
        }
        let path = self.dir.join(blob_name(kind, round, shard, fnv));
        if !path.exists() {
            write_atomic(&path, bytes)?;
            crate::obs::metrics::RECOVERY_CKPT_BYTES.add(bytes.len() as u64);
        }
        self.entries.insert(k, entry);
        self.write_manifest()
    }

    /// Fetch the blob for `(kind, round, shard)`, verifying its digest.
    /// `Ok(None)` when the key was never stored; an error when the blob
    /// file is missing or its contents no longer hash to the manifest's
    /// digest (disk corruption must not become a diverged rejoin).
    pub fn get(&self, kind: &str, round: u64, shard: u32) -> anyhow::Result<Option<Vec<u8>>> {
        let Some(entry) = self.entries.get(&key(kind, round, shard)) else {
            return Ok(None);
        };
        let path = self.dir.join(blob_name(kind, round, shard, entry.fnv));
        let bytes = fs::read(&path)
            .map_err(|e| anyhow::anyhow!("ckpt blob {}: {e}", path.display()))?;
        anyhow::ensure!(
            bytes.len() == entry.len && fnv1a64(&bytes) == entry.fnv,
            "ckpt blob {} failed verification: {} bytes (manifest: {}), content digest \
             mismatch — refusing to serve a corrupt checkpoint",
            path.display(),
            bytes.len(),
            entry.len,
        );
        crate::obs::metrics::RECOVERY_CKPT_READ_BYTES.add(bytes.len() as u64);
        Ok(Some(bytes))
    }

    fn write_manifest(&self) -> anyhow::Result<()> {
        let mut obj = BTreeMap::new();
        for (k, e) in &self.entries {
            let mut rec = BTreeMap::new();
            rec.insert("fnv".to_string(), Json::Str(format!("{:016x}", e.fnv)));
            rec.insert("bytes".to_string(), Json::Num(e.len as f64));
            obj.insert(k.clone(), Json::Obj(rec));
        }
        let mut doc = BTreeMap::new();
        doc.insert("version".to_string(), Json::Num(1.0));
        doc.insert("entries".to_string(), Json::Obj(obj));
        write_atomic(&self.dir.join(MANIFEST), Json::Obj(doc).to_string_compact().as_bytes())
    }
}

/// Write via a sibling temp file + rename, so readers (and the next
/// process to `open` the dir after a crash) never observe a torn file.
pub(crate) fn write_atomic(path: &Path, bytes: &[u8]) -> anyhow::Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = fs::File::create(&tmp)
            .map_err(|e| anyhow::anyhow!("ckpt write {}: {e}", tmp.display()))?;
        f.write_all(bytes).map_err(|e| anyhow::anyhow!("ckpt write {}: {e}", tmp.display()))?;
        f.sync_all().ok(); // best effort: durability, not correctness
    }
    fs::rename(&tmp, path)
        .map_err(|e| anyhow::anyhow!("ckpt rename {} -> {}: {e}", tmp.display(), path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "dqgan-ckpt-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn put_get_round_trips_and_is_idempotent() {
        let dir = tmp_dir("rt");
        let mut s = CkptStore::open(&dir).unwrap();
        assert!(s.is_empty());
        s.put("bcast", 3, 0, b"hello frame").unwrap();
        s.put("bcast", 3, 0, b"hello frame").unwrap(); // no-op re-put
        assert_eq!(s.len(), 1);
        assert!(s.contains("bcast", 3, 0));
        assert!(!s.contains("bcast", 4, 0));
        assert_eq!(s.get("bcast", 3, 0).unwrap().as_deref(), Some(&b"hello frame"[..]));
        assert_eq!(s.get("bcast", 9, 0).unwrap(), None);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_survives_reopen() {
        let dir = tmp_dir("reopen");
        let mut s = CkptStore::open(&dir).unwrap();
        s.put("model", 10, 2, &[1, 2, 3, 4]).unwrap();
        s.put("bcast", 11, 0, &[9, 9]).unwrap();
        drop(s);
        let s = CkptStore::open(&dir).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.get("model", 10, 2).unwrap(), Some(vec![1, 2, 3, 4]));
        assert_eq!(s.get("bcast", 11, 0).unwrap(), Some(vec![9, 9]));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn superseding_a_key_serves_the_new_content() {
        let dir = tmp_dir("supersede");
        let mut s = CkptStore::open(&dir).unwrap();
        s.put("bcast", 0, 0, b"old").unwrap();
        s.put("bcast", 0, 0, b"new").unwrap();
        assert_eq!(s.get("bcast", 0, 0).unwrap().as_deref(), Some(&b"new"[..]));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_blob_is_refused() {
        let dir = tmp_dir("corrupt");
        let mut s = CkptStore::open(&dir).unwrap();
        s.put("bcast", 5, 0, b"trusted bytes").unwrap();
        let blob = dir.join(blob_name("bcast", 5, 0, fnv1a64(b"trusted bytes")));
        fs::write(&blob, b"tampered bytes").unwrap();
        let err = s.get("bcast", 5, 0).unwrap_err().to_string();
        assert!(err.contains("failed verification"), "{err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_blob_is_refused_with_path_in_error() {
        let dir = tmp_dir("trunc");
        let mut s = CkptStore::open(&dir).unwrap();
        s.put("wstate", 7, 1, b"state bytes that matter").unwrap();
        let blob = dir.join(blob_name("wstate", 7, 1, fnv1a64(b"state bytes that matter")));
        fs::write(&blob, b"state by").unwrap(); // torn tail
        let err = s.get("wstate", 7, 1).unwrap_err().to_string();
        assert!(err.contains("failed verification"), "{err}");
        assert!(err.contains(&blob.display().to_string()), "error must name the path: {err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn bit_flipped_blob_is_refused_even_at_same_length() {
        let dir = tmp_dir("bitflip");
        let mut s = CkptStore::open(&dir).unwrap();
        s.put("model", 2, 0, &[0u8, 1, 2, 3, 4, 5, 6, 7]).unwrap();
        let blob = dir.join(blob_name("model", 2, 0, fnv1a64(&[0u8, 1, 2, 3, 4, 5, 6, 7])));
        let mut bytes = fs::read(&blob).unwrap();
        bytes[3] ^= 0x40; // same length, one flipped bit
        fs::write(&blob, &bytes).unwrap();
        let err = s.get("model", 2, 0).unwrap_err().to_string();
        assert!(err.contains("refusing to serve a corrupt checkpoint"), "{err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_keeps_last_k_rounds_and_the_protected_round() {
        let dir = tmp_dir("gc");
        let mut s = CkptStore::open(&dir).unwrap();
        for r in 0..10u64 {
            s.put("bcast", r, 0, format!("frame {r}").as_bytes()).unwrap();
            s.put("wstate", r, 0, format!("state {r}").as_bytes()).unwrap();
        }
        // Keep the newest 3 rounds (7, 8, 9) and protect round 2.
        let pruned = s.gc_keep(3, Some(2)).unwrap();
        assert_eq!(pruned, 12, "rounds 0,1,3,4,5,6 × 2 kinds");
        assert_eq!(s.rounds("bcast"), vec![2, 7, 8, 9]);
        assert_eq!(s.rounds("wstate"), vec![2, 7, 8, 9]);
        // Survivors still read back verified.
        assert_eq!(s.get("bcast", 2, 0).unwrap().as_deref(), Some(&b"frame 2"[..]));
        assert_eq!(s.get("wstate", 9, 0).unwrap().as_deref(), Some(&b"state 9"[..]));
        assert_eq!(s.get("bcast", 5, 0).unwrap(), None);
        // The pruned blob files are really gone from disk.
        let blob5 = dir.join(blob_name("bcast", 5, 0, fnv1a64(b"frame 5")));
        assert!(!blob5.exists());
        // And the manifest rewrite survives a reopen.
        drop(s);
        let s = CkptStore::open(&dir).unwrap();
        assert_eq!(s.rounds("bcast"), vec![2, 7, 8, 9]);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_with_fewer_rounds_than_keep_is_a_no_op() {
        let dir = tmp_dir("gc-noop");
        let mut s = CkptStore::open(&dir).unwrap();
        s.put("bcast", 0, 0, b"a").unwrap();
        s.put("bcast", 1, 0, b"b").unwrap();
        assert_eq!(s.gc_keep(5, None).unwrap(), 0);
        assert_eq!(s.len(), 2);
        assert!(s.gc_keep(0, None).is_err(), "--keep 0 must be rejected");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn rounds_lists_distinct_rounds_per_kind() {
        let dir = tmp_dir("rounds");
        let mut s = CkptStore::open(&dir).unwrap();
        s.put("wstate", 4, 0, b"a").unwrap();
        s.put("wstate", 4, 1, b"b").unwrap();
        s.put("wstate", 9, 0, b"c").unwrap();
        s.put("bcast", 3, 0, b"d").unwrap();
        assert_eq!(s.rounds("wstate"), vec![4, 9]);
        assert_eq!(s.rounds("bcast"), vec![3]);
        assert!(s.rounds("model").is_empty());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_path_hostile_kinds() {
        let dir = tmp_dir("hostile");
        let mut s = CkptStore::open(&dir).unwrap();
        assert!(s.put("../evil", 0, 0, b"x").is_err());
        assert!(s.put("", 0, 0, b"x").is_err());
        let _ = fs::remove_dir_all(&dir);
    }
}
