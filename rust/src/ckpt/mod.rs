//! Content-addressed checkpoint store for elastic recovery.
//!
//! The leader's replay ledger (`ps/server.rs`) keeps only the last
//! `--replay-depth` broadcast frames in memory; anything older — and any
//! periodic model/error-memory snapshot — lands here when `--ckpt-dir`
//! is set. Blobs are **content-addressed**: a blob's filename embeds the
//! byte-wise FNV-1a digest of its contents
//! (`<kind>-r<round>-s<shard>-<fnv:016x>.bin`), so
//!
//! * a re-put of identical content is a no-op (the file already exists
//!   under the same name — crash-and-retry is idempotent),
//! * a read verifies the digest before returning, turning silent disk
//!   corruption into a loud error instead of a diverged rejoin.
//!
//! A small JSON manifest (`MANIFEST.json`, via the zero-dep
//! [`crate::util::json`] writer) maps the logical key `(kind, round,
//! shard)` to the blob's digest and length; it is rewritten atomically
//! (temp file + rename) after every put, so a torn write leaves the
//! previous manifest intact. The store deliberately has no notion of
//! "latest" — callers address snapshots by round, which is the unit of
//! consistency in a synchronous parameter-server run.

use crate::util::bytes::fnv1a64;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Manifest filename inside the store directory.
const MANIFEST: &str = "MANIFEST.json";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Entry {
    fnv: u64,
    len: usize,
}

/// A directory of content-addressed, round-stamped blobs plus a JSON
/// manifest. One store per run (leader-side); readers and writers go
/// through the same instance, so no cross-process locking is needed.
#[derive(Debug)]
pub struct CkptStore {
    dir: PathBuf,
    /// Logical key `"<kind>-r<round>-s<shard>"` → blob identity.
    entries: BTreeMap<String, Entry>,
}

fn key(kind: &str, round: u64, shard: u32) -> String {
    format!("{kind}-r{round}-s{shard}")
}

fn blob_name(kind: &str, round: u64, shard: u32, fnv: u64) -> String {
    format!("{kind}-r{round}-s{shard}-{fnv:016x}.bin")
}

impl CkptStore {
    /// Open (or create) the store at `dir`, loading the manifest if one
    /// exists. Fails on an unreadable or malformed manifest rather than
    /// silently starting empty — an operator pointing `--ckpt-dir` at a
    /// corrupt store should hear about it before the run depends on it.
    pub fn open(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir)
            .map_err(|e| anyhow::anyhow!("ckpt dir {}: {e}", dir.display()))?;
        let mut entries = BTreeMap::new();
        let manifest = dir.join(MANIFEST);
        if manifest.exists() {
            let text = fs::read_to_string(&manifest)
                .map_err(|e| anyhow::anyhow!("ckpt manifest {}: {e}", manifest.display()))?;
            let doc = Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("ckpt manifest {}: {e}", manifest.display()))?;
            let obj = doc
                .get("entries")
                .and_then(Json::as_obj)
                .ok_or_else(|| anyhow::anyhow!("ckpt manifest: missing \"entries\" object"))?;
            for (k, v) in obj {
                let fnv_hex = v
                    .get("fnv")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("ckpt manifest entry {k}: missing fnv"))?;
                let fnv = u64::from_str_radix(fnv_hex, 16)
                    .map_err(|_| anyhow::anyhow!("ckpt manifest entry {k}: bad fnv hex"))?;
                let len = v
                    .get("bytes")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("ckpt manifest entry {k}: missing bytes"))?;
                entries.insert(k.clone(), Entry { fnv, len });
            }
        }
        Ok(Self { dir, entries })
    }

    /// Number of blobs the manifest knows about.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Whether a blob exists for `(kind, round, shard)`.
    pub fn contains(&self, kind: &str, round: u64, shard: u32) -> bool {
        self.entries.contains_key(&key(kind, round, shard))
    }

    /// Store `bytes` under `(kind, round, shard)`. Content-addressed:
    /// re-putting identical bytes skips the data write entirely, and
    /// putting *different* bytes for the same key supersedes the old
    /// blob in the manifest (the old file stays on disk as garbage — a
    /// deliberate trade: recovery never deletes data it might be asked
    /// to trust again).
    pub fn put(&mut self, kind: &str, round: u64, shard: u32, bytes: &[u8]) -> anyhow::Result<()> {
        anyhow::ensure!(
            !kind.is_empty() && kind.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'_'),
            "ckpt kind {kind:?} must be non-empty [A-Za-z0-9_] (it names files)"
        );
        let fnv = fnv1a64(bytes);
        let entry = Entry { fnv, len: bytes.len() };
        let k = key(kind, round, shard);
        if self.entries.get(&k) == Some(&entry) {
            return Ok(()); // idempotent re-put of identical content
        }
        let path = self.dir.join(blob_name(kind, round, shard, fnv));
        if !path.exists() {
            write_atomic(&path, bytes)?;
            crate::obs::metrics::RECOVERY_CKPT_BYTES.add(bytes.len() as u64);
        }
        self.entries.insert(k, entry);
        self.write_manifest()
    }

    /// Fetch the blob for `(kind, round, shard)`, verifying its digest.
    /// `Ok(None)` when the key was never stored; an error when the blob
    /// file is missing or its contents no longer hash to the manifest's
    /// digest (disk corruption must not become a diverged rejoin).
    pub fn get(&self, kind: &str, round: u64, shard: u32) -> anyhow::Result<Option<Vec<u8>>> {
        let Some(entry) = self.entries.get(&key(kind, round, shard)) else {
            return Ok(None);
        };
        let path = self.dir.join(blob_name(kind, round, shard, entry.fnv));
        let bytes = fs::read(&path)
            .map_err(|e| anyhow::anyhow!("ckpt blob {}: {e}", path.display()))?;
        anyhow::ensure!(
            bytes.len() == entry.len && fnv1a64(&bytes) == entry.fnv,
            "ckpt blob {} failed verification: {} bytes (manifest: {}), content digest \
             mismatch — refusing to serve a corrupt checkpoint",
            path.display(),
            bytes.len(),
            entry.len,
        );
        Ok(Some(bytes))
    }

    fn write_manifest(&self) -> anyhow::Result<()> {
        let mut obj = BTreeMap::new();
        for (k, e) in &self.entries {
            let mut rec = BTreeMap::new();
            rec.insert("fnv".to_string(), Json::Str(format!("{:016x}", e.fnv)));
            rec.insert("bytes".to_string(), Json::Num(e.len as f64));
            obj.insert(k.clone(), Json::Obj(rec));
        }
        let mut doc = BTreeMap::new();
        doc.insert("version".to_string(), Json::Num(1.0));
        doc.insert("entries".to_string(), Json::Obj(obj));
        write_atomic(&self.dir.join(MANIFEST), Json::Obj(doc).to_string_compact().as_bytes())
    }
}

/// Write via a sibling temp file + rename, so readers (and the next
/// process to `open` the dir after a crash) never observe a torn file.
fn write_atomic(path: &Path, bytes: &[u8]) -> anyhow::Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = fs::File::create(&tmp)
            .map_err(|e| anyhow::anyhow!("ckpt write {}: {e}", tmp.display()))?;
        f.write_all(bytes).map_err(|e| anyhow::anyhow!("ckpt write {}: {e}", tmp.display()))?;
        f.sync_all().ok(); // best effort: durability, not correctness
    }
    fs::rename(&tmp, path)
        .map_err(|e| anyhow::anyhow!("ckpt rename {} -> {}: {e}", tmp.display(), path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "dqgan-ckpt-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn put_get_round_trips_and_is_idempotent() {
        let dir = tmp_dir("rt");
        let mut s = CkptStore::open(&dir).unwrap();
        assert!(s.is_empty());
        s.put("bcast", 3, 0, b"hello frame").unwrap();
        s.put("bcast", 3, 0, b"hello frame").unwrap(); // no-op re-put
        assert_eq!(s.len(), 1);
        assert!(s.contains("bcast", 3, 0));
        assert!(!s.contains("bcast", 4, 0));
        assert_eq!(s.get("bcast", 3, 0).unwrap().as_deref(), Some(&b"hello frame"[..]));
        assert_eq!(s.get("bcast", 9, 0).unwrap(), None);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_survives_reopen() {
        let dir = tmp_dir("reopen");
        let mut s = CkptStore::open(&dir).unwrap();
        s.put("model", 10, 2, &[1, 2, 3, 4]).unwrap();
        s.put("bcast", 11, 0, &[9, 9]).unwrap();
        drop(s);
        let s = CkptStore::open(&dir).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.get("model", 10, 2).unwrap(), Some(vec![1, 2, 3, 4]));
        assert_eq!(s.get("bcast", 11, 0).unwrap(), Some(vec![9, 9]));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn superseding_a_key_serves_the_new_content() {
        let dir = tmp_dir("supersede");
        let mut s = CkptStore::open(&dir).unwrap();
        s.put("bcast", 0, 0, b"old").unwrap();
        s.put("bcast", 0, 0, b"new").unwrap();
        assert_eq!(s.get("bcast", 0, 0).unwrap().as_deref(), Some(&b"new"[..]));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_blob_is_refused() {
        let dir = tmp_dir("corrupt");
        let mut s = CkptStore::open(&dir).unwrap();
        s.put("bcast", 5, 0, b"trusted bytes").unwrap();
        let blob = dir.join(blob_name("bcast", 5, 0, fnv1a64(b"trusted bytes")));
        fs::write(&blob, b"tampered bytes").unwrap();
        let err = s.get("bcast", 5, 0).unwrap_err().to_string();
        assert!(err.contains("failed verification"), "{err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_path_hostile_kinds() {
        let dir = tmp_dir("hostile");
        let mut s = CkptStore::open(&dir).unwrap();
        assert!(s.put("../evil", 0, 0, b"x").is_err());
        assert!(s.put("", 0, 0, b"x").is_err());
        let _ = fs::remove_dir_all(&dir);
    }
}
