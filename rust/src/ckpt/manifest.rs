//! The crash-consistent **run manifest** (`RUN.json`): one small JSON
//! document that names the single round a leader restart may resume
//! from, plus everything needed to refuse an incompatible resume.
//!
//! The store's blob manifest (`MANIFEST.json`) says *what bytes exist*;
//! `RUN.json` says *which round is consistent* — it is only advanced
//! after every worker's round-stamped state snapshot and the round's
//! broadcast frame are durably in the store, so the pointed-at round is
//! always restorable as a unit. Both files go through the same
//! temp-file + atomic-rename writer, so a crash mid-update leaves the
//! previous version intact: a torn write before the rename is invisible
//! (the `.tmp` sibling is ignored on open), and the rename itself is
//! atomic. The torn-prefix property test below drives every byte prefix
//! through that path.
//!
//! u64 digests and fingerprints are serialized as 16-digit hex strings —
//! JSON numbers ride through an f64 (`crate::util::json`), which cannot
//! hold all 64 bits.

use super::write_atomic;
use crate::algo::WorkerAlgo;
use crate::util::bytes::{put_u32, put_u64, Reader};
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

/// Manifest filename inside the checkpoint directory.
pub const RUN_MANIFEST: &str = "RUN.json";

/// Current `RUN.json` schema version.
const RUN_VERSION: u64 = 1;

/// Worker-state blob framing magic ("DQGAN Worker State").
const WSTATE_MAGIC: &[u8; 4] = b"DQWS";
const WSTATE_VERSION: u32 = 1;

/// The run-level recovery record. `round` is the last round whose
/// broadcast *and* all per-worker snapshots are in the store; a resumed
/// leader restarts the loop at `round + 1`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RunManifest {
    /// Last fully-checkpointed round (broadcast + all worker snapshots).
    pub round: u64,
    /// Session epoch: bumped on every resume, echoed in the reconnect
    /// handshake so a worker can tell a restarted leader from the one it
    /// lost.
    pub epoch: u64,
    /// Config fingerprint ([`crate::ps::ClusterConfig::fingerprint`]) —
    /// a resume under a different algorithm/policy/seed is refused, not
    /// silently diverged.
    pub fingerprint: u64,
    /// Fleet size the snapshots were taken with.
    pub workers: usize,
    /// Per-worker `wstate` blob digests at `round`, index = worker id.
    pub worker_digests: Vec<u64>,
    /// Rounds whose broadcast frames are replayable from the store.
    pub replay_rounds: Vec<u64>,
}

impl RunManifest {
    /// Load `RUN.json` from `dir`. `Ok(None)` when no manifest exists
    /// (fresh run); an error on a malformed one — the file is written
    /// atomically, so a parse failure means real damage, not a crash.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Option<Self>> {
        let path = dir.as_ref().join(RUN_MANIFEST);
        if !path.exists() {
            return Ok(None);
        }
        let text = fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("run manifest {}: {e}", path.display()))?;
        let doc = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("run manifest {}: {e}", path.display()))?;
        let version = doc
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("run manifest: missing version"))?;
        anyhow::ensure!(
            version as u64 == RUN_VERSION,
            "run manifest {}: unsupported version {version}",
            path.display()
        );
        let hex_u64 = |key: &str| -> anyhow::Result<u64> {
            let s = doc
                .get(key)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("run manifest: missing {key}"))?;
            u64::from_str_radix(s, 16)
                .map_err(|_| anyhow::anyhow!("run manifest: bad hex in {key}"))
        };
        let num_u64 = |key: &str| -> anyhow::Result<u64> {
            doc.get(key)
                .and_then(Json::as_usize)
                .map(|v| v as u64)
                .ok_or_else(|| anyhow::anyhow!("run manifest: missing {key}"))
        };
        let worker_digests = doc
            .get("worker_digests")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("run manifest: missing worker_digests"))?
            .iter()
            .map(|v| {
                let s = v
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("run manifest: non-string digest"))?;
                u64::from_str_radix(s, 16)
                    .map_err(|_| anyhow::anyhow!("run manifest: bad digest hex"))
            })
            .collect::<anyhow::Result<Vec<u64>>>()?;
        let replay_rounds = doc
            .get("replay_rounds")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("run manifest: missing replay_rounds"))?
            .iter()
            .map(|v| {
                v.as_usize()
                    .map(|r| r as u64)
                    .ok_or_else(|| anyhow::anyhow!("run manifest: non-numeric replay round"))
            })
            .collect::<anyhow::Result<Vec<u64>>>()?;
        let workers = doc
            .get("workers")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("run manifest: missing workers"))?;
        anyhow::ensure!(
            worker_digests.len() == workers,
            "run manifest: {} digests for {workers} workers",
            worker_digests.len()
        );
        Ok(Some(Self {
            round: num_u64("round")?,
            epoch: num_u64("epoch")?,
            fingerprint: hex_u64("fingerprint")?,
            workers,
            worker_digests,
            replay_rounds,
        }))
    }

    /// Atomically write `RUN.json` into `dir` (temp + rename — a reader
    /// or a post-crash `load` sees either the previous manifest or this
    /// one, never a prefix).
    pub fn save(&self, dir: impl AsRef<Path>) -> anyhow::Result<()> {
        write_atomic(&dir.as_ref().join(RUN_MANIFEST), self.to_json().as_bytes())
    }

    /// The serialized form `save` writes (exposed for the torn-write
    /// property test).
    pub fn to_json(&self) -> String {
        let mut doc = BTreeMap::new();
        doc.insert("version".to_string(), Json::Num(RUN_VERSION as f64));
        doc.insert("round".to_string(), Json::Num(self.round as f64));
        doc.insert("epoch".to_string(), Json::Num(self.epoch as f64));
        doc.insert(
            "fingerprint".to_string(),
            Json::Str(format!("{:016x}", self.fingerprint)),
        );
        doc.insert("workers".to_string(), Json::Num(self.workers as f64));
        doc.insert(
            "worker_digests".to_string(),
            Json::Arr(
                self.worker_digests.iter().map(|d| Json::Str(format!("{d:016x}"))).collect(),
            ),
        );
        doc.insert(
            "replay_rounds".to_string(),
            Json::Arr(self.replay_rounds.iter().map(|&r| Json::Num(r as f64)).collect()),
        );
        Json::Obj(doc).to_string_compact()
    }
}

/// Serialize a worker's full resumable state — rng cursor + algorithm
/// state — into one `wstate` blob. The algorithm name is embedded so a
/// resume under a different `--algo` fails at decode with a clear
/// message (defense in depth under the config fingerprint).
pub fn encode_worker_state(rng: &Pcg32, algo: &dyn WorkerAlgo) -> anyhow::Result<Vec<u8>> {
    let mut out = Vec::new();
    out.extend_from_slice(WSTATE_MAGIC);
    put_u32(&mut out, WSTATE_VERSION);
    let name = algo.name();
    put_u32(&mut out, name.len() as u32);
    out.extend_from_slice(name.as_bytes());
    let (state, inc) = rng.state_parts();
    put_u64(&mut out, state);
    put_u64(&mut out, inc);
    let mut algo_bytes = Vec::new();
    algo.save_state(&mut algo_bytes)?;
    put_u32(&mut out, algo_bytes.len() as u32);
    out.extend_from_slice(&algo_bytes);
    Ok(out)
}

/// Restore a worker from [`encode_worker_state`] bytes: the rng resumes
/// the exact stream, the algorithm reloads its persistent fields.
pub fn decode_worker_state(
    bytes: &[u8],
    rng: &mut Pcg32,
    algo: &mut dyn WorkerAlgo,
) -> anyhow::Result<()> {
    let mut r = Reader::new(bytes);
    let magic = r.bytes(4)?;
    anyhow::ensure!(magic == WSTATE_MAGIC, "worker snapshot: bad magic {magic:02x?}");
    let version = r.u32()?;
    anyhow::ensure!(version == WSTATE_VERSION, "worker snapshot: unsupported version {version}");
    let name_len = r.u32()? as usize;
    let name = std::str::from_utf8(r.bytes(name_len)?)
        .map_err(|_| anyhow::anyhow!("worker snapshot: non-utf8 algorithm name"))?
        .to_string();
    anyhow::ensure!(
        name == algo.name(),
        "worker snapshot was taken by algorithm {name:?}, run is configured for {:?}",
        algo.name()
    );
    let state = r.u64()?;
    let inc = r.u64()?;
    let algo_len = r.u32()? as usize;
    let algo_bytes = r.bytes(algo_len)?;
    anyhow::ensure!(r.remaining() == 0, "worker snapshot has trailing bytes");
    algo.load_state(algo_bytes)?;
    *rng = Pcg32::from_state_parts(state, inc);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "dqgan-run-manifest-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample(round: u64, epoch: u64) -> RunManifest {
        RunManifest {
            round,
            epoch,
            fingerprint: 0xDEAD_BEEF_CAFE_F00D,
            workers: 3,
            worker_digests: vec![0xFFFF_FFFF_FFFF_FFFF, 1, 0x8000_0000_0000_0001],
            replay_rounds: vec![round.saturating_sub(1), round],
        }
    }

    #[test]
    fn save_load_round_trips_including_full_u64_values() {
        let dir = tmp_dir("rt");
        assert_eq!(RunManifest::load(&dir).unwrap(), None);
        let m = sample(41, 2);
        m.save(&dir).unwrap();
        assert_eq!(RunManifest::load(&dir).unwrap(), Some(m));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_prefix_before_rename_always_loads_the_old_version() {
        // Simulate a crash at every byte of the new manifest's write:
        // the writer puts bytes into the `.tmp` sibling and only renames
        // when complete, so for *every* prefix length the visible
        // `RUN.json` must still parse as exactly the old manifest —
        // never an error, never a blend of old and new fields.
        let dir = tmp_dir("torn");
        let old = sample(10, 1);
        old.save(&dir).unwrap();
        let new = sample(11, 2);
        let new_bytes = new.to_json().into_bytes();
        let tmp = dir.join(RUN_MANIFEST).with_extension("tmp");
        for cut in 0..=new_bytes.len() {
            fs::write(&tmp, &new_bytes[..cut]).unwrap();
            let got = RunManifest::load(&dir)
                .unwrap_or_else(|e| panic!("torn write at byte {cut} surfaced: {e}"))
                .expect("old manifest must still be visible");
            assert_eq!(got, old, "torn write at byte {cut} leaked mixed state");
        }
        // The completed write + rename flips atomically to the new one.
        fs::write(&tmp, &new_bytes).unwrap();
        fs::rename(&tmp, dir.join(RUN_MANIFEST)).unwrap();
        assert_eq!(RunManifest::load(&dir).unwrap(), Some(new));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn digest_count_must_match_worker_count() {
        let dir = tmp_dir("mismatch");
        let mut m = sample(5, 1);
        m.worker_digests.pop();
        m.save(&dir).unwrap();
        let err = RunManifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("digests"), "{err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn worker_state_round_trips_through_the_blob_format() {
        use crate::algo::{AlgoKind, WorkerAlgo as _};
        use crate::optim::LrSchedule;
        let kind = AlgoKind::parse("dqgan-adam:linf8").unwrap();
        let w0: Vec<f32> = (0..16).map(|i| i as f32 * 0.25 - 2.0).collect();
        let mut algo = kind.build_worker(w0.clone(), LrSchedule::constant(0.01));
        let mut rng = Pcg32::new(9);
        for _ in 0..7 {
            rng.next_u32();
        }
        let blob = encode_worker_state(&rng, algo.as_ref()).unwrap();
        let mut algo2 = kind.build_worker(w0, LrSchedule::constant(0.01));
        let mut rng2 = Pcg32::new(0);
        decode_worker_state(&blob, &mut rng2, algo2.as_mut()).unwrap();
        assert_eq!(rng.state_parts(), rng2.state_parts());
        assert_eq!(algo.params(), algo2.params());
        // Streams continue identically.
        assert_eq!(rng.next_u32(), rng2.next_u32());
    }

    #[test]
    fn worker_state_refuses_a_different_algorithm() {
        use crate::algo::AlgoKind;
        use crate::optim::LrSchedule;
        let w0 = vec![0.0f32; 4];
        let gda = AlgoKind::parse("gda").unwrap().build_worker(w0.clone(), LrSchedule::constant(0.1));
        let rng = Pcg32::new(1);
        let blob = encode_worker_state(&rng, gda.as_ref()).unwrap();
        let mut cpo =
            AlgoKind::parse("cpoadam").unwrap().build_worker(w0, LrSchedule::constant(0.1));
        let mut rng2 = Pcg32::new(2);
        let err = decode_worker_state(&blob, &mut rng2, cpo.as_mut()).unwrap_err().to_string();
        assert!(err.contains("configured for"), "{err}");
    }
}
