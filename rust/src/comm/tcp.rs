//! TCP transport: the same [`WorkerEnd`]/[`ServerEnd`] contract over real
//! sockets with length-prefixed frames. Used by the multi-process mode
//! (`dqgan train --transport tcp`) and the integration tests; proves the
//! wire format is genuinely serializable, not an in-memory shortcut.
//!
//! Framing: `[frame_len:u32][frame bytes]` where `frame` is
//! [`Message::encode`]'s output (which carries its own CRC).
//!
//! Setup is two-phase so the ephemeral port is known before workers
//! connect: [`TcpServerBuilder::listen`] → spawn workers → `accept(m)`.

use super::message::{Message, MsgKind};
use super::{validate_round_batch, ByteCounter, ServerEnd, WorkerEnd};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;

fn write_frame(stream: &mut TcpStream, msg: &Message) -> anyhow::Result<usize> {
    let frame = msg.encode();
    let len = (frame.len() as u32).to_le_bytes();
    stream.write_all(&len)?;
    stream.write_all(&frame)?;
    stream.flush()?;
    Ok(4 + frame.len())
}

fn read_frame(stream: &mut TcpStream) -> anyhow::Result<Message> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    // 256 MiB frame cap: protects against corrupt length prefixes.
    if len > 256 * 1024 * 1024 {
        anyhow::bail!("frame length {len} exceeds cap");
    }
    let mut frame = vec![0u8; len];
    stream.read_exact(&mut frame)?;
    Message::decode(&frame)
}

/// Phase-1 handle: the listener is bound (port known) but workers have
/// not been accepted yet.
pub struct TcpServerBuilder {
    listener: TcpListener,
    addr: SocketAddr,
}

impl TcpServerBuilder {
    /// Bind (use port 0 for an ephemeral port).
    pub fn listen(addr: &str) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(Self { listener, addr })
    }

    /// The bound address (hand this to workers).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Phase 2: accept exactly `m` worker registrations.
    pub fn accept(self, m: usize) -> anyhow::Result<TcpServerEnd> {
        let mut streams: Vec<Option<TcpStream>> = (0..m).map(|_| None).collect();
        let mut accepted = 0;
        while accepted < m {
            let (mut s, _) = self.listener.accept()?;
            s.set_nodelay(true)?;
            let hello = read_frame(&mut s)?;
            anyhow::ensure!(hello.round == u64::MAX, "bad registration frame");
            let id = hello.worker as usize;
            anyhow::ensure!(id < m, "worker id {id} out of range");
            anyhow::ensure!(streams[id].is_none(), "duplicate worker id {id}");
            streams[id] = Some(s);
            accepted += 1;
        }
        Ok(TcpServerEnd {
            streams: streams.into_iter().map(|s| s.unwrap()).collect(),
            counter: ByteCounter::new(),
        })
    }
}

/// TCP worker endpoint (connects to the server).
pub struct TcpWorkerEnd {
    id: u32,
    stream: TcpStream,
    counter: Arc<ByteCounter>,
}

impl TcpWorkerEnd {
    /// Connect to `addr` and register with the given worker id.
    pub fn connect(addr: &str, id: u32) -> anyhow::Result<Self> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        // Registration: a Payload-kind hello with round u64::MAX.
        write_frame(&mut stream, &Message::payload(id, u64::MAX, Vec::new()))?;
        Ok(Self { id, stream, counter: ByteCounter::new() })
    }
}

impl WorkerEnd for TcpWorkerEnd {
    fn send(&mut self, msg: Message) -> anyhow::Result<()> {
        let n = write_frame(&mut self.stream, &msg)?;
        self.counter.add_up(n);
        Ok(())
    }

    fn recv(&mut self) -> anyhow::Result<Message> {
        read_frame(&mut self.stream)
    }

    fn id(&self) -> u32 {
        self.id
    }
}

/// TCP server endpoint (all workers registered).
pub struct TcpServerEnd {
    streams: Vec<TcpStream>,
    counter: Arc<ByteCounter>,
}

impl TcpServerEnd {
    pub fn counter(&self) -> Arc<ByteCounter> {
        Arc::clone(&self.counter)
    }
}

impl ServerEnd for TcpServerEnd {
    fn recv_round(&mut self) -> anyhow::Result<Vec<Message>> {
        let mut msgs = Vec::with_capacity(self.streams.len());
        for s in &mut self.streams {
            let msg = read_frame(s)?;
            if msg.kind == MsgKind::WorkerError {
                // Fail before reading the remaining sockets — the
                // erroring worker's peers may not send this round.
                validate_round_batch(std::slice::from_ref(&msg))?;
            }
            self.counter.add_up(msg.frame_len() + 4);
            msgs.push(msg);
        }
        msgs.sort_by_key(|m| m.worker);
        validate_round_batch(&msgs)?;
        Ok(msgs)
    }

    fn broadcast(&mut self, msg: Message) -> anyhow::Result<()> {
        for s in &mut self.streams {
            let n = write_frame(s, &msg)?;
            self.counter.add_down(n);
        }
        Ok(())
    }

    fn workers(&self) -> usize {
        self.streams.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcp_round_trip() {
        let m = 3;
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let workers: Vec<_> = (0..m as u32)
            .map(|id| {
                std::thread::spawn(move || {
                    let mut w = TcpWorkerEnd::connect(&addr.to_string(), id).unwrap();
                    w.send(Message::payload(id, 0, vec![id as u8; 16])).unwrap();
                    let b = w.recv().unwrap();
                    assert_eq!(b.kind, MsgKind::Broadcast);
                    assert_eq!(b.payload, vec![7, 7]);
                    let s = w.recv().unwrap();
                    assert_eq!(s.kind, MsgKind::Shutdown);
                })
            })
            .collect();
        let mut server = builder.accept(m).unwrap();
        let msgs = server.recv_round().unwrap();
        assert_eq!(msgs.len(), m);
        assert_eq!(msgs[1].payload, vec![1u8; 16]);
        server.broadcast(Message::broadcast(0, vec![7, 7])).unwrap();
        server.broadcast(Message::shutdown(1)).unwrap();
        for w in workers {
            w.join().unwrap();
        }
        assert!(server.counter().up_total() > 0);
    }

    #[test]
    fn rejects_duplicate_ids() {
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let w = std::thread::spawn(move || {
            let _a = TcpWorkerEnd::connect(&addr.to_string(), 0).unwrap();
            let _b = TcpWorkerEnd::connect(&addr.to_string(), 0);
            // keep the connections open long enough for accept to see both
            std::thread::sleep(std::time::Duration::from_millis(300));
        });
        let res = builder.accept(2);
        assert!(res.is_err(), "duplicate registration must fail accept");
        w.join().unwrap();
    }

    #[test]
    fn rejects_out_of_range_id() {
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let w = std::thread::spawn(move || {
            let _a = TcpWorkerEnd::connect(&addr.to_string(), 9).unwrap();
            std::thread::sleep(std::time::Duration::from_millis(300));
        });
        let res = builder.accept(2);
        assert!(res.is_err());
        w.join().unwrap();
    }
}
