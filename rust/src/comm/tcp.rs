//! TCP transport: the same [`WorkerEnd`]/[`ServerEnd`] contract over real
//! sockets with length-prefixed frames. Used by the multi-process mode
//! (`dqgan train --transport tcp`) and the integration tests; proves the
//! wire format is genuinely serializable, not an in-memory shortcut.
//!
//! Framing: `[frame_len:u32][frame bytes]` where `frame` is
//! [`Message::encode`]'s output (which carries its own CRC).
//!
//! Setup is two-phase so the ephemeral port is known before workers
//! connect: [`TcpServerBuilder::listen`] → spawn workers → `accept(m)`.

use super::delay::DelayPlan;
use super::message::{Message, MsgKind};
use super::{
    validate_round_batch, ArrivalSet, BroadcastHandle, ByteCounter, ServerEnd, StreamDirective,
    StreamOutcome, WorkerEnd, WriterPool,
};
#[cfg(unix)]
use super::PendingDelivery;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError};
use std::sync::Arc;
#[cfg(unix)]
use std::sync::Mutex;
use std::time::Instant;

/// Worker-side reconnect policy: `--connect-retry N,BASE_MS`. Attempt k
/// (0-based; the first try is attempt 0 and sleeps nothing) is preceded
/// by `base_ms·2^k + jitter` milliseconds, where the jitter is a
/// **deterministic** function of (worker, attempt) — reproducible chaos
/// runs cannot tolerate wall-clock randomness, and decorrelating workers
/// by id is all jitter is for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total connect attempts (≥ 1).
    pub attempts: u32,
    /// Base backoff in milliseconds (0 = retry immediately).
    pub base_ms: u64,
}

/// Ceiling on a single backoff sleep: keeps `N,BASE_MS` typos from
/// turning into hour-long hangs.
const BACKOFF_CAP_MS: u64 = 10_000;

impl RetryPolicy {
    /// Parse the CLI form `N,BASE_MS` (e.g. `8,50`).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let (n, base) = s
            .split_once(',')
            .ok_or_else(|| anyhow::anyhow!("--connect-retry wants N,BASE_MS, got {s:?}"))?;
        let attempts: u32 = n
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("--connect-retry: bad attempt count {n:?}"))?;
        anyhow::ensure!(attempts >= 1, "--connect-retry: need at least 1 attempt");
        let base_ms: u64 = base
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("--connect-retry: bad base ms {base:?}"))?;
        Ok(Self { attempts, base_ms })
    }

    /// Backoff before attempt `attempt` (1-based — attempt 0 never
    /// sleeps): exponential in the attempt with a deterministic
    /// per-(worker, attempt) jitter in `[0, base_ms)`.
    pub fn backoff_ms(&self, worker: u32, attempt: u32) -> u64 {
        if attempt == 0 || self.base_ms == 0 {
            return 0;
        }
        let exp = self.base_ms.saturating_mul(1u64 << (attempt - 1).min(16));
        let mut seed = Vec::with_capacity(8);
        seed.extend_from_slice(&worker.to_le_bytes());
        seed.extend_from_slice(&attempt.to_le_bytes());
        let jitter = crate::util::bytes::fnv1a64(&seed) % self.base_ms;
        exp.saturating_add(jitter).min(BACKOFF_CAP_MS)
    }
}

/// What the leader's [`Message::welcome`] told a session-handshaking
/// worker: the session epoch this connection runs under and the first
/// round it will serve.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionWelcome {
    pub epoch: u64,
    pub resume_round: u64,
}

/// What the leader answers `Hello` handshakes with.
#[derive(Debug, Clone, Copy)]
pub struct SessionInfo {
    /// Current session epoch (bumped on every `--resume`).
    pub epoch: u64,
    /// Config fingerprint the run was built from.
    pub fingerprint: u64,
    /// First round this session serves (0 fresh, `manifest.round + 1`
    /// on resume).
    pub resume_round: u64,
}

fn write_frame(stream: &mut TcpStream, msg: &Message) -> anyhow::Result<usize> {
    let frame = msg.encode();
    let len = (frame.len() as u32).to_le_bytes();
    stream.write_all(&len)?;
    stream.write_all(&frame)?;
    stream.flush()?;
    Ok(4 + frame.len())
}

fn read_frame(stream: &mut TcpStream) -> anyhow::Result<Message> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    // Frame cap: protects against corrupt length prefixes.
    if len > super::message::FRAME_CAP {
        anyhow::bail!("frame length {len} exceeds cap");
    }
    let mut frame = vec![0u8; len];
    stream.read_exact(&mut frame)?;
    Message::decode(&frame)
}

/// Phase-1 handle: the listener is bound (port known) but workers have
/// not been accepted yet.
pub struct TcpServerBuilder {
    listener: TcpListener,
    addr: SocketAddr,
}

impl TcpServerBuilder {
    /// Bind (use port 0 for an ephemeral port).
    pub fn listen(addr: &str) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(Self { listener, addr })
    }

    /// The bound address (hand this to workers).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Phase 2: accept exactly `m` worker registrations.
    pub fn accept(self, m: usize) -> anyhow::Result<TcpServerEnd> {
        Ok(TcpServerEnd {
            streams: self.accept_streams(m, None)?,
            counter: ByteCounter::new(),
            readers: None,
            pipeline_depth: 2,
            writers: None,
        })
    }

    /// [`Self::accept`] in session mode: workers registering with a
    /// [`MsgKind::Hello`] handshake get a [`MsgKind::Welcome`] answer
    /// carrying the session epoch, the leader's config fingerprint, and
    /// the round the session resumes at. A fingerprint mismatch fails
    /// the accept loudly on *both* ends (the `Welcome` is written first
    /// so the worker can diagnose it too). Legacy registration frames
    /// are still accepted, so mixed fleets keep working.
    pub fn accept_session(self, m: usize, session: SessionInfo) -> anyhow::Result<TcpServerEnd> {
        Ok(TcpServerEnd {
            streams: self.accept_streams(m, Some(session))?,
            counter: ByteCounter::new(),
            readers: None,
            pipeline_depth: 2,
            writers: None,
        })
    }

    /// Phase 2, readiness-loop flavor: accept exactly `m` registrations
    /// and hand every connection to a single `dqgan-evloop` thread —
    /// O(1) leader threads in M instead of the threaded end's
    /// reader+writer pair per worker. Workers must be built with the
    /// `connect_evloop*` constructors (they send `Ack` control frames).
    #[cfg(unix)]
    pub fn accept_evloop(self, m: usize) -> anyhow::Result<TcpEvloopServerEnd> {
        let streams = self.accept_streams(m, None)?;
        // The listener stays with the loop: in elastic-membership mode it
        // keeps accepting, so an evicted worker can reconnect with a
        // Rejoin hello and be spliced back into its old slot.
        TcpEvloopServerEnd::spawn(streams, self.listener)
    }

    /// [`Self::accept_evloop`] in session mode — the `Hello`/`Welcome`
    /// handshake runs during the blocking accept phase, before the
    /// readiness loop takes the sockets, so the loop itself is unchanged.
    #[cfg(unix)]
    pub fn accept_evloop_session(
        self,
        m: usize,
        session: SessionInfo,
    ) -> anyhow::Result<TcpEvloopServerEnd> {
        let streams = self.accept_streams(m, Some(session))?;
        TcpEvloopServerEnd::spawn(streams, self.listener)
    }

    fn accept_streams(
        &self,
        m: usize,
        session: Option<SessionInfo>,
    ) -> anyhow::Result<Vec<TcpStream>> {
        let mut streams: Vec<Option<TcpStream>> = (0..m).map(|_| None).collect();
        let mut accepted = 0;
        while accepted < m {
            let (mut s, _) = self.listener.accept()?;
            s.set_nodelay(true)?;
            let hello = read_frame(&mut s)?;
            let id = hello.worker as usize;
            anyhow::ensure!(id < m, "worker id {id} out of range");
            anyhow::ensure!(streams[id].is_none(), "duplicate worker id {id}");
            match hello.kind {
                MsgKind::Hello => {
                    let sess = session.ok_or_else(|| {
                        anyhow::anyhow!(
                            "worker {id} sent a session handshake but the leader \
                             was not started in session mode"
                        )
                    })?;
                    let worker_fp = hello.hello_fingerprint()?;
                    // Answer before judging the fingerprint: on a
                    // mismatch the worker reads the Welcome, compares,
                    // and refuses with its own clear error instead of
                    // seeing an unexplained hangup.
                    write_frame(
                        &mut s,
                        &Message::welcome(
                            hello.worker,
                            sess.epoch,
                            sess.fingerprint,
                            sess.resume_round,
                        ),
                    )?;
                    anyhow::ensure!(
                        worker_fp == sess.fingerprint,
                        "worker {id} registered with config fingerprint {worker_fp:016x} \
                         but this run has {:016x}: refusing to mix run configurations",
                        sess.fingerprint
                    );
                    // A worker claiming an epoch *ahead* of ours belongs
                    // to a newer leader incarnation than this one — the
                    // fleet and leader disagree about history.
                    anyhow::ensure!(
                        hello.round <= sess.epoch,
                        "worker {id} claims session epoch {} but the leader is at \
                         epoch {}: worker has seen a newer leader incarnation",
                        hello.round,
                        sess.epoch
                    );
                }
                _ => anyhow::ensure!(hello.round == u64::MAX, "bad registration frame"),
            }
            streams[id] = Some(s);
            accepted += 1;
        }
        Ok(streams.into_iter().map(|s| s.unwrap()).collect())
    }
}

/// TCP worker endpoint (connects to the server).
pub struct TcpWorkerEnd {
    id: u32,
    /// Server address, kept so an evicted worker can reconnect
    /// ([`WorkerEnd::rejoin`]) without outside help.
    addr: String,
    stream: TcpStream,
    counter: Arc<ByteCounter>,
    /// Straggler-injection schedule (tests/benches only) — the same
    /// *uplink* gate/permit contract the in-process worker end honors,
    /// so the cross-transport equivalence suites can scramble TCP
    /// arrival orders deterministically too. (Downlink gates are an
    /// in-process-only hook; see `comm/delay.rs`.)
    plan: Option<DelayPlan>,
    /// Whether [`WorkerEnd::ack`] emits an `Ack` control frame. Enabled
    /// by the evloop constructors only: the threaded server's barrier
    /// bookkeeping has no ack channel, so acks toward it would corrupt
    /// its gathers. Evloop server ⇔ acking workers is a symmetric,
    /// per-cluster contract picked by `--transport`.
    send_acks: bool,
}

impl TcpWorkerEnd {
    /// Connect to `addr` and register with the given worker id.
    pub fn connect(addr: &str, id: u32) -> anyhow::Result<Self> {
        Self::connect_with_plan(addr, id, None)
    }

    /// [`Self::connect`] with a [`DelayPlan`] attached: payload sends
    /// consult the plan's uplink gates before hitting the socket.
    pub fn connect_with_plan(
        addr: &str,
        id: u32,
        plan: Option<DelayPlan>,
    ) -> anyhow::Result<Self> {
        Self::connect_inner(addr, id, plan, false)
    }

    /// Connect to a readiness-loop server ([`TcpServerBuilder::accept_evloop`]):
    /// identical wire behavior plus `Ack` control frames from
    /// [`WorkerEnd::ack`] feeding the leader's applied-broadcast ledger.
    #[cfg(unix)]
    pub fn connect_evloop(addr: &str, id: u32) -> anyhow::Result<Self> {
        Self::connect_inner(addr, id, None, true)
    }

    /// [`Self::connect_evloop`] with a [`DelayPlan`] attached.
    #[cfg(unix)]
    pub fn connect_evloop_with_plan(
        addr: &str,
        id: u32,
        plan: Option<DelayPlan>,
    ) -> anyhow::Result<Self> {
        Self::connect_inner(addr, id, plan, true)
    }

    fn connect_inner(
        addr: &str,
        id: u32,
        plan: Option<DelayPlan>,
        send_acks: bool,
    ) -> anyhow::Result<Self> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        // Registration: a Payload-kind hello with round u64::MAX.
        write_frame(&mut stream, &Message::payload(id, u64::MAX, Vec::new()))?;
        Ok(Self {
            id,
            addr: addr.to_string(),
            stream,
            counter: ByteCounter::new(),
            plan,
            send_acks,
        })
    }

    /// Reconnect a previously evicted worker id to a readiness-loop
    /// server: sends a [`MsgKind::Rejoin`] hello (instead of the fresh
    /// registration frame) naming the first missed round, so the leader
    /// splices the socket into the worker's old slot and replays missed
    /// broadcasts ahead of any new traffic. Elastic-membership mode only.
    #[cfg(unix)]
    pub fn reconnect_evloop(addr: &str, id: u32, resume_round: u64) -> anyhow::Result<Self> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        write_frame(&mut stream, &Message::rejoin(id, resume_round))?;
        Ok(Self {
            id,
            addr: addr.to_string(),
            stream,
            counter: ByteCounter::new(),
            plan: None,
            send_acks: true,
        })
    }

    /// Session-mode connect: dial `addr` under `retry` (each failed
    /// attempt sleeps the policy's deterministic backoff), then run the
    /// `Hello`/`Welcome` handshake — send our config `fingerprint` and
    /// `last_epoch`, read back the leader's epoch, fingerprint, and
    /// resume round. Refuses loudly when the fingerprints differ (the
    /// fleet must not resume under a different run configuration) or
    /// when the leader's epoch is older than one we already served
    /// under (a stale leader incarnation).
    pub fn connect_session(
        addr: &str,
        id: u32,
        fingerprint: u64,
        last_epoch: u64,
        retry: Option<RetryPolicy>,
        send_acks: bool,
    ) -> anyhow::Result<(Self, SessionWelcome)> {
        let mut stream = Self::dial_with_retry(addr, id, retry)?;
        stream.set_nodelay(true)?;
        write_frame(&mut stream, &Message::hello(id, last_epoch, fingerprint))?;
        let welcome = read_frame(&mut stream)?;
        anyhow::ensure!(
            welcome.kind == MsgKind::Welcome,
            "worker {id}: expected a Welcome handshake, got {:?}",
            welcome.kind
        );
        let (leader_fp, resume_round) = welcome.welcome_parts()?;
        anyhow::ensure!(
            leader_fp == fingerprint,
            "worker {id}: config fingerprint mismatch — worker built {fingerprint:016x}, \
             leader serves {leader_fp:016x}: refusing to resume under a different run \
             configuration"
        );
        let epoch = welcome.round;
        anyhow::ensure!(
            epoch >= last_epoch,
            "worker {id}: leader session epoch {epoch} is older than the epoch {last_epoch} \
             this worker already served under — stale leader, refusing"
        );
        Ok((
            Self {
                id,
                addr: addr.to_string(),
                stream,
                counter: ByteCounter::new(),
                plan: None,
                send_acks,
            },
            SessionWelcome { epoch, resume_round },
        ))
    }

    /// `TcpStream::connect` under a [`RetryPolicy`]: attempt 0 dials
    /// immediately, later attempts sleep the policy's exponential
    /// backoff first. Every dial bumps `recovery.reconnect_attempts`;
    /// every sleep bumps `recovery.backoff_sleeps`.
    fn dial_with_retry(
        addr: &str,
        id: u32,
        retry: Option<RetryPolicy>,
    ) -> anyhow::Result<TcpStream> {
        let policy = retry.unwrap_or(RetryPolicy { attempts: 1, base_ms: 0 });
        let attempts = policy.attempts.max(1);
        let mut last_err = None;
        for attempt in 0..attempts {
            let ms = policy.backoff_ms(id, attempt);
            if ms > 0 {
                crate::obs::metrics::RECOVERY_BACKOFF_SLEEPS.inc();
                std::thread::sleep(std::time::Duration::from_millis(ms));
            }
            crate::obs::metrics::RECOVERY_RECONNECT_ATTEMPTS.inc();
            match TcpStream::connect(addr) {
                Ok(s) => return Ok(s),
                Err(e) => last_err = Some(e),
            }
        }
        Err(anyhow::anyhow!(
            "worker {id}: connect to {addr} failed after {attempts} attempt(s): {}",
            last_err.expect("at least one attempt ran")
        ))
    }

    /// This worker's byte counters (uplink = sent, downlink = received,
    /// ctrl = ack frames).
    pub fn counter(&self) -> Arc<ByteCounter> {
        Arc::clone(&self.counter)
    }
}

impl WorkerEnd for TcpWorkerEnd {
    fn send(&mut self, msg: Message) -> anyhow::Result<()> {
        // Deterministic straggler injection, mirroring the in-process
        // worker end: a held gate blocks the payload before it reaches
        // the wire.
        if msg.kind == MsgKind::Payload {
            if let Some(plan) = &self.plan {
                plan.wait(msg.worker, msg.round);
            }
        }
        let n = write_frame(&mut self.stream, &msg)?;
        self.counter.add_up(n);
        Ok(())
    }

    fn recv(&mut self) -> anyhow::Result<Message> {
        let msg = read_frame(&mut self.stream)?;
        // Downlink accounting: broadcast/shutdown frames plus the length
        // prefix, mirroring `send`'s uplink accounting.
        self.counter.add_down(msg.frame_len() + 4);
        Ok(msg)
    }

    fn ack(&mut self, round: u64) -> anyhow::Result<()> {
        if !self.send_acks {
            return Ok(());
        }
        // Control-plane accounting: ack bytes are real wire traffic but
        // live in the ctrl counter so up/down stay identical to the
        // threaded transport's data-plane totals.
        let n = write_frame(&mut self.stream, &Message::ack(self.id, round))?;
        self.counter.add_ctrl(n);
        Ok(())
    }

    fn rejoin(&mut self, resume_round: u64) -> anyhow::Result<()> {
        // Fresh socket + a Rejoin hello naming the first missed round:
        // the leader splices it into this worker's old slot and replays
        // the missed broadcasts before any new traffic. The hello is
        // control plane, like acks.
        let mut stream = TcpStream::connect(&self.addr)?;
        stream.set_nodelay(true)?;
        let n = write_frame(&mut stream, &Message::rejoin(self.id, resume_round))?;
        self.counter.add_ctrl(n);
        self.stream = stream;
        Ok(())
    }

    fn id(&self) -> u32 {
        self.id
    }
}

/// TCP server endpoint (all workers registered).
pub struct TcpServerEnd {
    streams: Vec<TcpStream>,
    counter: Arc<ByteCounter>,
    /// Arrival-ordered frame source: one reader thread per worker socket
    /// pushing into a bounded channel. Spawned lazily on the first
    /// streaming gather; once active, *all* receives go through it (the
    /// reader threads own the read halves from then on).
    readers: Option<Receiver<anyhow::Result<Message>>>,
    /// Per-worker queue bound for async broadcasts (`--pipeline-depth`).
    pipeline_depth: usize,
    /// Per-worker downlink writer threads ([`WriterPool`]), mirroring
    /// `readers`: spawned lazily on the first `broadcast_async`, and
    /// from then on *all* broadcasts route through them (the writer
    /// threads own the write halves, so per-worker frame order stays
    /// total). Dropping this end joins them after their queues drain, so
    /// a queued trailing `Shutdown` frame is flushed before the sockets
    /// close.
    writers: Option<WriterPool>,
}

impl TcpServerEnd {
    pub fn counter(&self) -> Arc<ByteCounter> {
        Arc::clone(&self.counter)
    }

    /// Spawn the downlink [`WriterPool`] over dup'd write halves
    /// (idempotent), the mirror image of [`Self::start_readers`]: the
    /// delivery step writes the frame and counts its wire bytes when the
    /// write completes — identical totals to the synchronous loop.
    fn start_writers(&mut self) -> anyhow::Result<()> {
        if self.writers.is_some() {
            return Ok(());
        }
        // Clone every write half up front so a dup failure spawns nothing.
        let mut write_halves = Vec::with_capacity(self.streams.len());
        for s in &self.streams {
            write_halves.push(s.try_clone()?);
        }
        let counter = Arc::clone(&self.counter);
        let pool = WriterPool::spawn(
            "dqgan-tcp-writer",
            write_halves,
            self.pipeline_depth,
            move |_w, half: &mut TcpStream, msg: &Message| {
                let n = write_frame(half, msg)?;
                counter.add_down(n);
                Ok(())
            },
        )?;
        self.writers = Some(pool);
        Ok(())
    }

    /// Spawn one detached reader thread per worker socket (idempotent).
    ///
    /// Each reader loops `read_frame` on a dup'd handle of its socket and
    /// pushes results into a bounded channel (capacity 2·M: one in-flight
    /// frame per worker plus next-round read-ahead; a full channel blocks
    /// the reader, which is exactly the backpressure we want). A read
    /// error is forwarded once, then the thread exits; threads also exit
    /// when the channel's receiver (this struct) is dropped and their next
    /// send fails. Threads are detached rather than joined: a reader may
    /// be parked in a blocking read on a still-open socket at teardown,
    /// and it unblocks only when the peer closes.
    fn start_readers(&mut self) -> anyhow::Result<()> {
        if self.readers.is_some() {
            return Ok(());
        }
        // Clone every read half up front so a dup failure spawns nothing.
        let mut read_halves = Vec::with_capacity(self.streams.len());
        for s in &self.streams {
            read_halves.push(s.try_clone()?);
        }
        let (tx, rx) = sync_channel::<anyhow::Result<Message>>(2 * self.streams.len());
        // Install the channel *before* spawning: if a spawn fails partway,
        // the already-running readers own their sockets and every later
        // receive goes through the channel — never a direct read racing an
        // orphan reader on the same fd. (The caller propagates the error,
        // the endpoint is dropped, and the orphans exit on their next
        // send.)
        self.readers = Some(rx);
        for (i, mut read_half) in read_halves.into_iter().enumerate() {
            let tx = tx.clone();
            std::thread::Builder::new()
                .name(format!("dqgan-tcp-reader-{i}"))
                .spawn(move || loop {
                    let res = read_frame(&mut read_half);
                    let failed = res.is_err();
                    if tx.send(res).is_err() || failed {
                        break;
                    }
                })
                .map_err(|e| anyhow::anyhow!("spawn tcp reader {i}: {e}"))?;
        }
        Ok(())
    }

    /// Pop the next arrived frame off the reader channel.
    fn next_arrival(&mut self) -> anyhow::Result<Message> {
        let rx = self.readers.as_ref().expect("readers started");
        let msg = rx.recv().map_err(|_| anyhow::anyhow!("all tcp reader threads exited"))??;
        self.counter.add_up(msg.frame_len() + 4);
        Ok(msg)
    }
}

impl ServerEnd for TcpServerEnd {
    fn recv_round(&mut self) -> anyhow::Result<Vec<Message>> {
        let m = self.streams.len();
        let mut msgs = Vec::with_capacity(m);
        if self.readers.is_some() {
            // Streaming readers own the read halves: gather through the
            // arrival channel, then restore worker-id order.
            let mut arrivals = ArrivalSet::new(m);
            for _ in 0..m {
                let msg = self.next_arrival()?;
                arrivals.admit(&msg)?;
                msgs.push(msg);
            }
        } else {
            for s in &mut self.streams {
                let msg = read_frame(s)?;
                if msg.kind == MsgKind::WorkerError {
                    // Fail before reading the remaining sockets — the
                    // erroring worker's peers may not send this round.
                    validate_round_batch(std::slice::from_ref(&msg))?;
                }
                self.counter.add_up(msg.frame_len() + 4);
                msgs.push(msg);
            }
        }
        msgs.sort_by_key(|m| m.worker);
        validate_round_batch(&msgs)?;
        Ok(msgs)
    }

    fn recv_round_streaming(
        &mut self,
        on_msg: &mut dyn FnMut(Message) -> anyhow::Result<()>,
    ) -> anyhow::Result<()> {
        // Arrival-order gather: no fixed-id read order, so one straggler
        // can no longer block payloads already sitting in other sockets,
        // and a WorkerError frame aborts the barrier the moment it lands
        // regardless of which worker sent it.
        self.start_readers()?;
        let m = self.streams.len();
        let mut arrivals = ArrivalSet::new(m);
        for _ in 0..m {
            let msg = self.next_arrival()?;
            arrivals.admit(&msg)?;
            on_msg(msg)?;
        }
        Ok(())
    }

    fn recv_round_streaming_timed(
        &mut self,
        on_msg: &mut dyn FnMut(Message) -> anyhow::Result<StreamDirective>,
    ) -> anyhow::Result<StreamOutcome> {
        // Same arrival channel as the untimed gather (reader threads own
        // the read halves), but the callback owns all round bookkeeping
        // and its directive bounds the wait for the next frame.
        self.start_readers()?;
        let rx = self.readers.as_ref().expect("readers started");
        let counter = &self.counter;
        super::drive_timed_stream(
            &mut |deadline| {
                let msg = match deadline {
                    None => rx
                        .recv()
                        .map_err(|_| anyhow::anyhow!("all tcp reader threads exited"))??,
                    Some(dl) => {
                        let left = dl.saturating_duration_since(Instant::now());
                        match rx.recv_timeout(left) {
                            Ok(res) => res?,
                            Err(RecvTimeoutError::Timeout) => return Ok(None),
                            Err(RecvTimeoutError::Disconnected) => {
                                anyhow::bail!("all tcp reader threads exited")
                            }
                        }
                    }
                };
                counter.add_up(msg.frame_len() + 4);
                Ok(Some(msg))
            },
            on_msg,
        )
    }

    fn broadcast(&mut self, msg: Message) -> anyhow::Result<()> {
        if self.writers.is_some() {
            // Writer threads own the write halves: route through their
            // FIFOs (preserving per-worker frame order) and block until
            // every write is out — the synchronous contract.
            return self.broadcast_async(msg)?.wait();
        }
        for s in &mut self.streams {
            let n = write_frame(s, &msg)?;
            self.counter.add_down(n);
        }
        Ok(())
    }

    fn broadcast_async(&mut self, msg: Message) -> anyhow::Result<BroadcastHandle> {
        self.start_writers()?;
        self.writers.as_ref().expect("writers started").enqueue(msg)
    }

    fn set_pipeline_depth(&mut self, depth: usize) {
        if self.writers.is_none() {
            self.pipeline_depth = depth.max(1);
        }
    }

    fn workers(&self) -> usize {
        self.streams.len()
    }

    fn counter(&self) -> Option<Arc<ByteCounter>> {
        Some(Arc::clone(&self.counter))
    }
}

/// One broadcast command for the readiness loop: the encoded wire bytes
/// (shared across all M outboxes) plus the completion handle the loop
/// attaches a [`PendingDelivery`] per worker to.
#[cfg(unix)]
enum LoopCmd {
    Broadcast {
        wire: Arc<Vec<u8>>,
        handle: BroadcastHandle,
    },
    /// Targeted frame (rejoin replay / directed shutdown): rides one
    /// worker's outbox, fire-and-forget — nobody waits on its handle.
    SendTo {
        worker: usize,
        wire: Arc<Vec<u8>>,
    },
    /// Leader-initiated eviction (liveness timeout or ack stall).
    /// `notify` additionally surfaces an in-band [`MsgKind::Gone`] frame
    /// on the arrival channel, for evictions decided outside a gather.
    Evict {
        worker: usize,
        what: String,
        notify: bool,
    },
}

/// A reconnecting socket that has been accepted but not yet identified:
/// it leaves this staging area when its [`MsgKind::Rejoin`] hello lands
/// (spliced into the worker's old slot) or on any protocol error
/// (dropped).
#[cfg(unix)]
struct JoiningConn {
    stream: TcpStream,
    asm: super::message::FrameAssembler,
}

/// Per-connection state of the readiness loop: the nonblocking socket,
/// the incremental read-side reassembler, the write-side outbound ring,
/// and the sticky first failure.
#[cfg(unix)]
struct EvConn {
    stream: TcpStream,
    asm: super::message::FrameAssembler,
    out: super::evloop::OutRing,
    failed: Option<String>,
}

/// State shared between the loop thread and the leader-facing endpoint.
#[cfg(unix)]
struct EvShared {
    /// First worker failure observed by the loop (sticky): surfaced by
    /// the next `broadcast_async` call, in addition to completing every
    /// affected [`BroadcastHandle`] with it.
    first_error: Mutex<Option<String>>,
    /// `--on-worker-loss evict`: worker loss becomes an in-band
    /// [`MsgKind::Gone`] frame plus a reclaimed outbox instead of a
    /// sticky fatal error, and the listener keeps accepting Rejoin
    /// hellos from evicted workers.
    evict: std::sync::atomic::AtomicBool,
}

/// Mark connection `i` failed. Abort mode (default): complete its queued
/// deliveries with the error, record the sticky first failure (naming
/// the worker id — the satellite-3 contract), release it from the ack
/// ledger, and surface the error once on the arrival channel so a
/// blocked gather fails too. Evict mode: reclaim the parked frames
/// *without* poisoning the survivors' broadcast handles, and surface the
/// loss as an in-band [`MsgKind::Gone`] frame — the leader evicts the
/// worker and the round closes over the survivors.
#[cfg(unix)]
fn fail_conn(
    conn: &mut EvConn,
    i: usize,
    what: &str,
    evict: bool,
    shared: &EvShared,
    ledger: &super::evloop::AckLedger,
    arrivals_tx: &std::sync::mpsc::Sender<anyhow::Result<Message>>,
) {
    let what = format!("worker {i} socket failed: {what}");
    ledger.mark_dead(i as u32);
    if evict {
        conn.out.skip_all();
        conn.failed = Some(what.clone());
        let _ = arrivals_tx.send(Ok(Message::gone(i as u32, 0, &what)));
        return;
    }
    let mut g = shared.first_error.lock().unwrap();
    if g.is_none() {
        *g = Some(what.clone());
    }
    drop(g);
    conn.out.fail_all(&what);
    conn.failed = Some(what.clone());
    let _ = arrivals_tx.send(Err(anyhow::anyhow!(what)));
}

/// Leader-initiated eviction of worker `i` (liveness timeout or ack
/// stall): reclaim its parked outbox frames, close the socket so the
/// worker's next recv errors out (its clean-exit path), and release its
/// ledger slot. `notify` additionally surfaces an in-band Gone frame —
/// used when the eviction was decided outside the gather (ack stall in
/// `broadcast_async`), so the next gather still observes the loss.
#[cfg(unix)]
fn evict_conn(
    conn: &mut EvConn,
    i: usize,
    what: &str,
    notify: bool,
    ledger: &super::evloop::AckLedger,
    arrivals_tx: &std::sync::mpsc::Sender<anyhow::Result<Message>>,
) {
    if conn.failed.is_some() {
        return;
    }
    let what = format!("worker {i} evicted: {what}");
    conn.out.skip_all();
    conn.failed = Some(what.clone());
    ledger.mark_dead(i as u32);
    let _ = conn.stream.shutdown(std::net::Shutdown::Both);
    if notify {
        let _ = arrivals_tx.send(Ok(Message::gone(i as u32, 0, &what)));
    }
}

/// Body of the single `dqgan-evloop` leader thread: poll every worker
/// socket (read-interest always, write-interest while its outbox is
/// non-empty) plus the waker, demux arriving frames (`Ack` → ledger,
/// everything else → the arrival channel the gathers pop), and flush
/// outboxes as sockets become writable. When the command channel
/// disconnects (endpoint dropped) the loop flushes every remaining
/// outbox — a queued trailing `Shutdown` still reaches the workers —
/// then exits.
#[cfg(unix)]
#[allow(clippy::too_many_arguments)]
fn run_evloop(
    mut conns: Vec<EvConn>,
    listener: Option<TcpListener>,
    mut waker_rx: std::os::unix::net::UnixStream,
    cmd_rx: std::sync::mpsc::Receiver<LoopCmd>,
    arrivals_tx: std::sync::mpsc::Sender<anyhow::Result<Message>>,
    counter: Arc<ByteCounter>,
    ledger: Arc<super::evloop::AckLedger>,
    shared: Arc<EvShared>,
) {
    use super::evloop::{drain_waker, poll_ready, PollFd, POLLERR, POLLHUP, POLLIN, POLLOUT};
    use std::io::Read;
    use std::os::fd::AsRawFd;

    let mut scratch = vec![0u8; 64 * 1024];
    let mut fds: Vec<PollFd> = Vec::with_capacity(conns.len() + 2);
    let mut idx: Vec<usize> = Vec::with_capacity(conns.len());
    let mut joining: Vec<JoiningConn> = Vec::new();
    let mut closing = false;
    loop {
        let evict_on = shared.evict.load(std::sync::atomic::Ordering::Relaxed);
        fds.clear();
        idx.clear();
        fds.push(PollFd { fd: waker_rx.as_raw_fd(), events: POLLIN, revents: 0 });
        for (i, c) in conns.iter().enumerate() {
            if c.failed.is_some() {
                continue;
            }
            // While closing, only write-interest remains: drain the
            // outboxes, never accept new frames.
            let mut events = if closing { 0 } else { POLLIN };
            if !c.out.is_empty() {
                events |= POLLOUT;
            }
            if events != 0 {
                fds.push(PollFd { fd: c.stream.as_raw_fd(), events, revents: 0 });
                idx.push(i);
            }
        }
        if closing && idx.is_empty() {
            return; // every live outbox flushed: teardown complete
        }
        // Rejoin plumbing participates only in elastic mode: the listener
        // keeps accepting reconnects, and accepted-but-unidentified
        // sockets wait in `joining` until their Rejoin hello arrives.
        let mut listener_pos = None;
        if evict_on && !closing {
            if let Some(l) = &listener {
                listener_pos = Some(fds.len());
                fds.push(PollFd { fd: l.as_raw_fd(), events: POLLIN, revents: 0 });
            }
        }
        let join_base = fds.len();
        let join_snapshot = if closing { 0 } else { joining.len() };
        for j in &joining[..join_snapshot] {
            fds.push(PollFd { fd: j.stream.as_raw_fd(), events: POLLIN, revents: 0 });
        }
        crate::obs::metrics::EVLOOP_POLL_ITERATIONS.inc();
        let idle_t0 = crate::obs::maybe_now();
        let polled = poll_ready(&mut fds, -1);
        crate::obs::record_elapsed(&crate::obs::metrics::EVLOOP_IDLE_WAIT_NS, idle_t0);
        if let Err(e) = polled {
            // poll(2) itself failing is unrecoverable even in elastic
            // mode: fail every connection (abort semantics) so no gather
            // or broadcast handle can hang.
            let what = e.to_string();
            for (i, c) in conns.iter_mut().enumerate() {
                if c.failed.is_none() {
                    fail_conn(c, i, &what, false, &shared, &ledger, &arrivals_tx);
                }
            }
            return;
        }
        if fds[0].revents & POLLIN != 0 {
            crate::obs::metrics::EVLOOP_WAKEUPS.inc();
            drain_waker(&mut waker_rx);
        }
        // Drain commands on every wakeup (cheap when empty).
        loop {
            match cmd_rx.try_recv() {
                Ok(LoopCmd::Broadcast { wire, handle }) => {
                    // Load the mode fresh: a flip between the poll and
                    // this drain must not misclassify a delivery.
                    let evict = shared.evict.load(std::sync::atomic::Ordering::Relaxed);
                    for c in conns.iter_mut() {
                        let pd = PendingDelivery::new(handle.clone());
                        match &c.failed {
                            // An evicted worker's deliveries are skipped
                            // (count as satisfied), never failed — the
                            // survivors' handle must stay clean.
                            Some(_) if evict => pd.skipped(),
                            Some(what) => pd.failed(what),
                            None => c.out.push(Arc::clone(&wire), pd),
                        }
                    }
                }
                Ok(LoopCmd::SendTo { worker, wire }) => {
                    if let Some(c) = conns.get_mut(worker) {
                        if c.failed.is_none() {
                            c.out.push(wire, PendingDelivery::new(BroadcastHandle::new(1)));
                        }
                    }
                }
                Ok(LoopCmd::Evict { worker, what, notify }) => {
                    if let Some(c) = conns.get_mut(worker) {
                        evict_conn(c, worker, &what, notify, &ledger, &arrivals_tx);
                    }
                }
                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    closing = true;
                    break;
                }
            }
        }
        for (k, i) in idx.iter().copied().enumerate() {
            let revents = fds[k + 1].revents;
            if revents == 0 {
                continue;
            }
            let conn = &mut conns[i];
            // Reads first: acks queued ahead of payloads on the same
            // socket release ledger backpressure as early as possible.
            if !closing && revents & (POLLIN | POLLERR | POLLHUP) != 0 {
                let mut failure: Option<String> = None;
                let mut msgs = Vec::new();
                loop {
                    match conn.stream.read(&mut scratch) {
                        Ok(0) => {
                            failure = Some("connection closed".into());
                            break;
                        }
                        Ok(n) => {
                            // A decode failure still delivers the frames
                            // completed before the corrupt one.
                            if let Err(e) = conn.asm.push(&scratch[..n], &mut msgs) {
                                failure = Some(e.to_string());
                                break;
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                        Err(e) => {
                            failure = Some(e.to_string());
                            break;
                        }
                    }
                }
                for msg in msgs {
                    if msg.kind == MsgKind::Ack {
                        // Control plane: ledger + ctrl accounting; never
                        // enters the gather stream.
                        counter.add_ctrl(msg.frame_len() + 4);
                        crate::obs::note_ack(msg.worker as usize, msg.round);
                        ledger.on_ack(msg.worker);
                    } else {
                        // Uplink bytes are counted at the pop, exactly
                        // like the threaded reader channel.
                        let _ = arrivals_tx.send(Ok(msg));
                    }
                }
                if let Some(what) = failure {
                    // Fresh load: the mode may have flipped while this
                    // iteration was parked in poll.
                    let evict = shared.evict.load(std::sync::atomic::Ordering::Relaxed);
                    fail_conn(conn, i, &what, evict, &shared, &ledger, &arrivals_tx);
                    continue;
                }
            }
            if revents & (POLLOUT | POLLERR | POLLHUP) != 0 && !conn.out.is_empty() {
                let counter = &counter;
                if let Err(e) = conn.out.pump(&mut conn.stream, |wire_len| {
                    counter.add_down(wire_len);
                    crate::obs::metrics::EVLOOP_DELIVERIES.inc();
                }) {
                    let evict = shared.evict.load(std::sync::atomic::Ordering::Relaxed);
                    fail_conn(conn, i, &e.to_string(), evict, &shared, &ledger, &arrivals_tx);
                }
            }
        }
        // Elastic mode: accept pending reconnects (listener is
        // nonblocking; drain until WouldBlock).
        if let Some(pos) = listener_pos {
            if fds[pos].revents & POLLIN != 0 {
                if let Some(l) = &listener {
                    loop {
                        match l.accept() {
                            Ok((s, _)) => {
                                if s.set_nodelay(true).is_ok() && s.set_nonblocking(true).is_ok() {
                                    joining.push(JoiningConn {
                                        stream: s,
                                        asm: super::message::FrameAssembler::new(),
                                    });
                                }
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                            Err(_) => break,
                        }
                    }
                }
            }
        }
        // Joining sockets: read until the Rejoin hello lands, then splice
        // the connection into its old worker slot. A bad hello or a read
        // failure drops the staging socket — never an established worker.
        let mut splice: Vec<(usize, Option<Message>)> = Vec::new();
        for j in 0..join_snapshot {
            let revents = fds[join_base + j].revents;
            if revents == 0 {
                continue;
            }
            let jc = &mut joining[j];
            let mut failure = false;
            let mut msgs = Vec::new();
            loop {
                match jc.stream.read(&mut scratch) {
                    Ok(0) => {
                        failure = true;
                        break;
                    }
                    Ok(n) => {
                        if jc.asm.push(&scratch[..n], &mut msgs).is_err() {
                            failure = true;
                            break;
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        failure = true;
                        break;
                    }
                }
            }
            match msgs.into_iter().next() {
                Some(hello)
                    if hello.kind == MsgKind::Rejoin
                        && (hello.worker as usize) < conns.len() =>
                {
                    splice.push((j, Some(hello)));
                }
                Some(_) => splice.push((j, None)),
                None if failure => splice.push((j, None)),
                None => {} // partial frame: keep waiting
            }
        }
        // Descending order keeps pending indices valid across swap_remove.
        for (j, hello) in splice.into_iter().rev() {
            let jc = joining.swap_remove(j);
            if let Some(hello) = hello {
                let w = hello.worker as usize;
                if conns[w].failed.is_none() {
                    continue; // slot is healthy (duplicate rejoin): drop it
                }
                let conn = &mut conns[w];
                conn.stream = jc.stream;
                conn.asm = jc.asm;
                conn.out = super::evloop::OutRing::default();
                conn.failed = None;
                ledger.mark_alive(hello.worker);
                // The Rejoin frame flows in-band so a blocked gather
                // observes the readmission and starts the replay.
                let _ = arrivals_tx.send(Ok(hello));
            }
        }
    }
}

/// TCP server endpoint driven by one readiness-loop thread — the O(1)
/// leader-threads replacement for [`TcpServerEnd`]'s per-worker reader
/// and writer armies. Same [`ServerEnd`] contract, same wire format,
/// same byte accounting; plus ack-based flow control: `--pipeline-depth`
/// bounds each worker's *applied* broadcasts via the [`MsgKind::Ack`]
/// frames its [`WorkerEnd::ack`] emits.
#[cfg(unix)]
pub struct TcpEvloopServerEnd {
    m: usize,
    counter: Arc<ByteCounter>,
    /// Arrival-ordered uplink frames from the loop thread. Unbounded by
    /// construction but bounded in practice by the round protocol: each
    /// worker has at most `pipeline_depth` rounds in flight, so at most
    /// that many payload frames can precede a pop. (A bounded channel
    /// here could deadlock the loop: it must never block while it still
    /// owes writes.)
    arrivals: std::sync::mpsc::Receiver<anyhow::Result<Message>>,
    cmd_tx: Option<std::sync::mpsc::Sender<LoopCmd>>,
    waker: super::evloop::Waker,
    ledger: Arc<super::evloop::AckLedger>,
    shared: Arc<EvShared>,
    pipeline_depth: usize,
    thread: Option<std::thread::JoinHandle<()>>,
}

#[cfg(unix)]
impl TcpEvloopServerEnd {
    fn spawn(streams: Vec<TcpStream>, listener: TcpListener) -> anyhow::Result<Self> {
        let m = streams.len();
        let mut conns = Vec::with_capacity(m);
        for s in streams {
            s.set_nonblocking(true)?;
            conns.push(EvConn {
                stream: s,
                asm: super::message::FrameAssembler::new(),
                out: super::evloop::OutRing::default(),
                failed: None,
            });
        }
        listener.set_nonblocking(true)?;
        let (waker, waker_rx) = super::evloop::Waker::pair()?;
        let (cmd_tx, cmd_rx) = std::sync::mpsc::channel();
        let (arrivals_tx, arrivals) = std::sync::mpsc::channel();
        let counter = ByteCounter::new();
        let ledger = super::evloop::AckLedger::new(m);
        let shared = Arc::new(EvShared {
            first_error: Mutex::new(None),
            evict: std::sync::atomic::AtomicBool::new(false),
        });
        let thread = {
            let counter = Arc::clone(&counter);
            let ledger = Arc::clone(&ledger);
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("dqgan-evloop".into())
                .spawn(move || {
                    run_evloop(
                        conns,
                        Some(listener),
                        waker_rx,
                        cmd_rx,
                        arrivals_tx,
                        counter,
                        ledger,
                        shared,
                    )
                })
                .map_err(|e| anyhow::anyhow!("spawn dqgan-evloop: {e}"))?
        };
        Ok(Self {
            m,
            counter,
            arrivals,
            cmd_tx: Some(cmd_tx),
            waker,
            ledger,
            shared,
            pipeline_depth: 2,
            thread: Some(thread),
        })
    }

    pub fn counter(&self) -> Arc<ByteCounter> {
        Arc::clone(&self.counter)
    }

    fn next_arrival(&mut self) -> anyhow::Result<Message> {
        let msg =
            self.arrivals.recv().map_err(|_| anyhow::anyhow!("event loop exited"))??;
        // Gone frames are leader-internal (synthesized, never on the
        // wire): keep them out of the uplink byte totals.
        if msg.kind != MsgKind::Gone {
            self.counter.add_up(msg.frame_len() + 4);
        }
        Ok(msg)
    }
}

#[cfg(unix)]
impl ServerEnd for TcpEvloopServerEnd {
    fn recv_round(&mut self) -> anyhow::Result<Vec<Message>> {
        let mut arrivals = ArrivalSet::new(self.m);
        let mut msgs = Vec::with_capacity(self.m);
        for _ in 0..self.m {
            let msg = self.next_arrival()?;
            arrivals.admit(&msg)?;
            msgs.push(msg);
        }
        msgs.sort_by_key(|m| m.worker);
        validate_round_batch(&msgs)?;
        Ok(msgs)
    }

    fn recv_round_streaming(
        &mut self,
        on_msg: &mut dyn FnMut(Message) -> anyhow::Result<()>,
    ) -> anyhow::Result<()> {
        let mut arrivals = ArrivalSet::new(self.m);
        for _ in 0..self.m {
            let msg = self.next_arrival()?;
            arrivals.admit(&msg)?;
            on_msg(msg)?;
        }
        Ok(())
    }

    fn recv_round_streaming_timed(
        &mut self,
        on_msg: &mut dyn FnMut(Message) -> anyhow::Result<StreamDirective>,
    ) -> anyhow::Result<StreamOutcome> {
        let rx = &self.arrivals;
        let counter = &self.counter;
        super::drive_timed_stream(
            &mut |deadline| {
                let msg = match deadline {
                    None => rx.recv().map_err(|_| anyhow::anyhow!("event loop exited"))??,
                    Some(dl) => {
                        let left = dl.saturating_duration_since(Instant::now());
                        match rx.recv_timeout(left) {
                            Ok(res) => res?,
                            Err(RecvTimeoutError::Timeout) => return Ok(None),
                            Err(RecvTimeoutError::Disconnected) => {
                                anyhow::bail!("event loop exited")
                            }
                        }
                    }
                };
                if msg.kind != MsgKind::Gone {
                    counter.add_up(msg.frame_len() + 4);
                }
                Ok(Some(msg))
            },
            on_msg,
        )
    }

    fn broadcast(&mut self, msg: Message) -> anyhow::Result<()> {
        // The loop owns every socket: the synchronous contract is
        // "queued through the loop, then wait until each delivery has
        // left the leader" — and a sticky worker failure surfaces here
        // with the failing worker id via the handle.
        self.broadcast_async(msg)?.wait()
    }

    fn broadcast_async(&mut self, msg: Message) -> anyhow::Result<BroadcastHandle> {
        if let Some(e) = self.shared.first_error.lock().unwrap().clone() {
            anyhow::bail!("async broadcast failed: {e}");
        }
        // Applied-broadcast flow control: data broadcasts charge the
        // ledger (acks, consumed on the loop thread, discharge it);
        // Shutdown is control flow and never acked.
        if matches!(msg.kind, MsgKind::Broadcast | MsgKind::PartialBroadcast) {
            if self.shared.evict.load(std::sync::atomic::Ordering::Relaxed) {
                // Elastic mode: a stalled worker is evicted instead of
                // taking down the run — the loop closes its socket and a
                // Gone frame reaches the next gather (satellite-1 path).
                for w in self
                    .ledger
                    .charge_evicting(self.pipeline_depth, std::time::Duration::from_secs(30))
                {
                    let _ = self
                        .cmd_tx
                        .as_ref()
                        .expect("command channel alive until drop")
                        .send(LoopCmd::Evict {
                            worker: w as usize,
                            what: format!(
                                "pipeline stall: {} unapplied broadcasts (depth {}) and acks stopped",
                                self.pipeline_depth, self.pipeline_depth
                            ),
                            notify: true,
                        });
                    self.waker.wake();
                }
            } else {
                self.ledger.charge(self.pipeline_depth)?;
            }
        }
        let handle = BroadcastHandle::new(self.m);
        let wire = Arc::new(super::evloop::wire_frame(&msg));
        self.cmd_tx
            .as_ref()
            .expect("command channel alive until drop")
            .send(LoopCmd::Broadcast { wire, handle: handle.clone() })
            .map_err(|_| anyhow::anyhow!("event loop exited"))?;
        self.waker.wake();
        Ok(handle)
    }

    fn set_pipeline_depth(&mut self, depth: usize) {
        // Charged per-broadcast (not baked into spawned queues), so the
        // depth is adjustable at any time.
        self.pipeline_depth = depth.max(1);
    }

    fn workers(&self) -> usize {
        self.m
    }

    fn counter(&self) -> Option<Arc<ByteCounter>> {
        Some(Arc::clone(&self.counter))
    }

    fn set_evict_on_loss(&mut self, on: bool) {
        self.shared.evict.store(on, std::sync::atomic::Ordering::Relaxed);
        // Re-arm the poll set: the loop adds listener interest (rejoin
        // accepts) on its next iteration.
        self.waker.wake();
    }

    fn evict_worker(&mut self, worker: usize) -> anyhow::Result<()> {
        self.cmd_tx
            .as_ref()
            .expect("command channel alive until drop")
            .send(LoopCmd::Evict {
                worker,
                what: "evicted by leader".into(),
                notify: false,
            })
            .map_err(|_| anyhow::anyhow!("event loop exited"))?;
        self.waker.wake();
        Ok(())
    }

    fn rejoin_worker(&mut self, _worker: usize) -> anyhow::Result<()> {
        // The loop already spliced the reconnect into the worker's slot
        // when it forwarded the Rejoin hello; nothing to do here.
        Ok(())
    }

    fn send_to(&mut self, worker: usize, msg: &Message) -> anyhow::Result<()> {
        let wire = Arc::new(super::evloop::wire_frame(msg));
        self.cmd_tx
            .as_ref()
            .expect("command channel alive until drop")
            .send(LoopCmd::SendTo { worker, wire })
            .map_err(|_| anyhow::anyhow!("event loop exited"))?;
        self.waker.wake();
        Ok(())
    }
}

#[cfg(unix)]
impl Drop for TcpEvloopServerEnd {
    fn drop(&mut self) {
        // Disconnect the command channel, wake the loop so it notices,
        // and join: the loop flushes every outbox (a queued trailing
        // Shutdown still lands) before exiting.
        self.cmd_tx.take();
        self.waker.wake();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcp_round_trip() {
        let m = 3;
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let workers: Vec<_> = (0..m as u32)
            .map(|id| {
                std::thread::spawn(move || {
                    let mut w = TcpWorkerEnd::connect(&addr.to_string(), id).unwrap();
                    w.send(Message::payload(id, 0, vec![id as u8; 16])).unwrap();
                    let b = w.recv().unwrap();
                    assert_eq!(b.kind, MsgKind::Broadcast);
                    assert_eq!(b.payload, vec![7, 7]);
                    let s = w.recv().unwrap();
                    assert_eq!(s.kind, MsgKind::Shutdown);
                    w.counter().down_total()
                })
            })
            .collect();
        let mut server = builder.accept(m).unwrap();
        let msgs = server.recv_round().unwrap();
        assert_eq!(msgs.len(), m);
        assert_eq!(msgs[1].payload, vec![1u8; 16]);
        server.broadcast(Message::broadcast(0, vec![7, 7])).unwrap();
        server.broadcast(Message::shutdown(1)).unwrap();
        // Worker-side downlink telemetry: exactly the broadcast + shutdown
        // frames (each with its 4-byte length prefix) — regression for the
        // counter that used to stay at 0.
        let expected_down = (Message::broadcast(0, vec![7, 7]).frame_len()
            + Message::shutdown(1).frame_len()
            + 8) as u64;
        for w in workers {
            assert_eq!(w.join().unwrap(), expected_down);
        }
        assert!(server.counter().up_total() > 0);
    }

    #[test]
    fn tcp_streaming_round_trip() {
        // Round 0 gathers via the streaming (arrival-order) path, round 1
        // via the classic barrier — proving both coexist once the reader
        // threads own the sockets.
        let m = 3;
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let workers: Vec<_> = (0..m as u32)
            .map(|id| {
                std::thread::spawn(move || {
                    let mut w = TcpWorkerEnd::connect(&addr.to_string(), id).unwrap();
                    for round in 0..2u64 {
                        w.send(Message::payload(id, round, vec![id as u8; 8])).unwrap();
                        let b = w.recv().unwrap();
                        assert_eq!(b.kind, MsgKind::Broadcast);
                        assert_eq!(b.round, round);
                    }
                    let s = w.recv().unwrap();
                    assert_eq!(s.kind, MsgKind::Shutdown);
                })
            })
            .collect();
        let mut server = builder.accept(m).unwrap();
        let mut seen = Vec::new();
        server
            .recv_round_streaming(&mut |msg| {
                assert_eq!(msg.round, 0);
                seen.push(msg.worker);
                Ok(())
            })
            .unwrap();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2]);
        server.broadcast(Message::broadcast(0, vec![1])).unwrap();
        let msgs = server.recv_round().unwrap();
        assert_eq!(msgs.len(), m);
        assert!(msgs.windows(2).all(|w| w[0].worker < w[1].worker), "sorted by id");
        assert!(msgs.iter().all(|m| m.round == 1));
        server.broadcast(Message::broadcast(1, vec![2])).unwrap();
        server.broadcast(Message::shutdown(2)).unwrap();
        for w in workers {
            w.join().unwrap();
        }
    }

    #[test]
    fn tcp_timed_streaming_closes_early_and_honors_deadlines() {
        let m = 2;
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let workers: Vec<_> = (0..m as u32)
            .map(|id| {
                std::thread::spawn(move || {
                    let mut w = TcpWorkerEnd::connect(&addr.to_string(), id).unwrap();
                    if id == 0 {
                        // Worker 0 contributes the single frame of each
                        // gather; worker 1 stays silent throughout.
                        w.send(Message::payload(0, 0, vec![7])).unwrap();
                        w.send(Message::payload(0, 1, vec![8])).unwrap();
                    }
                    // Hold the connection open until the server is done
                    // (recv unblocks with an error when it drops).
                    let _ = w.recv();
                })
            })
            .collect();
        let mut server = builder.accept(m).unwrap();
        // Gather 1: close on the first frame — no waiting on worker 1.
        let mut seen = Vec::new();
        let outcome = server
            .recv_round_streaming_timed(&mut |msg| {
                seen.push((msg.worker, msg.round));
                Ok(StreamDirective::Close)
            })
            .unwrap();
        assert_eq!(outcome, StreamOutcome::Closed);
        assert_eq!(seen, vec![(0, 0)]);
        // Gather 2: arm a short grace after worker 0's frame; worker 1
        // never sends, so the deadline must expire.
        let outcome = server
            .recv_round_streaming_timed(&mut |msg| {
                assert_eq!((msg.worker, msg.round), (0, 1));
                Ok(StreamDirective::WaitUntil(
                    Instant::now() + std::time::Duration::from_millis(20),
                ))
            })
            .unwrap();
        assert_eq!(outcome, StreamOutcome::DeadlineExpired);
        drop(server); // unblocks the workers' trailing recv
        for w in workers {
            w.join().unwrap();
        }
    }

    #[test]
    fn async_broadcast_preserves_per_worker_frame_order_and_byte_accounting() {
        // Writer-thread regressions: frames queued with broadcast_async
        // (plus a trailing synchronous broadcast routed through the same
        // queues) arrive at every worker in exactly enqueue order, and
        // the server's downlink counter equals the frame_len + prefix
        // sums once every handle reports delivery.
        let m = 2;
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let frames: Vec<Message> =
            (0..5u64).map(|r| Message::broadcast(r, vec![r as u8; 6])).collect();
        let expected = frames.clone();
        let workers: Vec<_> = (0..m as u32)
            .map(|id| {
                let expected = expected.clone();
                std::thread::spawn(move || {
                    let mut w = TcpWorkerEnd::connect(&addr.to_string(), id).unwrap();
                    for f in &expected {
                        assert_eq!(&w.recv().unwrap(), f, "worker {id} frame order");
                    }
                    assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
                    w.counter().down_total()
                })
            })
            .collect();
        let mut server = builder.accept(m).unwrap();
        let mut handles = Vec::new();
        for f in &frames {
            handles.push(server.broadcast_async(f.clone()).unwrap());
        }
        server.broadcast(Message::shutdown(5)).unwrap();
        for h in &handles {
            h.wait().unwrap();
        }
        let per_worker: u64 = frames
            .iter()
            .map(|f| (f.frame_len() + 4) as u64)
            .chain(std::iter::once((Message::shutdown(5).frame_len() + 4) as u64))
            .sum();
        assert_eq!(server.counter().down_total(), per_worker * m as u64);
        for w in workers {
            assert_eq!(w.join().unwrap(), per_worker, "worker-side downlink accounting");
        }
    }

    #[test]
    fn dropping_the_server_drains_queued_async_broadcasts() {
        // Clean-shutdown regression: broadcasts queued via
        // broadcast_async — including the final Shutdown — must reach
        // the workers even when the server end is dropped immediately,
        // without waiting on any handle (Drop joins the writers after
        // their queues drain).
        let m = 2;
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let workers: Vec<_> = (0..m as u32)
            .map(|id| {
                std::thread::spawn(move || {
                    let mut w = TcpWorkerEnd::connect(&addr.to_string(), id).unwrap();
                    let b = w.recv().unwrap();
                    assert_eq!(b.kind, MsgKind::Broadcast);
                    assert_eq!(b.payload, vec![7; 3]);
                    assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
                })
            })
            .collect();
        let mut server = builder.accept(m).unwrap();
        server.broadcast_async(Message::broadcast(0, vec![7; 3])).unwrap();
        server.broadcast_async(Message::shutdown(1)).unwrap();
        drop(server);
        for w in workers {
            w.join().unwrap();
        }
    }

    #[test]
    fn gated_tcp_worker_send_blocks_until_released() {
        // The DelayPlan contract now holds on TCP worker ends too: a
        // held uplink gate keeps the payload off the wire.
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let plan = DelayPlan::new();
        plan.hold(1, 0);
        let plans: Vec<_> = (0..2u32).map(|_| plan.clone()).collect();
        let workers: Vec<_> = plans
            .into_iter()
            .enumerate()
            .map(|(id, plan)| {
                std::thread::spawn(move || {
                    let mut w = TcpWorkerEnd::connect_with_plan(
                        &addr.to_string(),
                        id as u32,
                        Some(plan),
                    )
                    .unwrap();
                    w.send(Message::payload(id as u32, 0, vec![id as u8])).unwrap();
                    let _ = w.recv();
                })
            })
            .collect();
        let mut server = builder.accept(2).unwrap();
        let mut seen = Vec::new();
        server
            .recv_round_streaming(&mut |msg| {
                if seen.is_empty() {
                    // Worker 0's frame arrived while worker 1's uplink
                    // gate is provably still held.
                    assert_eq!(msg.worker, 0);
                    assert!(plan.is_held(1, 0));
                    plan.release(1, 0);
                }
                seen.push(msg.worker);
                Ok(())
            })
            .unwrap();
        assert_eq!(seen, vec![0, 1]);
        server.broadcast(Message::shutdown(0)).unwrap();
        for w in workers {
            w.join().unwrap();
        }
    }

    #[test]
    fn rejects_duplicate_ids() {
        // Deterministic: the worker thread holds both connections open
        // until `accept` has returned, so the server always reads both
        // registration frames (no sleep, no slow-runner flake).
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
        let w = std::thread::spawn(move || {
            let _a = TcpWorkerEnd::connect(&addr.to_string(), 0).unwrap();
            let _b = TcpWorkerEnd::connect(&addr.to_string(), 0);
            // Keep the connections open until accept has failed.
            let _ = done_rx.recv();
        });
        let res = builder.accept(2);
        assert!(res.is_err(), "duplicate registration must fail accept");
        done_tx.send(()).unwrap();
        w.join().unwrap();
    }

    #[test]
    fn rejects_out_of_range_id() {
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
        let w = std::thread::spawn(move || {
            let _a = TcpWorkerEnd::connect(&addr.to_string(), 9).unwrap();
            let _ = done_rx.recv();
        });
        let res = builder.accept(2);
        assert!(res.is_err());
        done_tx.send(()).unwrap();
        w.join().unwrap();
    }

    #[test]
    #[cfg(unix)]
    fn evloop_round_trip_matches_threaded_byte_accounting() {
        // Same exchange as `tcp_round_trip`, over the readiness loop:
        // identical wire frames, identical up/down totals on both ends
        // (the threaded test's constants), with ack traffic isolated in
        // the ctrl counters.
        let m = 3;
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let workers: Vec<_> = (0..m as u32)
            .map(|id| {
                std::thread::spawn(move || {
                    let mut w = TcpWorkerEnd::connect_evloop(&addr.to_string(), id).unwrap();
                    w.send(Message::payload(id, 0, vec![id as u8; 16])).unwrap();
                    let b = w.recv().unwrap();
                    assert_eq!(b.kind, MsgKind::Broadcast);
                    assert_eq!(b.payload, vec![7, 7]);
                    w.ack(b.round).unwrap();
                    let s = w.recv().unwrap();
                    assert_eq!(s.kind, MsgKind::Shutdown);
                    let c = w.counter();
                    (c.up_total(), c.down_total(), c.ctrl_total())
                })
            })
            .collect();
        let mut server = builder.accept_evloop(m).unwrap();
        let msgs = server.recv_round().unwrap();
        assert_eq!(msgs.len(), m);
        assert_eq!(msgs[1].payload, vec![1u8; 16]);
        server.broadcast(Message::broadcast(0, vec![7, 7])).unwrap();
        server.broadcast(Message::shutdown(1)).unwrap();
        let expected_up = (Message::payload(0, 0, vec![0u8; 16]).frame_len() + 4) as u64;
        let expected_down = (Message::broadcast(0, vec![7, 7]).frame_len()
            + Message::shutdown(1).frame_len()
            + 8) as u64;
        let expected_ctrl = (Message::ack(0, 0).frame_len() + 4) as u64;
        for w in workers {
            let (up, down, ctrl) = w.join().unwrap();
            assert_eq!(up, expected_up, "worker uplink = threaded constant");
            assert_eq!(down, expected_down, "worker downlink = threaded constant");
            assert_eq!(ctrl, expected_ctrl, "one ack, ctrl plane only");
        }
        assert_eq!(server.counter().up_total(), expected_up * m as u64);
        assert_eq!(server.counter().down_total(), expected_down * m as u64);
    }

    #[test]
    #[cfg(unix)]
    fn evloop_leader_thread_count_is_flat_in_worker_count() {
        // The O(1)-in-M claim: with 64 workers, the readiness-loop server
        // adds a single leader thread, where the threaded transport would
        // add 2·M = 128 (reader + writer per worker) once fully active.
        // The assertion allows generous slack for unrelated test threads
        // coming and going in this process — it only has to separate
        // O(1) from O(M).
        use crate::util::threads::live_threads;
        let m = 64;
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<()>();
        let workers: Vec<_> = (0..m as u32)
            .map(|id| {
                let ready_tx = ready_tx.clone();
                std::thread::spawn(move || {
                    let mut w = TcpWorkerEnd::connect_evloop(&addr.to_string(), id).unwrap();
                    ready_tx.send(()).unwrap();
                    for round in 0..2u64 {
                        w.send(Message::payload(id, round, vec![id as u8; 8])).unwrap();
                        let b = w.recv().unwrap();
                        assert_eq!(b.round, round);
                        w.ack(round).unwrap();
                    }
                    assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
                })
            })
            .collect();
        for _ in 0..m {
            ready_rx.recv().unwrap(); // all worker threads connected + counted
        }
        let base = live_threads();
        let mut server = builder.accept_evloop(m).unwrap();
        assert!(
            live_threads() <= base + 8,
            "accept_evloop must add O(1) threads, not O(M)"
        );
        for round in 0..2u64 {
            let msgs = server.recv_round().unwrap();
            assert_eq!(msgs.len(), m);
            server.broadcast(Message::broadcast(round, vec![9])).unwrap();
        }
        // Still flat after gathers and broadcasts: unlike the threaded
        // end, nothing spawns lazily per worker.
        assert!(
            live_threads() <= base + 8,
            "steady-state leader threads must stay O(1) in M"
        );
        server.broadcast(Message::shutdown(2)).unwrap();
        drop(server);
        for w in workers {
            w.join().unwrap();
        }
    }

    #[test]
    #[cfg(unix)]
    fn evloop_sticky_failure_names_worker_on_both_broadcast_paths() {
        // Satellite-3 regression: a worker socket dying mid-run must
        // surface with the failing worker's id through BOTH delivery
        // paths — the BroadcastHandle from broadcast_async, and the next
        // synchronous broadcast (sticky first-failure).
        let m = 2;
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let (dead_tx, dead_rx) = std::sync::mpsc::channel::<()>();
        let w0 = std::thread::spawn(move || {
            let mut w = TcpWorkerEnd::connect_evloop(&addr.to_string(), 0).unwrap();
            // Receive whatever lands until the server goes away.
            while w.recv().is_ok() {}
        });
        let w1 = std::thread::spawn(move || {
            let w = TcpWorkerEnd::connect_evloop(&addr.to_string(), 1).unwrap();
            drop(w); // close the socket right after registration
            dead_tx.send(()).unwrap();
        });
        let mut server = builder.accept_evloop(m).unwrap();
        dead_rx.recv().unwrap(); // worker 1's socket is closed
        // Async path: the handle completes with the failure, naming the
        // worker. (The loop learns of the close either before queuing —
        // failing the delivery immediately — or when the write hits the
        // dead socket; both must name worker 1.)
        let handle = server.broadcast_async(Message::broadcast(0, vec![1, 2])).unwrap();
        let err = handle.wait().expect_err("delivery to a dead worker must fail");
        let text = format!("{err:#}");
        assert!(text.contains("broadcast delivery failed"), "got: {text}");
        assert!(text.contains("worker 1"), "must name the failing worker: {text}");
        // Sync path: the sticky first failure fails the next broadcast
        // up front, again naming the worker.
        let err = server
            .broadcast(Message::broadcast(1, vec![3]))
            .expect_err("sticky failure must surface on the sync path");
        let text = format!("{err:#}");
        assert!(text.contains("worker 1 socket failed"), "got: {text}");
        drop(server); // unblocks worker 0's recv loop
        w0.join().unwrap();
        w1.join().unwrap();
    }

    #[test]
    #[cfg(unix)]
    fn evloop_pipeline_depth_bounds_applied_not_written_broadcasts() {
        // End-to-end Lemma-1 staleness bound: with depth 1, the second
        // data broadcast must block until the worker has ACKED (applied)
        // the first — not merely until the first was written.
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let (go_tx, go_rx) = std::sync::mpsc::channel::<()>();
        let (got_tx, got_rx) = std::sync::mpsc::channel::<()>();
        let worker = std::thread::spawn(move || {
            let mut w = TcpWorkerEnd::connect_evloop(&addr.to_string(), 0).unwrap();
            let b0 = w.recv().unwrap();
            got_tx.send(()).unwrap(); // b0 received (written + read), not yet acked
            go_rx.recv().unwrap();
            w.ack(b0.round).unwrap();
            let b1 = w.recv().unwrap();
            w.ack(b1.round).unwrap();
            assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
            w.counter().ctrl_total()
        });
        let mut server = builder.accept_evloop(1).unwrap();
        server.set_pipeline_depth(1);
        server.broadcast(Message::broadcast(0, vec![1])).unwrap();
        got_rx.recv().unwrap();
        let second_done = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let flag = Arc::clone(&second_done);
        let srv = std::thread::spawn(move || {
            server.broadcast(Message::broadcast(1, vec![2])).unwrap();
            flag.store(true, std::sync::atomic::Ordering::SeqCst);
            server.broadcast(Message::shutdown(2)).unwrap();
        });
        // b0 is fully written AND read by the worker, yet the second
        // broadcast must still be parked on the unacked charge.
        std::thread::sleep(std::time::Duration::from_millis(100));
        assert!(
            !second_done.load(std::sync::atomic::Ordering::SeqCst),
            "depth-1 broadcast must wait for the APPLY ack, not the write"
        );
        go_tx.send(()).unwrap(); // worker acks b0 → charge clears
        srv.join().unwrap();
        assert!(second_done.load(std::sync::atomic::Ordering::SeqCst));
        let ctrl = worker.join().unwrap();
        assert_eq!(ctrl, 2 * (Message::ack(0, 0).frame_len() + 4) as u64);
    }

    #[test]
    #[cfg(unix)]
    fn evict_mode_turns_socket_death_into_gone_and_splices_rejoins() {
        // Elastic-membership end-to-end on raw transports: a dying worker
        // socket surfaces as an in-band Gone frame (not a fatal gather
        // error), broadcasts keep completing cleanly for the survivor,
        // and a reconnect with a Rejoin hello is spliced back into the
        // old slot and can receive targeted frames again.
        let m = 2;
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let (die_tx, die_rx) = std::sync::mpsc::channel::<()>();
        let (back_tx, back_rx) = std::sync::mpsc::channel::<()>();
        let w0 = std::thread::spawn(move || {
            let mut w = TcpWorkerEnd::connect_evloop(&addr.to_string(), 0).unwrap();
            w.send(Message::payload(0, 0, vec![1])).unwrap();
            let b = w.recv().unwrap();
            assert_eq!(b.kind, MsgKind::Broadcast);
            w.ack(b.round).unwrap();
            while w.recv().is_ok() {}
        });
        let w1 = std::thread::spawn(move || {
            let w = TcpWorkerEnd::connect_evloop(&addr.to_string(), 1).unwrap();
            // Die only once evict mode is armed (keeps the test free of
            // the startup race where the loop would still abort).
            die_rx.recv().unwrap();
            drop(w);
            back_rx.recv().unwrap();
            // Reconnect with the old id: Rejoin hello instead of a fresh
            // registration, then receive the targeted replay frame.
            let mut w = TcpWorkerEnd::reconnect_evloop(&addr.to_string(), 1, 1).unwrap();
            let replay = w.recv().unwrap();
            assert_eq!(replay, Message::broadcast(1, vec![9]));
            while w.recv().is_ok() {}
        });
        let mut server = builder.accept_evloop(m).unwrap();
        server.set_evict_on_loss(true);
        die_tx.send(()).unwrap();
        // Gather: worker 0's payload plus worker 1's synthesized Gone —
        // the gather must NOT fail.
        let mut seen_payload = false;
        let mut seen_gone = false;
        server
            .recv_round_streaming_timed(&mut |msg| {
                match msg.kind {
                    MsgKind::Payload => seen_payload = true,
                    MsgKind::Gone => {
                        assert_eq!(msg.worker, 1);
                        seen_gone = true;
                    }
                    other => panic!("unexpected frame kind {other:?}"),
                }
                if seen_payload && seen_gone {
                    Ok(StreamDirective::Close)
                } else {
                    Ok(StreamDirective::Wait)
                }
            })
            .unwrap();
        // Broadcast completes without error: the evicted worker's
        // delivery is skipped, not failed.
        server.broadcast(Message::broadcast(0, vec![7])).unwrap();
        // Worker 1 reconnects; the loop splices it in and forwards the
        // Rejoin hello in-band.
        back_tx.send(()).unwrap();
        server
            .recv_round_streaming_timed(&mut |msg| {
                assert_eq!(msg.kind, MsgKind::Rejoin);
                assert_eq!((msg.worker, msg.round), (1, 1));
                Ok(StreamDirective::Close)
            })
            .unwrap();
        // Targeted replay to the rejoined worker only.
        server.send_to(1, &Message::broadcast(1, vec![9])).unwrap();
        server.broadcast(Message::shutdown(2)).unwrap();
        drop(server);
        w0.join().unwrap();
        w1.join().unwrap();
    }

    #[test]
    fn retry_policy_parses_and_backs_off_deterministically() {
        let p = RetryPolicy::parse("8,50").unwrap();
        assert_eq!(p, RetryPolicy { attempts: 8, base_ms: 50 });
        assert_eq!(RetryPolicy::parse(" 3 , 0 ").unwrap().base_ms, 0);
        assert!(RetryPolicy::parse("8").is_err(), "missing base");
        assert!(RetryPolicy::parse("0,50").is_err(), "zero attempts");
        assert!(RetryPolicy::parse("x,50").is_err());
        assert!(RetryPolicy::parse("8,y").is_err());
        // Attempt 0 never sleeps; later attempts grow exponentially and
        // are bit-for-bit reproducible (the jitter is a pure function of
        // worker id and attempt, never wall clock).
        assert_eq!(p.backoff_ms(3, 0), 0);
        for attempt in 1..6u32 {
            let a = p.backoff_ms(3, attempt);
            assert_eq!(a, p.backoff_ms(3, attempt), "deterministic");
            let exp = 50u64 << (attempt - 1);
            assert!(a >= exp && a < exp + 50, "exp + jitter in [0, base): {a}");
        }
        // Different workers decorrelate.
        assert_ne!(p.backoff_ms(0, 1), p.backoff_ms(1, 1));
        // The cap bounds typo-sized bases.
        let big = RetryPolicy { attempts: 30, base_ms: 5_000 };
        assert_eq!(big.backoff_ms(0, 20), BACKOFF_CAP_MS);
        // base_ms = 0 retries immediately.
        assert_eq!(RetryPolicy { attempts: 4, base_ms: 0 }.backoff_ms(1, 3), 0);
    }

    #[test]
    fn session_handshake_welcomes_matching_fingerprints() {
        let m = 2;
        let fp = 0xFEED_FACE_CAFE_0001u64;
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let workers: Vec<_> = (0..m as u32)
            .map(|id| {
                std::thread::spawn(move || {
                    let (mut w, welcome) = TcpWorkerEnd::connect_session(
                        &addr.to_string(),
                        id,
                        fp,
                        3, // last epoch this worker served under
                        Some(RetryPolicy { attempts: 3, base_ms: 1 }),
                        false,
                    )
                    .unwrap();
                    assert_eq!(welcome, SessionWelcome { epoch: 4, resume_round: 17 });
                    // The data plane works unchanged after the handshake.
                    w.send(Message::payload(id, 17, vec![id as u8])).unwrap();
                    assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
                })
            })
            .collect();
        let session = SessionInfo { epoch: 4, fingerprint: fp, resume_round: 17 };
        let mut server = builder.accept_session(m, session).unwrap();
        let msgs = server.recv_round().unwrap();
        assert_eq!(msgs.len(), m);
        assert!(msgs.iter().all(|msg| msg.round == 17));
        server.broadcast(Message::shutdown(18)).unwrap();
        for w in workers {
            w.join().unwrap();
        }
    }

    #[test]
    fn session_handshake_refuses_fingerprint_mismatch_on_both_ends() {
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let worker = std::thread::spawn(move || {
            TcpWorkerEnd::connect_session(&addr.to_string(), 0, 0xAAAA, 0, None, false)
                .unwrap_err()
        });
        let session = SessionInfo { epoch: 0, fingerprint: 0xBBBB, resume_round: 0 };
        let leader_err = builder.accept_session(1, session).unwrap_err();
        assert!(
            leader_err.to_string().contains("refusing to mix run configurations"),
            "{leader_err}"
        );
        let worker_err = worker.join().unwrap();
        assert!(
            worker_err.to_string().contains("config fingerprint mismatch"),
            "{worker_err}"
        );
    }

    #[test]
    fn session_handshake_refuses_a_worker_from_the_future() {
        // A worker that served under epoch 9 reaching a leader at epoch 2
        // means the fleet has seen a newer incarnation than this leader —
        // the leader must refuse rather than rewind history.
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let fp = 0x1234u64;
        let worker = std::thread::spawn(move || {
            // The worker-side check also fires: welcome.epoch 2 < its 9.
            TcpWorkerEnd::connect_session(&addr.to_string(), 0, fp, 9, None, false).unwrap_err()
        });
        let session = SessionInfo { epoch: 2, fingerprint: fp, resume_round: 5 };
        let leader_err = builder.accept_session(1, session).unwrap_err();
        assert!(
            leader_err.to_string().contains("newer leader incarnation"),
            "{leader_err}"
        );
        let worker_err = worker.join().unwrap();
        assert!(worker_err.to_string().contains("stale leader"), "{worker_err}");
    }

    #[test]
    fn connect_retry_survives_a_late_listener_and_gives_up_cleanly() {
        // Bind then immediately drop a listener to get an address that
        // refuses connections, and verify the retry loop reports attempts.
        let dead = TcpListener::bind("127.0.0.1:0").unwrap();
        let dead_addr = dead.local_addr().unwrap().to_string();
        drop(dead);
        let err = TcpWorkerEnd::connect_session(
            &dead_addr,
            7,
            0x1,
            0,
            Some(RetryPolicy { attempts: 3, base_ms: 1 }),
            false,
        )
        .unwrap_err();
        assert!(err.to_string().contains("after 3 attempt(s)"), "{err}");
        // Late leader: start the listener only after the worker has been
        // dialing for a while — the backoff loop must reach it.
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let fp = 0x77u64;
        let worker = std::thread::spawn(move || {
            let (_, welcome) = TcpWorkerEnd::connect_session(
                &addr.to_string(),
                0,
                fp,
                0,
                Some(RetryPolicy { attempts: 10, base_ms: 5 }),
                false,
            )
            .unwrap();
            welcome
        });
        let session = SessionInfo { epoch: 1, fingerprint: fp, resume_round: 3 };
        let _server = builder.accept_session(1, session).unwrap();
        assert_eq!(worker.join().unwrap(), SessionWelcome { epoch: 1, resume_round: 3 });
    }

    #[test]
    fn legacy_registration_still_works_in_session_mode() {
        // Mixed fleets: a worker using the historical Payload/u64::MAX
        // hello registers fine with a session-mode leader (it just never
        // learns the epoch).
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let worker = std::thread::spawn(move || {
            let mut w = TcpWorkerEnd::connect(&addr.to_string(), 0).unwrap();
            w.send(Message::payload(0, 0, vec![1])).unwrap();
            assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
        });
        let session = SessionInfo { epoch: 1, fingerprint: 0x9, resume_round: 0 };
        let mut server = builder.accept_session(1, session).unwrap();
        assert_eq!(server.recv_round().unwrap().len(), 1);
        server.broadcast(Message::shutdown(1)).unwrap();
        worker.join().unwrap();
    }
}
