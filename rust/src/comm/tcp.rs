//! TCP transport: the same [`WorkerEnd`]/[`ServerEnd`] contract over real
//! sockets with length-prefixed frames. Used by the multi-process mode
//! (`dqgan train --transport tcp`) and the integration tests; proves the
//! wire format is genuinely serializable, not an in-memory shortcut.
//!
//! Framing: `[frame_len:u32][frame bytes]` where `frame` is
//! [`Message::encode`]'s output (which carries its own CRC).
//!
//! Setup is two-phase so the ephemeral port is known before workers
//! connect: [`TcpServerBuilder::listen`] → spawn workers → `accept(m)`.

use super::delay::DelayPlan;
use super::message::{Message, MsgKind};
use super::{
    validate_round_batch, ArrivalSet, BroadcastHandle, ByteCounter, ServerEnd, StreamDirective,
    StreamOutcome, WorkerEnd, WriterPool,
};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::Instant;

fn write_frame(stream: &mut TcpStream, msg: &Message) -> anyhow::Result<usize> {
    let frame = msg.encode();
    let len = (frame.len() as u32).to_le_bytes();
    stream.write_all(&len)?;
    stream.write_all(&frame)?;
    stream.flush()?;
    Ok(4 + frame.len())
}

fn read_frame(stream: &mut TcpStream) -> anyhow::Result<Message> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    // 256 MiB frame cap: protects against corrupt length prefixes.
    if len > 256 * 1024 * 1024 {
        anyhow::bail!("frame length {len} exceeds cap");
    }
    let mut frame = vec![0u8; len];
    stream.read_exact(&mut frame)?;
    Message::decode(&frame)
}

/// Phase-1 handle: the listener is bound (port known) but workers have
/// not been accepted yet.
pub struct TcpServerBuilder {
    listener: TcpListener,
    addr: SocketAddr,
}

impl TcpServerBuilder {
    /// Bind (use port 0 for an ephemeral port).
    pub fn listen(addr: &str) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(Self { listener, addr })
    }

    /// The bound address (hand this to workers).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Phase 2: accept exactly `m` worker registrations.
    pub fn accept(self, m: usize) -> anyhow::Result<TcpServerEnd> {
        let mut streams: Vec<Option<TcpStream>> = (0..m).map(|_| None).collect();
        let mut accepted = 0;
        while accepted < m {
            let (mut s, _) = self.listener.accept()?;
            s.set_nodelay(true)?;
            let hello = read_frame(&mut s)?;
            anyhow::ensure!(hello.round == u64::MAX, "bad registration frame");
            let id = hello.worker as usize;
            anyhow::ensure!(id < m, "worker id {id} out of range");
            anyhow::ensure!(streams[id].is_none(), "duplicate worker id {id}");
            streams[id] = Some(s);
            accepted += 1;
        }
        Ok(TcpServerEnd {
            streams: streams.into_iter().map(|s| s.unwrap()).collect(),
            counter: ByteCounter::new(),
            readers: None,
            pipeline_depth: 2,
            writers: None,
        })
    }
}

/// TCP worker endpoint (connects to the server).
pub struct TcpWorkerEnd {
    id: u32,
    stream: TcpStream,
    counter: Arc<ByteCounter>,
    /// Straggler-injection schedule (tests/benches only) — the same
    /// *uplink* gate/permit contract the in-process worker end honors,
    /// so the cross-transport equivalence suites can scramble TCP
    /// arrival orders deterministically too. (Downlink gates are an
    /// in-process-only hook; see `comm/delay.rs`.)
    plan: Option<DelayPlan>,
}

impl TcpWorkerEnd {
    /// Connect to `addr` and register with the given worker id.
    pub fn connect(addr: &str, id: u32) -> anyhow::Result<Self> {
        Self::connect_with_plan(addr, id, None)
    }

    /// [`Self::connect`] with a [`DelayPlan`] attached: payload sends
    /// consult the plan's uplink gates before hitting the socket.
    pub fn connect_with_plan(
        addr: &str,
        id: u32,
        plan: Option<DelayPlan>,
    ) -> anyhow::Result<Self> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        // Registration: a Payload-kind hello with round u64::MAX.
        write_frame(&mut stream, &Message::payload(id, u64::MAX, Vec::new()))?;
        Ok(Self { id, stream, counter: ByteCounter::new(), plan })
    }

    /// This worker's byte counters (uplink = sent, downlink = received).
    pub fn counter(&self) -> Arc<ByteCounter> {
        Arc::clone(&self.counter)
    }
}

impl WorkerEnd for TcpWorkerEnd {
    fn send(&mut self, msg: Message) -> anyhow::Result<()> {
        // Deterministic straggler injection, mirroring the in-process
        // worker end: a held gate blocks the payload before it reaches
        // the wire.
        if msg.kind == MsgKind::Payload {
            if let Some(plan) = &self.plan {
                plan.wait(msg.worker, msg.round);
            }
        }
        let n = write_frame(&mut self.stream, &msg)?;
        self.counter.add_up(n);
        Ok(())
    }

    fn recv(&mut self) -> anyhow::Result<Message> {
        let msg = read_frame(&mut self.stream)?;
        // Downlink accounting: broadcast/shutdown frames plus the length
        // prefix, mirroring `send`'s uplink accounting.
        self.counter.add_down(msg.frame_len() + 4);
        Ok(msg)
    }

    fn id(&self) -> u32 {
        self.id
    }
}

/// TCP server endpoint (all workers registered).
pub struct TcpServerEnd {
    streams: Vec<TcpStream>,
    counter: Arc<ByteCounter>,
    /// Arrival-ordered frame source: one reader thread per worker socket
    /// pushing into a bounded channel. Spawned lazily on the first
    /// streaming gather; once active, *all* receives go through it (the
    /// reader threads own the read halves from then on).
    readers: Option<Receiver<anyhow::Result<Message>>>,
    /// Per-worker queue bound for async broadcasts (`--pipeline-depth`).
    pipeline_depth: usize,
    /// Per-worker downlink writer threads ([`WriterPool`]), mirroring
    /// `readers`: spawned lazily on the first `broadcast_async`, and
    /// from then on *all* broadcasts route through them (the writer
    /// threads own the write halves, so per-worker frame order stays
    /// total). Dropping this end joins them after their queues drain, so
    /// a queued trailing `Shutdown` frame is flushed before the sockets
    /// close.
    writers: Option<WriterPool>,
}

impl TcpServerEnd {
    pub fn counter(&self) -> Arc<ByteCounter> {
        Arc::clone(&self.counter)
    }

    /// Spawn the downlink [`WriterPool`] over dup'd write halves
    /// (idempotent), the mirror image of [`Self::start_readers`]: the
    /// delivery step writes the frame and counts its wire bytes when the
    /// write completes — identical totals to the synchronous loop.
    fn start_writers(&mut self) -> anyhow::Result<()> {
        if self.writers.is_some() {
            return Ok(());
        }
        // Clone every write half up front so a dup failure spawns nothing.
        let mut write_halves = Vec::with_capacity(self.streams.len());
        for s in &self.streams {
            write_halves.push(s.try_clone()?);
        }
        let counter = Arc::clone(&self.counter);
        let pool = WriterPool::spawn(
            "dqgan-tcp-writer",
            write_halves,
            self.pipeline_depth,
            move |_w, half: &mut TcpStream, msg: &Message| {
                let n = write_frame(half, msg)?;
                counter.add_down(n);
                Ok(())
            },
        )?;
        self.writers = Some(pool);
        Ok(())
    }

    /// Spawn one detached reader thread per worker socket (idempotent).
    ///
    /// Each reader loops `read_frame` on a dup'd handle of its socket and
    /// pushes results into a bounded channel (capacity 2·M: one in-flight
    /// frame per worker plus next-round read-ahead; a full channel blocks
    /// the reader, which is exactly the backpressure we want). A read
    /// error is forwarded once, then the thread exits; threads also exit
    /// when the channel's receiver (this struct) is dropped and their next
    /// send fails. Threads are detached rather than joined: a reader may
    /// be parked in a blocking read on a still-open socket at teardown,
    /// and it unblocks only when the peer closes.
    fn start_readers(&mut self) -> anyhow::Result<()> {
        if self.readers.is_some() {
            return Ok(());
        }
        // Clone every read half up front so a dup failure spawns nothing.
        let mut read_halves = Vec::with_capacity(self.streams.len());
        for s in &self.streams {
            read_halves.push(s.try_clone()?);
        }
        let (tx, rx) = sync_channel::<anyhow::Result<Message>>(2 * self.streams.len());
        // Install the channel *before* spawning: if a spawn fails partway,
        // the already-running readers own their sockets and every later
        // receive goes through the channel — never a direct read racing an
        // orphan reader on the same fd. (The caller propagates the error,
        // the endpoint is dropped, and the orphans exit on their next
        // send.)
        self.readers = Some(rx);
        for (i, mut read_half) in read_halves.into_iter().enumerate() {
            let tx = tx.clone();
            std::thread::Builder::new()
                .name(format!("dqgan-tcp-reader-{i}"))
                .spawn(move || loop {
                    let res = read_frame(&mut read_half);
                    let failed = res.is_err();
                    if tx.send(res).is_err() || failed {
                        break;
                    }
                })
                .map_err(|e| anyhow::anyhow!("spawn tcp reader {i}: {e}"))?;
        }
        Ok(())
    }

    /// Pop the next arrived frame off the reader channel.
    fn next_arrival(&mut self) -> anyhow::Result<Message> {
        let rx = self.readers.as_ref().expect("readers started");
        let msg = rx.recv().map_err(|_| anyhow::anyhow!("all tcp reader threads exited"))??;
        self.counter.add_up(msg.frame_len() + 4);
        Ok(msg)
    }
}

impl ServerEnd for TcpServerEnd {
    fn recv_round(&mut self) -> anyhow::Result<Vec<Message>> {
        let m = self.streams.len();
        let mut msgs = Vec::with_capacity(m);
        if self.readers.is_some() {
            // Streaming readers own the read halves: gather through the
            // arrival channel, then restore worker-id order.
            let mut arrivals = ArrivalSet::new(m);
            for _ in 0..m {
                let msg = self.next_arrival()?;
                arrivals.admit(&msg)?;
                msgs.push(msg);
            }
        } else {
            for s in &mut self.streams {
                let msg = read_frame(s)?;
                if msg.kind == MsgKind::WorkerError {
                    // Fail before reading the remaining sockets — the
                    // erroring worker's peers may not send this round.
                    validate_round_batch(std::slice::from_ref(&msg))?;
                }
                self.counter.add_up(msg.frame_len() + 4);
                msgs.push(msg);
            }
        }
        msgs.sort_by_key(|m| m.worker);
        validate_round_batch(&msgs)?;
        Ok(msgs)
    }

    fn recv_round_streaming(
        &mut self,
        on_msg: &mut dyn FnMut(Message) -> anyhow::Result<()>,
    ) -> anyhow::Result<()> {
        // Arrival-order gather: no fixed-id read order, so one straggler
        // can no longer block payloads already sitting in other sockets,
        // and a WorkerError frame aborts the barrier the moment it lands
        // regardless of which worker sent it.
        self.start_readers()?;
        let m = self.streams.len();
        let mut arrivals = ArrivalSet::new(m);
        for _ in 0..m {
            let msg = self.next_arrival()?;
            arrivals.admit(&msg)?;
            on_msg(msg)?;
        }
        Ok(())
    }

    fn recv_round_streaming_timed(
        &mut self,
        on_msg: &mut dyn FnMut(Message) -> anyhow::Result<StreamDirective>,
    ) -> anyhow::Result<StreamOutcome> {
        // Same arrival channel as the untimed gather (reader threads own
        // the read halves), but the callback owns all round bookkeeping
        // and its directive bounds the wait for the next frame.
        self.start_readers()?;
        let rx = self.readers.as_ref().expect("readers started");
        let counter = &self.counter;
        super::drive_timed_stream(
            &mut |deadline| {
                let msg = match deadline {
                    None => rx
                        .recv()
                        .map_err(|_| anyhow::anyhow!("all tcp reader threads exited"))??,
                    Some(dl) => {
                        let left = dl.saturating_duration_since(Instant::now());
                        match rx.recv_timeout(left) {
                            Ok(res) => res?,
                            Err(RecvTimeoutError::Timeout) => return Ok(None),
                            Err(RecvTimeoutError::Disconnected) => {
                                anyhow::bail!("all tcp reader threads exited")
                            }
                        }
                    }
                };
                counter.add_up(msg.frame_len() + 4);
                Ok(Some(msg))
            },
            on_msg,
        )
    }

    fn broadcast(&mut self, msg: Message) -> anyhow::Result<()> {
        if self.writers.is_some() {
            // Writer threads own the write halves: route through their
            // FIFOs (preserving per-worker frame order) and block until
            // every write is out — the synchronous contract.
            return self.broadcast_async(msg)?.wait();
        }
        for s in &mut self.streams {
            let n = write_frame(s, &msg)?;
            self.counter.add_down(n);
        }
        Ok(())
    }

    fn broadcast_async(&mut self, msg: Message) -> anyhow::Result<BroadcastHandle> {
        self.start_writers()?;
        self.writers.as_ref().expect("writers started").enqueue(msg)
    }

    fn set_pipeline_depth(&mut self, depth: usize) {
        if self.writers.is_none() {
            self.pipeline_depth = depth.max(1);
        }
    }

    fn workers(&self) -> usize {
        self.streams.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcp_round_trip() {
        let m = 3;
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let workers: Vec<_> = (0..m as u32)
            .map(|id| {
                std::thread::spawn(move || {
                    let mut w = TcpWorkerEnd::connect(&addr.to_string(), id).unwrap();
                    w.send(Message::payload(id, 0, vec![id as u8; 16])).unwrap();
                    let b = w.recv().unwrap();
                    assert_eq!(b.kind, MsgKind::Broadcast);
                    assert_eq!(b.payload, vec![7, 7]);
                    let s = w.recv().unwrap();
                    assert_eq!(s.kind, MsgKind::Shutdown);
                    w.counter().down_total()
                })
            })
            .collect();
        let mut server = builder.accept(m).unwrap();
        let msgs = server.recv_round().unwrap();
        assert_eq!(msgs.len(), m);
        assert_eq!(msgs[1].payload, vec![1u8; 16]);
        server.broadcast(Message::broadcast(0, vec![7, 7])).unwrap();
        server.broadcast(Message::shutdown(1)).unwrap();
        // Worker-side downlink telemetry: exactly the broadcast + shutdown
        // frames (each with its 4-byte length prefix) — regression for the
        // counter that used to stay at 0.
        let expected_down = (Message::broadcast(0, vec![7, 7]).frame_len()
            + Message::shutdown(1).frame_len()
            + 8) as u64;
        for w in workers {
            assert_eq!(w.join().unwrap(), expected_down);
        }
        assert!(server.counter().up_total() > 0);
    }

    #[test]
    fn tcp_streaming_round_trip() {
        // Round 0 gathers via the streaming (arrival-order) path, round 1
        // via the classic barrier — proving both coexist once the reader
        // threads own the sockets.
        let m = 3;
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let workers: Vec<_> = (0..m as u32)
            .map(|id| {
                std::thread::spawn(move || {
                    let mut w = TcpWorkerEnd::connect(&addr.to_string(), id).unwrap();
                    for round in 0..2u64 {
                        w.send(Message::payload(id, round, vec![id as u8; 8])).unwrap();
                        let b = w.recv().unwrap();
                        assert_eq!(b.kind, MsgKind::Broadcast);
                        assert_eq!(b.round, round);
                    }
                    let s = w.recv().unwrap();
                    assert_eq!(s.kind, MsgKind::Shutdown);
                })
            })
            .collect();
        let mut server = builder.accept(m).unwrap();
        let mut seen = Vec::new();
        server
            .recv_round_streaming(&mut |msg| {
                assert_eq!(msg.round, 0);
                seen.push(msg.worker);
                Ok(())
            })
            .unwrap();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2]);
        server.broadcast(Message::broadcast(0, vec![1])).unwrap();
        let msgs = server.recv_round().unwrap();
        assert_eq!(msgs.len(), m);
        assert!(msgs.windows(2).all(|w| w[0].worker < w[1].worker), "sorted by id");
        assert!(msgs.iter().all(|m| m.round == 1));
        server.broadcast(Message::broadcast(1, vec![2])).unwrap();
        server.broadcast(Message::shutdown(2)).unwrap();
        for w in workers {
            w.join().unwrap();
        }
    }

    #[test]
    fn tcp_timed_streaming_closes_early_and_honors_deadlines() {
        let m = 2;
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let workers: Vec<_> = (0..m as u32)
            .map(|id| {
                std::thread::spawn(move || {
                    let mut w = TcpWorkerEnd::connect(&addr.to_string(), id).unwrap();
                    if id == 0 {
                        // Worker 0 contributes the single frame of each
                        // gather; worker 1 stays silent throughout.
                        w.send(Message::payload(0, 0, vec![7])).unwrap();
                        w.send(Message::payload(0, 1, vec![8])).unwrap();
                    }
                    // Hold the connection open until the server is done
                    // (recv unblocks with an error when it drops).
                    let _ = w.recv();
                })
            })
            .collect();
        let mut server = builder.accept(m).unwrap();
        // Gather 1: close on the first frame — no waiting on worker 1.
        let mut seen = Vec::new();
        let outcome = server
            .recv_round_streaming_timed(&mut |msg| {
                seen.push((msg.worker, msg.round));
                Ok(StreamDirective::Close)
            })
            .unwrap();
        assert_eq!(outcome, StreamOutcome::Closed);
        assert_eq!(seen, vec![(0, 0)]);
        // Gather 2: arm a short grace after worker 0's frame; worker 1
        // never sends, so the deadline must expire.
        let outcome = server
            .recv_round_streaming_timed(&mut |msg| {
                assert_eq!((msg.worker, msg.round), (0, 1));
                Ok(StreamDirective::WaitUntil(
                    Instant::now() + std::time::Duration::from_millis(20),
                ))
            })
            .unwrap();
        assert_eq!(outcome, StreamOutcome::DeadlineExpired);
        drop(server); // unblocks the workers' trailing recv
        for w in workers {
            w.join().unwrap();
        }
    }

    #[test]
    fn async_broadcast_preserves_per_worker_frame_order_and_byte_accounting() {
        // Writer-thread regressions: frames queued with broadcast_async
        // (plus a trailing synchronous broadcast routed through the same
        // queues) arrive at every worker in exactly enqueue order, and
        // the server's downlink counter equals the frame_len + prefix
        // sums once every handle reports delivery.
        let m = 2;
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let frames: Vec<Message> =
            (0..5u64).map(|r| Message::broadcast(r, vec![r as u8; 6])).collect();
        let expected = frames.clone();
        let workers: Vec<_> = (0..m as u32)
            .map(|id| {
                let expected = expected.clone();
                std::thread::spawn(move || {
                    let mut w = TcpWorkerEnd::connect(&addr.to_string(), id).unwrap();
                    for f in &expected {
                        assert_eq!(&w.recv().unwrap(), f, "worker {id} frame order");
                    }
                    assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
                    w.counter().down_total()
                })
            })
            .collect();
        let mut server = builder.accept(m).unwrap();
        let mut handles = Vec::new();
        for f in &frames {
            handles.push(server.broadcast_async(f.clone()).unwrap());
        }
        server.broadcast(Message::shutdown(5)).unwrap();
        for h in &handles {
            h.wait().unwrap();
        }
        let per_worker: u64 = frames
            .iter()
            .map(|f| (f.frame_len() + 4) as u64)
            .chain(std::iter::once((Message::shutdown(5).frame_len() + 4) as u64))
            .sum();
        assert_eq!(server.counter().down_total(), per_worker * m as u64);
        for w in workers {
            assert_eq!(w.join().unwrap(), per_worker, "worker-side downlink accounting");
        }
    }

    #[test]
    fn dropping_the_server_drains_queued_async_broadcasts() {
        // Clean-shutdown regression: broadcasts queued via
        // broadcast_async — including the final Shutdown — must reach
        // the workers even when the server end is dropped immediately,
        // without waiting on any handle (Drop joins the writers after
        // their queues drain).
        let m = 2;
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let workers: Vec<_> = (0..m as u32)
            .map(|id| {
                std::thread::spawn(move || {
                    let mut w = TcpWorkerEnd::connect(&addr.to_string(), id).unwrap();
                    let b = w.recv().unwrap();
                    assert_eq!(b.kind, MsgKind::Broadcast);
                    assert_eq!(b.payload, vec![7; 3]);
                    assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
                })
            })
            .collect();
        let mut server = builder.accept(m).unwrap();
        server.broadcast_async(Message::broadcast(0, vec![7; 3])).unwrap();
        server.broadcast_async(Message::shutdown(1)).unwrap();
        drop(server);
        for w in workers {
            w.join().unwrap();
        }
    }

    #[test]
    fn gated_tcp_worker_send_blocks_until_released() {
        // The DelayPlan contract now holds on TCP worker ends too: a
        // held uplink gate keeps the payload off the wire.
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let plan = DelayPlan::new();
        plan.hold(1, 0);
        let plans: Vec<_> = (0..2u32).map(|_| plan.clone()).collect();
        let workers: Vec<_> = plans
            .into_iter()
            .enumerate()
            .map(|(id, plan)| {
                std::thread::spawn(move || {
                    let mut w = TcpWorkerEnd::connect_with_plan(
                        &addr.to_string(),
                        id as u32,
                        Some(plan),
                    )
                    .unwrap();
                    w.send(Message::payload(id as u32, 0, vec![id as u8])).unwrap();
                    let _ = w.recv();
                })
            })
            .collect();
        let mut server = builder.accept(2).unwrap();
        let mut seen = Vec::new();
        server
            .recv_round_streaming(&mut |msg| {
                if seen.is_empty() {
                    // Worker 0's frame arrived while worker 1's uplink
                    // gate is provably still held.
                    assert_eq!(msg.worker, 0);
                    assert!(plan.is_held(1, 0));
                    plan.release(1, 0);
                }
                seen.push(msg.worker);
                Ok(())
            })
            .unwrap();
        assert_eq!(seen, vec![0, 1]);
        server.broadcast(Message::shutdown(0)).unwrap();
        for w in workers {
            w.join().unwrap();
        }
    }

    #[test]
    fn rejects_duplicate_ids() {
        // Deterministic: the worker thread holds both connections open
        // until `accept` has returned, so the server always reads both
        // registration frames (no sleep, no slow-runner flake).
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
        let w = std::thread::spawn(move || {
            let _a = TcpWorkerEnd::connect(&addr.to_string(), 0).unwrap();
            let _b = TcpWorkerEnd::connect(&addr.to_string(), 0);
            // Keep the connections open until accept has failed.
            let _ = done_rx.recv();
        });
        let res = builder.accept(2);
        assert!(res.is_err(), "duplicate registration must fail accept");
        done_tx.send(()).unwrap();
        w.join().unwrap();
    }

    #[test]
    fn rejects_out_of_range_id() {
        let builder = TcpServerBuilder::listen("127.0.0.1:0").unwrap();
        let addr = builder.addr();
        let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
        let w = std::thread::spawn(move || {
            let _a = TcpWorkerEnd::connect(&addr.to_string(), 9).unwrap();
            let _ = done_rx.recv();
        });
        let res = builder.accept(2);
        assert!(res.is_err());
        done_tx.send(()).unwrap();
        w.join().unwrap();
    }
}
