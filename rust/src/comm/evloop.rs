//! Readiness-loop building blocks: a raw-`poll(2)` shim, a pipe-pair
//! waker, the nonblocking outbound ring buffer, and the ack ledger for
//! applied-broadcast flow control.
//!
//! One leader thread drives *all* worker connections (see
//! `comm/tcp.rs::TcpEvloopServerEnd`): sockets are nonblocking, `poll`
//! reports which are readable/writable, reads feed the incremental
//! [`FrameAssembler`](super::message::FrameAssembler) and writes drain
//! per-worker [`OutRing`]s. That replaces the two-threads-per-worker
//! armies (uplink readers + downlink writers) with O(1) leader threads
//! in M — the property that makes M ≈ 4096 workable at all.
//!
//! The shim is deliberately tiny and dependency-free: the `libc` crate
//! is not in the build (docs/adr/003-readiness-loop-shim.md — the same
//! no-new-deps stance ADR-002 took for JSON), so `poll` and its
//! `pollfd` struct are declared directly against the platform C ABI.

use super::message::Message;
use super::PendingDelivery;
use std::collections::VecDeque;
use std::io::{Read, Write};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// `struct pollfd` from `<poll.h>` (identical layout on every unix libc).
#[repr(C)]
#[derive(Clone, Copy)]
pub(crate) struct PollFd {
    pub(crate) fd: std::os::raw::c_int,
    pub(crate) events: i16,
    pub(crate) revents: i16,
}

pub(crate) const POLLIN: i16 = 0x001;
pub(crate) const POLLOUT: i16 = 0x004;
pub(crate) const POLLERR: i16 = 0x008;
pub(crate) const POLLHUP: i16 = 0x010;

#[cfg(unix)]
extern "C" {
    fn poll(fds: *mut PollFd, nfds: std::ffi::c_ulong, timeout: std::os::raw::c_int) -> i32;
}

/// Block until at least one fd in `fds` is ready (or `timeout_ms`
/// passes; -1 blocks indefinitely). Retries on EINTR; `revents` fields
/// are filled in place. Returns the number of ready fds.
#[cfg(unix)]
pub(crate) fn poll_ready(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
    loop {
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as std::ffi::c_ulong, timeout_ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = std::io::Error::last_os_error();
        if err.kind() != std::io::ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

/// Cross-thread wakeup for a thread parked in [`poll_ready`]: a
/// socketpair where the read end sits in the poll set and [`Waker::wake`]
/// makes it readable (the classic self-pipe trick, over
/// `UnixStream::pair` so no raw `pipe(2)` FFI is needed).
#[cfg(unix)]
pub(crate) struct Waker {
    tx: std::os::unix::net::UnixStream,
}

#[cfg(unix)]
impl Waker {
    /// Returns the wake handle and the nonblocking read end to register
    /// with the poll set.
    pub(crate) fn pair() -> std::io::Result<(Self, std::os::unix::net::UnixStream)> {
        let (tx, rx) = std::os::unix::net::UnixStream::pair()?;
        tx.set_nonblocking(true)?;
        rx.set_nonblocking(true)?;
        Ok((Self { tx }, rx))
    }

    /// Make the read end readable. Idempotent while a wake is pending
    /// (a full pipe means the loop is already due to wake) and silent
    /// once the loop has exited (broken pipe).
    pub(crate) fn wake(&self) {
        let _ = (&self.tx).write(&[1u8]);
    }
}

/// Drain every pending wake byte (called by the loop when the waker's
/// read end polls readable).
#[cfg(unix)]
pub(crate) fn drain_waker(rx: &mut std::os::unix::net::UnixStream) {
    let mut buf = [0u8; 64];
    while matches!(rx.read(&mut buf), Ok(n) if n > 0) {}
}

/// Per-connection outbound ring: queued wire frames plus a cursor into
/// the front frame, so a partial write (short `write`/`WouldBlock` on a
/// full socket buffer) resumes exactly where it stopped. Frames are
/// shared (`Arc`) across the per-worker rings — one encode per
/// broadcast, M rings referencing it.
#[derive(Default)]
pub(crate) struct OutRing {
    queue: VecDeque<(Arc<Vec<u8>>, PendingDelivery)>,
    /// Bytes of the front frame already written.
    cursor: usize,
}

impl OutRing {
    pub(crate) fn push(&mut self, wire: Arc<Vec<u8>>, pd: PendingDelivery) {
        self.queue.push_back((wire, pd));
        crate::obs::metrics::EVLOOP_OUTRING_DEPTH.set(self.queue.len() as u64);
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Write as much queued data as `sink` accepts right now.
    /// `on_frame(wire_len)` fires once per *fully written* frame (the
    /// byte-accounting hook — identical timing to the threaded writer,
    /// which counted on `write_frame` completion). `WouldBlock` is a
    /// clean stop (re-armed via write-interest); every other error is
    /// returned to the caller, which fails the connection.
    pub(crate) fn pump<W: Write>(
        &mut self,
        sink: &mut W,
        mut on_frame: impl FnMut(usize),
    ) -> std::io::Result<()> {
        // A nonzero cursor at entry means the previous pump stopped
        // mid-frame (short write / WouldBlock) and we are resuming it.
        if self.cursor > 0 {
            crate::obs::metrics::EVLOOP_PARTIAL_WRITES_RESUMED.inc();
        }
        while let Some((wire, _)) = self.queue.front() {
            let remaining = &wire[self.cursor..];
            match sink.write(remaining) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WriteZero,
                        "socket accepted zero bytes",
                    ))
                }
                Ok(n) => {
                    self.cursor += n;
                    if self.cursor == wire.len() {
                        let (wire, pd) = self.queue.pop_front().expect("front exists");
                        self.cursor = 0;
                        on_frame(wire.len());
                        pd.delivered();
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(()),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    /// Fail every queued delivery (sticky connection failure): the
    /// handles complete with `what` instead of hanging.
    pub(crate) fn fail_all(&mut self, what: &str) {
        self.cursor = 0;
        for (_, pd) in self.queue.drain(..) {
            pd.failed(what);
        }
    }

    /// Reclaim every parked frame of an **evicted** worker: the queued
    /// deliveries complete their broadcast handles without error (the
    /// worker is outside the quorum now, so the broadcast is satisfied
    /// over the survivors), and the ring empties so its bytes never
    /// count as wire traffic. Returns the number of frames reclaimed.
    pub(crate) fn skip_all(&mut self) -> usize {
        self.cursor = 0;
        let n = self.queue.len();
        for (_, pd) in self.queue.drain(..) {
            pd.skipped();
        }
        n
    }
}

/// Applied-broadcast flow control: one inflight count per worker,
/// incremented when a broadcast is queued for that worker and
/// decremented when its [`MsgKind::Ack`](super::MsgKind::Ack) frame
/// arrives. `--pipeline-depth` thereby bounds broadcasts a worker has
/// *received-but-not-applied* — the quantity the Lemma-1 staleness bound
/// constrains — rather than merely the frames written into its socket,
/// which a deep kernel buffer would happily absorb.
pub(crate) struct AckLedger {
    state: Mutex<LedgerState>,
    cv: Condvar,
}

struct LedgerState {
    inflight: Vec<usize>,
    dead: Vec<bool>,
}

impl AckLedger {
    /// Upper bound a depth-charge will wait for acks before erroring —
    /// a worker that stopped acking becomes a loud failure, not a hang.
    pub(crate) const MAX_WAIT: Duration = Duration::from_secs(30);

    pub(crate) fn new(workers: usize) -> Arc<Self> {
        Arc::new(Self {
            state: Mutex::new(LedgerState {
                inflight: vec![0; workers],
                dead: vec![false; workers],
            }),
            cv: Condvar::new(),
        })
    }

    /// Charge one queued broadcast to every live worker if *all* of them
    /// are under `depth`; returns whether the charge was taken.
    pub(crate) fn try_charge(&self, depth: usize) -> bool {
        let mut st = self.state.lock().unwrap();
        if Self::over(&st, depth).is_some() {
            return false;
        }
        for (n, dead) in st.inflight.iter_mut().zip(&st.dead) {
            if !dead {
                *n += 1;
            }
        }
        Self::note_inflight(&st);
        true
    }

    /// Blocking [`Self::try_charge`]: waits (bounded by
    /// [`Self::MAX_WAIT`]) for acks to bring every live worker under
    /// `depth`. Only safe when acks are consumed by *another* thread
    /// (the TCP readiness loop); the in-process leader pops its own
    /// uplink channel instead, so it loops `try_charge` by hand.
    pub(crate) fn charge(&self, depth: usize) -> anyhow::Result<()> {
        let start = Instant::now();
        let mut st = self.state.lock().unwrap();
        while let Some(w) = Self::over(&st, depth) {
            let elapsed = start.elapsed();
            if elapsed >= Self::MAX_WAIT {
                anyhow::bail!(
                    "pipeline-depth backpressure stalled: worker {w} has {} unapplied \
                     broadcasts (depth {depth}) after {:?} — worker stopped acking?",
                    st.inflight[w],
                    Self::MAX_WAIT
                );
            }
            let (guard, _) = self.cv.wait_timeout(st, Self::MAX_WAIT - elapsed).unwrap();
            st = guard;
        }
        for (n, dead) in st.inflight.iter_mut().zip(&st.dead) {
            if !dead {
                *n += 1;
            }
        }
        Self::note_inflight(&st);
        Ok(())
    }

    /// A worker acked (applied) one broadcast.
    pub(crate) fn on_ack(&self, worker: u32) {
        let mut st = self.state.lock().unwrap();
        if let Some(n) = st.inflight.get_mut(worker as usize) {
            *n = n.saturating_sub(1);
        }
        Self::note_inflight(&st);
        drop(st);
        self.cv.notify_all();
    }

    /// Stop charging (and waiting on) a failed worker.
    pub(crate) fn mark_dead(&self, worker: u32) {
        let mut st = self.state.lock().unwrap();
        if let Some(d) = st.dead.get_mut(worker as usize) {
            *d = true;
        }
        drop(st);
        self.cv.notify_all();
    }

    /// Re-admit a rejoined worker: clear its dead mark and zero its
    /// inflight count (its replayed broadcasts are charged afresh as
    /// they are acked — the ledger restarts clean for it).
    pub(crate) fn mark_alive(&self, worker: u32) {
        let mut st = self.state.lock().unwrap();
        let w = worker as usize;
        if let Some(d) = st.dead.get_mut(w) {
            *d = false;
        }
        if let Some(n) = st.inflight.get_mut(w) {
            *n = 0;
        }
        Self::note_inflight(&st);
        drop(st);
        self.cv.notify_all();
    }

    /// Eviction-mode variant of [`Self::charge`]: waits (bounded by
    /// `max_wait`) like the blocking charge, but a stall is not fatal —
    /// every live worker still at or over `depth` when the wait expires
    /// is marked dead and reported back, so the caller can evict it
    /// (reclaim its frames, synthesize its `Gone`) and the pipeline
    /// keeps moving over the survivors. The charge is then taken
    /// against the remaining live workers. Callers pass
    /// [`Self::MAX_WAIT`]; tests shrink the bound.
    pub(crate) fn charge_evicting(&self, depth: usize, max_wait: Duration) -> Vec<u32> {
        let start = Instant::now();
        let mut st = self.state.lock().unwrap();
        let mut stalled = Vec::new();
        while Self::over(&st, depth).is_some() {
            let elapsed = start.elapsed();
            if elapsed >= max_wait {
                for w in 0..st.inflight.len() {
                    if !st.dead[w] && st.inflight[w] >= depth {
                        st.dead[w] = true;
                        stalled.push(w as u32);
                    }
                }
                break;
            }
            let (guard, _) = self.cv.wait_timeout(st, max_wait - elapsed).unwrap();
            st = guard;
        }
        for (n, dead) in st.inflight.iter_mut().zip(&st.dead) {
            if !dead {
                *n += 1;
            }
        }
        Self::note_inflight(&st);
        drop(st);
        if !stalled.is_empty() {
            self.cv.notify_all();
        }
        stalled
    }

    /// Unapplied-broadcast count for `worker` (structural test hook).
    pub(crate) fn inflight(&self, worker: u32) -> usize {
        self.state.lock().unwrap().inflight[worker as usize]
    }

    /// Publish the max live-worker inflight depth to the obs gauge
    /// (current value; the gauge's high-water mark keeps the peak).
    fn note_inflight(st: &LedgerState) {
        if !crate::obs::metrics_enabled() {
            return;
        }
        let peak = st
            .inflight
            .iter()
            .zip(&st.dead)
            .filter(|&(_, &dead)| !dead)
            .map(|(&n, _)| n as u64)
            .max()
            .unwrap_or(0);
        crate::obs::metrics::ACK_INFLIGHT.set(peak);
    }

    /// First live worker at or over `depth`, if any.
    fn over(st: &LedgerState, depth: usize) -> Option<usize> {
        st.inflight
            .iter()
            .zip(&st.dead)
            .position(|(&n, &dead)| !dead && n >= depth)
    }
}

/// Build the wire bytes of one frame under the TCP framing
/// (`[frame_len:u32 LE][frame]`) — the unit an [`OutRing`] queues.
pub(crate) fn wire_frame(msg: &Message) -> Vec<u8> {
    let frame = msg.encode();
    let mut wire = Vec::with_capacity(4 + frame.len());
    wire.extend_from_slice(&(frame.len() as u32).to_le_bytes());
    wire.extend_from_slice(&frame);
    wire
}

#[cfg(test)]
mod tests {
    use super::super::message::FrameAssembler;
    use super::super::BroadcastHandle;
    use super::*;

    /// A sink that accepts at most `grant` bytes per call, then reports
    /// `WouldBlock` — a scripted nonblocking socket with a tiny buffer.
    struct TrickleSink {
        accepted: Vec<u8>,
        grant: usize,
        starve: bool,
    }

    impl Write for TrickleSink {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            if self.starve {
                self.starve = false;
                return Err(std::io::ErrorKind::WouldBlock.into());
            }
            let n = buf.len().min(self.grant);
            self.accepted.extend_from_slice(&buf[..n]);
            self.starve = true;
            Ok(n)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn out_ring_partial_writes_reassemble_byte_identically() {
        // Satellite-4 property test, write half: frames leave the ring
        // in 1..=7-byte grants with a WouldBlock between every grant, and
        // the receiving FrameAssembler must reproduce them byte-for-byte
        // with exact per-frame accounting totals.
        let msgs = [
            Message::broadcast(0, (0..23u8).collect()),
            Message::shutdown(1),
            Message::payload(4, 2, vec![0xEE; 41]),
        ];
        for grant in 1..=7usize {
            let mut ring = OutRing::default();
            let handle = BroadcastHandle::new(msgs.len());
            let mut queued = 0usize;
            for m in &msgs {
                let wire = Arc::new(wire_frame(m));
                queued += wire.len();
                ring.push(wire, PendingDelivery::new(handle.clone()));
            }
            let mut sink = TrickleSink { accepted: Vec::new(), grant, starve: false };
            let mut counted = 0usize;
            let mut pumps = 0usize;
            while !ring.is_empty() {
                ring.pump(&mut sink, |n| counted += n).unwrap();
                pumps += 1;
                assert!(pumps < 10_000, "pump must make progress (grant {grant})");
            }
            assert_eq!(counted, queued, "exact counter totals (grant {grant})");
            handle.wait().unwrap();
            // Read half: reassemble from the exact bytes the sink took.
            let mut asm = FrameAssembler::new();
            let mut out = Vec::new();
            for chunk in sink.accepted.chunks(grant) {
                asm.push(chunk, &mut out).unwrap();
            }
            asm.finish().unwrap();
            assert_eq!(out, msgs.to_vec(), "byte-identical reassembly (grant {grant})");
        }
    }

    #[test]
    fn out_ring_fail_all_completes_every_handle() {
        let mut ring = OutRing::default();
        let handle = BroadcastHandle::new(2);
        ring.push(Arc::new(wire_frame(&Message::shutdown(0))), PendingDelivery::new(handle.clone()));
        ring.push(Arc::new(wire_frame(&Message::shutdown(1))), PendingDelivery::new(handle.clone()));
        ring.fail_all("worker 3 socket failed: boom");
        let err = handle.wait().unwrap_err();
        assert!(err.to_string().contains("worker 3"), "{err}");
        assert!(ring.is_empty());
    }

    #[test]
    fn out_ring_surfaces_write_errors() {
        struct FailSink;
        impl Write for FailSink {
            fn write(&mut self, _: &[u8]) -> std::io::Result<usize> {
                Err(std::io::ErrorKind::BrokenPipe.into())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut ring = OutRing::default();
        let handle = BroadcastHandle::new(1);
        ring.push(Arc::new(wire_frame(&Message::shutdown(0))), PendingDelivery::new(handle.clone()));
        let err = ring.pump(&mut FailSink, |_| {}).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::BrokenPipe);
        // The caller fails the connection; the queued delivery is still
        // pending until then.
        ring.fail_all("worker 0 socket failed: broken pipe");
        assert!(handle.wait().is_err());
    }

    #[test]
    fn ack_ledger_bounds_applied_broadcasts() {
        let ledger = AckLedger::new(2);
        assert!(ledger.try_charge(2));
        assert!(ledger.try_charge(2));
        // Both workers now hold 2 unapplied broadcasts: depth reached.
        assert!(!ledger.try_charge(2));
        assert_eq!(ledger.inflight(0), 2);
        ledger.on_ack(0);
        // Worker 1 still at depth — the bound is per-worker, all must clear.
        assert!(!ledger.try_charge(2));
        ledger.on_ack(1);
        assert!(ledger.try_charge(2));
    }

    #[test]
    fn ack_ledger_blocking_charge_wakes_on_ack() {
        let ledger = AckLedger::new(1);
        assert!(ledger.try_charge(1));
        let l2 = Arc::clone(&ledger);
        let t = std::thread::spawn(move || l2.charge(1));
        // The acker lives on another thread — exactly the TCP shape
        // (the readiness loop consumes acks, the leader blocks here).
        ledger.on_ack(0);
        t.join().unwrap().unwrap();
        assert_eq!(ledger.inflight(0), 1);
    }

    #[test]
    fn ack_ledger_skips_dead_workers() {
        let ledger = AckLedger::new(2);
        assert!(ledger.try_charge(1));
        // Worker 1 never acks but dies: it must stop gating the pipeline.
        assert!(!ledger.try_charge(1));
        ledger.mark_dead(1);
        ledger.on_ack(0);
        assert!(ledger.try_charge(1));
        // Dead workers are no longer charged either.
        assert_eq!(ledger.inflight(1), 1);
    }

    #[test]
    fn out_ring_skip_all_satisfies_handles_without_error() {
        // Eviction reclaim: parked frames complete their broadcast
        // handles cleanly (the worker left the quorum; the survivors'
        // broadcast must not fail because of it).
        let mut ring = OutRing::default();
        let handle = BroadcastHandle::new(2);
        ring.push(Arc::new(wire_frame(&Message::shutdown(0))), PendingDelivery::new(handle.clone()));
        ring.push(Arc::new(wire_frame(&Message::shutdown(1))), PendingDelivery::new(handle.clone()));
        assert_eq!(ring.skip_all(), 2);
        assert!(ring.is_empty());
        handle.wait().unwrap();
    }

    #[test]
    fn ack_ledger_mark_alive_readmits_a_dead_worker() {
        let ledger = AckLedger::new(2);
        assert!(ledger.try_charge(1));
        ledger.mark_dead(1);
        ledger.on_ack(0);
        // Dead worker 1 no longer gates or gets charged.
        assert!(ledger.try_charge(1));
        assert_eq!(ledger.inflight(1), 1);
        // Rejoin: alive again with a clean slate, gating resumes.
        ledger.mark_alive(1);
        assert_eq!(ledger.inflight(1), 0);
        ledger.on_ack(0);
        assert!(ledger.try_charge(1));
        assert!(!ledger.try_charge(1), "live again: worker 1 at depth gates the charge");
    }

    #[test]
    fn charge_evicting_marks_stalled_workers_dead_instead_of_failing() {
        let ledger = AckLedger::new(2);
        assert!(ledger.try_charge(1));
        // Worker 1 never acks: the eviction-mode charge must report it
        // (marked dead) and still take the charge for worker 0.
        ledger.on_ack(0);
        let stalled = ledger.charge_evicting(1, Duration::from_millis(20));
        assert_eq!(stalled, vec![1]);
        assert_eq!(ledger.inflight(0), 1, "live worker was charged");
        // Dead worker no longer gates: no wait, no new stalls.
        ledger.on_ack(0);
        assert!(ledger.charge_evicting(1, Duration::from_millis(20)).is_empty());
    }

    #[cfg(unix)]
    #[test]
    fn waker_wakes_a_polling_thread() {
        use std::os::fd::AsRawFd;
        let (waker, mut rx) = Waker::pair().unwrap();
        let fd = rx.as_raw_fd();
        let t = std::thread::spawn(move || {
            let mut fds = [PollFd { fd, events: POLLIN, revents: 0 }];
            let n = poll_ready(&mut fds, -1).unwrap();
            assert_eq!(n, 1);
            assert!(fds[0].revents & POLLIN != 0);
        });
        waker.wake();
        t.join().unwrap();
        drain_waker(&mut rx);
        // Drained: a zero-timeout poll reports nothing ready.
        let mut fds = [PollFd { fd: rx.as_raw_fd(), events: POLLIN, revents: 0 }];
        assert_eq!(poll_ready(&mut fds, 0).unwrap(), 0);
    }
}
