//! In-process transport: mpsc channels between the leader thread and the
//! worker threads. This is the default transport for experiments — zero
//! copies beyond the payload Vec, byte counters still track the *wire*
//! frame sizes so accounting matches the TCP path exactly.

use super::delay::DelayPlan;
use super::evloop::AckLedger;
use super::message::{Message, MsgKind};
use super::{
    validate_round_batch, ArrivalSet, BroadcastHandle, ByteCounter, PendingDelivery, ServerEnd,
    StreamDirective, StreamOutcome, WorkerEnd, WriterPool,
};
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Worker side of the in-process transport.
pub struct InprocWorkerEnd {
    id: u32,
    to_server: Sender<Message>,
    from_server: Receiver<Message>,
    counter: Arc<ByteCounter>,
    /// Straggler-injection schedule (tests/benches only; `None` in
    /// production clusters).
    plan: Option<DelayPlan>,
    /// Whether [`WorkerEnd::ack`] emits an `Ack` control frame up the
    /// shared channel. Enabled by the evloop constructors only: the
    /// threaded [`InprocServerEnd`] has no ack demux, so acks toward it
    /// would corrupt its gathers.
    send_acks: bool,
}

impl WorkerEnd for InprocWorkerEnd {
    fn send(&mut self, msg: Message) -> anyhow::Result<()> {
        // Deterministic straggler injection: a held gate blocks this
        // payload *before* it becomes visible to the leader.
        if msg.kind == MsgKind::Payload {
            if let Some(plan) = &self.plan {
                plan.wait(msg.worker, msg.round);
            }
        }
        self.counter.add_up(msg.frame_len());
        self.to_server.send(msg).map_err(|_| anyhow::anyhow!("server hung up"))
    }

    fn recv(&mut self) -> anyhow::Result<Message> {
        let msg = self.from_server.recv().map_err(|_| anyhow::anyhow!("server hung up"))?;
        Ok(msg)
    }

    fn ack(&mut self, round: u64) -> anyhow::Result<()> {
        if !self.send_acks {
            return Ok(());
        }
        let msg = Message::ack(self.id, round);
        // One shared counter per in-process cluster, so ack frames are
        // counted once, at the sending end — in the ctrl plane, keeping
        // up/down identical to the threaded transport's totals.
        self.counter.add_ctrl(msg.frame_len());
        self.to_server.send(msg).map_err(|_| anyhow::anyhow!("server hung up"))
    }

    fn rejoin(&mut self, resume_round: u64) -> anyhow::Result<()> {
        // Re-registration hello naming the first missed round: the
        // leader un-evicts this id and replays the missed broadcasts.
        // The uplink channel outlives eviction (only the downlink is
        // muted), so the hello rides the normal path. Control plane,
        // like acks.
        let msg = Message::rejoin(self.id, resume_round);
        self.counter.add_ctrl(msg.frame_len());
        self.to_server.send(msg).map_err(|_| anyhow::anyhow!("server hung up"))
    }

    fn id(&self) -> u32 {
        self.id
    }
}

/// Server side of the in-process transport.
pub struct InprocServerEnd {
    from_workers: Receiver<Message>,
    to_workers: Vec<Sender<Message>>,
    counter: Arc<ByteCounter>,
    /// Straggler-injection schedule; the *downlink* gates model a slow
    /// receiver, blocking broadcast deliveries per (worker, round).
    plan: Option<DelayPlan>,
    /// Per-worker queue bound for async broadcasts (`--pipeline-depth`);
    /// effective once the writer threads spawn.
    pipeline_depth: usize,
    /// Per-worker downlink writer threads ([`WriterPool`]). Spawned
    /// lazily on the first `broadcast_async`; once active, *all*
    /// broadcasts route through them (the writers own the downlink order
    /// from then on), and dropping this end joins them after their
    /// queues drain — clean shutdown loses no frame.
    writers: Option<WriterPool>,
}

impl InprocServerEnd {
    /// Spawn the downlink [`WriterPool`] (idempotent): the delivery step
    /// waits out any scripted downlink gate, sends the frame to the
    /// worker's channel, and counts its wire bytes — per-worker frame
    /// order and byte accounting are exactly the synchronous path's, but
    /// one gated/slow worker no longer blocks the leader or its peers.
    fn start_writers(&mut self) -> anyhow::Result<()> {
        if self.writers.is_some() {
            return Ok(());
        }
        let counter = Arc::clone(&self.counter);
        let plan = self.plan.clone();
        let pool = WriterPool::spawn(
            "dqgan-inproc-writer",
            self.to_workers.clone(),
            self.pipeline_depth,
            move |w, down: &mut Sender<Message>, msg: &Message| {
                if let Some(plan) = &plan {
                    plan.wait_down(w as u32, msg.round);
                }
                down.send(msg.clone()).map_err(|_| anyhow::anyhow!("worker hung up"))?;
                counter.add_down(msg.frame_len());
                Ok(())
            },
        )?;
        self.writers = Some(pool);
        Ok(())
    }
}

impl ServerEnd for InprocServerEnd {
    fn recv_round(&mut self) -> anyhow::Result<Vec<Message>> {
        let m = self.to_workers.len();
        let mut msgs = Vec::with_capacity(m);
        for _ in 0..m {
            let msg =
                self.from_workers.recv().map_err(|_| anyhow::anyhow!("workers hung up"))?;
            if msg.kind == MsgKind::WorkerError {
                // Fail before waiting on the rest of the barrier — the
                // erroring worker's peers may be blocked behind it.
                validate_round_batch(std::slice::from_ref(&msg))?;
            }
            msgs.push(msg);
        }
        msgs.sort_by_key(|m| m.worker);
        validate_round_batch(&msgs)?;
        Ok(msgs)
    }

    fn recv_round_streaming(
        &mut self,
        on_msg: &mut dyn FnMut(Message) -> anyhow::Result<()>,
    ) -> anyhow::Result<()> {
        // The shared uplink channel already delivers frames in arrival
        // order, so streaming is the natural read here: hand each frame
        // to the aggregator the moment `recv` returns it.
        let m = self.to_workers.len();
        let mut arrivals = ArrivalSet::new(m);
        for _ in 0..m {
            let msg =
                self.from_workers.recv().map_err(|_| anyhow::anyhow!("workers hung up"))?;
            arrivals.admit(&msg)?;
            on_msg(msg)?;
        }
        Ok(())
    }

    fn recv_round_streaming_timed(
        &mut self,
        on_msg: &mut dyn FnMut(Message) -> anyhow::Result<StreamDirective>,
    ) -> anyhow::Result<StreamOutcome> {
        // Policy-driven gather: frames pop in arrival order off the
        // shared uplink channel; the callback owns all round bookkeeping
        // (see the trait docs) and its directive arms/clears the
        // bounded wait for the next frame.
        let from_workers = &self.from_workers;
        super::drive_timed_stream(
            &mut |deadline| match deadline {
                None => from_workers
                    .recv()
                    .map(Some)
                    .map_err(|_| anyhow::anyhow!("workers hung up")),
                Some(dl) => {
                    let left = dl.saturating_duration_since(Instant::now());
                    match from_workers.recv_timeout(left) {
                        Ok(msg) => Ok(Some(msg)),
                        Err(RecvTimeoutError::Timeout) => Ok(None),
                        Err(RecvTimeoutError::Disconnected) => {
                            anyhow::bail!("workers hung up")
                        }
                    }
                }
            },
            on_msg,
        )
    }

    fn broadcast(&mut self, msg: Message) -> anyhow::Result<()> {
        if self.writers.is_some() {
            // Writer threads own the downlink from the first async
            // broadcast on: route through them (preserving per-worker
            // frame order) and block until every delivery is out —
            // exactly the synchronous contract.
            return self.broadcast_async(msg)?.wait();
        }
        for (w, tx) in self.to_workers.iter().enumerate() {
            // A held downlink gate models a slow receiver: the delivery
            // (and on this synchronous path, the whole round loop)
            // blocks before the frame becomes visible to the worker.
            if let Some(plan) = &self.plan {
                plan.wait_down(w as u32, msg.round);
            }
            self.counter.add_down(msg.frame_len());
            tx.send(msg.clone()).map_err(|_| anyhow::anyhow!("worker hung up"))?;
        }
        Ok(())
    }

    fn broadcast_async(&mut self, msg: Message) -> anyhow::Result<BroadcastHandle> {
        self.start_writers()?;
        self.writers.as_ref().expect("writers started").enqueue(msg)
    }

    fn set_pipeline_depth(&mut self, depth: usize) {
        if self.writers.is_none() {
            self.pipeline_depth = depth.max(1);
        }
    }

    fn workers(&self) -> usize {
        self.to_workers.len()
    }

    fn counter(&self) -> Option<Arc<ByteCounter>> {
        Some(Arc::clone(&self.counter))
    }
}

/// Build an in-process PS cluster with `m` workers. Returns the server
/// end, the worker ends, and the shared byte counter.
pub fn inproc_cluster(m: usize) -> (InprocServerEnd, Vec<InprocWorkerEnd>, Arc<ByteCounter>) {
    build_cluster(m, None)
}

/// [`inproc_cluster`] with a [`DelayPlan`] attached to every worker end:
/// payload sends consult the plan's gate/permit schedule, so tests and
/// benches can script exact arrival orders and holdouts without sleeps.
pub fn inproc_cluster_with_plan(
    m: usize,
    plan: DelayPlan,
) -> (InprocServerEnd, Vec<InprocWorkerEnd>, Arc<ByteCounter>) {
    build_cluster(m, Some(plan))
}

fn build_cluster(
    m: usize,
    plan: Option<DelayPlan>,
) -> (InprocServerEnd, Vec<InprocWorkerEnd>, Arc<ByteCounter>) {
    assert!(m > 0);
    let counter = ByteCounter::new();
    let (up_tx, up_rx) = channel::<Message>();
    let mut worker_ends = Vec::with_capacity(m);
    let mut down_txs = Vec::with_capacity(m);
    for id in 0..m {
        let (down_tx, down_rx) = channel::<Message>();
        down_txs.push(down_tx);
        worker_ends.push(InprocWorkerEnd {
            id: id as u32,
            to_server: up_tx.clone(),
            from_server: down_rx,
            counter: Arc::clone(&counter),
            plan: plan.clone(),
            send_acks: false,
        });
    }
    let server = InprocServerEnd {
        from_workers: up_rx,
        to_workers: down_txs,
        counter: Arc::clone(&counter),
        plan,
        pipeline_depth: 2,
        writers: None,
    };
    (server, worker_ends, counter)
}

/// One event for the in-process delivery thread.
enum Ev {
    /// Deliver `msg` to `worker`, completing `pd` when it lands (or
    /// parking it while the worker's downlink gate is held).
    Deliver { worker: usize, msg: Message, pd: PendingDelivery },
    /// A [`DelayPlan`] gate was released somewhere: re-scan parked
    /// queues. (Sent by the plan's release listener.)
    Poke,
    /// Leader evicted `worker`: reclaim its parked frames (skipped, not
    /// failed) and mute its future data deliveries. Shutdown frames are
    /// still delivered so an evicted worker can exit cleanly.
    Evict(usize),
    /// `worker` rejoined: resume normal deliveries.
    Rejoin(usize),
    /// Targeted frame (rejoin replay / directed shutdown): one worker's
    /// downlink, fire-and-forget — nobody waits on its delivery.
    Send { worker: usize, msg: Message },
    /// Leader dropped: drain parked frames (waiting out their gates),
    /// then exit. Always the leader's last event, so every `Deliver`
    /// queued before it is processed first.
    Shutdown,
}

/// Body of the single `dqgan-inproc-evloop` delivery thread — the
/// in-process analogue of the TCP readiness loop's write side. One
/// thread serves every worker's downlink: a held [`DelayPlan`] downlink
/// gate *parks* that worker's frames (per-worker FIFO) instead of
/// blocking the thread, so a gated worker never head-of-line blocks its
/// peers; the plan's release listener pokes the thread to re-scan.
#[allow(clippy::too_many_arguments)]
fn run_inproc_downlink(
    rx: Receiver<Ev>,
    to_workers: Vec<Sender<Message>>,
    plan: Option<DelayPlan>,
    counter: Arc<ByteCounter>,
    ledger: Arc<AckLedger>,
    first_error: Arc<Mutex<Option<String>>>,
    evict_mode: Arc<std::sync::atomic::AtomicBool>,
    up_tx: Sender<Message>,
) {
    let m = to_workers.len();
    let mut parked: Vec<VecDeque<(Message, PendingDelivery)>> =
        (0..m).map(|_| VecDeque::new()).collect();
    let mut failed: Vec<Option<String>> = (0..m).map(|_| None).collect();
    let deliver_now = |w: usize,
                       msg: Message,
                       pd: PendingDelivery,
                       failed: &mut Vec<Option<String>>,
                       evicted: &mut Vec<bool>| {
        // An evicted worker's data deliveries are skipped (count as
        // satisfied — survivors' handles stay clean); Shutdown still
        // goes through so the worker thread can exit and be joined.
        if evicted[w] && msg.kind != MsgKind::Shutdown {
            pd.skipped();
            return;
        }
        if let Some(what) = &failed[w] {
            pd.failed(what);
            return;
        }
        let n = msg.frame_len();
        if to_workers[w].send(msg).is_err() {
            let what = format!("worker {w} hung up");
            ledger.mark_dead(w as u32);
            if evict_mode.load(std::sync::atomic::Ordering::Relaxed) {
                // Elastic mode: the loss becomes an in-band Gone frame
                // on the uplink (the gather evicts the worker), never a
                // sticky fatal error.
                if !evicted[w] {
                    evicted[w] = true;
                    let _ = up_tx.send(Message::gone(w as u32, 0, &what));
                }
                pd.skipped();
                return;
            }
            // Sticky per-worker failure, naming the worker — the same
            // contract the TCP loop's fail_conn keeps.
            let mut g = first_error.lock().unwrap();
            if g.is_none() {
                *g = Some(what.clone());
            }
            drop(g);
            pd.failed(&what);
            failed[w] = Some(what);
            return;
        }
        counter.add_down(n);
        crate::obs::metrics::EVLOOP_DELIVERIES.inc();
        pd.delivered();
    };
    let held = |w: usize, round: u64| {
        plan.as_ref().is_some_and(|p| p.is_held_down(w as u32, round))
    };
    let mut evicted: Vec<bool> = vec![false; m];
    loop {
        match rx.recv() {
            Ok(Ev::Deliver { worker: w, msg, pd }) => {
                // Per-worker FIFO: anything already parked goes first.
                if !parked[w].is_empty() || held(w, msg.round) {
                    parked[w].push_back((msg, pd));
                    crate::obs::metrics::EVLOOP_PARKED_FRAMES.set(parked[w].len() as u64);
                } else {
                    deliver_now(w, msg, pd, &mut failed, &mut evicted);
                }
            }
            Ok(Ev::Send { worker: w, msg }) => {
                let pd = PendingDelivery::new(BroadcastHandle::new(1));
                if !parked[w].is_empty() || held(w, msg.round) {
                    parked[w].push_back((msg, pd));
                    crate::obs::metrics::EVLOOP_PARKED_FRAMES.set(parked[w].len() as u64);
                } else {
                    deliver_now(w, msg, pd, &mut failed, &mut evicted);
                }
            }
            Ok(Ev::Evict(w)) => {
                evicted[w] = true;
                // Reclaim parked frames: satisfied, never failed — the
                // survivors' broadcast handles must stay clean.
                while let Some((_, pd)) = parked[w].pop_front() {
                    pd.skipped();
                }
                crate::obs::metrics::EVLOOP_PARKED_FRAMES.set(0);
            }
            Ok(Ev::Rejoin(w)) => {
                evicted[w] = false;
            }
            Ok(Ev::Poke) => {
                crate::obs::metrics::EVLOOP_WAKEUPS.inc();
            }
            Ok(Ev::Shutdown) | Err(_) => break,
        }
        // Pump every parked queue whose head gate has opened.
        for w in 0..m {
            while parked[w].front().is_some_and(|(msg, _)| !held(w, msg.round)) {
                let (msg, pd) = parked[w].pop_front().unwrap();
                crate::obs::metrics::EVLOOP_PARKED_FRAMES.set(parked[w].len() as u64);
                deliver_now(w, msg, pd, &mut failed, &mut evicted);
            }
        }
    }
    // Teardown: deliver every still-parked frame, now waiting each gate
    // out on this thread (the plan's bounded blocking wait, so a test
    // that forgets a release still fails loudly) — "drop drains queued
    // broadcasts" holds under gates too.
    for w in 0..m {
        while let Some((msg, pd)) = parked[w].pop_front() {
            if let Some(p) = &plan {
                p.wait_down(w as u32, msg.round);
            }
            deliver_now(w, msg, pd, &mut failed, &mut evicted);
        }
    }
}

/// Server side of the in-process transport, readiness-loop flavor: one
/// eager `dqgan-inproc-evloop` delivery thread replaces the per-worker
/// [`WriterPool`] army, and `--pipeline-depth` bounds *applied* (acked)
/// broadcasts per worker via the shared [`AckLedger`] instead of written
/// ones. The uplink channel carries data frames and `Ack` control frames
/// interleaved; the leader demuxes on pop, so acks never reach a gather.
pub struct InprocEvloopServerEnd {
    from_workers: Receiver<Message>,
    m: usize,
    counter: Arc<ByteCounter>,
    ledger: Arc<AckLedger>,
    /// Data frames popped while draining acks during a charge: the next
    /// gather consumes these before touching the channel again.
    pending: VecDeque<Message>,
    down_tx: Option<Sender<Ev>>,
    first_error: Arc<Mutex<Option<String>>>,
    pipeline_depth: usize,
    /// `--on-worker-loss evict`: worker loss becomes an in-band
    /// [`MsgKind::Gone`] frame and a muted downlink instead of a sticky
    /// fatal error. Shared with the delivery thread.
    evict: Arc<std::sync::atomic::AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl InprocEvloopServerEnd {
    /// Demux one popped uplink frame: acks feed the ledger, data frames
    /// are stashed for the next gather.
    fn stash_or_ack(&mut self, msg: Message) {
        if msg.kind == MsgKind::Ack {
            crate::obs::note_ack(msg.worker as usize, msg.round);
            self.ledger.on_ack(msg.worker);
        } else {
            self.pending.push_back(msg);
        }
    }

    /// Next data frame in arrival order (acks filtered into the ledger).
    fn next_uplink(&mut self) -> anyhow::Result<Message> {
        loop {
            if let Some(msg) = self.pending.pop_front() {
                return Ok(msg);
            }
            let idle_t0 = crate::obs::maybe_now();
            let msg =
                self.from_workers.recv().map_err(|_| anyhow::anyhow!("workers hung up"))?;
            crate::obs::record_elapsed(&crate::obs::metrics::EVLOOP_IDLE_WAIT_NS, idle_t0);
            if msg.kind == MsgKind::Ack {
                crate::obs::note_ack(msg.worker as usize, msg.round);
                self.ledger.on_ack(msg.worker);
                continue;
            }
            return Ok(msg);
        }
    }

    /// Charge one broadcast against every live worker's unapplied count.
    /// Unlike the TCP loop — where a separate thread consumes acks and
    /// the blocking [`AckLedger::charge`] suffices — the in-process
    /// leader owns the uplink channel, so it must pop acks *itself*
    /// while waiting: a blocking charge would deadlock against acks
    /// sitting unread in its own channel.
    fn charge_inproc(&mut self) -> anyhow::Result<()> {
        let start = Instant::now();
        loop {
            if self.ledger.try_charge(self.pipeline_depth) {
                return Ok(());
            }
            if start.elapsed() >= AckLedger::MAX_WAIT {
                if self.evict.load(std::sync::atomic::Ordering::Relaxed) {
                    // Elastic mode (satellite-1 path): evict every
                    // stalled worker instead of killing the run. The
                    // Gone frames surface the loss to the next gather;
                    // survivors are charged and the broadcast proceeds.
                    let stalled =
                        self.ledger.charge_evicting(self.pipeline_depth, Duration::ZERO);
                    let tx =
                        self.down_tx.as_ref().expect("delivery channel alive until drop");
                    for w in stalled {
                        let what = format!(
                            "worker {w} evicted: pipeline stall (depth {}) — worker \
                             stopped acking",
                            self.pipeline_depth
                        );
                        let _ = tx.send(Ev::Evict(w as usize));
                        self.pending.push_back(Message::gone(w, 0, &what));
                    }
                    return Ok(());
                }
                let w = (0..self.m)
                    .find(|&w| self.ledger.inflight(w as u32) >= self.pipeline_depth)
                    .unwrap_or(0);
                anyhow::bail!(
                    "pipeline-depth backpressure stalled: worker {w} has {} unapplied \
                     broadcasts (depth {}) after {:?} — worker stopped acking?",
                    self.ledger.inflight(w as u32),
                    self.pipeline_depth,
                    AckLedger::MAX_WAIT
                );
            }
            match self.from_workers.recv_timeout(Duration::from_millis(100)) {
                Ok(msg) => self.stash_or_ack(msg),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => anyhow::bail!("workers hung up"),
            }
        }
    }
}

impl ServerEnd for InprocEvloopServerEnd {
    fn recv_round(&mut self) -> anyhow::Result<Vec<Message>> {
        let mut msgs = Vec::with_capacity(self.m);
        for _ in 0..self.m {
            let msg = self.next_uplink()?;
            if msg.kind == MsgKind::WorkerError {
                // Fail before waiting on the rest of the barrier — the
                // erroring worker's peers may be blocked behind it.
                validate_round_batch(std::slice::from_ref(&msg))?;
            }
            msgs.push(msg);
        }
        msgs.sort_by_key(|m| m.worker);
        validate_round_batch(&msgs)?;
        Ok(msgs)
    }

    fn recv_round_streaming(
        &mut self,
        on_msg: &mut dyn FnMut(Message) -> anyhow::Result<()>,
    ) -> anyhow::Result<()> {
        let mut arrivals = ArrivalSet::new(self.m);
        for _ in 0..self.m {
            let msg = self.next_uplink()?;
            arrivals.admit(&msg)?;
            on_msg(msg)?;
        }
        Ok(())
    }

    fn recv_round_streaming_timed(
        &mut self,
        on_msg: &mut dyn FnMut(Message) -> anyhow::Result<StreamDirective>,
    ) -> anyhow::Result<StreamOutcome> {
        let pending = &mut self.pending;
        let from_workers = &self.from_workers;
        let ledger = &self.ledger;
        super::drive_timed_stream(
            &mut |deadline| loop {
                if let Some(msg) = pending.pop_front() {
                    return Ok(Some(msg));
                }
                let msg = match deadline {
                    None => from_workers
                        .recv()
                        .map_err(|_| anyhow::anyhow!("workers hung up"))?,
                    Some(dl) => {
                        let left = dl.saturating_duration_since(Instant::now());
                        match from_workers.recv_timeout(left) {
                            Ok(msg) => msg,
                            Err(RecvTimeoutError::Timeout) => return Ok(None),
                            Err(RecvTimeoutError::Disconnected) => {
                                anyhow::bail!("workers hung up")
                            }
                        }
                    }
                };
                if msg.kind == MsgKind::Ack {
                    crate::obs::note_ack(msg.worker as usize, msg.round);
                    ledger.on_ack(msg.worker);
                    continue;
                }
                return Ok(Some(msg));
            },
            on_msg,
        )
    }

    fn broadcast(&mut self, msg: Message) -> anyhow::Result<()> {
        // The delivery thread owns the downlink: queue through it, then
        // wait until every delivery is out — the synchronous contract,
        // with a sticky worker failure surfacing here via the handle.
        self.broadcast_async(msg)?.wait()
    }

    fn broadcast_async(&mut self, msg: Message) -> anyhow::Result<BroadcastHandle> {
        if let Some(e) = self.first_error.lock().unwrap().clone() {
            anyhow::bail!("async broadcast failed: {e}");
        }
        // Applied-broadcast flow control: data broadcasts charge the
        // ledger; Shutdown is control flow and never acked.
        if matches!(msg.kind, MsgKind::Broadcast | MsgKind::PartialBroadcast) {
            self.charge_inproc()?;
        }
        let handle = BroadcastHandle::new(self.m);
        let tx = self.down_tx.as_ref().expect("delivery channel alive until drop");
        for w in 0..self.m {
            tx.send(Ev::Deliver {
                worker: w,
                msg: msg.clone(),
                pd: PendingDelivery::new(handle.clone()),
            })
            .map_err(|_| anyhow::anyhow!("delivery thread exited"))?;
        }
        Ok(handle)
    }

    fn set_pipeline_depth(&mut self, depth: usize) {
        // Charged per-broadcast, so the depth is adjustable at any time.
        self.pipeline_depth = depth.max(1);
    }

    fn workers(&self) -> usize {
        self.m
    }

    fn counter(&self) -> Option<Arc<ByteCounter>> {
        Some(Arc::clone(&self.counter))
    }

    fn set_evict_on_loss(&mut self, on: bool) {
        self.evict.store(on, std::sync::atomic::Ordering::Relaxed);
    }

    fn evict_worker(&mut self, worker: usize) -> anyhow::Result<()> {
        // Ledger release happens here, synchronously: a broadcast issued
        // right after the eviction must not charge the dead worker.
        self.ledger.mark_dead(worker as u32);
        self.down_tx
            .as_ref()
            .expect("delivery channel alive until drop")
            .send(Ev::Evict(worker))
            .map_err(|_| anyhow::anyhow!("delivery thread exited"))
    }

    fn rejoin_worker(&mut self, worker: usize) -> anyhow::Result<()> {
        // Mirror image: readmit to the ledger before any new broadcast
        // charges, then unmute the downlink.
        self.ledger.mark_alive(worker as u32);
        self.down_tx
            .as_ref()
            .expect("delivery channel alive until drop")
            .send(Ev::Rejoin(worker))
            .map_err(|_| anyhow::anyhow!("delivery thread exited"))
    }

    fn send_to(&mut self, worker: usize, msg: &Message) -> anyhow::Result<()> {
        self.down_tx
            .as_ref()
            .expect("delivery channel alive until drop")
            .send(Ev::Send { worker, msg: msg.clone() })
            .map_err(|_| anyhow::anyhow!("delivery thread exited"))
    }
}

impl Drop for InprocEvloopServerEnd {
    fn drop(&mut self) {
        // An explicit Shutdown event (not a channel disconnect: the
        // plan's release listener may hold a sender clone) — the thread
        // processes every Deliver queued before it, then drains parked
        // frames, so a queued trailing Shutdown frame still lands.
        if let Some(tx) = self.down_tx.take() {
            let _ = tx.send(Ev::Shutdown);
        }
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// [`inproc_cluster`], readiness-loop flavor: same worker ends (now
/// acking applied broadcasts), one delivery thread instead of a
/// per-worker writer army, ack-based `--pipeline-depth` flow control.
pub fn inproc_cluster_evloop(
    m: usize,
) -> (InprocEvloopServerEnd, Vec<InprocWorkerEnd>, Arc<ByteCounter>) {
    build_cluster_evloop(m, None)
}

/// [`inproc_cluster_evloop`] with a [`DelayPlan`] attached: uplink gates
/// block payload sends as usual; *downlink* gates park frames inside the
/// delivery thread (no cross-worker head-of-line blocking), and gate
/// releases poke it to re-scan.
pub fn inproc_cluster_evloop_with_plan(
    m: usize,
    plan: DelayPlan,
) -> (InprocEvloopServerEnd, Vec<InprocWorkerEnd>, Arc<ByteCounter>) {
    build_cluster_evloop(m, Some(plan))
}

fn build_cluster_evloop(
    m: usize,
    plan: Option<DelayPlan>,
) -> (InprocEvloopServerEnd, Vec<InprocWorkerEnd>, Arc<ByteCounter>) {
    assert!(m > 0);
    let counter = ByteCounter::new();
    let (up_tx, up_rx) = channel::<Message>();
    let mut worker_ends = Vec::with_capacity(m);
    let mut down_txs = Vec::with_capacity(m);
    for id in 0..m {
        let (down_tx, down_rx) = channel::<Message>();
        down_txs.push(down_tx);
        worker_ends.push(InprocWorkerEnd {
            id: id as u32,
            to_server: up_tx.clone(),
            from_server: down_rx,
            counter: Arc::clone(&counter),
            plan: plan.clone(),
            send_acks: true,
        });
    }
    let ledger = AckLedger::new(m);
    let first_error = Arc::new(Mutex::new(None));
    let (ev_tx, ev_rx) = channel::<Ev>();
    if let Some(p) = &plan {
        // Gate releases poke the delivery thread so parked frames move
        // the moment their gate opens — no polling, no sleeps.
        let tx = ev_tx.clone();
        p.on_release(Box::new(move || {
            let _ = tx.send(Ev::Poke);
        }));
    }
    let evict = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let thread = {
        let counter = Arc::clone(&counter);
        let ledger = Arc::clone(&ledger);
        let first_error = Arc::clone(&first_error);
        let evict = Arc::clone(&evict);
        // The delivery thread holds an uplink sender so elastic-mode
        // losses surface as in-band Gone frames to the gathers.
        let up_tx = up_tx.clone();
        std::thread::Builder::new()
            .name("dqgan-inproc-evloop".into())
            .spawn(move || {
                run_inproc_downlink(
                    ev_rx,
                    down_txs,
                    plan,
                    counter,
                    ledger,
                    first_error,
                    evict,
                    up_tx,
                )
            })
            .expect("spawn dqgan-inproc-evloop")
    };
    let server = InprocEvloopServerEnd {
        from_workers: up_rx,
        m,
        counter: Arc::clone(&counter),
        ledger,
        pending: VecDeque::new(),
        down_tx: Some(ev_tx),
        first_error,
        pipeline_depth: 2,
        evict,
        thread: Some(thread),
    };
    (server, worker_ends, counter)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_with_threads() {
        let (mut server, workers, counter) = inproc_cluster(3);
        let handles: Vec<_> = workers
            .into_iter()
            .map(|mut w| {
                std::thread::spawn(move || {
                    let id = w.id();
                    w.send(Message::payload(id, 0, vec![id as u8; 8])).unwrap();
                    let b = w.recv().unwrap();
                    assert_eq!(b.kind, MsgKind::Broadcast);
                    b.payload[0]
                })
            })
            .collect();
        let msgs = server.recv_round().unwrap();
        assert_eq!(msgs.len(), 3);
        assert_eq!(msgs[0].worker, 0);
        assert_eq!(msgs[2].payload, vec![2u8; 8]);
        server.broadcast(Message::broadcast(0, vec![42])).unwrap();
        for h in handles {
            assert_eq!(h.join().unwrap(), 42);
        }
        assert!(counter.up_total() > 0);
        assert!(counter.down_total() > 0);
    }

    #[test]
    fn worker_error_propagates() {
        let (mut server, mut workers, _) = inproc_cluster(2);
        workers[0].send(Message::payload(0, 0, vec![])).unwrap();
        workers[1].send(Message::worker_error(1, 0, "injected")).unwrap();
        let err = server.recv_round().unwrap_err();
        assert!(err.to_string().contains("injected"), "{err}");
    }

    #[test]
    fn streaming_delivers_in_arrival_order() {
        let (mut server, mut workers, _) = inproc_cluster(3);
        // Send in reverse worker-id order: arrival order must be preserved.
        for id in (0..3u32).rev() {
            workers[id as usize].send(Message::payload(id, 0, vec![id as u8])).unwrap();
        }
        let mut order = Vec::new();
        server
            .recv_round_streaming(&mut |msg| {
                order.push(msg.worker);
                Ok(())
            })
            .unwrap();
        assert_eq!(order, vec![2, 1, 0]);
    }

    #[test]
    fn streaming_fails_fast_on_worker_error() {
        let (mut server, mut workers, _) = inproc_cluster(2);
        workers[1].send(Message::worker_error(1, 0, "injected")).unwrap();
        // Worker 0 never sends: the error frame must abort the barrier
        // without waiting on it.
        let mut count = 0usize;
        let err = server
            .recv_round_streaming(&mut |_| {
                count += 1;
                Ok(())
            })
            .unwrap_err();
        assert!(err.to_string().contains("injected"), "{err}");
        assert_eq!(count, 0, "error frame must not reach the callback");
    }

    #[test]
    fn timed_streaming_closes_on_directive_without_all_frames() {
        let (mut server, mut workers, _) = inproc_cluster(3);
        // Only two of three workers ever send: the Close directive must
        // end the gather without waiting on the third.
        workers[1].send(Message::payload(1, 0, vec![1])).unwrap();
        workers[0].send(Message::payload(0, 0, vec![0])).unwrap();
        let mut seen = Vec::new();
        let outcome = server
            .recv_round_streaming_timed(&mut |msg| {
                seen.push(msg.worker);
                Ok(if seen.len() == 2 {
                    StreamDirective::Close
                } else {
                    StreamDirective::Wait
                })
            })
            .unwrap();
        assert_eq!(outcome, StreamOutcome::Closed);
        assert_eq!(seen, vec![1, 0], "arrival order must be preserved");
    }

    #[test]
    fn timed_streaming_reports_deadline_expiry() {
        let (mut server, mut workers, _) = inproc_cluster(2);
        workers[0].send(Message::payload(0, 0, vec![])).unwrap();
        let mut seen = 0usize;
        let outcome = server
            .recv_round_streaming_timed(&mut |_msg| {
                seen += 1;
                // Arm a short grace window; worker 1 never sends.
                Ok(StreamDirective::WaitUntil(
                    Instant::now() + std::time::Duration::from_millis(20),
                ))
            })
            .unwrap();
        assert_eq!(outcome, StreamOutcome::DeadlineExpired);
        assert_eq!(seen, 1);
    }

    #[test]
    fn delay_plan_gates_payload_sends_deterministically() {
        let plan = DelayPlan::new();
        plan.hold(1, 0);
        let (mut server, workers, _) = inproc_cluster_with_plan(2, plan.clone());
        let handles: Vec<_> = workers
            .into_iter()
            .map(|mut w| {
                std::thread::spawn(move || {
                    let id = w.id();
                    w.send(Message::payload(id, 0, vec![id as u8])).unwrap();
                })
            })
            .collect();
        // Worker 0's frame arrives while worker 1's gate is still held —
        // provable structurally, no sleeps involved.
        let first = server.from_workers.recv().unwrap();
        assert_eq!(first.worker, 0);
        assert!(plan.is_held(1, 0));
        plan.release(1, 0);
        let second = server.from_workers.recv().unwrap();
        assert_eq!(second.worker, 1);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn async_broadcast_preserves_order_and_byte_accounting() {
        let (mut server, workers, counter) = inproc_cluster(2);
        let frames: Vec<Message> =
            (0..4u64).map(|r| Message::broadcast(r, vec![r as u8; 8])).collect();
        let mut handles = Vec::new();
        for f in &frames {
            handles.push(server.broadcast_async(f.clone()).unwrap());
        }
        // A later synchronous broadcast routes through the same writer
        // queues, so cross-path order is preserved too.
        server.broadcast(Message::shutdown(4)).unwrap();
        for h in &handles {
            h.wait().unwrap();
            assert!(h.is_done());
            assert!(h.completed_at().is_some());
        }
        // Exact downlink accounting: every frame counted once per worker.
        let expected: u64 = frames
            .iter()
            .map(|f| f.frame_len() as u64)
            .chain(std::iter::once(Message::shutdown(4).frame_len() as u64))
            .sum::<u64>()
            * 2;
        assert_eq!(counter.down_total(), expected);
        for mut w in workers {
            for f in &frames {
                assert_eq!(&w.recv().unwrap(), f, "per-worker frame order");
            }
            assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
        }
    }

    #[test]
    fn dropping_the_server_drains_queued_async_broadcasts() {
        let (mut server, workers, _) = inproc_cluster(2);
        server.broadcast_async(Message::broadcast(0, vec![5])).unwrap();
        server.broadcast_async(Message::shutdown(1)).unwrap();
        // No waiting: Drop must join the writers after they drain, so
        // neither frame (in particular the Shutdown) is lost.
        drop(server);
        for mut w in workers {
            assert_eq!(w.recv().unwrap().payload, vec![5]);
            assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
        }
    }

    #[test]
    fn downlink_gate_blocks_only_the_gated_workers_writer() {
        // Worker 1's round-0 broadcast delivery is gated; worker 0 must
        // receive it anyway (per-worker writers: no head-of-line
        // blocking across workers), and the handle must stay incomplete
        // until the gate opens.
        let plan = DelayPlan::new();
        plan.hold_down(1, 0);
        let (mut server, mut workers, _) = inproc_cluster_with_plan(2, plan.clone());
        let h = server.broadcast_async(Message::broadcast(0, vec![9])).unwrap();
        let b0 = workers[0].recv().unwrap();
        assert_eq!(b0.payload, vec![9]);
        // Worker 0 has its frame while worker 1's delivery is provably
        // still gate-held — the broadcast is in flight, not done.
        assert!(plan.is_held_down(1, 0));
        assert!(!h.is_done());
        plan.release_down(1, 0);
        h.wait().unwrap();
        assert_eq!(workers[1].recv().unwrap().payload, vec![9]);
    }

    #[test]
    fn sync_broadcast_waits_out_downlink_gates_on_the_leader_thread() {
        // Without writer threads the downlink gate blocks the leader's
        // own broadcast loop — the slow-receiver model the pipelined
        // mode's A/B benchmark compares against.
        let plan = DelayPlan::new();
        plan.hold_down(1, 0);
        let (mut server, mut workers, _) = inproc_cluster_with_plan(2, plan.clone());
        let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
        let t = std::thread::spawn(move || {
            server.broadcast(Message::broadcast(0, vec![1])).unwrap();
            done_tx.send(()).unwrap();
            server
        });
        // Worker 0's delivery precedes the gate (id order), so it lands
        // while the broadcast call is still blocked on worker 1's gate.
        assert_eq!(workers[0].recv().unwrap().payload, vec![1]);
        assert!(
            done_rx.try_recv().is_err(),
            "broadcast must still be blocked on the held downlink gate"
        );
        plan.release_down(1, 0);
        done_rx.recv().unwrap();
        assert_eq!(workers[1].recv().unwrap().payload, vec![1]);
        drop(t.join().unwrap());
    }

    #[test]
    fn mixed_round_detection() {
        let (mut server, mut workers, _) = inproc_cluster(2);
        workers[0].send(Message::payload(0, 0, vec![])).unwrap();
        workers[1].send(Message::payload(1, 1, vec![])).unwrap();
        let err = server.recv_round().unwrap_err();
        assert!(err.to_string().contains("mixed rounds"), "{err}");
    }

    #[test]
    fn evloop_round_trip_matches_threaded_byte_accounting() {
        // Same exchange as `round_trip_with_threads`, over the evloop
        // cluster: identical up/down totals (the shared counter counts
        // frame_len once per frame, exactly like the threaded path),
        // with the per-broadcast acks isolated in the ctrl counter.
        let m = 3;
        let (mut server, workers, counter) = inproc_cluster_evloop(m);
        let handles: Vec<_> = workers
            .into_iter()
            .map(|mut w| {
                std::thread::spawn(move || {
                    let id = w.id();
                    w.send(Message::payload(id, 0, vec![id as u8; 8])).unwrap();
                    let b = w.recv().unwrap();
                    assert_eq!(b.kind, MsgKind::Broadcast);
                    w.ack(b.round).unwrap();
                    b.payload[0]
                })
            })
            .collect();
        let msgs = server.recv_round().unwrap();
        assert_eq!(msgs.len(), m);
        assert_eq!(msgs[2].payload, vec![2u8; 8]);
        server.broadcast(Message::broadcast(0, vec![42])).unwrap();
        for h in handles {
            assert_eq!(h.join().unwrap(), 42);
        }
        let up = m as u64 * Message::payload(0, 0, vec![0; 8]).frame_len() as u64;
        let down = m as u64 * Message::broadcast(0, vec![42]).frame_len() as u64;
        let ctrl = m as u64 * Message::ack(0, 0).frame_len() as u64;
        assert_eq!(counter.up_total(), up, "uplink = threaded constant");
        assert_eq!(counter.down_total(), down, "downlink = threaded constant");
        assert_eq!(counter.ctrl_total(), ctrl, "acks live in the ctrl plane");
    }

    #[test]
    fn evloop_acks_are_demuxed_out_of_gathers() {
        // Acks share the uplink channel with data frames; the leader's
        // demux must feed them to the ledger, never to a gather.
        let (mut server, mut workers, counter) = inproc_cluster_evloop(2);
        workers[0].ack(7).unwrap(); // stray ack ahead of the round
        workers[0].send(Message::payload(0, 0, vec![1])).unwrap();
        workers[1].send(Message::payload(1, 0, vec![2])).unwrap();
        let msgs = server.recv_round().unwrap();
        assert_eq!(msgs.len(), 2);
        assert!(msgs.iter().all(|m| m.kind == MsgKind::Payload));
        assert_eq!(counter.ctrl_total(), Message::ack(0, 7).frame_len() as u64);
    }

    #[test]
    fn evloop_downlink_gate_parks_only_the_gated_worker() {
        // The evloop analogue of the per-writer gate test: worker 1's
        // delivery is *parked* inside the single delivery thread, so
        // worker 0 still gets its frame at once, and the release's poke
        // moves the parked frame without any polling.
        let plan = DelayPlan::new();
        plan.hold_down(1, 0);
        let (mut server, mut workers, _) = inproc_cluster_evloop_with_plan(2, plan.clone());
        let h = server.broadcast_async(Message::broadcast(0, vec![9])).unwrap();
        assert_eq!(workers[0].recv().unwrap().payload, vec![9]);
        assert!(plan.is_held_down(1, 0));
        assert!(!h.is_done());
        plan.release_down(1, 0);
        h.wait().unwrap();
        assert_eq!(workers[1].recv().unwrap().payload, vec![9]);
    }

    #[test]
    fn evloop_drop_drains_queued_and_parked_broadcasts() {
        // Drop must deliver everything still queued — including frames
        // parked behind a held downlink gate, which teardown waits out
        // on the delivery thread (bounded by the plan's MAX_WAIT).
        let plan = DelayPlan::new();
        plan.hold_down(0, 0);
        let (mut server, mut workers, _) = inproc_cluster_evloop_with_plan(1, plan.clone());
        let h = server.broadcast_async(Message::broadcast(0, vec![3])).unwrap();
        server.broadcast_async(Message::shutdown(1)).unwrap();
        assert!(!h.is_done(), "frame is gate-parked, not delivered");
        let t = std::thread::spawn(move || drop(server));
        assert!(plan.is_held_down(0, 0));
        plan.release_down(0, 0);
        t.join().unwrap();
        h.wait().unwrap();
        assert_eq!(workers[0].recv().unwrap().payload, vec![3]);
        assert_eq!(workers[0].recv().unwrap().kind, MsgKind::Shutdown);
    }

    #[test]
    fn evloop_sticky_failure_names_worker_on_both_broadcast_paths() {
        // Satellite-3 regression, in-process flavor: a hung-up worker
        // surfaces with its id through the BroadcastHandle AND the next
        // synchronous broadcast.
        let (mut server, mut workers, _) = inproc_cluster_evloop(2);
        drop(workers.remove(1));
        let h = server.broadcast_async(Message::broadcast(0, vec![1])).unwrap();
        let err = h.wait().expect_err("delivery to a dropped worker must fail");
        let text = format!("{err:#}");
        assert!(text.contains("broadcast delivery failed"), "got: {text}");
        assert!(text.contains("worker 1 hung up"), "must name the worker: {text}");
        let err = server
            .broadcast(Message::broadcast(1, vec![2]))
            .expect_err("sticky failure must surface on the sync path");
        let text = format!("{err:#}");
        assert!(text.contains("worker 1 hung up"), "got: {text}");
        // Worker 0 still received the first frame (its delivery isn't
        // hostage to its dead peer).
        assert_eq!(workers[0].recv().unwrap().payload, vec![1]);
    }

    #[test]
    fn evloop_pipeline_depth_bounds_applied_not_written_broadcasts() {
        // Lemma-1 staleness bound, in-process flavor: with depth 1 the
        // second data broadcast blocks until the worker ACKS (applies)
        // the first — receipt alone is not enough.
        let (mut server, mut workers, _) = inproc_cluster_evloop(1);
        server.set_pipeline_depth(1);
        server.broadcast(Message::broadcast(0, vec![1])).unwrap();
        let b0 = workers[0].recv().unwrap(); // received, NOT yet acked
        let done = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let flag = Arc::clone(&done);
        let t = std::thread::spawn(move || {
            server.broadcast(Message::broadcast(1, vec![2])).unwrap();
            flag.store(true, std::sync::atomic::Ordering::SeqCst);
            server
        });
        std::thread::sleep(std::time::Duration::from_millis(100));
        assert!(
            !done.load(std::sync::atomic::Ordering::SeqCst),
            "depth-1 broadcast must wait for the APPLY ack, not delivery"
        );
        workers[0].ack(b0.round).unwrap(); // apply → charge clears
        let server = t.join().unwrap();
        assert!(done.load(std::sync::atomic::Ordering::SeqCst));
        let b1 = workers[0].recv().unwrap();
        assert_eq!(b1.payload, vec![2]);
        workers[0].ack(b1.round).unwrap();
        drop(server);
    }
}
