//! In-process transport: mpsc channels between the leader thread and the
//! worker threads. This is the default transport for experiments — zero
//! copies beyond the payload Vec, byte counters still track the *wire*
//! frame sizes so accounting matches the TCP path exactly.

use super::message::{Message, MsgKind};
use super::{validate_round_batch, ArrivalSet, ByteCounter, ServerEnd, WorkerEnd};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// Worker side of the in-process transport.
pub struct InprocWorkerEnd {
    id: u32,
    to_server: Sender<Message>,
    from_server: Receiver<Message>,
    counter: Arc<ByteCounter>,
}

impl WorkerEnd for InprocWorkerEnd {
    fn send(&mut self, msg: Message) -> anyhow::Result<()> {
        self.counter.add_up(msg.frame_len());
        self.to_server.send(msg).map_err(|_| anyhow::anyhow!("server hung up"))
    }

    fn recv(&mut self) -> anyhow::Result<Message> {
        let msg = self.from_server.recv().map_err(|_| anyhow::anyhow!("server hung up"))?;
        Ok(msg)
    }

    fn id(&self) -> u32 {
        self.id
    }
}

/// Server side of the in-process transport.
pub struct InprocServerEnd {
    from_workers: Receiver<Message>,
    to_workers: Vec<Sender<Message>>,
    counter: Arc<ByteCounter>,
}

impl ServerEnd for InprocServerEnd {
    fn recv_round(&mut self) -> anyhow::Result<Vec<Message>> {
        let m = self.to_workers.len();
        let mut msgs = Vec::with_capacity(m);
        for _ in 0..m {
            let msg =
                self.from_workers.recv().map_err(|_| anyhow::anyhow!("workers hung up"))?;
            if msg.kind == MsgKind::WorkerError {
                // Fail before waiting on the rest of the barrier — the
                // erroring worker's peers may be blocked behind it.
                validate_round_batch(std::slice::from_ref(&msg))?;
            }
            msgs.push(msg);
        }
        msgs.sort_by_key(|m| m.worker);
        validate_round_batch(&msgs)?;
        Ok(msgs)
    }

    fn recv_round_streaming(
        &mut self,
        on_msg: &mut dyn FnMut(Message) -> anyhow::Result<()>,
    ) -> anyhow::Result<()> {
        // The shared uplink channel already delivers frames in arrival
        // order, so streaming is the natural read here: hand each frame
        // to the aggregator the moment `recv` returns it.
        let m = self.to_workers.len();
        let mut arrivals = ArrivalSet::new(m);
        for _ in 0..m {
            let msg =
                self.from_workers.recv().map_err(|_| anyhow::anyhow!("workers hung up"))?;
            arrivals.admit(&msg)?;
            on_msg(msg)?;
        }
        Ok(())
    }

    fn broadcast(&mut self, msg: Message) -> anyhow::Result<()> {
        for tx in &self.to_workers {
            self.counter.add_down(msg.frame_len());
            tx.send(msg.clone()).map_err(|_| anyhow::anyhow!("worker hung up"))?;
        }
        Ok(())
    }

    fn workers(&self) -> usize {
        self.to_workers.len()
    }
}

/// Build an in-process PS cluster with `m` workers. Returns the server
/// end, the worker ends, and the shared byte counter.
pub fn inproc_cluster(m: usize) -> (InprocServerEnd, Vec<InprocWorkerEnd>, Arc<ByteCounter>) {
    assert!(m > 0);
    let counter = ByteCounter::new();
    let (up_tx, up_rx) = channel::<Message>();
    let mut worker_ends = Vec::with_capacity(m);
    let mut down_txs = Vec::with_capacity(m);
    for id in 0..m {
        let (down_tx, down_rx) = channel::<Message>();
        down_txs.push(down_tx);
        worker_ends.push(InprocWorkerEnd {
            id: id as u32,
            to_server: up_tx.clone(),
            from_server: down_rx,
            counter: Arc::clone(&counter),
        });
    }
    let server = InprocServerEnd {
        from_workers: up_rx,
        to_workers: down_txs,
        counter: Arc::clone(&counter),
    };
    (server, worker_ends, counter)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_with_threads() {
        let (mut server, workers, counter) = inproc_cluster(3);
        let handles: Vec<_> = workers
            .into_iter()
            .map(|mut w| {
                std::thread::spawn(move || {
                    let id = w.id();
                    w.send(Message::payload(id, 0, vec![id as u8; 8])).unwrap();
                    let b = w.recv().unwrap();
                    assert_eq!(b.kind, MsgKind::Broadcast);
                    b.payload[0]
                })
            })
            .collect();
        let msgs = server.recv_round().unwrap();
        assert_eq!(msgs.len(), 3);
        assert_eq!(msgs[0].worker, 0);
        assert_eq!(msgs[2].payload, vec![2u8; 8]);
        server.broadcast(Message::broadcast(0, vec![42])).unwrap();
        for h in handles {
            assert_eq!(h.join().unwrap(), 42);
        }
        assert!(counter.up_total() > 0);
        assert!(counter.down_total() > 0);
    }

    #[test]
    fn worker_error_propagates() {
        let (mut server, mut workers, _) = inproc_cluster(2);
        workers[0].send(Message::payload(0, 0, vec![])).unwrap();
        workers[1].send(Message::worker_error(1, 0, "injected")).unwrap();
        let err = server.recv_round().unwrap_err();
        assert!(err.to_string().contains("injected"), "{err}");
    }

    #[test]
    fn streaming_delivers_in_arrival_order() {
        let (mut server, mut workers, _) = inproc_cluster(3);
        // Send in reverse worker-id order: arrival order must be preserved.
        for id in (0..3u32).rev() {
            workers[id as usize].send(Message::payload(id, 0, vec![id as u8])).unwrap();
        }
        let mut order = Vec::new();
        server
            .recv_round_streaming(&mut |msg| {
                order.push(msg.worker);
                Ok(())
            })
            .unwrap();
        assert_eq!(order, vec![2, 1, 0]);
    }

    #[test]
    fn streaming_fails_fast_on_worker_error() {
        let (mut server, mut workers, _) = inproc_cluster(2);
        workers[1].send(Message::worker_error(1, 0, "injected")).unwrap();
        // Worker 0 never sends: the error frame must abort the barrier
        // without waiting on it.
        let mut count = 0usize;
        let err = server
            .recv_round_streaming(&mut |_| {
                count += 1;
                Ok(())
            })
            .unwrap_err();
        assert!(err.to_string().contains("injected"), "{err}");
        assert_eq!(count, 0, "error frame must not reach the callback");
    }

    #[test]
    fn mixed_round_detection() {
        let (mut server, mut workers, _) = inproc_cluster(2);
        workers[0].send(Message::payload(0, 0, vec![])).unwrap();
        workers[1].send(Message::payload(1, 1, vec![])).unwrap();
        let err = server.recv_round().unwrap_err();
        assert!(err.to_string().contains("mixed rounds"), "{err}");
    }
}
