//! In-process transport: mpsc channels between the leader thread and the
//! worker threads. This is the default transport for experiments — zero
//! copies beyond the payload Vec, byte counters still track the *wire*
//! frame sizes so accounting matches the TCP path exactly.

use super::delay::DelayPlan;
use super::message::{Message, MsgKind};
use super::{
    validate_round_batch, ArrivalSet, BroadcastHandle, ByteCounter, ServerEnd, StreamDirective,
    StreamOutcome, WorkerEnd, WriterPool,
};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Instant;

/// Worker side of the in-process transport.
pub struct InprocWorkerEnd {
    id: u32,
    to_server: Sender<Message>,
    from_server: Receiver<Message>,
    counter: Arc<ByteCounter>,
    /// Straggler-injection schedule (tests/benches only; `None` in
    /// production clusters).
    plan: Option<DelayPlan>,
}

impl WorkerEnd for InprocWorkerEnd {
    fn send(&mut self, msg: Message) -> anyhow::Result<()> {
        // Deterministic straggler injection: a held gate blocks this
        // payload *before* it becomes visible to the leader.
        if msg.kind == MsgKind::Payload {
            if let Some(plan) = &self.plan {
                plan.wait(msg.worker, msg.round);
            }
        }
        self.counter.add_up(msg.frame_len());
        self.to_server.send(msg).map_err(|_| anyhow::anyhow!("server hung up"))
    }

    fn recv(&mut self) -> anyhow::Result<Message> {
        let msg = self.from_server.recv().map_err(|_| anyhow::anyhow!("server hung up"))?;
        Ok(msg)
    }

    fn id(&self) -> u32 {
        self.id
    }
}

/// Server side of the in-process transport.
pub struct InprocServerEnd {
    from_workers: Receiver<Message>,
    to_workers: Vec<Sender<Message>>,
    counter: Arc<ByteCounter>,
    /// Straggler-injection schedule; the *downlink* gates model a slow
    /// receiver, blocking broadcast deliveries per (worker, round).
    plan: Option<DelayPlan>,
    /// Per-worker queue bound for async broadcasts (`--pipeline-depth`);
    /// effective once the writer threads spawn.
    pipeline_depth: usize,
    /// Per-worker downlink writer threads ([`WriterPool`]). Spawned
    /// lazily on the first `broadcast_async`; once active, *all*
    /// broadcasts route through them (the writers own the downlink order
    /// from then on), and dropping this end joins them after their
    /// queues drain — clean shutdown loses no frame.
    writers: Option<WriterPool>,
}

impl InprocServerEnd {
    /// Spawn the downlink [`WriterPool`] (idempotent): the delivery step
    /// waits out any scripted downlink gate, sends the frame to the
    /// worker's channel, and counts its wire bytes — per-worker frame
    /// order and byte accounting are exactly the synchronous path's, but
    /// one gated/slow worker no longer blocks the leader or its peers.
    fn start_writers(&mut self) -> anyhow::Result<()> {
        if self.writers.is_some() {
            return Ok(());
        }
        let counter = Arc::clone(&self.counter);
        let plan = self.plan.clone();
        let pool = WriterPool::spawn(
            "dqgan-inproc-writer",
            self.to_workers.clone(),
            self.pipeline_depth,
            move |w, down: &mut Sender<Message>, msg: &Message| {
                if let Some(plan) = &plan {
                    plan.wait_down(w as u32, msg.round);
                }
                down.send(msg.clone()).map_err(|_| anyhow::anyhow!("worker hung up"))?;
                counter.add_down(msg.frame_len());
                Ok(())
            },
        )?;
        self.writers = Some(pool);
        Ok(())
    }
}

impl ServerEnd for InprocServerEnd {
    fn recv_round(&mut self) -> anyhow::Result<Vec<Message>> {
        let m = self.to_workers.len();
        let mut msgs = Vec::with_capacity(m);
        for _ in 0..m {
            let msg =
                self.from_workers.recv().map_err(|_| anyhow::anyhow!("workers hung up"))?;
            if msg.kind == MsgKind::WorkerError {
                // Fail before waiting on the rest of the barrier — the
                // erroring worker's peers may be blocked behind it.
                validate_round_batch(std::slice::from_ref(&msg))?;
            }
            msgs.push(msg);
        }
        msgs.sort_by_key(|m| m.worker);
        validate_round_batch(&msgs)?;
        Ok(msgs)
    }

    fn recv_round_streaming(
        &mut self,
        on_msg: &mut dyn FnMut(Message) -> anyhow::Result<()>,
    ) -> anyhow::Result<()> {
        // The shared uplink channel already delivers frames in arrival
        // order, so streaming is the natural read here: hand each frame
        // to the aggregator the moment `recv` returns it.
        let m = self.to_workers.len();
        let mut arrivals = ArrivalSet::new(m);
        for _ in 0..m {
            let msg =
                self.from_workers.recv().map_err(|_| anyhow::anyhow!("workers hung up"))?;
            arrivals.admit(&msg)?;
            on_msg(msg)?;
        }
        Ok(())
    }

    fn recv_round_streaming_timed(
        &mut self,
        on_msg: &mut dyn FnMut(Message) -> anyhow::Result<StreamDirective>,
    ) -> anyhow::Result<StreamOutcome> {
        // Policy-driven gather: frames pop in arrival order off the
        // shared uplink channel; the callback owns all round bookkeeping
        // (see the trait docs) and its directive arms/clears the
        // bounded wait for the next frame.
        let from_workers = &self.from_workers;
        super::drive_timed_stream(
            &mut |deadline| match deadline {
                None => from_workers
                    .recv()
                    .map(Some)
                    .map_err(|_| anyhow::anyhow!("workers hung up")),
                Some(dl) => {
                    let left = dl.saturating_duration_since(Instant::now());
                    match from_workers.recv_timeout(left) {
                        Ok(msg) => Ok(Some(msg)),
                        Err(RecvTimeoutError::Timeout) => Ok(None),
                        Err(RecvTimeoutError::Disconnected) => {
                            anyhow::bail!("workers hung up")
                        }
                    }
                }
            },
            on_msg,
        )
    }

    fn broadcast(&mut self, msg: Message) -> anyhow::Result<()> {
        if self.writers.is_some() {
            // Writer threads own the downlink from the first async
            // broadcast on: route through them (preserving per-worker
            // frame order) and block until every delivery is out —
            // exactly the synchronous contract.
            return self.broadcast_async(msg)?.wait();
        }
        for (w, tx) in self.to_workers.iter().enumerate() {
            // A held downlink gate models a slow receiver: the delivery
            // (and on this synchronous path, the whole round loop)
            // blocks before the frame becomes visible to the worker.
            if let Some(plan) = &self.plan {
                plan.wait_down(w as u32, msg.round);
            }
            self.counter.add_down(msg.frame_len());
            tx.send(msg.clone()).map_err(|_| anyhow::anyhow!("worker hung up"))?;
        }
        Ok(())
    }

    fn broadcast_async(&mut self, msg: Message) -> anyhow::Result<BroadcastHandle> {
        self.start_writers()?;
        self.writers.as_ref().expect("writers started").enqueue(msg)
    }

    fn set_pipeline_depth(&mut self, depth: usize) {
        if self.writers.is_none() {
            self.pipeline_depth = depth.max(1);
        }
    }

    fn workers(&self) -> usize {
        self.to_workers.len()
    }
}

/// Build an in-process PS cluster with `m` workers. Returns the server
/// end, the worker ends, and the shared byte counter.
pub fn inproc_cluster(m: usize) -> (InprocServerEnd, Vec<InprocWorkerEnd>, Arc<ByteCounter>) {
    build_cluster(m, None)
}

/// [`inproc_cluster`] with a [`DelayPlan`] attached to every worker end:
/// payload sends consult the plan's gate/permit schedule, so tests and
/// benches can script exact arrival orders and holdouts without sleeps.
pub fn inproc_cluster_with_plan(
    m: usize,
    plan: DelayPlan,
) -> (InprocServerEnd, Vec<InprocWorkerEnd>, Arc<ByteCounter>) {
    build_cluster(m, Some(plan))
}

fn build_cluster(
    m: usize,
    plan: Option<DelayPlan>,
) -> (InprocServerEnd, Vec<InprocWorkerEnd>, Arc<ByteCounter>) {
    assert!(m > 0);
    let counter = ByteCounter::new();
    let (up_tx, up_rx) = channel::<Message>();
    let mut worker_ends = Vec::with_capacity(m);
    let mut down_txs = Vec::with_capacity(m);
    for id in 0..m {
        let (down_tx, down_rx) = channel::<Message>();
        down_txs.push(down_tx);
        worker_ends.push(InprocWorkerEnd {
            id: id as u32,
            to_server: up_tx.clone(),
            from_server: down_rx,
            counter: Arc::clone(&counter),
            plan: plan.clone(),
        });
    }
    let server = InprocServerEnd {
        from_workers: up_rx,
        to_workers: down_txs,
        counter: Arc::clone(&counter),
        plan,
        pipeline_depth: 2,
        writers: None,
    };
    (server, worker_ends, counter)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_with_threads() {
        let (mut server, workers, counter) = inproc_cluster(3);
        let handles: Vec<_> = workers
            .into_iter()
            .map(|mut w| {
                std::thread::spawn(move || {
                    let id = w.id();
                    w.send(Message::payload(id, 0, vec![id as u8; 8])).unwrap();
                    let b = w.recv().unwrap();
                    assert_eq!(b.kind, MsgKind::Broadcast);
                    b.payload[0]
                })
            })
            .collect();
        let msgs = server.recv_round().unwrap();
        assert_eq!(msgs.len(), 3);
        assert_eq!(msgs[0].worker, 0);
        assert_eq!(msgs[2].payload, vec![2u8; 8]);
        server.broadcast(Message::broadcast(0, vec![42])).unwrap();
        for h in handles {
            assert_eq!(h.join().unwrap(), 42);
        }
        assert!(counter.up_total() > 0);
        assert!(counter.down_total() > 0);
    }

    #[test]
    fn worker_error_propagates() {
        let (mut server, mut workers, _) = inproc_cluster(2);
        workers[0].send(Message::payload(0, 0, vec![])).unwrap();
        workers[1].send(Message::worker_error(1, 0, "injected")).unwrap();
        let err = server.recv_round().unwrap_err();
        assert!(err.to_string().contains("injected"), "{err}");
    }

    #[test]
    fn streaming_delivers_in_arrival_order() {
        let (mut server, mut workers, _) = inproc_cluster(3);
        // Send in reverse worker-id order: arrival order must be preserved.
        for id in (0..3u32).rev() {
            workers[id as usize].send(Message::payload(id, 0, vec![id as u8])).unwrap();
        }
        let mut order = Vec::new();
        server
            .recv_round_streaming(&mut |msg| {
                order.push(msg.worker);
                Ok(())
            })
            .unwrap();
        assert_eq!(order, vec![2, 1, 0]);
    }

    #[test]
    fn streaming_fails_fast_on_worker_error() {
        let (mut server, mut workers, _) = inproc_cluster(2);
        workers[1].send(Message::worker_error(1, 0, "injected")).unwrap();
        // Worker 0 never sends: the error frame must abort the barrier
        // without waiting on it.
        let mut count = 0usize;
        let err = server
            .recv_round_streaming(&mut |_| {
                count += 1;
                Ok(())
            })
            .unwrap_err();
        assert!(err.to_string().contains("injected"), "{err}");
        assert_eq!(count, 0, "error frame must not reach the callback");
    }

    #[test]
    fn timed_streaming_closes_on_directive_without_all_frames() {
        let (mut server, mut workers, _) = inproc_cluster(3);
        // Only two of three workers ever send: the Close directive must
        // end the gather without waiting on the third.
        workers[1].send(Message::payload(1, 0, vec![1])).unwrap();
        workers[0].send(Message::payload(0, 0, vec![0])).unwrap();
        let mut seen = Vec::new();
        let outcome = server
            .recv_round_streaming_timed(&mut |msg| {
                seen.push(msg.worker);
                Ok(if seen.len() == 2 {
                    StreamDirective::Close
                } else {
                    StreamDirective::Wait
                })
            })
            .unwrap();
        assert_eq!(outcome, StreamOutcome::Closed);
        assert_eq!(seen, vec![1, 0], "arrival order must be preserved");
    }

    #[test]
    fn timed_streaming_reports_deadline_expiry() {
        let (mut server, mut workers, _) = inproc_cluster(2);
        workers[0].send(Message::payload(0, 0, vec![])).unwrap();
        let mut seen = 0usize;
        let outcome = server
            .recv_round_streaming_timed(&mut |_msg| {
                seen += 1;
                // Arm a short grace window; worker 1 never sends.
                Ok(StreamDirective::WaitUntil(
                    Instant::now() + std::time::Duration::from_millis(20),
                ))
            })
            .unwrap();
        assert_eq!(outcome, StreamOutcome::DeadlineExpired);
        assert_eq!(seen, 1);
    }

    #[test]
    fn delay_plan_gates_payload_sends_deterministically() {
        let plan = DelayPlan::new();
        plan.hold(1, 0);
        let (mut server, workers, _) = inproc_cluster_with_plan(2, plan.clone());
        let handles: Vec<_> = workers
            .into_iter()
            .map(|mut w| {
                std::thread::spawn(move || {
                    let id = w.id();
                    w.send(Message::payload(id, 0, vec![id as u8])).unwrap();
                })
            })
            .collect();
        // Worker 0's frame arrives while worker 1's gate is still held —
        // provable structurally, no sleeps involved.
        let first = server.from_workers.recv().unwrap();
        assert_eq!(first.worker, 0);
        assert!(plan.is_held(1, 0));
        plan.release(1, 0);
        let second = server.from_workers.recv().unwrap();
        assert_eq!(second.worker, 1);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn async_broadcast_preserves_order_and_byte_accounting() {
        let (mut server, workers, counter) = inproc_cluster(2);
        let frames: Vec<Message> =
            (0..4u64).map(|r| Message::broadcast(r, vec![r as u8; 8])).collect();
        let mut handles = Vec::new();
        for f in &frames {
            handles.push(server.broadcast_async(f.clone()).unwrap());
        }
        // A later synchronous broadcast routes through the same writer
        // queues, so cross-path order is preserved too.
        server.broadcast(Message::shutdown(4)).unwrap();
        for h in &handles {
            h.wait().unwrap();
            assert!(h.is_done());
            assert!(h.completed_at().is_some());
        }
        // Exact downlink accounting: every frame counted once per worker.
        let expected: u64 = frames
            .iter()
            .map(|f| f.frame_len() as u64)
            .chain(std::iter::once(Message::shutdown(4).frame_len() as u64))
            .sum::<u64>()
            * 2;
        assert_eq!(counter.down_total(), expected);
        for mut w in workers {
            for f in &frames {
                assert_eq!(&w.recv().unwrap(), f, "per-worker frame order");
            }
            assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
        }
    }

    #[test]
    fn dropping_the_server_drains_queued_async_broadcasts() {
        let (mut server, workers, _) = inproc_cluster(2);
        server.broadcast_async(Message::broadcast(0, vec![5])).unwrap();
        server.broadcast_async(Message::shutdown(1)).unwrap();
        // No waiting: Drop must join the writers after they drain, so
        // neither frame (in particular the Shutdown) is lost.
        drop(server);
        for mut w in workers {
            assert_eq!(w.recv().unwrap().payload, vec![5]);
            assert_eq!(w.recv().unwrap().kind, MsgKind::Shutdown);
        }
    }

    #[test]
    fn downlink_gate_blocks_only_the_gated_workers_writer() {
        // Worker 1's round-0 broadcast delivery is gated; worker 0 must
        // receive it anyway (per-worker writers: no head-of-line
        // blocking across workers), and the handle must stay incomplete
        // until the gate opens.
        let plan = DelayPlan::new();
        plan.hold_down(1, 0);
        let (mut server, mut workers, _) = inproc_cluster_with_plan(2, plan.clone());
        let h = server.broadcast_async(Message::broadcast(0, vec![9])).unwrap();
        let b0 = workers[0].recv().unwrap();
        assert_eq!(b0.payload, vec![9]);
        // Worker 0 has its frame while worker 1's delivery is provably
        // still gate-held — the broadcast is in flight, not done.
        assert!(plan.is_held_down(1, 0));
        assert!(!h.is_done());
        plan.release_down(1, 0);
        h.wait().unwrap();
        assert_eq!(workers[1].recv().unwrap().payload, vec![9]);
    }

    #[test]
    fn sync_broadcast_waits_out_downlink_gates_on_the_leader_thread() {
        // Without writer threads the downlink gate blocks the leader's
        // own broadcast loop — the slow-receiver model the pipelined
        // mode's A/B benchmark compares against.
        let plan = DelayPlan::new();
        plan.hold_down(1, 0);
        let (mut server, mut workers, _) = inproc_cluster_with_plan(2, plan.clone());
        let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
        let t = std::thread::spawn(move || {
            server.broadcast(Message::broadcast(0, vec![1])).unwrap();
            done_tx.send(()).unwrap();
            server
        });
        // Worker 0's delivery precedes the gate (id order), so it lands
        // while the broadcast call is still blocked on worker 1's gate.
        assert_eq!(workers[0].recv().unwrap().payload, vec![1]);
        assert!(
            done_rx.try_recv().is_err(),
            "broadcast must still be blocked on the held downlink gate"
        );
        plan.release_down(1, 0);
        done_rx.recv().unwrap();
        assert_eq!(workers[1].recv().unwrap().payload, vec![1]);
        drop(t.join().unwrap());
    }

    #[test]
    fn mixed_round_detection() {
        let (mut server, mut workers, _) = inproc_cluster(2);
        workers[0].send(Message::payload(0, 0, vec![])).unwrap();
        workers[1].send(Message::payload(1, 1, vec![])).unwrap();
        let err = server.recv_round().unwrap_err();
        assert!(err.to_string().contains("mixed rounds"), "{err}");
    }
}
