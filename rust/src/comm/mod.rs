//! Communication layer for the parameter-server topology (paper Fig. 1):
//! message framing, transports (in-process channels and TCP), byte
//! accounting, and the simulated-network cost model that drives the
//! Figure 4 speedup reproduction.
//!
//! The PS round is strictly synchronous, so the transport interface is a
//! pair of blocking endpoints:
//!
//! - [`WorkerEnd`]: `send` one payload per round, `recv` one broadcast;
//! - [`ServerEnd`]: `recv_round` gathers all M payloads, `broadcast`
//!   pushes the averaged result.
//!
//! The paper's testbed is NCCL on a GPU cluster; DESIGN.md §5 documents
//! why a byte-accurate transport + [`sim::NetworkModel`] preserves the
//! quantities Figure 4 measures.

pub mod delay;
pub mod inproc;
pub mod message;
pub mod sim;
pub mod tcp;

pub use delay::DelayPlan;
pub use inproc::{inproc_cluster, inproc_cluster_with_plan};
pub use message::{bitmap_included, read_inclusion_bitmap, Message, MsgKind};
pub use sim::NetworkModel;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Validate one gathered barrier batch (shared by every [`ServerEnd`]
/// implementation): fail fast on `WorkerError` frames and on mixed
/// rounds, naming the offending workers. Callers pass the batch sorted by
/// worker id so the reported ids are deterministic.
pub fn validate_round_batch(msgs: &[Message]) -> anyhow::Result<()> {
    for m in msgs {
        if m.kind == MsgKind::WorkerError {
            anyhow::bail!(
                "worker {} failed at round {}: {}",
                m.worker,
                m.round,
                String::from_utf8_lossy(&m.payload)
            );
        }
    }
    // Round consistency check: a synchronous PS must never mix rounds.
    if let Some(first) = msgs.first() {
        for m in msgs {
            if m.round != first.round {
                anyhow::bail!(
                    "mixed rounds in barrier: worker {} at round {} vs worker {} at round {}",
                    m.worker,
                    m.round,
                    first.worker,
                    first.round
                );
            }
        }
    }
    Ok(())
}

/// What a timed streaming gather should do next — returned by the
/// per-arrival callback of [`ServerEnd::recv_round_streaming_timed`]
/// (the round-completion policy's verdict after each frame).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamDirective {
    /// Keep gathering; block indefinitely for the next frame.
    Wait,
    /// Keep gathering, but if no frame lands before the instant passes,
    /// end the gather with [`StreamOutcome::DeadlineExpired`].
    WaitUntil(Instant),
    /// The round is complete: stop gathering now.
    Close,
}

/// How a timed streaming gather ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamOutcome {
    /// The callback returned [`StreamDirective::Close`].
    Closed,
    /// An armed deadline expired with no further frame.
    DeadlineExpired,
}

/// Worker-side endpoint of a PS transport.
pub trait WorkerEnd: Send {
    /// Push this worker's round payload to the server (blocking).
    fn send(&mut self, msg: Message) -> anyhow::Result<()>;
    /// Block until the server's broadcast for the current round arrives.
    fn recv(&mut self) -> anyhow::Result<Message>;
    /// Worker id (0-based).
    fn id(&self) -> u32;
}

/// Server-side endpoint of a PS transport.
pub trait ServerEnd: Send {
    /// Gather exactly one message from every worker (blocking). Messages
    /// are returned sorted by worker id.
    fn recv_round(&mut self) -> anyhow::Result<Vec<Message>>;
    /// Event-driven round gather: invoke `on_msg` once per worker frame in
    /// **arrival order**, as soon as each frame is available — the hook the
    /// streaming aggregation engine uses to decode payloads while slower
    /// workers are still in flight. Implementations fail fast on
    /// `WorkerError` frames and on duplicate worker ids within the
    /// barrier; exactly `workers()` callbacks fire on success. The default
    /// degrades to [`recv_round`] (worker-id order), which is correct but
    /// forfeits the overlap.
    fn recv_round_streaming(
        &mut self,
        on_msg: &mut dyn FnMut(Message) -> anyhow::Result<()>,
    ) -> anyhow::Result<()> {
        for msg in self.recv_round()? {
            on_msg(msg)?;
        }
        Ok(())
    }
    /// Timed, policy-driven variant of [`Self::recv_round_streaming`]:
    /// frames are handed to `on_msg` in arrival order **unvalidated**
    /// (the caller owns round bookkeeping — duplicate/skew checks,
    /// `WorkerError` handling, and draining of late frames from earlier
    /// partially-aggregated rounds), and the callback's
    /// [`StreamDirective`] steers the gather: `Close` ends it,
    /// `WaitUntil` bounds the wait for the *next* frame. Unlike the
    /// barrier gathers this never requires all M frames — it is the
    /// transport hook for K-of-M and deadline round policies.
    fn recv_round_streaming_timed(
        &mut self,
        _on_msg: &mut dyn FnMut(Message) -> anyhow::Result<StreamDirective>,
    ) -> anyhow::Result<StreamOutcome> {
        anyhow::bail!("this transport does not support timed streaming gathers")
    }
    /// Broadcast one message to every worker.
    fn broadcast(&mut self, msg: Message) -> anyhow::Result<()>;
    /// Number of workers.
    fn workers(&self) -> usize;
}

/// Shared driver for [`ServerEnd::recv_round_streaming_timed`]: pops
/// frames from `next_frame` — which must honor the optional deadline and
/// return `Ok(None)` when it expires with no frame — and dispatches the
/// policy callback's directives. Both transports implement their timed
/// gather with this, so the deadline/directive state machine exists
/// exactly once.
pub(crate) fn drive_timed_stream(
    next_frame: &mut dyn FnMut(Option<Instant>) -> anyhow::Result<Option<Message>>,
    on_msg: &mut dyn FnMut(Message) -> anyhow::Result<StreamDirective>,
) -> anyhow::Result<StreamOutcome> {
    let mut deadline: Option<Instant> = None;
    loop {
        let msg = match next_frame(deadline)? {
            Some(msg) => msg,
            None => return Ok(StreamOutcome::DeadlineExpired),
        };
        match on_msg(msg)? {
            StreamDirective::Wait => deadline = None,
            StreamDirective::WaitUntil(dl) => deadline = Some(dl),
            StreamDirective::Close => return Ok(StreamOutcome::Closed),
        }
    }
}

/// Per-barrier arrival bookkeeping shared by the streaming gathers:
/// fail fast on `WorkerError` frames, reject out-of-range and duplicate
/// worker ids (each worker contributes exactly one frame per barrier).
pub(crate) struct ArrivalSet {
    seen: Vec<bool>,
}

impl ArrivalSet {
    pub(crate) fn new(workers: usize) -> Self {
        Self { seen: vec![false; workers] }
    }

    pub(crate) fn admit(&mut self, msg: &Message) -> anyhow::Result<()> {
        if msg.kind == MsgKind::WorkerError {
            validate_round_batch(std::slice::from_ref(msg))?;
        }
        let id = msg.worker as usize;
        anyhow::ensure!(
            id < self.seen.len(),
            "worker id {id} out of range (M = {})",
            self.seen.len()
        );
        anyhow::ensure!(!self.seen[id], "duplicate frame from worker {id} within one barrier");
        self.seen[id] = true;
        Ok(())
    }
}

/// Shared byte counters (uplink = workers→server, downlink = server→workers).
#[derive(Debug, Default)]
pub struct ByteCounter {
    pub up: AtomicU64,
    pub down: AtomicU64,
}

impl ByteCounter {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    pub fn add_up(&self, n: usize) {
        self.up.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn add_down(&self, n: usize) {
        self.down.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn up_total(&self) -> u64 {
        self.up.load(Ordering::Relaxed)
    }

    pub fn down_total(&self) -> u64 {
        self.down.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_set_enforces_barrier_invariants() {
        let mut set = ArrivalSet::new(2);
        set.admit(&Message::payload(1, 0, vec![])).unwrap();
        set.admit(&Message::payload(0, 0, vec![])).unwrap();
        // Duplicate within one barrier.
        let mut dup = ArrivalSet::new(2);
        dup.admit(&Message::payload(0, 0, vec![])).unwrap();
        assert!(dup.admit(&Message::payload(0, 0, vec![])).is_err());
        // Out of range.
        assert!(ArrivalSet::new(2).admit(&Message::payload(5, 0, vec![])).is_err());
        // WorkerError fails fast with the worker's message.
        let err = ArrivalSet::new(2)
            .admit(&Message::worker_error(1, 3, "boom"))
            .unwrap_err();
        assert!(err.to_string().contains("boom"), "{err}");
    }
}
