//! Communication layer for the parameter-server topology (paper Fig. 1):
//! message framing, transports (in-process channels and TCP), byte
//! accounting, and the simulated-network cost model that drives the
//! Figure 4 speedup reproduction.
//!
//! The PS round is strictly synchronous, so the transport interface is a
//! pair of blocking endpoints:
//!
//! - [`WorkerEnd`]: `send` one payload per round, `recv` one broadcast;
//! - [`ServerEnd`]: `recv_round` gathers all M payloads, `broadcast`
//!   pushes the averaged result.
//!
//! The paper's testbed is NCCL on a GPU cluster; DESIGN.md §5 documents
//! why a byte-accurate transport + [`sim::NetworkModel`] preserves the
//! quantities Figure 4 measures.

pub mod delay;
pub(crate) mod evloop;
pub mod inproc;
pub mod message;
pub mod sim;
pub mod tcp;

pub use delay::DelayPlan;
pub use inproc::{
    inproc_cluster, inproc_cluster_evloop, inproc_cluster_evloop_with_plan,
    inproc_cluster_with_plan,
};
pub use message::{bitmap_included, read_inclusion_bitmap, FrameAssembler, Message, MsgKind};
pub use sim::NetworkModel;
pub use tcp::{RetryPolicy, SessionInfo, SessionWelcome};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Completion handle for one [`ServerEnd::broadcast_async`] call: tracks
/// the per-worker deliveries of that frame. Cheap to clone (each writer
/// thread holds one clone and marks its delivery off).
///
/// "Delivered" means the frame left the leader — written to the worker's
/// socket (TCP) or pushed into its downlink channel (in-process) — not
/// that the worker has read it; that is exactly what the synchronous
/// [`ServerEnd::broadcast`] loop guaranteed per socket.
#[derive(Clone)]
pub struct BroadcastHandle {
    inner: Arc<HandleInner>,
}

struct HandleInner {
    state: Mutex<HandleState>,
    cv: Condvar,
}

struct HandleState {
    remaining: usize,
    completed_at: Option<Instant>,
    error: Option<String>,
}

impl BroadcastHandle {
    /// A handle awaiting `workers` deliveries. With `workers == 0` it is
    /// born complete (the default synchronous fallback uses this).
    pub(crate) fn new(workers: usize) -> Self {
        Self {
            inner: Arc::new(HandleInner {
                state: Mutex::new(HandleState {
                    remaining: workers,
                    completed_at: if workers == 0 { Some(Instant::now()) } else { None },
                    error: None,
                }),
                cv: Condvar::new(),
            }),
        }
    }

    /// One worker's delivery finished successfully.
    pub(crate) fn mark_delivered(&self) {
        self.finish_one(None);
    }

    /// One worker's delivery failed; the first failure is kept and
    /// surfaced by [`Self::wait`].
    pub(crate) fn mark_failed(&self, what: &str) {
        self.finish_one(Some(what));
    }

    fn finish_one(&self, err: Option<&str>) {
        let mut st = self.inner.state.lock().unwrap();
        if let Some(what) = err {
            if st.error.is_none() {
                st.error = Some(what.to_string());
            }
        }
        st.remaining = st.remaining.saturating_sub(1);
        if st.remaining == 0 && st.completed_at.is_none() {
            st.completed_at = Some(Instant::now());
        }
        drop(st);
        self.inner.cv.notify_all();
    }

    /// Whether every per-worker delivery has finished (successfully or
    /// not). `false` means the broadcast is provably still in flight —
    /// the structural fact the overlap probes assert.
    pub fn is_done(&self) -> bool {
        self.inner.state.lock().unwrap().remaining == 0
    }

    /// When the last delivery finished (`None` while still in flight) —
    /// the input to `RoundRecord::overlap_secs`.
    pub fn completed_at(&self) -> Option<Instant> {
        self.inner.state.lock().unwrap().completed_at
    }

    /// Block until every delivery has finished; surfaces the first
    /// per-worker failure. This is how a synchronous broadcast is
    /// expressed once writer threads own the downlink.
    pub fn wait(&self) -> anyhow::Result<()> {
        let mut st = self.inner.state.lock().unwrap();
        while st.remaining > 0 {
            st = self.inner.cv.wait(st).unwrap();
        }
        match &st.error {
            Some(e) => anyhow::bail!("broadcast delivery failed: {e}"),
            None => Ok(()),
        }
    }
}

/// One queued downlink delivery: a completion guard around the broadcast
/// handle. If it is dropped without an explicit outcome — a writer thread
/// panicking (e.g. the `DelayPlan` anti-hang assertion) or tearing down
/// with frames still queued — the drop marks the delivery failed, so
/// [`BroadcastHandle::wait`] can never hang on an abandoned queue.
pub(crate) struct PendingDelivery {
    handle: BroadcastHandle,
    done: bool,
}

impl PendingDelivery {
    pub(crate) fn new(handle: BroadcastHandle) -> Self {
        Self { handle, done: false }
    }

    pub(crate) fn delivered(mut self) {
        self.done = true;
        self.handle.mark_delivered();
    }

    pub(crate) fn failed(mut self, what: &str) {
        self.done = true;
        self.handle.mark_failed(what);
    }

    /// The delivery's worker was evicted: the frame will never be
    /// written, but the broadcast is still **satisfied** — an evicted
    /// worker is outside the quorum, so its queued frames complete
    /// their handles without error instead of poisoning
    /// [`BroadcastHandle::wait`] for the survivors.
    pub(crate) fn skipped(mut self) {
        self.done = true;
        self.handle.mark_delivered();
    }
}

impl Drop for PendingDelivery {
    fn drop(&mut self) {
        if !self.done {
            self.handle.mark_failed("delivery abandoned (writer thread exited)");
        }
    }
}

/// The per-worker downlink writer subsystem both transports share: one
/// thread per worker draining a bounded FIFO of queued broadcast frames.
/// The transport supplies only the delivery step (`deliver(w, sink, msg)`
/// — socket write on TCP, gate-wait + channel send in-process), which
/// also owns that transport's downlink byte accounting. Guarantees:
///
/// - per-worker frame order is total (one FIFO per worker);
/// - frames are shared, not copied, across writers (`Arc<Message>`);
/// - a delivery failure is sticky per worker (later frames for it fail
///   fast), is surfaced by [`Self::enqueue`] on the next call, and every
///   affected [`BroadcastHandle`] completes with the error — never hangs;
/// - dropping the pool closes the queues and **joins** the writers, so
///   everything already queued (e.g. a trailing `Shutdown`) is delivered
///   before the sinks close.
pub(crate) struct WriterPool {
    txs: Vec<SyncSender<(Arc<Message>, PendingDelivery)>>,
    threads: Vec<std::thread::JoinHandle<()>>,
    error: Arc<Mutex<Option<String>>>,
}

impl WriterPool {
    /// Spawn one named writer thread per sink with queue bound `depth`.
    pub(crate) fn spawn<S, D>(
        thread_prefix: &str,
        sinks: Vec<S>,
        depth: usize,
        deliver: D,
    ) -> anyhow::Result<Self>
    where
        S: Send + 'static,
        D: Fn(usize, &mut S, &Message) -> anyhow::Result<()> + Send + Sync + Clone + 'static,
    {
        let error = Arc::new(Mutex::new(None));
        let mut txs = Vec::with_capacity(sinks.len());
        let mut threads = Vec::with_capacity(sinks.len());
        for (w, mut sink) in sinks.into_iter().enumerate() {
            let (tx, rx) = sync_channel::<(Arc<Message>, PendingDelivery)>(depth.max(1));
            let deliver = deliver.clone();
            let error = Arc::clone(&error);
            let handle = std::thread::Builder::new()
                .name(format!("{thread_prefix}-{w}"))
                .spawn(move || {
                    let mut failed: Option<String> = None;
                    while let Ok((msg, pd)) = rx.recv() {
                        if let Some(what) = &failed {
                            pd.failed(what);
                            continue;
                        }
                        match deliver(w, &mut sink, &msg) {
                            Ok(()) => pd.delivered(),
                            Err(e) => {
                                let what = format!("downlink to worker {w} failed: {e}");
                                let mut g = error.lock().unwrap();
                                if g.is_none() {
                                    *g = Some(what.clone());
                                }
                                drop(g);
                                pd.failed(&what);
                                failed = Some(what);
                            }
                        }
                    }
                })
                .map_err(|e| anyhow::anyhow!("spawn {thread_prefix}-{w}: {e}"))?;
            txs.push(tx);
            threads.push(handle);
        }
        Ok(Self { txs, threads, error })
    }

    /// Queue `msg` for every worker. Blocks per worker only when that
    /// worker already has `depth` undelivered frames (backpressure); a
    /// prior delivery failure is surfaced here instead.
    pub(crate) fn enqueue(&self, msg: Message) -> anyhow::Result<BroadcastHandle> {
        if let Some(e) = self.error.lock().unwrap().clone() {
            anyhow::bail!("async broadcast failed: {e}");
        }
        let handle = BroadcastHandle::new(self.txs.len());
        let msg = Arc::new(msg);
        for tx in &self.txs {
            // A send only fails if the writer thread is gone; the
            // returned PendingDelivery drops and marks the failure, so
            // the handle still completes for any concurrent waiter.
            tx.send((Arc::clone(&msg), PendingDelivery::new(handle.clone())))
                .map_err(|_| anyhow::anyhow!("downlink writer thread exited"))?;
        }
        Ok(handle)
    }
}

impl Drop for WriterPool {
    fn drop(&mut self) {
        // Close the queues, then join: writers drain what is already
        // queued and exit. A writer parked on a scripted downlink gate
        // panics after `DelayPlan::MAX_WAIT`; its pending deliveries are
        // drop-marked failed either way, and the join result is ignored.
        self.txs.clear();
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
    }
}

/// Validate one gathered barrier batch (shared by every [`ServerEnd`]
/// implementation): fail fast on `WorkerError` frames and on mixed
/// rounds, naming the offending workers. Callers pass the batch sorted by
/// worker id so the reported ids are deterministic.
pub fn validate_round_batch(msgs: &[Message]) -> anyhow::Result<()> {
    for m in msgs {
        if m.kind == MsgKind::WorkerError {
            anyhow::bail!(
                "worker {} failed at round {}: {}",
                m.worker,
                m.round,
                String::from_utf8_lossy(&m.payload)
            );
        }
    }
    // Round consistency check: a synchronous PS must never mix rounds.
    if let Some(first) = msgs.first() {
        for m in msgs {
            if m.round != first.round {
                anyhow::bail!(
                    "mixed rounds in barrier: worker {} at round {} vs worker {} at round {}",
                    m.worker,
                    m.round,
                    first.worker,
                    first.round
                );
            }
        }
    }
    Ok(())
}

/// What a timed streaming gather should do next — returned by the
/// per-arrival callback of [`ServerEnd::recv_round_streaming_timed`]
/// (the round-completion policy's verdict after each frame).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamDirective {
    /// Keep gathering; block indefinitely for the next frame.
    Wait,
    /// Keep gathering, but if no frame lands before the instant passes,
    /// end the gather with [`StreamOutcome::DeadlineExpired`].
    WaitUntil(Instant),
    /// The round is complete: stop gathering now.
    Close,
}

/// How a timed streaming gather ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamOutcome {
    /// The callback returned [`StreamDirective::Close`].
    Closed,
    /// An armed deadline expired with no further frame.
    DeadlineExpired,
}

/// Worker-side endpoint of a PS transport.
pub trait WorkerEnd: Send {
    /// Push this worker's round payload to the server (blocking).
    fn send(&mut self, msg: Message) -> anyhow::Result<()>;
    /// Block until the server's broadcast for the current round arrives.
    fn recv(&mut self) -> anyhow::Result<Message>;
    /// Tell the server this worker has *applied* the round-`round`
    /// broadcast. On the readiness-loop transport this emits a
    /// [`MsgKind::Ack`] control frame feeding the leader's ack ledger
    /// (`--pipeline-depth` bounds applied broadcasts per worker); the
    /// threaded transports have no ack channel, so the default is a
    /// no-op and the worker loop can call it unconditionally.
    fn ack(&mut self, _round: u64) -> anyhow::Result<()> {
        Ok(())
    }
    /// Worker id (0-based).
    fn id(&self) -> u32;
    /// Re-register with the leader after an eviction: reconnect (TCP) or
    /// re-announce (in-process) and ask for a replay of every broadcast
    /// from `resume_round` on ([`MsgKind::Rejoin`]). After a successful
    /// rejoin the missed broadcasts arrive in round order through the
    /// normal [`Self::recv`] path, bitwise-identical to the originals.
    /// Default: unsupported.
    fn rejoin(&mut self, _resume_round: u64) -> anyhow::Result<()> {
        anyhow::bail!("this transport does not support rejoin")
    }
}

/// Server-side endpoint of a PS transport.
pub trait ServerEnd: Send {
    /// Gather exactly one message from every worker (blocking). Messages
    /// are returned sorted by worker id.
    fn recv_round(&mut self) -> anyhow::Result<Vec<Message>>;
    /// Event-driven round gather: invoke `on_msg` once per worker frame in
    /// **arrival order**, as soon as each frame is available — the hook the
    /// streaming aggregation engine uses to decode payloads while slower
    /// workers are still in flight. Implementations fail fast on
    /// `WorkerError` frames and on duplicate worker ids within the
    /// barrier; exactly `workers()` callbacks fire on success. The default
    /// degrades to [`recv_round`] (worker-id order), which is correct but
    /// forfeits the overlap.
    fn recv_round_streaming(
        &mut self,
        on_msg: &mut dyn FnMut(Message) -> anyhow::Result<()>,
    ) -> anyhow::Result<()> {
        for msg in self.recv_round()? {
            on_msg(msg)?;
        }
        Ok(())
    }
    /// Timed, policy-driven variant of [`Self::recv_round_streaming`]:
    /// frames are handed to `on_msg` in arrival order **unvalidated**
    /// (the caller owns round bookkeeping — duplicate/skew checks,
    /// `WorkerError` handling, and draining of late frames from earlier
    /// partially-aggregated rounds), and the callback's
    /// [`StreamDirective`] steers the gather: `Close` ends it,
    /// `WaitUntil` bounds the wait for the *next* frame. Unlike the
    /// barrier gathers this never requires all M frames — it is the
    /// transport hook for K-of-M and deadline round policies.
    fn recv_round_streaming_timed(
        &mut self,
        _on_msg: &mut dyn FnMut(Message) -> anyhow::Result<StreamDirective>,
    ) -> anyhow::Result<StreamOutcome> {
        anyhow::bail!("this transport does not support timed streaming gathers")
    }
    /// Broadcast one message to every worker (blocking until each
    /// delivery has left the leader).
    fn broadcast(&mut self, msg: Message) -> anyhow::Result<()>;
    /// Queue one message for delivery to every worker **without blocking
    /// on slow receivers**: per-worker writer threads (mirroring the
    /// reader threads of the streaming gathers) own the downlink from the
    /// first call on, so one stalled receiver no longer delays the next
    /// round's gather. Guarantees per implementation:
    ///
    /// - per-worker frame order is preserved (one FIFO queue per worker,
    ///   and later synchronous [`Self::broadcast`] calls route through
    ///   the same queues);
    /// - downlink byte accounting is identical to the synchronous path
    ///   (each writer counts its frame when the write completes);
    /// - a bounded queue per worker (see `set_pipeline_depth`) applies
    ///   backpressure: when a worker already has `depth` undelivered
    ///   frames queued, the next call blocks until its writer drains one.
    ///
    /// The returned [`BroadcastHandle`] reports delivery completion; the
    /// default implementation degrades to the blocking [`Self::broadcast`]
    /// and returns an already-completed handle.
    fn broadcast_async(&mut self, msg: Message) -> anyhow::Result<BroadcastHandle> {
        self.broadcast(msg)?;
        Ok(BroadcastHandle::new(0))
    }
    /// Bound the per-worker queue of not-yet-delivered async broadcasts
    /// (the `--pipeline-depth` knob). Takes effect only before the first
    /// [`Self::broadcast_async`] call spawns the writer threads; the
    /// default implementation ignores it.
    fn set_pipeline_depth(&mut self, _depth: usize) {}
    /// Number of workers.
    fn workers(&self) -> usize;
    /// The transport's shared byte counter, when it keeps one: the
    /// round engine snapshots `down_total()` around each broadcast for
    /// the per-round `bytes_down` column, and the obs layer folds the
    /// final totals into the unified `transport.bytes_*` metrics at
    /// run end. Default: no counter (the quantities stay unknown).
    fn counter(&self) -> Option<Arc<ByteCounter>> {
        None
    }
    /// Switch the transport into eviction mode (`--on-worker-loss
    /// evict`): a dead socket/channel or an ack-ledger stall no longer
    /// poisons the transport with a sticky fatal error — instead the
    /// lost worker's parked frames are reclaimed and a leader-internal
    /// [`MsgKind::Gone`] frame is synthesized into the arrival stream so
    /// the round engine can shrink the quorum. Default: ignored (losses
    /// stay fatal, the historical behavior).
    fn set_evict_on_loss(&mut self, _on: bool) {}
    /// Evict `worker` at the leader's initiative (liveness violation):
    /// close its connection, reclaim parked frames (completing their
    /// broadcast handles without error), and mark it dead in the ack
    /// ledger so flow control skips it. Idempotent. Default:
    /// unsupported.
    fn evict_worker(&mut self, _worker: usize) -> anyhow::Result<()> {
        anyhow::bail!("eviction is not supported on this transport (use --transport evloop)")
    }
    /// Re-admit a previously evicted `worker` (it sent a
    /// [`MsgKind::Rejoin`] hello): resume deliveries to it and clear its
    /// dead mark in the ack ledger. On TCP the readiness loop already
    /// re-admitted the connection when it accepted the reconnect, so
    /// this may be a no-op there. Default: unsupported.
    fn rejoin_worker(&mut self, _worker: usize) -> anyhow::Result<()> {
        anyhow::bail!("rejoin is not supported on this transport (use --transport evloop)")
    }
    /// Send one frame to a single worker (the replay path: missed
    /// broadcasts are re-sent to exactly the rejoining worker, in round
    /// order, ahead of any frame broadcast later). Default: unsupported.
    fn send_to(&mut self, _worker: usize, _msg: &Message) -> anyhow::Result<()> {
        anyhow::bail!("targeted sends are not supported on this transport")
    }
}

/// Shared driver for [`ServerEnd::recv_round_streaming_timed`]: pops
/// frames from `next_frame` — which must honor the optional deadline and
/// return `Ok(None)` when it expires with no frame — and dispatches the
/// policy callback's directives. Both transports implement their timed
/// gather with this, so the deadline/directive state machine exists
/// exactly once.
pub(crate) fn drive_timed_stream(
    next_frame: &mut dyn FnMut(Option<Instant>) -> anyhow::Result<Option<Message>>,
    on_msg: &mut dyn FnMut(Message) -> anyhow::Result<StreamDirective>,
) -> anyhow::Result<StreamOutcome> {
    let mut deadline: Option<Instant> = None;
    loop {
        let msg = match next_frame(deadline)? {
            Some(msg) => msg,
            None => return Ok(StreamOutcome::DeadlineExpired),
        };
        match on_msg(msg)? {
            StreamDirective::Wait => deadline = None,
            StreamDirective::WaitUntil(dl) => deadline = Some(dl),
            StreamDirective::Close => return Ok(StreamOutcome::Closed),
        }
    }
}

/// Per-barrier arrival bookkeeping shared by the streaming gathers:
/// fail fast on `WorkerError` frames, reject out-of-range and duplicate
/// worker ids (each worker contributes exactly one frame per barrier).
pub(crate) struct ArrivalSet {
    seen: Vec<bool>,
}

impl ArrivalSet {
    pub(crate) fn new(workers: usize) -> Self {
        Self { seen: vec![false; workers] }
    }

    pub(crate) fn admit(&mut self, msg: &Message) -> anyhow::Result<()> {
        if msg.kind == MsgKind::WorkerError {
            validate_round_batch(std::slice::from_ref(msg))?;
        }
        let id = msg.worker as usize;
        anyhow::ensure!(
            id < self.seen.len(),
            "worker id {id} out of range (M = {})",
            self.seen.len()
        );
        anyhow::ensure!(!self.seen[id], "duplicate frame from worker {id} within one barrier");
        self.seen[id] = true;
        Ok(())
    }
}

/// Shared byte counters (uplink = workers→server, downlink = server→workers).
///
/// `ctrl` counts control-plane frames — today exactly the
/// [`MsgKind::Ack`] traffic of the readiness-loop transport — separately
/// from the data plane, so `up`/`down` totals stay bitwise comparable
/// between the evloop and threaded transports (the equivalence suite's
/// byte-accounting gate).
#[derive(Debug, Default)]
pub struct ByteCounter {
    pub up: AtomicU64,
    pub down: AtomicU64,
    pub ctrl: AtomicU64,
}

impl ByteCounter {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    pub fn add_up(&self, n: usize) {
        self.up.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn add_down(&self, n: usize) {
        self.down.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn add_ctrl(&self, n: usize) {
        self.ctrl.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn up_total(&self) -> u64 {
        self.up.load(Ordering::Relaxed)
    }

    pub fn down_total(&self) -> u64 {
        self.down.load(Ordering::Relaxed)
    }

    pub fn ctrl_total(&self) -> u64 {
        self.ctrl.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broadcast_handle_completes_after_every_delivery() {
        let h = BroadcastHandle::new(2);
        assert!(!h.is_done());
        assert!(h.completed_at().is_none());
        h.mark_delivered();
        assert!(!h.is_done(), "one of two deliveries is not completion");
        let h2 = h.clone();
        let t = std::thread::spawn(move || h2.wait());
        h.mark_delivered();
        t.join().unwrap().unwrap();
        assert!(h.is_done());
        assert!(h.completed_at().is_some());
        // Zero-worker handles (the sync fallback) are born complete.
        let done = BroadcastHandle::new(0);
        assert!(done.is_done());
        done.wait().unwrap();
    }

    #[test]
    fn broadcast_handle_surfaces_the_first_failure() {
        let h = BroadcastHandle::new(2);
        h.mark_failed("worker 1 hung up");
        h.mark_delivered();
        let err = h.wait().unwrap_err();
        assert!(err.to_string().contains("worker 1 hung up"), "{err}");
        assert!(h.is_done());
    }

    #[test]
    fn abandoned_pending_delivery_fails_the_handle_instead_of_hanging() {
        // The anti-hang guard: a delivery dropped without an outcome (a
        // panicking or exiting writer) must complete the handle with an
        // error so wait() returns.
        let h = BroadcastHandle::new(1);
        let pd = PendingDelivery::new(h.clone());
        drop(pd);
        let err = h.wait().unwrap_err();
        assert!(err.to_string().contains("abandoned"), "{err}");
    }

    #[test]
    fn writer_pool_delivers_in_order_and_reports_sticky_failures() {
        // Two sinks: sink 0 collects, sink 1 fails on its second frame.
        // Order must be preserved on the healthy sink, the failure must
        // be sticky (frame 3 on sink 1 fails without delivery), and
        // every handle must complete.
        let collected: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
        let sink0 = Arc::clone(&collected);
        let pool = WriterPool::spawn(
            "test-writer",
            vec![0usize, 1usize],
            2,
            move |w, _sink, msg: &Message| {
                if w == 0 {
                    sink0.lock().unwrap().push(msg.round);
                    Ok(())
                } else if msg.round < 1 {
                    Ok(())
                } else {
                    anyhow::bail!("boom")
                }
            },
        )
        .unwrap();
        let h0 = pool.enqueue(Message::broadcast(0, vec![])).unwrap();
        let h1 = pool.enqueue(Message::broadcast(1, vec![])).unwrap();
        h0.wait().unwrap();
        let err = h1.wait().unwrap_err();
        assert!(err.to_string().contains("worker 1"), "{err}");
        assert!(err.to_string().contains("boom"), "{err}");
        // Sticky: once a handle has reported the failure, the error was
        // recorded first, so the next enqueue surfaces it up front.
        let e = pool.enqueue(Message::broadcast(2, vec![])).unwrap_err();
        assert!(e.to_string().contains("boom"), "{e}");
        drop(pool); // joins the writers
        assert_eq!(*collected.lock().unwrap(), vec![0, 1]);
    }

    #[test]
    fn arrival_set_enforces_barrier_invariants() {
        let mut set = ArrivalSet::new(2);
        set.admit(&Message::payload(1, 0, vec![])).unwrap();
        set.admit(&Message::payload(0, 0, vec![])).unwrap();
        // Duplicate within one barrier.
        let mut dup = ArrivalSet::new(2);
        dup.admit(&Message::payload(0, 0, vec![])).unwrap();
        assert!(dup.admit(&Message::payload(0, 0, vec![])).is_err());
        // Out of range.
        assert!(ArrivalSet::new(2).admit(&Message::payload(5, 0, vec![])).is_err());
        // WorkerError fails fast with the worker's message.
        let err = ArrivalSet::new(2)
            .admit(&Message::worker_error(1, 3, "boom"))
            .unwrap_err();
        assert!(err.to_string().contains("boom"), "{err}");
    }
}
