//! Simulated-network cost model — the substitution for the paper's
//! NCCL/GPU-cluster testbed (DESIGN.md §5).
//!
//! Figure 4 plots *speedup vs number of workers*: time is dominated by
//! `max(compute, communication)` per synchronous round. The model charges:
//!
//! - **uplink (incast)**: all M workers push their payload through the
//!   server's NIC: `t_up = latency + M·bytes_up / server_bw`;
//! - **downlink (broadcast)**: `t_down = latency + M·bytes_down / server_bw`
//!   (a PS unicasts M copies; this is exactly why quantization matters);
//! - **compute**: the per-round gradient time, divided across workers when
//!   the dataset is sharded (epoch semantics) — workers run in parallel, so
//!   per-round compute does not scale with M, but *rounds per epoch* fall
//!   as 1/M (each round consumes M·B samples).
//!
//! All quantities are f64 seconds; the model is deterministic, so speedup
//! curves are exactly reproducible. Measured per-round compute times from
//! the real runtime feed the model (see `exp/fig4.rs`).

/// Parameters of the simulated PS network.
#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    /// Server NIC bandwidth, bytes/second (shared by up- and downlink).
    pub server_bandwidth: f64,
    /// Per-worker NIC bandwidth, bytes/second.
    pub worker_bandwidth: f64,
    /// One-way message latency, seconds (per barrier phase, not per byte).
    pub latency: f64,
}

impl NetworkModel {
    /// 10 GbE datacenter defaults (1.25 GB/s), 50 µs latency.
    pub fn ten_gbe() -> Self {
        Self { server_bandwidth: 1.25e9, worker_bandwidth: 1.25e9, latency: 50e-6 }
    }

    /// 100 GbE / NVLink-ish fabric.
    pub fn hundred_gbe() -> Self {
        Self { server_bandwidth: 12.5e9, worker_bandwidth: 12.5e9, latency: 20e-6 }
    }

    /// 1 GbE commodity cluster — the regime where Fig. 4's gap is widest.
    pub fn one_gbe() -> Self {
        Self { server_bandwidth: 0.125e9, worker_bandwidth: 0.125e9, latency: 100e-6 }
    }

    /// Uplink time for one synchronous gather of `bytes_up` per worker
    /// from `m` workers (server NIC is the bottleneck; each worker's own
    /// NIC bounds its share).
    pub fn t_up(&self, bytes_up: usize, m: usize) -> f64 {
        let serialized = (m as f64 * bytes_up as f64) / self.server_bandwidth;
        let per_worker = bytes_up as f64 / self.worker_bandwidth;
        self.latency + serialized.max(per_worker)
    }

    /// Uplink time until the **K fastest** of `m` pushes of `bytes_up`
    /// each have landed — the communication term of a K-of-M partial
    /// aggregation round (`--policy kofm:K`).
    ///
    /// Deterministic straggler model: worker readiness is staggered
    /// uniformly over `[0, jitter]` seconds (the k-th fastest worker
    /// starts `jitter·(k−1)/(m−1)` late), and the server NIC serializes
    /// the k payloads it actually waits for. With `jitter = 0` and
    /// `k = m` this reduces exactly to [`Self::t_up`]. Monotone
    /// non-decreasing in `k`: waiting for more workers can only take
    /// longer — which is precisely the wall-clock the policy trades
    /// against gradient staleness.
    pub fn t_up_kofm(&self, bytes_up: usize, m: usize, k: usize, jitter: f64) -> f64 {
        assert!(m >= 1, "need at least one worker");
        assert!((1..=m).contains(&k), "K must satisfy 1 <= K <= M (got K={k}, M={m})");
        assert!(jitter >= 0.0, "jitter must be non-negative");
        let spread =
            if m > 1 { jitter * (k - 1) as f64 / (m - 1) as f64 } else { 0.0 };
        let serialized = (k as f64 * bytes_up as f64) / self.server_bandwidth;
        let per_worker = bytes_up as f64 / self.worker_bandwidth;
        self.latency + spread + serialized.max(per_worker)
    }

    /// Downlink time for broadcasting `bytes_down` to `m` workers.
    pub fn t_down(&self, bytes_down: usize, m: usize) -> f64 {
        let serialized = (m as f64 * bytes_down as f64) / self.server_bandwidth;
        let per_worker = bytes_down as f64 / self.worker_bandwidth;
        self.latency + serialized.max(per_worker)
    }

    /// Total communication time for one round.
    pub fn t_round_comm(&self, bytes_up: usize, bytes_down: usize, m: usize) -> f64 {
        self.t_up(bytes_up, m) + self.t_down(bytes_down, m)
    }

    /// Wall-clock for one epoch under data sharding.
    ///
    /// * `samples` — dataset size; each round consumes `m·batch` samples,
    ///   so an epoch is `ceil(samples / (m·batch))` rounds.
    /// * `t_compute` — measured per-round gradient+quantize compute time
    ///   on one worker (rounds of all workers overlap).
    pub fn epoch_time(
        &self,
        samples: usize,
        batch: usize,
        m: usize,
        t_compute: f64,
        bytes_up: usize,
        bytes_down: usize,
    ) -> f64 {
        let rounds = samples.div_ceil(m * batch) as f64;
        rounds * (t_compute + self.t_round_comm(bytes_up, bytes_down, m))
    }

    /// Speedup of running on `m` workers vs 1 worker for the same epoch.
    #[allow(clippy::too_many_arguments)]
    pub fn speedup(
        &self,
        samples: usize,
        batch: usize,
        m: usize,
        t_compute: f64,
        bytes_up: usize,
        bytes_down: usize,
    ) -> f64 {
        let t1 = self.epoch_time(samples, batch, 1, t_compute, bytes_up, bytes_down);
        let tm = self.epoch_time(samples, batch, m, t_compute, bytes_up, bytes_down);
        t1 / tm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uplink_scales_with_workers() {
        let net = NetworkModel::ten_gbe();
        let t4 = net.t_up(1_000_000, 4);
        let t8 = net.t_up(1_000_000, 8);
        assert!(t8 > t4 * 1.5, "t4={t4} t8={t8}");
    }

    #[test]
    fn latency_floors_small_messages() {
        let net = NetworkModel::ten_gbe();
        let t = net.t_up(10, 2);
        assert!(t >= net.latency);
        assert!(t < net.latency * 1.1);
    }

    #[test]
    fn kofm_uplink_is_monotone_in_k_and_matches_t_up_at_full_barrier() {
        for net in [NetworkModel::one_gbe(), NetworkModel::ten_gbe()] {
            let (bytes, m) = (1_000_000usize, 16usize);
            for jitter in [0.0, 5e-3] {
                let mut prev = 0.0;
                for k in 1..=m {
                    let t = net.t_up_kofm(bytes, m, k, jitter);
                    assert!(
                        t >= prev,
                        "t_up_kofm must be monotone in K: k={k} jitter={jitter} {t} < {prev}"
                    );
                    prev = t;
                }
                // Waiting for fewer workers is never slower than the
                // full barrier under the same jitter.
                assert!(net.t_up_kofm(bytes, m, 1, jitter) <= net.t_up_kofm(bytes, m, m, jitter));
            }
            // jitter=0, K=M degenerates to the synchronous incast model.
            let full = net.t_up_kofm(bytes, m, m, 0.0);
            assert!((full - net.t_up(bytes, m)).abs() < 1e-12, "{full} vs {}", net.t_up(bytes, m));
        }
    }

    #[test]
    fn kofm_uplink_jitter_spreads_the_tail() {
        // With nonzero jitter, skipping the slowest workers buys real
        // time: K = M/2 must be strictly cheaper than the full barrier.
        let net = NetworkModel::ten_gbe();
        let (bytes, m) = (100_000usize, 8usize);
        let jitter = 10e-3;
        let half = net.t_up_kofm(bytes, m, m / 2, jitter);
        let full = net.t_up_kofm(bytes, m, m, jitter);
        assert!(half < full, "half={half} full={full}");
    }

    #[test]
    fn epoch_rounds_fall_with_m() {
        let net = NetworkModel::hundred_gbe();
        // communication-free regime: epoch time should scale ~1/M.
        let t1 = net.epoch_time(10_000, 10, 1, 1e-3, 0, 0);
        let t10 = net.epoch_time(10_000, 10, 10, 1e-3, 0, 0);
        assert!((t1 / t10 - 10.0).abs() < 0.5, "{}", t1 / t10);
    }

    #[test]
    fn quantization_beats_fp32_at_scale() {
        // The Fig-4 shape: once comm is a non-trivial fraction of the
        // round, 8-bit payloads give a strictly better speedup than
        // 32-bit. (In the fully comm-saturated PS regime speedups of both
        // saturate — the paper's GPU testbed is compute-dominated, so we
        // test that regime: 50 ms compute vs ~6 ms fp32 comm on 10 GbE.)
        let net = NetworkModel::ten_gbe();
        let d = 1_000_000; // 1M params
        let t_compute = 50e-3;
        let samples = 60_000;
        let batch = 64;
        let m = 32;
        let s_fp32 = net.speedup(samples, batch, m, t_compute, 4 * d, 4 * d);
        let s_8bit = net.speedup(samples, batch, m, t_compute, d, 4 * d);
        assert!(
            s_8bit > s_fp32 * 1.2,
            "8-bit speedup {s_8bit} should beat fp32 {s_fp32}"
        );
    }

    #[test]
    fn speedup_grows_with_m() {
        let net = NetworkModel::ten_gbe();
        let d = 100_000;
        let s2 = net.speedup(60_000, 64, 2, 5e-3, d, 4 * d);
        let s8 = net.speedup(60_000, 64, 8, 5e-3, d, 4 * d);
        let s32 = net.speedup(60_000, 64, 32, 5e-3, d, 4 * d);
        assert!(s2 < s8 && s8 < s32, "s2={s2} s8={s8} s32={s32}");
    }
}
