//! Wire message format shared by every transport.
//!
//! Frame layout (little-endian):
//!
//! ```text
//! [kind:u8][worker:u32][round:u64][len:u32][payload:len bytes][crc32:u32]
//! ```
//!
//! The CRC covers the header + payload and exists for the TCP path
//! (corruption detection in tests uses it too).

use crate::util::bytes::{put_u32, put_u64, Reader};

/// Message discriminator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum MsgKind {
    /// Worker → server: this round's (possibly compressed) payload.
    Payload = 1,
    /// Server → workers: the averaged vector to apply.
    Broadcast = 2,
    /// Server → workers: end of training.
    Shutdown = 3,
    /// Worker → server: fatal worker error (failure injection path).
    WorkerError = 4,
    /// Server → workers: a partially-aggregated broadcast (K-of-M /
    /// deadline round-completion policies). Payload layout:
    /// `[n_bitmap:u32][bitmap:n_bitmap bytes][avg: dim × f32]` — bit m of
    /// the bitmap set ⇔ worker m's payload was included in the average.
    /// Skipped workers re-absorb their entire sent payload into local
    /// error memory (see `WorkerAlgo::absorb_skipped`).
    PartialBroadcast = 5,
    /// Worker → server: "I have *applied* the round-`round` broadcast."
    /// Empty payload. The readiness-loop transport uses these for
    /// ack-based flow control: `--pipeline-depth` bounds the number of
    /// broadcasts a worker has received-but-not-applied, not merely the
    /// number written into its socket, which is what the Lemma-1
    /// staleness bound actually talks about.
    Ack = 6,
    /// Worker → server: re-registration hello from a previously evicted
    /// worker. `round` carries the first round whose broadcast the
    /// worker is missing (its resume point); empty payload. The leader
    /// answers by replaying the missed broadcasts in order from its
    /// replay ledger (or the checkpoint store beyond the ledger's
    /// depth) and re-admitting the worker to the quorum.
    Rejoin = 7,
    /// Leader-internal: "worker `worker` was lost" (payload = the error
    /// text). Never crosses the wire — a transport synthesizes it into
    /// the arrival stream under `--on-worker-loss evict` so a gather
    /// blocked on that worker wakes up and shrinks the quorum instead
    /// of hanging (or aborting, which is what the loss turns into under
    /// `abort`).
    Gone = 8,
    /// Worker → server: session-epoch handshake opener. `round` carries
    /// the last session epoch this worker ran under (0 on a first
    /// connect); the payload is the worker's 8-byte config fingerprint
    /// (LE). Sent by a reconnecting worker before any data frame, so a
    /// leader restarted under different config refuses it *before* state
    /// can diverge. The leader answers with a [`MsgKind::Welcome`].
    Hello = 9,
    /// Server → worker: handshake answer. `round` carries the leader's
    /// current session epoch; the payload is
    /// `[fingerprint:u64 LE][resume_round:u64 LE]` — the leader's config
    /// fingerprint and the round the session (re)starts at (0 for a
    /// fresh run, `manifest.round + 1` after `--resume`). The worker
    /// compares fingerprints and either rolls its own state to
    /// `resume_round` from its snapshot or refuses loudly.
    Welcome = 10,
}

impl MsgKind {
    fn from_u8(v: u8) -> anyhow::Result<Self> {
        Ok(match v {
            1 => Self::Payload,
            2 => Self::Broadcast,
            3 => Self::Shutdown,
            4 => Self::WorkerError,
            5 => Self::PartialBroadcast,
            6 => Self::Ack,
            7 => Self::Rejoin,
            8 => Self::Gone,
            9 => Self::Hello,
            10 => Self::Welcome,
            other => anyhow::bail!("bad message kind {other}"),
        })
    }
}

/// Hard cap on a single frame's wire size (header + payload + crc). A
/// length prefix above this is rejected *before* any buffer allocation,
/// so a corrupt or hostile 4-byte prefix can never trigger a multi-GiB
/// allocation. Shared by the blocking reader and the readiness-loop
/// `FrameAssembler`.
pub const FRAME_CAP: usize = 256 * 1024 * 1024;

/// Smallest legal frame: empty payload — `1 + 4 + 8 + 4 + 0 + 4`.
pub const MIN_FRAME_LEN: usize = 21;

/// A transport message.
#[derive(Debug, Clone, PartialEq)]
pub struct Message {
    pub kind: MsgKind,
    pub worker: u32,
    pub round: u64,
    pub payload: Vec<u8>,
}

impl Message {
    pub fn payload(worker: u32, round: u64, payload: Vec<u8>) -> Self {
        Self { kind: MsgKind::Payload, worker, round, payload }
    }

    pub fn broadcast(round: u64, payload: Vec<u8>) -> Self {
        Self { kind: MsgKind::Broadcast, worker: u32::MAX, round, payload }
    }

    pub fn shutdown(round: u64) -> Self {
        Self { kind: MsgKind::Shutdown, worker: u32::MAX, round, payload: Vec::new() }
    }

    pub fn worker_error(worker: u32, round: u64, what: &str) -> Self {
        Self { kind: MsgKind::WorkerError, worker, round, payload: what.as_bytes().to_vec() }
    }

    /// Worker `worker` has applied the round-`round` broadcast.
    pub fn ack(worker: u32, round: u64) -> Self {
        Self { kind: MsgKind::Ack, worker, round, payload: Vec::new() }
    }

    /// Re-registration hello: worker `worker` reconnects and asks for a
    /// replay of every broadcast from `resume_round` on.
    pub fn rejoin(worker: u32, resume_round: u64) -> Self {
        Self { kind: MsgKind::Rejoin, worker, round: resume_round, payload: Vec::new() }
    }

    /// Leader-internal loss notification: worker `worker` died with
    /// `what` at (leader) round `round`. Synthesized by transports under
    /// eviction mode; never written to a socket.
    pub fn gone(worker: u32, round: u64, what: &str) -> Self {
        Self { kind: MsgKind::Gone, worker, round, payload: what.as_bytes().to_vec() }
    }

    /// Session handshake opener: worker `worker` last ran under session
    /// `epoch` with config fingerprint `fingerprint`.
    pub fn hello(worker: u32, epoch: u64, fingerprint: u64) -> Self {
        Self {
            kind: MsgKind::Hello,
            worker,
            round: epoch,
            payload: fingerprint.to_le_bytes().to_vec(),
        }
    }

    /// Session handshake answer: the leader runs session `epoch` with
    /// `fingerprint`, and this connection's first round is `resume_round`.
    pub fn welcome(worker: u32, epoch: u64, fingerprint: u64, resume_round: u64) -> Self {
        let mut payload = Vec::with_capacity(16);
        put_u64(&mut payload, fingerprint);
        put_u64(&mut payload, resume_round);
        Self { kind: MsgKind::Welcome, worker, round: epoch, payload }
    }

    /// Parse a [`MsgKind::Hello`] payload → the worker's fingerprint.
    pub fn hello_fingerprint(&self) -> anyhow::Result<u64> {
        anyhow::ensure!(self.kind == MsgKind::Hello, "not a hello frame");
        let mut r = Reader::new(&self.payload);
        let f = r.u64()?;
        anyhow::ensure!(r.remaining() == 0, "trailing bytes in hello payload");
        Ok(f)
    }

    /// Parse a [`MsgKind::Welcome`] payload → `(fingerprint, resume_round)`.
    pub fn welcome_parts(&self) -> anyhow::Result<(u64, u64)> {
        anyhow::ensure!(self.kind == MsgKind::Welcome, "not a welcome frame");
        let mut r = Reader::new(&self.payload);
        let f = r.u64()?;
        let resume = r.u64()?;
        anyhow::ensure!(r.remaining() == 0, "trailing bytes in welcome payload");
        Ok((f, resume))
    }

    /// Build a [`MsgKind::PartialBroadcast`] frame: the inclusion bitmap
    /// (bit m set ⇔ worker m's payload entered the average) followed by
    /// the averaged f32 vector.
    pub fn partial_broadcast(round: u64, included: &[bool], avg: &[f32]) -> Self {
        let payload = Self::partial_broadcast_prefix(included, avg.len());
        Self::partial_broadcast_from_prefix(round, payload, avg)
    }

    /// Everything of a partial-broadcast payload that does **not** need
    /// the averaged values: the bitmap header, in a buffer pre-sized for
    /// the `dim` f32s to follow. The pipelined leader builds this while
    /// the offloaded reduce is still folding, then completes the frame
    /// with [`Self::partial_broadcast_from_prefix`] once the mean lands.
    pub fn partial_broadcast_prefix(included: &[bool], dim: usize) -> Vec<u8> {
        let n_bitmap = included.len().div_ceil(8);
        let mut payload = Vec::with_capacity(4 + n_bitmap + 4 * dim);
        put_u32(&mut payload, n_bitmap as u32);
        for chunk in included.chunks(8) {
            let mut byte = 0u8;
            for (bit, &inc) in chunk.iter().enumerate() {
                if inc {
                    byte |= 1 << bit;
                }
            }
            payload.push(byte);
        }
        payload
    }

    /// Second half of [`Self::partial_broadcast_prefix`]: append the
    /// averaged vector and wrap the frame.
    pub fn partial_broadcast_from_prefix(round: u64, mut payload: Vec<u8>, avg: &[f32]) -> Self {
        crate::util::bytes::put_f32_slice(&mut payload, avg);
        Self { kind: MsgKind::PartialBroadcast, worker: u32::MAX, round, payload }
    }

    /// Total frame size on the wire.
    pub fn frame_len(&self) -> usize {
        1 + 4 + 8 + 4 + self.payload.len() + 4
    }

    /// Serialize to the framed wire format.
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(self.frame_len());
        buf.push(self.kind as u8);
        put_u32(&mut buf, self.worker);
        put_u64(&mut buf, self.round);
        put_u32(&mut buf, self.payload.len() as u32);
        buf.extend_from_slice(&self.payload);
        let crc = crc32(&buf);
        put_u32(&mut buf, crc);
        buf
    }

    /// Parse one frame (must be exactly one frame).
    pub fn decode(bytes: &[u8]) -> anyhow::Result<Self> {
        if bytes.len() < MIN_FRAME_LEN {
            anyhow::bail!("frame too short: {}", bytes.len());
        }
        if bytes.len() > FRAME_CAP {
            anyhow::bail!("frame length {} exceeds cap", bytes.len());
        }
        let body = &bytes[..bytes.len() - 4];
        let mut tail = Reader::new(&bytes[bytes.len() - 4..]);
        let want_crc = tail.u32()?;
        let got_crc = crc32(body);
        if want_crc != got_crc {
            anyhow::bail!("crc mismatch: frame {want_crc:#x} computed {got_crc:#x}");
        }
        let mut r = Reader::new(body);
        let kind = MsgKind::from_u8(r.u8()?)?;
        let worker = r.u32()?;
        let round = r.u64()?;
        let len = r.u32()? as usize;
        let payload = r.bytes(len)?.to_vec();
        if r.remaining() != 0 {
            anyhow::bail!("trailing bytes in frame");
        }
        Ok(Self { kind, worker, round, payload })
    }
}

/// Incremental decoder for the length-prefixed TCP framing
/// (`[frame_len:u32 LE][frame bytes]`*): feed it byte chunks of any
/// size — single bytes, half a length prefix, three frames at once — and
/// it hands back every complete [`Message`] in arrival order.
///
/// This is the read half of the readiness-loop transport's nonblocking
/// state machine, but it is also the *hardened* frame decoder: a length
/// prefix outside `[MIN_FRAME_LEN, FRAME_CAP]` is rejected with an
/// explicit error before a single payload byte is buffered (no panic, no
/// attacker-sized allocation), and [`FrameAssembler::finish`] turns an
/// EOF in the middle of a frame into an explicit truncation error
/// instead of silent data loss.
///
/// Once an error is returned the assembler is poisoned: every later
/// `push` fails with the same diagnosis (a corrupt stream has no
/// resynchronization point).
#[derive(Default)]
pub struct FrameAssembler {
    /// Bytes of the 4-byte length prefix accumulated so far.
    prefix: Vec<u8>,
    /// Frame bytes accumulated so far (empty while reading the prefix).
    frame: Vec<u8>,
    /// Total frame length announced by the prefix (0 while reading it).
    want: usize,
    poisoned: Option<String>,
}

impl FrameAssembler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Consume `chunk`, appending every frame it completes to `out`.
    /// Returns the number of messages completed by this chunk.
    pub fn push(&mut self, mut chunk: &[u8], out: &mut Vec<Message>) -> anyhow::Result<usize> {
        if let Some(e) = &self.poisoned {
            anyhow::bail!("frame stream already failed: {e}");
        }
        let mut completed = 0;
        while !chunk.is_empty() {
            if self.want == 0 {
                // Accumulating the 4-byte length prefix.
                let take = chunk.len().min(4 - self.prefix.len());
                self.prefix.extend_from_slice(&chunk[..take]);
                chunk = &chunk[take..];
                if self.prefix.len() < 4 {
                    continue;
                }
                let len =
                    u32::from_le_bytes(self.prefix[..4].try_into().expect("4-byte prefix"))
                        as usize;
                self.prefix.clear();
                if len > FRAME_CAP {
                    return Err(self.poison(format!("frame length {len} exceeds cap")));
                }
                if len < MIN_FRAME_LEN {
                    return Err(self.poison(format!("frame length {len} below minimum")));
                }
                self.want = len;
                self.frame.reserve(len);
            } else {
                let take = chunk.len().min(self.want - self.frame.len());
                self.frame.extend_from_slice(&chunk[..take]);
                chunk = &chunk[take..];
                if self.frame.len() == self.want {
                    let msg = match Message::decode(&self.frame) {
                        Ok(m) => m,
                        Err(e) => return Err(self.poison(e.to_string())),
                    };
                    self.frame.clear();
                    self.want = 0;
                    out.push(msg);
                    completed += 1;
                }
            }
        }
        Ok(completed)
    }

    /// Whether the stream is at a frame boundary (nothing buffered).
    pub fn is_idle(&self) -> bool {
        self.prefix.is_empty() && self.want == 0 && self.poisoned.is_none()
    }

    /// Call at EOF: a stream that ends mid-prefix or mid-frame is a
    /// truncation, reported explicitly.
    pub fn finish(&self) -> anyhow::Result<()> {
        if let Some(e) = &self.poisoned {
            anyhow::bail!("frame stream already failed: {e}");
        }
        if !self.prefix.is_empty() {
            anyhow::bail!(
                "truncated frame: stream ended {} bytes into the length prefix",
                self.prefix.len()
            );
        }
        if self.want != 0 {
            anyhow::bail!(
                "truncated frame: stream ended {} bytes into a {}-byte frame",
                self.frame.len(),
                self.want
            );
        }
        Ok(())
    }

    fn poison(&mut self, what: String) -> anyhow::Error {
        self.poisoned = Some(what.clone());
        anyhow::anyhow!(what)
    }
}

/// Read the inclusion-bitmap header of a [`MsgKind::PartialBroadcast`]
/// payload, leaving the reader positioned at the f32 average.
pub fn read_inclusion_bitmap<'a>(r: &mut Reader<'a>) -> anyhow::Result<&'a [u8]> {
    let n = r.u32()? as usize;
    Ok(r.bytes(n)?)
}

/// Whether bit `worker` of an inclusion bitmap is set (out-of-range bits
/// read as not-included).
pub fn bitmap_included(bitmap: &[u8], worker: u32) -> bool {
    let idx = worker as usize;
    bitmap.get(idx / 8).map(|b| (b >> (idx % 8)) & 1 == 1).unwrap_or(false)
}

/// CRC-32 (IEEE 802.3, reflected). Dispatches between the byte-at-a-time
/// baseline and a slicing-by-8 arm on the process-global
/// [`crate::kernels`] mode; both compute the mathematically identical
/// CRC, so frames written under one mode verify under the other.
pub fn crc32(data: &[u8]) -> u32 {
    match crate::kernels::mode() {
        crate::config::KernelMode::Simd => crc32_slice8(data),
        crate::config::KernelMode::Scalar => crc32_scalar(data),
    }
}

fn crc32_base_table() -> [u32; 256] {
    let mut t = [0u32; 256];
    for (i, e) in t.iter_mut().enumerate() {
        let mut c = i as u32;
        for _ in 0..8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
        }
        *e = c;
    }
    t
}

/// Scalar arm of [`crc32`]: one table lookup per byte.
pub fn crc32_scalar(data: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(crc32_base_table);
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

/// Slicing-by-8 arm of [`crc32`]: eight bytes per iteration through eight
/// precomputed tables (the standard zlib-style construction — table k
/// advances a byte's contribution k more positions through the
/// polynomial, so the eight lookups are independent and the serial
/// per-byte dependency chain disappears). Identical output to
/// [`crc32_scalar`] by construction of the tables.
pub fn crc32_slice8(data: &[u8]) -> u32 {
    static TABLES: std::sync::OnceLock<[[u32; 256]; 8]> = std::sync::OnceLock::new();
    let t = TABLES.get_or_init(|| {
        let base = crc32_base_table();
        let mut t = [[0u32; 256]; 8];
        t[0] = base;
        for b in 0..256 {
            for k in 1..8 {
                let prev = t[k - 1][b];
                t[k][b] = (prev >> 8) ^ t[0][(prev & 0xFF) as usize];
            }
        }
        t
    });
    let mut crc = 0xFFFF_FFFFu32;
    let mut chunks = data.chunks_exact(8);
    for d in &mut chunks {
        let d: &[u8; 8] = d.try_into().expect("exact chunk");
        let x = crc ^ u32::from_le_bytes([d[0], d[1], d[2], d[3]]);
        crc = t[7][(x & 0xFF) as usize]
            ^ t[6][((x >> 8) & 0xFF) as usize]
            ^ t[5][((x >> 16) & 0xFF) as usize]
            ^ t[4][(x >> 24) as usize]
            ^ t[3][d[4] as usize]
            ^ t[2][d[5] as usize]
            ^ t[1][d[6] as usize]
            ^ t[0][d[7] as usize];
    }
    for &b in chunks.remainder() {
        crc = t[0][((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips() {
        let m = Message::payload(3, 17, vec![1, 2, 3, 4, 5]);
        let bytes = m.encode();
        assert_eq!(bytes.len(), m.frame_len());
        let back = Message::decode(&bytes).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn detects_corruption() {
        let m = Message::broadcast(2, vec![9; 64]);
        let mut bytes = m.encode();
        bytes[10] ^= 0xFF;
        assert!(Message::decode(&bytes).is_err());
    }

    #[test]
    fn rejects_short_frames() {
        assert!(Message::decode(&[1, 2, 3]).is_err());
    }

    #[test]
    fn crc32_known_vector() {
        // CRC-32("123456789") = 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32_scalar(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32_slice8(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn crc32_arms_agree_on_all_alignments() {
        // Every length 0..64 plus larger buffers: the slicing-by-8 arm
        // must equal the byte-at-a-time baseline regardless of how many
        // ragged tail bytes remain.
        let mut state = 0x1234_5678u32;
        let data: Vec<u8> = (0..1024)
            .map(|_| {
                state = state.wrapping_mul(1_664_525).wrapping_add(1_013_904_223);
                (state >> 24) as u8
            })
            .collect();
        for n in (0..64).chain([65, 127, 128, 129, 511, 1024]) {
            assert_eq!(crc32_scalar(&data[..n]), crc32_slice8(&data[..n]), "n={n}");
        }
    }

    #[test]
    fn kinds_round_trip() {
        for m in [
            Message::payload(0, 0, vec![]),
            Message::broadcast(1, vec![1]),
            Message::shutdown(9),
            Message::worker_error(2, 3, "boom"),
            Message::partial_broadcast(4, &[true, false, true], &[1.0, -2.0]),
            Message::ack(5, 11),
            Message::rejoin(6, 12),
            Message::gone(7, 13, "socket failed"),
            Message::hello(8, 2, 0xAABB_CCDD_EEFF_0011),
            Message::welcome(8, 3, 0xAABB_CCDD_EEFF_0011, 14),
        ] {
            assert_eq!(Message::decode(&m.encode()).unwrap(), m);
        }
    }

    #[test]
    fn handshake_payloads_parse_back() {
        let h = Message::hello(4, 7, u64::MAX);
        assert_eq!(h.round, 7, "hello carries the epoch in the round field");
        assert_eq!(h.hello_fingerprint().unwrap(), u64::MAX);
        let w = Message::welcome(4, 8, 0x0123_4567_89AB_CDEF, 42);
        assert_eq!(w.round, 8);
        assert_eq!(w.welcome_parts().unwrap(), (0x0123_4567_89AB_CDEF, 42));
        // Cross-parsing is refused.
        assert!(h.welcome_parts().is_err());
        assert!(w.hello_fingerprint().is_err());
    }

    #[test]
    fn ack_frames_are_minimal() {
        let m = Message::ack(7, 42);
        assert_eq!(m.kind, MsgKind::Ack);
        assert!(m.payload.is_empty());
        assert_eq!(m.frame_len(), MIN_FRAME_LEN);
    }

    /// The TCP framing of a message: `[frame_len:u32 LE][frame]`.
    fn framed(m: &Message) -> Vec<u8> {
        let frame = m.encode();
        let mut wire = (frame.len() as u32).to_le_bytes().to_vec();
        wire.extend_from_slice(&frame);
        wire
    }

    #[test]
    fn assembler_reassembles_frames_split_at_every_byte_boundary() {
        // Satellite 1's split-point test: each frame in the stream is
        // fragmented at every possible byte boundary (including inside
        // the length prefix) and must reassemble byte-identically.
        let msgs = [
            Message::payload(3, 17, (0..37u8).collect()),
            Message::ack(3, 17),
            Message::broadcast(18, vec![0xAB; 5]),
        ];
        for m in &msgs {
            let wire = framed(m);
            for split in 0..=wire.len() {
                let mut asm = FrameAssembler::new();
                let mut out = Vec::new();
                asm.push(&wire[..split], &mut out).unwrap();
                asm.push(&wire[split..], &mut out).unwrap();
                assert_eq!(out, vec![m.clone()], "split at {split}");
                assert!(asm.is_idle());
                asm.finish().unwrap();
            }
        }
        // And a multi-frame stream delivered one byte at a time.
        let mut wire = Vec::new();
        for m in &msgs {
            wire.extend_from_slice(&framed(m));
        }
        let mut asm = FrameAssembler::new();
        let mut out = Vec::new();
        for b in &wire {
            asm.push(std::slice::from_ref(b), &mut out).unwrap();
        }
        assert_eq!(out, msgs.to_vec());
        asm.finish().unwrap();
    }

    #[test]
    fn assembler_rejects_oversized_length_prefix_without_allocating() {
        // A hostile prefix claiming a 4 GiB frame must fail before any
        // payload buffering (the error arrives with ZERO frame bytes
        // fed), and the assembler stays poisoned afterwards.
        let mut asm = FrameAssembler::new();
        let mut out = Vec::new();
        let err = asm.push(&u32::MAX.to_le_bytes(), &mut out).unwrap_err();
        assert!(err.to_string().contains("exceeds cap"), "{err}");
        assert!(out.is_empty());
        let err = asm.push(&[0u8; 8], &mut out).unwrap_err();
        assert!(err.to_string().contains("already failed"), "{err}");
        assert!(asm.finish().is_err());
    }

    #[test]
    fn assembler_rejects_undersized_length_prefix() {
        // A prefix smaller than the smallest legal frame can never carry
        // a valid CRC-bearing frame: explicit error, not a decode panic.
        let mut asm = FrameAssembler::new();
        let mut out = Vec::new();
        let err = asm.push(&(MIN_FRAME_LEN as u32 - 1).to_le_bytes(), &mut out).unwrap_err();
        assert!(err.to_string().contains("below minimum"), "{err}");
    }

    #[test]
    fn assembler_reports_truncation_at_every_cut_point() {
        let wire = framed(&Message::payload(1, 2, vec![7; 16]));
        for cut in 1..wire.len() {
            let mut asm = FrameAssembler::new();
            let mut out = Vec::new();
            asm.push(&wire[..cut], &mut out).unwrap();
            assert!(out.is_empty(), "cut at {cut}");
            let err = asm.finish().unwrap_err();
            assert!(err.to_string().contains("truncated frame"), "cut at {cut}: {err}");
        }
    }

    #[test]
    fn assembler_poisons_on_crc_corruption() {
        let mut wire = framed(&Message::payload(1, 2, vec![7; 16]));
        let n = wire.len();
        wire[n - 6] ^= 0xFF;
        let mut asm = FrameAssembler::new();
        let mut out = Vec::new();
        let err = asm.push(&wire, &mut out).unwrap_err();
        assert!(err.to_string().contains("crc mismatch"), "{err}");
        assert!(asm.push(&[0], &mut out).is_err());
    }

    #[test]
    fn partial_broadcast_bitmap_and_average_round_trip() {
        // 10 workers forces a two-byte bitmap with a partial tail byte.
        let included: Vec<bool> = (0..10).map(|w| w % 3 == 0).collect();
        let avg = [0.5f32, -1.25, 3.0];
        let m = Message::partial_broadcast(7, &included, &avg);
        assert_eq!(m.kind, MsgKind::PartialBroadcast);
        assert_eq!(m.round, 7);
        let mut r = Reader::new(&m.payload);
        let bitmap = read_inclusion_bitmap(&mut r).unwrap();
        assert_eq!(bitmap.len(), 2);
        for (w, &inc) in included.iter().enumerate() {
            assert_eq!(bitmap_included(bitmap, w as u32), inc, "worker {w}");
        }
        // Out-of-range bits read as skipped.
        assert!(!bitmap_included(bitmap, 16));
        assert!(!bitmap_included(bitmap, 1_000_000));
        assert_eq!(r.f32_vec(3).unwrap(), avg.to_vec());
        assert_eq!(r.remaining(), 0);
    }
}
