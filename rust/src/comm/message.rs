//! Wire message format shared by every transport.
//!
//! Frame layout (little-endian):
//!
//! ```text
//! [kind:u8][worker:u32][round:u64][len:u32][payload:len bytes][crc32:u32]
//! ```
//!
//! The CRC covers the header + payload and exists for the TCP path
//! (corruption detection in tests uses it too).

use crate::util::bytes::{put_u32, put_u64, Reader};

/// Message discriminator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum MsgKind {
    /// Worker → server: this round's (possibly compressed) payload.
    Payload = 1,
    /// Server → workers: the averaged vector to apply.
    Broadcast = 2,
    /// Server → workers: end of training.
    Shutdown = 3,
    /// Worker → server: fatal worker error (failure injection path).
    WorkerError = 4,
    /// Server → workers: a partially-aggregated broadcast (K-of-M /
    /// deadline round-completion policies). Payload layout:
    /// `[n_bitmap:u32][bitmap:n_bitmap bytes][avg: dim × f32]` — bit m of
    /// the bitmap set ⇔ worker m's payload was included in the average.
    /// Skipped workers re-absorb their entire sent payload into local
    /// error memory (see `WorkerAlgo::absorb_skipped`).
    PartialBroadcast = 5,
}

impl MsgKind {
    fn from_u8(v: u8) -> anyhow::Result<Self> {
        Ok(match v {
            1 => Self::Payload,
            2 => Self::Broadcast,
            3 => Self::Shutdown,
            4 => Self::WorkerError,
            5 => Self::PartialBroadcast,
            other => anyhow::bail!("bad message kind {other}"),
        })
    }
}

/// A transport message.
#[derive(Debug, Clone, PartialEq)]
pub struct Message {
    pub kind: MsgKind,
    pub worker: u32,
    pub round: u64,
    pub payload: Vec<u8>,
}

impl Message {
    pub fn payload(worker: u32, round: u64, payload: Vec<u8>) -> Self {
        Self { kind: MsgKind::Payload, worker, round, payload }
    }

    pub fn broadcast(round: u64, payload: Vec<u8>) -> Self {
        Self { kind: MsgKind::Broadcast, worker: u32::MAX, round, payload }
    }

    pub fn shutdown(round: u64) -> Self {
        Self { kind: MsgKind::Shutdown, worker: u32::MAX, round, payload: Vec::new() }
    }

    pub fn worker_error(worker: u32, round: u64, what: &str) -> Self {
        Self { kind: MsgKind::WorkerError, worker, round, payload: what.as_bytes().to_vec() }
    }

    /// Build a [`MsgKind::PartialBroadcast`] frame: the inclusion bitmap
    /// (bit m set ⇔ worker m's payload entered the average) followed by
    /// the averaged f32 vector.
    pub fn partial_broadcast(round: u64, included: &[bool], avg: &[f32]) -> Self {
        let payload = Self::partial_broadcast_prefix(included, avg.len());
        Self::partial_broadcast_from_prefix(round, payload, avg)
    }

    /// Everything of a partial-broadcast payload that does **not** need
    /// the averaged values: the bitmap header, in a buffer pre-sized for
    /// the `dim` f32s to follow. The pipelined leader builds this while
    /// the offloaded reduce is still folding, then completes the frame
    /// with [`Self::partial_broadcast_from_prefix`] once the mean lands.
    pub fn partial_broadcast_prefix(included: &[bool], dim: usize) -> Vec<u8> {
        let n_bitmap = included.len().div_ceil(8);
        let mut payload = Vec::with_capacity(4 + n_bitmap + 4 * dim);
        put_u32(&mut payload, n_bitmap as u32);
        for chunk in included.chunks(8) {
            let mut byte = 0u8;
            for (bit, &inc) in chunk.iter().enumerate() {
                if inc {
                    byte |= 1 << bit;
                }
            }
            payload.push(byte);
        }
        payload
    }

    /// Second half of [`Self::partial_broadcast_prefix`]: append the
    /// averaged vector and wrap the frame.
    pub fn partial_broadcast_from_prefix(round: u64, mut payload: Vec<u8>, avg: &[f32]) -> Self {
        crate::util::bytes::put_f32_slice(&mut payload, avg);
        Self { kind: MsgKind::PartialBroadcast, worker: u32::MAX, round, payload }
    }

    /// Total frame size on the wire.
    pub fn frame_len(&self) -> usize {
        1 + 4 + 8 + 4 + self.payload.len() + 4
    }

    /// Serialize to the framed wire format.
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(self.frame_len());
        buf.push(self.kind as u8);
        put_u32(&mut buf, self.worker);
        put_u64(&mut buf, self.round);
        put_u32(&mut buf, self.payload.len() as u32);
        buf.extend_from_slice(&self.payload);
        let crc = crc32(&buf);
        put_u32(&mut buf, crc);
        buf
    }

    /// Parse one frame (must be exactly one frame).
    pub fn decode(bytes: &[u8]) -> anyhow::Result<Self> {
        if bytes.len() < 1 + 4 + 8 + 4 + 4 {
            anyhow::bail!("frame too short: {}", bytes.len());
        }
        let body = &bytes[..bytes.len() - 4];
        let mut tail = Reader::new(&bytes[bytes.len() - 4..]);
        let want_crc = tail.u32()?;
        let got_crc = crc32(body);
        if want_crc != got_crc {
            anyhow::bail!("crc mismatch: frame {want_crc:#x} computed {got_crc:#x}");
        }
        let mut r = Reader::new(body);
        let kind = MsgKind::from_u8(r.u8()?)?;
        let worker = r.u32()?;
        let round = r.u64()?;
        let len = r.u32()? as usize;
        let payload = r.bytes(len)?.to_vec();
        if r.remaining() != 0 {
            anyhow::bail!("trailing bytes in frame");
        }
        Ok(Self { kind, worker, round, payload })
    }
}

/// Read the inclusion-bitmap header of a [`MsgKind::PartialBroadcast`]
/// payload, leaving the reader positioned at the f32 average.
pub fn read_inclusion_bitmap<'a>(r: &mut Reader<'a>) -> anyhow::Result<&'a [u8]> {
    let n = r.u32()? as usize;
    Ok(r.bytes(n)?)
}

/// Whether bit `worker` of an inclusion bitmap is set (out-of-range bits
/// read as not-included).
pub fn bitmap_included(bitmap: &[u8], worker: u32) -> bool {
    let idx = worker as usize;
    bitmap.get(idx / 8).map(|b| (b >> (idx % 8)) & 1 == 1).unwrap_or(false)
}

/// CRC-32 (IEEE 802.3, reflected). Dispatches between the byte-at-a-time
/// baseline and a slicing-by-8 arm on the process-global
/// [`crate::kernels`] mode; both compute the mathematically identical
/// CRC, so frames written under one mode verify under the other.
pub fn crc32(data: &[u8]) -> u32 {
    match crate::kernels::mode() {
        crate::config::KernelMode::Simd => crc32_slice8(data),
        crate::config::KernelMode::Scalar => crc32_scalar(data),
    }
}

fn crc32_base_table() -> [u32; 256] {
    let mut t = [0u32; 256];
    for (i, e) in t.iter_mut().enumerate() {
        let mut c = i as u32;
        for _ in 0..8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
        }
        *e = c;
    }
    t
}

/// Scalar arm of [`crc32`]: one table lookup per byte.
pub fn crc32_scalar(data: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(crc32_base_table);
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

/// Slicing-by-8 arm of [`crc32`]: eight bytes per iteration through eight
/// precomputed tables (the standard zlib-style construction — table k
/// advances a byte's contribution k more positions through the
/// polynomial, so the eight lookups are independent and the serial
/// per-byte dependency chain disappears). Identical output to
/// [`crc32_scalar`] by construction of the tables.
pub fn crc32_slice8(data: &[u8]) -> u32 {
    static TABLES: std::sync::OnceLock<[[u32; 256]; 8]> = std::sync::OnceLock::new();
    let t = TABLES.get_or_init(|| {
        let base = crc32_base_table();
        let mut t = [[0u32; 256]; 8];
        t[0] = base;
        for b in 0..256 {
            for k in 1..8 {
                let prev = t[k - 1][b];
                t[k][b] = (prev >> 8) ^ t[0][(prev & 0xFF) as usize];
            }
        }
        t
    });
    let mut crc = 0xFFFF_FFFFu32;
    let mut chunks = data.chunks_exact(8);
    for d in &mut chunks {
        let d: &[u8; 8] = d.try_into().expect("exact chunk");
        let x = crc ^ u32::from_le_bytes([d[0], d[1], d[2], d[3]]);
        crc = t[7][(x & 0xFF) as usize]
            ^ t[6][((x >> 8) & 0xFF) as usize]
            ^ t[5][((x >> 16) & 0xFF) as usize]
            ^ t[4][(x >> 24) as usize]
            ^ t[3][d[4] as usize]
            ^ t[2][d[5] as usize]
            ^ t[1][d[6] as usize]
            ^ t[0][d[7] as usize];
    }
    for &b in chunks.remainder() {
        crc = t[0][((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips() {
        let m = Message::payload(3, 17, vec![1, 2, 3, 4, 5]);
        let bytes = m.encode();
        assert_eq!(bytes.len(), m.frame_len());
        let back = Message::decode(&bytes).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn detects_corruption() {
        let m = Message::broadcast(2, vec![9; 64]);
        let mut bytes = m.encode();
        bytes[10] ^= 0xFF;
        assert!(Message::decode(&bytes).is_err());
    }

    #[test]
    fn rejects_short_frames() {
        assert!(Message::decode(&[1, 2, 3]).is_err());
    }

    #[test]
    fn crc32_known_vector() {
        // CRC-32("123456789") = 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32_scalar(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32_slice8(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn crc32_arms_agree_on_all_alignments() {
        // Every length 0..64 plus larger buffers: the slicing-by-8 arm
        // must equal the byte-at-a-time baseline regardless of how many
        // ragged tail bytes remain.
        let mut state = 0x1234_5678u32;
        let data: Vec<u8> = (0..1024)
            .map(|_| {
                state = state.wrapping_mul(1_664_525).wrapping_add(1_013_904_223);
                (state >> 24) as u8
            })
            .collect();
        for n in (0..64).chain([65, 127, 128, 129, 511, 1024]) {
            assert_eq!(crc32_scalar(&data[..n]), crc32_slice8(&data[..n]), "n={n}");
        }
    }

    #[test]
    fn kinds_round_trip() {
        for m in [
            Message::payload(0, 0, vec![]),
            Message::broadcast(1, vec![1]),
            Message::shutdown(9),
            Message::worker_error(2, 3, "boom"),
            Message::partial_broadcast(4, &[true, false, true], &[1.0, -2.0]),
        ] {
            assert_eq!(Message::decode(&m.encode()).unwrap(), m);
        }
    }

    #[test]
    fn partial_broadcast_bitmap_and_average_round_trip() {
        // 10 workers forces a two-byte bitmap with a partial tail byte.
        let included: Vec<bool> = (0..10).map(|w| w % 3 == 0).collect();
        let avg = [0.5f32, -1.25, 3.0];
        let m = Message::partial_broadcast(7, &included, &avg);
        assert_eq!(m.kind, MsgKind::PartialBroadcast);
        assert_eq!(m.round, 7);
        let mut r = Reader::new(&m.payload);
        let bitmap = read_inclusion_bitmap(&mut r).unwrap();
        assert_eq!(bitmap.len(), 2);
        for (w, &inc) in included.iter().enumerate() {
            assert_eq!(bitmap_included(bitmap, w as u32), inc, "worker {w}");
        }
        // Out-of-range bits read as skipped.
        assert!(!bitmap_included(bitmap, 16));
        assert!(!bitmap_included(bitmap, 1_000_000));
        assert_eq!(r.f32_vec(3).unwrap(), avg.to_vec());
        assert_eq!(r.remaining(), 0);
    }
}
