//! Deterministic straggler injection for the in-process transport.
//!
//! A [`DelayPlan`] is a per-(worker, round) **gate/permit schedule**: the
//! transport consults it just before a worker's payload frame enters the
//! uplink channel, and a held gate blocks the send until the controlling
//! test or benchmark releases it. Because the block happens *before* the
//! frame becomes visible to the leader, a scripted scenario can assert
//! structural facts ("this round closed while worker 3's gate was still
//! held") instead of racing against `sleep` timings — which is how
//! `benches/bench_policy.rs` and `tests/integration_policy.rs` prove
//! that K-of-M / deadline rounds close without waiting on a held-out
//! worker.
//!
//! Semantics:
//! - [`DelayPlan::hold`] gates `(worker, round)`; a later
//!   [`DelayPlan::release`] opens it (releasing an un-held gate is a
//!   no-op, so pre-issuing permits is harmless).
//! - Sends that were never held pass through untouched — a plan-free
//!   cluster behaves exactly like one built by
//!   [`super::inproc_cluster`].
//! - A gate held longer than [`DelayPlan::MAX_WAIT`] panics on the
//!   blocked worker thread: a forgotten `release` becomes a loud test
//!   failure rather than a CI hang.

use std::collections::HashSet;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

struct Inner {
    held: Mutex<HashSet<(u32, u64)>>,
    cv: Condvar,
}

/// Shared gate/permit schedule (cheaply clonable handle).
#[derive(Clone)]
pub struct DelayPlan {
    inner: Arc<Inner>,
}

impl Default for DelayPlan {
    fn default() -> Self {
        Self::new()
    }
}

impl DelayPlan {
    /// Upper bound a gated send will block before panicking — converts a
    /// missing `release` into a failure instead of a hang.
    pub const MAX_WAIT: Duration = Duration::from_secs(30);

    pub fn new() -> Self {
        Self { inner: Arc::new(Inner { held: Mutex::new(HashSet::new()), cv: Condvar::new() }) }
    }

    /// Gate worker `worker`'s round-`round` payload send until released.
    pub fn hold(&self, worker: u32, round: u64) {
        self.inner.held.lock().unwrap().insert((worker, round));
    }

    /// Open the gate for `(worker, round)` (no-op if never held).
    pub fn release(&self, worker: u32, round: u64) {
        self.inner.held.lock().unwrap().remove(&(worker, round));
        self.inner.cv.notify_all();
    }

    /// Open every gate (teardown safety for scripted scenarios).
    pub fn release_all(&self) {
        self.inner.held.lock().unwrap().clear();
        self.inner.cv.notify_all();
    }

    /// Whether `(worker, round)` is currently gated — the structural
    /// assertion scripted benchmarks use ("the round closed while this
    /// gate was still held").
    pub fn is_held(&self, worker: u32, round: u64) -> bool {
        self.inner.held.lock().unwrap().contains(&(worker, round))
    }

    /// Block while `(worker, round)` is gated (called by the transport
    /// on the sending worker's thread).
    pub(crate) fn wait(&self, worker: u32, round: u64) {
        let start = Instant::now();
        let mut held = self.inner.held.lock().unwrap();
        while held.contains(&(worker, round)) {
            let elapsed = start.elapsed();
            assert!(
                elapsed < Self::MAX_WAIT,
                "DelayPlan gate (worker {worker}, round {round}) held for more than \
                 {:?} — missing release()?",
                Self::MAX_WAIT
            );
            let (guard, _) =
                self.inner.cv.wait_timeout(held, Self::MAX_WAIT - elapsed).unwrap();
            held = guard;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unheld_gates_pass_through_immediately() {
        let plan = DelayPlan::new();
        plan.wait(0, 0); // must not block
        assert!(!plan.is_held(0, 0));
    }

    #[test]
    fn release_is_a_permit_when_issued_first() {
        let plan = DelayPlan::new();
        plan.release(1, 2); // pre-issued permit: later hold-free wait passes
        plan.wait(1, 2);
    }

    #[test]
    fn held_gate_blocks_until_released() {
        let plan = DelayPlan::new();
        plan.hold(3, 7);
        assert!(plan.is_held(3, 7));
        let p2 = plan.clone();
        let h = std::thread::spawn(move || {
            p2.wait(3, 7); // blocks until the main thread releases
            true
        });
        // The gate only governs (3, 7); other keys pass.
        plan.wait(3, 8);
        plan.release(3, 7);
        assert!(h.join().unwrap());
        assert!(!plan.is_held(3, 7));
    }

    #[test]
    fn release_all_opens_every_gate() {
        let plan = DelayPlan::new();
        plan.hold(0, 0);
        plan.hold(1, 5);
        plan.release_all();
        plan.wait(0, 0);
        plan.wait(1, 5);
    }
}
