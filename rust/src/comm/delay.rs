//! Deterministic straggler injection for the in-process transport.
//!
//! A [`DelayPlan`] is a per-(worker, round) **gate/permit schedule**: the
//! transport consults it just before a worker's payload frame enters the
//! uplink channel, and a held gate blocks the send until the controlling
//! test or benchmark releases it. Because the block happens *before* the
//! frame becomes visible to the leader, a scripted scenario can assert
//! structural facts ("this round closed while worker 3's gate was still
//! held") instead of racing against `sleep` timings — which is how
//! `benches/bench_policy.rs` and `tests/integration_policy.rs` prove
//! that K-of-M / deadline rounds close without waiting on a held-out
//! worker.
//!
//! Semantics:
//! - [`DelayPlan::hold`] gates `(worker, round)`; a later
//!   [`DelayPlan::release`] opens it (releasing an un-held gate is a
//!   no-op, so pre-issuing permits is harmless).
//! - Sends that were never held pass through untouched — a plan-free
//!   cluster behaves exactly like one built by
//!   [`super::inproc_cluster`].
//! - A gate held longer than [`DelayPlan::MAX_WAIT`] panics on the
//!   blocked worker thread: a forgotten `release` becomes a loud test
//!   failure rather than a CI hang.
//!
//! The plan carries two independent gate sets: the **uplink** gates
//! (worker payload sends — honored by both the in-process and TCP worker
//! ends) and the **downlink** gates added for the pipelined round engine
//! ([`DelayPlan::hold_down`] / [`DelayPlan::release_down`]), which model
//! a *slow receiver*: the leader's delivery of a round-`r` broadcast to
//! worker `w` blocks while `(w, r)` is down-held, exactly like a socket
//! write to a stalled peer. Downlink gates are an **in-process-only**
//! hook (the TCP server end carries no plan; kernel socket buffers would
//! swallow the stall anyway) — it is how the overlap probes prove "round
//! t+1 frames decoded while round t's broadcast is provably still in
//! flight" without sleeps.

use std::collections::HashSet;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

#[derive(Default)]
struct Gates {
    /// Uplink gates: worker payload sends.
    up: HashSet<(u32, u64)>,
    /// Downlink gates: leader broadcast deliveries (per worker, round).
    down: HashSet<(u32, u64)>,
}

struct Inner {
    held: Mutex<Gates>,
    cv: Condvar,
    /// Callbacks invoked after every `release*`. The readiness-loop
    /// transport registers one: a held downlink gate makes a worker
    /// "not writable" (the delivery is parked, other workers keep
    /// flowing), and the release poke is what re-arms the parked
    /// delivery — the in-process analogue of a socket's write-interest
    /// notification.
    listeners: Mutex<Vec<Box<dyn Fn() + Send + Sync>>>,
}

/// Shared gate/permit schedule (cheaply clonable handle).
#[derive(Clone)]
pub struct DelayPlan {
    inner: Arc<Inner>,
}

impl Default for DelayPlan {
    fn default() -> Self {
        Self::new()
    }
}

impl DelayPlan {
    /// Upper bound a gated send will block before panicking — converts a
    /// missing `release` into a failure instead of a hang.
    pub const MAX_WAIT: Duration = Duration::from_secs(30);

    pub fn new() -> Self {
        Self {
            inner: Arc::new(Inner {
                held: Mutex::new(Gates::default()),
                cv: Condvar::new(),
                listeners: Mutex::new(Vec::new()),
            }),
        }
    }

    /// Register a callback fired after every gate release (any kind).
    /// Used by the readiness-loop transport to re-check deliveries it
    /// parked behind a held downlink gate.
    pub(crate) fn on_release(&self, f: Box<dyn Fn() + Send + Sync>) {
        self.inner.listeners.lock().unwrap().push(f);
    }

    fn poke_listeners(&self) {
        for f in self.inner.listeners.lock().unwrap().iter() {
            f();
        }
    }

    /// Gate worker `worker`'s round-`round` payload send until released.
    pub fn hold(&self, worker: u32, round: u64) {
        self.inner.held.lock().unwrap().up.insert((worker, round));
    }

    /// Open the uplink gate for `(worker, round)` (no-op if never held).
    pub fn release(&self, worker: u32, round: u64) {
        self.inner.held.lock().unwrap().up.remove(&(worker, round));
        self.inner.cv.notify_all();
        self.poke_listeners();
    }

    /// Gate the delivery of round-`round` broadcast frames to worker
    /// `worker` until released — a scripted *slow receiver*.
    pub fn hold_down(&self, worker: u32, round: u64) {
        self.inner.held.lock().unwrap().down.insert((worker, round));
    }

    /// Open the downlink gate for `(worker, round)` (no-op if never held).
    pub fn release_down(&self, worker: u32, round: u64) {
        self.inner.held.lock().unwrap().down.remove(&(worker, round));
        self.inner.cv.notify_all();
        self.poke_listeners();
    }

    /// Open every gate, uplink and downlink (teardown safety for
    /// scripted scenarios).
    pub fn release_all(&self) {
        let mut gates = self.inner.held.lock().unwrap();
        gates.up.clear();
        gates.down.clear();
        drop(gates);
        self.inner.cv.notify_all();
        self.poke_listeners();
    }

    /// Whether `(worker, round)` is currently uplink-gated — the
    /// structural assertion scripted benchmarks use ("the round closed
    /// while this gate was still held").
    pub fn is_held(&self, worker: u32, round: u64) -> bool {
        self.inner.held.lock().unwrap().up.contains(&(worker, round))
    }

    /// Whether the round-`round` broadcast delivery to `worker` is
    /// currently downlink-gated ("round t+1 was gathered while round t's
    /// broadcast was provably still in flight").
    pub fn is_held_down(&self, worker: u32, round: u64) -> bool {
        self.inner.held.lock().unwrap().down.contains(&(worker, round))
    }

    /// Block while `(worker, round)` is uplink-gated (called by the
    /// transport on the sending worker's thread).
    pub(crate) fn wait(&self, worker: u32, round: u64) {
        self.wait_gate(worker, round, false);
    }

    /// Block while `(worker, round)` is downlink-gated (called by the
    /// transport on whichever thread delivers broadcasts — the leader
    /// itself on the synchronous path, a writer thread on the async one).
    pub(crate) fn wait_down(&self, worker: u32, round: u64) {
        self.wait_gate(worker, round, true);
    }

    fn wait_gate(&self, worker: u32, round: u64, down: bool) {
        let start = Instant::now();
        let mut held = self.inner.held.lock().unwrap();
        loop {
            let set = if down { &held.down } else { &held.up };
            if !set.contains(&(worker, round)) {
                return;
            }
            let elapsed = start.elapsed();
            assert!(
                elapsed < Self::MAX_WAIT,
                "DelayPlan {} gate (worker {worker}, round {round}) held for more than \
                 {:?} — missing release()?",
                if down { "downlink" } else { "uplink" },
                Self::MAX_WAIT
            );
            let (guard, _) =
                self.inner.cv.wait_timeout(held, Self::MAX_WAIT - elapsed).unwrap();
            held = guard;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unheld_gates_pass_through_immediately() {
        let plan = DelayPlan::new();
        plan.wait(0, 0); // must not block
        assert!(!plan.is_held(0, 0));
    }

    #[test]
    fn release_is_a_permit_when_issued_first() {
        let plan = DelayPlan::new();
        plan.release(1, 2); // pre-issued permit: later hold-free wait passes
        plan.wait(1, 2);
    }

    #[test]
    fn held_gate_blocks_until_released() {
        let plan = DelayPlan::new();
        plan.hold(3, 7);
        assert!(plan.is_held(3, 7));
        let p2 = plan.clone();
        let h = std::thread::spawn(move || {
            p2.wait(3, 7); // blocks until the main thread releases
            true
        });
        // The gate only governs (3, 7); other keys pass.
        plan.wait(3, 8);
        plan.release(3, 7);
        assert!(h.join().unwrap());
        assert!(!plan.is_held(3, 7));
    }

    #[test]
    fn release_all_opens_every_gate() {
        let plan = DelayPlan::new();
        plan.hold(0, 0);
        plan.hold(1, 5);
        plan.hold_down(2, 3);
        plan.release_all();
        plan.wait(0, 0);
        plan.wait(1, 5);
        plan.wait_down(2, 3);
    }

    #[test]
    fn downlink_gates_are_independent_of_uplink_gates() {
        let plan = DelayPlan::new();
        plan.hold_down(1, 4);
        assert!(plan.is_held_down(1, 4));
        // The uplink gate with the same key is untouched, and vice versa.
        assert!(!plan.is_held(1, 4));
        plan.wait(1, 4); // must not block
        plan.hold(1, 4);
        plan.release_down(1, 4);
        assert!(!plan.is_held_down(1, 4));
        assert!(plan.is_held(1, 4));
        plan.wait_down(1, 4); // must not block
        plan.release(1, 4);
    }

    #[test]
    fn held_downlink_gate_blocks_until_released() {
        let plan = DelayPlan::new();
        plan.hold_down(0, 2);
        let p2 = plan.clone();
        let h = std::thread::spawn(move || {
            p2.wait_down(0, 2);
            true
        });
        plan.release_down(0, 2);
        assert!(h.join().unwrap());
    }
}
