//! **Algorithm 2 — DQGAN** (the paper's contribution), worker side.
//!
//! Per round t, worker m with local state (w_{t−1}, F_prev, e_{t−1}):
//!
//! ```text
//! line 4:  w_{t−½} = w_{t−1} − [η·F(w_{t−3/2}; ξ_{t−1}) + e_{t−1}]
//! line 5:  F ← F(w_{t−½}; ξ_t)
//! line 6:  p  = η·F + e_{t−1}
//! line 7:  p̂  = Q(p)            → pushed to the server
//! line 8:  e_t = p − p̂
//! line 14: w_t = w_{t−1} − q̂    where q̂ = 1/M Σ_m p̂^(m)
//! ```
//!
//! Note the **double error compensation**: e_{t−1} enters both the half
//! step (line 4) and the transmitted message (line 6). This is the
//! min–max-specific error feedback the paper designs; CPOAdam-GQ omits it
//! and pays with the instability Figures 2–3 show.

use super::{Produced, RoundStats, WorkerAlgo};
use crate::compress::Compressor;
use crate::grad::GradientSource;
use crate::optim::LrSchedule;
use crate::tensor::ops;
use crate::util::rng::Pcg32;
use crate::util::stats::norm2_sq;
use std::sync::Arc;

/// Worker-local DQGAN state (Algorithm 2 lines 3–8 + 13–14).
pub struct DqganWorker {
    /// w_{t−1} — globally consistent parameters.
    w: Vec<f32>,
    /// F(w_{t−3/2}; ξ_{t−1}) — last round's stochastic gradient (line 2's
    /// "retrieve"). Zero-initialized: w_{−½} = w₀ (line 1).
    f_prev: Vec<f32>,
    /// e_{t−1} — the compression error memory (line 1: e₀ = 0).
    e: Vec<f32>,
    lr: LrSchedule,
    compressor: Arc<dyn Compressor>,
    t: u64,
    // Preallocated scratch (hot path: no allocation per round).
    w_half: Vec<f32>,
    f: Vec<f32>,
    p: Vec<f32>,
    /// p̂ = Q(p) — the dense quantized payload, reused every round.
    q: Vec<f32>,
    /// Wire bytes for p̂, reused every round (capacity = encoded size).
    wire_buf: Vec<u8>,
}

impl DqganWorker {
    pub fn new(w0: Vec<f32>, lr: LrSchedule, compressor: Arc<dyn Compressor>) -> Self {
        let d = w0.len();
        let wire_cap = compressor.encoded_size(d);
        Self {
            w: w0,
            f_prev: vec![0.0; d],
            e: vec![0.0; d],
            lr,
            compressor,
            t: 0,
            w_half: vec![0.0; d],
            f: vec![0.0; d],
            p: vec![0.0; d],
            q: vec![0.0; d],
            wire_buf: Vec::with_capacity(wire_cap),
        }
    }

    /// Current error memory (Lemma 1 instrumentation).
    pub fn error(&self) -> &[f32] {
        &self.e
    }

    /// Current step size η_t.
    pub fn eta(&self) -> f32 {
        self.lr.at(self.t)
    }
}

impl WorkerAlgo for DqganWorker {
    fn dim(&self) -> usize {
        self.w.len()
    }

    fn params(&self) -> &[f32] {
        &self.w
    }

    fn produce(
        &mut self,
        src: &mut dyn GradientSource,
        batch: usize,
        rng: &mut Pcg32,
    ) -> anyhow::Result<Produced<'_>> {
        let eta = self.eta();
        // line 4: w_{t−½} = w − (η·F_prev + e)
        for i in 0..self.w.len() {
            self.w_half[i] = self.w[i] - (eta * self.f_prev[i] + self.e[i]);
        }
        // line 5: F(w_{t−½}; ξ_t)
        let meta = src.grad(&self.w_half, batch, rng, &mut self.f)?;
        // line 6: p = η·F + e
        ops::scaled_add(eta, &self.f, &self.e, &mut self.p);
        // line 7: p̂ = Q(p), fused with the wire encoding (bit-exact pair),
        // both written into reused round buffers.
        self.wire_buf.clear();
        self.compressor.compress_encoded_observed(&self.p, rng, &mut self.wire_buf, &mut self.q);
        // line 8: e_t = p − p̂
        for i in 0..self.e.len() {
            self.e[i] = self.p[i] - self.q[i];
        }
        // store F for the next half step (line 2 "retrieve").
        self.f_prev.copy_from_slice(&self.f);
        self.t += 1;
        let stats = RoundStats {
            bytes_up: self.wire_buf.len(),
            grad_norm_sq: norm2_sq(&self.f),
            err_norm_sq: norm2_sq(&self.e),
            loss_g: meta.loss_g,
            loss_d: meta.loss_d,
        };
        Ok(Produced { wire: &self.wire_buf, dense: &self.q, stats })
    }

    fn apply(&mut self, avg: &[f32]) {
        // line 14: w_t = w_{t−1} − q̂
        ops::sub_assign(&mut self.w, avg);
    }

    fn absorb_skipped(&mut self) {
        // The leader skipped our p̂ this round: e ← e + p̂ restores
        // e = p − p̂ + p̂ = p = η·F + e_{t−1}, i.e. the full intended
        // transmission re-enters the error memory and rides into the
        // next round's line-4/line-6 compensation untouched.
        for i in 0..self.e.len() {
            self.e[i] += self.q[i];
        }
    }

    fn save_state(&self, out: &mut Vec<u8>) -> anyhow::Result<()> {
        use crate::util::bytes::{put_f32_slice, put_u32, put_u64};
        put_u64(out, self.t);
        put_u32(out, self.w.len() as u32);
        put_f32_slice(out, &self.w);
        put_f32_slice(out, &self.f_prev);
        put_f32_slice(out, &self.e);
        Ok(())
    }

    fn load_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut r = crate::util::bytes::Reader::new(bytes);
        let t = r.u64()?;
        let d = r.u32()? as usize;
        anyhow::ensure!(
            d == self.w.len(),
            "dqgan snapshot dim {d} != configured dim {}",
            self.w.len()
        );
        self.w = r.f32_vec(d)?;
        self.f_prev = r.f32_vec(d)?;
        self.e = r.f32_vec(d)?;
        anyhow::ensure!(r.remaining() == 0, "dqgan snapshot has trailing bytes");
        self.t = t;
        Ok(())
    }

    fn name(&self) -> String {
        format!("dqgan[{}]", self.compressor.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Identity, LinfStochastic};
    use crate::grad::QuadraticOperator;
    use crate::optim::LrSchedule;

    /// Drive M workers + an in-test "server" (mean of dense payloads).
    fn run_cluster(
        m: usize,
        compressor: Arc<dyn Compressor>,
        rounds: usize,
        noise: f32,
        eta: f32,
    ) -> (Vec<f32>, Vec<f32>, f32) {
        let mut seed_rng = Pcg32::new(42);
        let mut op = QuadraticOperator::new(16, noise, &mut seed_rng);
        let target = op.target.clone();
        let w0 = op.init_params(&mut seed_rng);
        let mut workers: Vec<DqganWorker> = (0..m)
            .map(|_| {
                DqganWorker::new(w0.clone(), LrSchedule::constant(eta), compressor.clone())
            })
            .collect();
        let mut rngs: Vec<Pcg32> = (0..m).map(|i| Pcg32::new(1000 + i as u64)).collect();
        let mut last_err = 0.0;
        for _ in 0..rounds {
            let mut payloads: Vec<Vec<f32>> = Vec::with_capacity(m);
            for (wk, rng) in workers.iter_mut().zip(&mut rngs) {
                let prod = wk.produce(&mut op, 8, rng).unwrap();
                last_err = prod.stats.err_norm_sq;
                payloads.push(prod.dense.to_vec());
            }
            let mut avg = vec![0.0; 16];
            let refs: Vec<&[f32]> = payloads.iter().map(|p| p.as_slice()).collect();
            ops::mean_into(&refs, &mut avg);
            for wk in workers.iter_mut() {
                wk.apply(&avg);
            }
        }
        (workers[0].params().to_vec(), target, last_err)
    }

    #[test]
    fn converges_on_quadratic_without_quantization() {
        let (w, target, err) = run_cluster(4, Arc::new(Identity), 800, 0.0, 0.1);
        for (a, b) in w.iter().zip(&target) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
        assert_eq!(err, 0.0, "identity compressor must have zero error memory");
    }

    #[test]
    fn converges_on_quadratic_with_8bit_quantization() {
        let (w, target, _) =
            run_cluster(4, Arc::new(LinfStochastic::with_bits(8)), 1500, 0.0, 0.1);
        for (a, b) in w.iter().zip(&target) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn workers_stay_synchronized() {
        // All workers apply the same q̄ ⇒ identical parameters forever.
        let compressor: Arc<dyn Compressor> = Arc::new(LinfStochastic::with_bits(8));
        let mut seed_rng = Pcg32::new(7);
        let mut op = QuadraticOperator::new(8, 0.5, &mut seed_rng);
        let w0 = op.init_params(&mut seed_rng);
        let mut a = DqganWorker::new(w0.clone(), LrSchedule::constant(0.05), compressor.clone());
        let mut b = DqganWorker::new(w0, LrSchedule::constant(0.05), compressor);
        let mut ra = Pcg32::new(1);
        let mut rb = Pcg32::new(2);
        for _ in 0..50 {
            let pa = a.produce(&mut op, 4, &mut ra).unwrap().dense.to_vec();
            let pb = b.produce(&mut op, 4, &mut rb).unwrap().dense.to_vec();
            let mut avg = vec![0.0; 8];
            ops::mean_into(&[&pa, &pb], &mut avg);
            a.apply(&avg);
            b.apply(&avg);
            assert_eq!(a.params(), b.params());
        }
    }

    #[test]
    fn wire_and_dense_agree() {
        let compressor: Arc<dyn Compressor> = Arc::new(LinfStochastic::with_bits(8));
        let mut seed_rng = Pcg32::new(9);
        let mut op = QuadraticOperator::new(32, 0.1, &mut seed_rng);
        let w0 = op.init_params(&mut seed_rng);
        let mut wk = DqganWorker::new(w0, LrSchedule::constant(0.05), compressor.clone());
        let mut rng = Pcg32::new(3);
        for _ in 0..5 {
            let prod = wk.produce(&mut op, 4, &mut rng).unwrap();
            let decoded = compressor.decode(prod.wire, 32).unwrap();
            let dense = prod.dense.to_vec();
            assert_eq!(decoded, dense, "wire and dense payloads must be bit-identical");
            wk.apply(&dense);
        }
    }

    #[test]
    fn produce_reuses_round_buffers() {
        // The "no allocation per round" contract: the wire and dense
        // payload views must point into the same reused buffers on every
        // round (the seed allocated a fresh wire Vec per produce).
        let mut seed_rng = Pcg32::new(5);
        let mut op = QuadraticOperator::new(32, 0.1, &mut seed_rng);
        let w0 = op.init_params(&mut seed_rng);
        let mut wk = DqganWorker::new(
            w0,
            LrSchedule::constant(0.05),
            Arc::new(LinfStochastic::with_bits(8)),
        );
        let mut rng = Pcg32::new(7);
        let (w0p, d0p) = {
            let prod = wk.produce(&mut op, 4, &mut rng).unwrap();
            (prod.wire.as_ptr(), prod.dense.as_ptr())
        };
        let (w1p, d1p) = {
            let prod = wk.produce(&mut op, 4, &mut rng).unwrap();
            (prod.wire.as_ptr(), prod.dense.as_ptr())
        };
        assert_eq!(w0p, w1p, "wire buffer must not be reallocated per round");
        assert_eq!(d0p, d1p, "dense buffer must not be reallocated per round");
    }

    #[test]
    fn absorb_skipped_restores_the_full_intended_transmission() {
        // Identity compressor ⇒ e is exactly 0 after produce (p̂ = p), so
        // absorbing a skip must set e to the sent payload bit-for-bit:
        // the error-memory norm grows from 0 by exactly ‖p̂‖.
        let mut seed_rng = Pcg32::new(17);
        let mut op = QuadraticOperator::new(16, 0.1, &mut seed_rng);
        let w0 = op.init_params(&mut seed_rng);
        let mut wk = DqganWorker::new(w0, LrSchedule::constant(0.05), Arc::new(Identity));
        let mut rng = Pcg32::new(23);
        let prod = wk.produce(&mut op, 4, &mut rng).unwrap();
        assert_eq!(prod.stats.err_norm_sq, 0.0);
        let sent = prod.dense.to_vec();
        assert!(sent.iter().any(|&x| x != 0.0), "payload must be non-trivial");
        wk.absorb_skipped();
        for (i, (&e, &q)) in wk.error().iter().zip(&sent).enumerate() {
            assert_eq!(e.to_bits(), q.to_bits(), "element {i}");
        }
    }

    #[test]
    fn absorb_skipped_adds_the_quantized_payload_to_the_error_memory() {
        // Lossy compressor: e = p − p̂ after produce; a skip must yield
        // e' = e + p̂ elementwise (so e' = p — the δ-approximate contract
        // with Q returning 0).
        let mut seed_rng = Pcg32::new(19);
        let mut op = QuadraticOperator::new(32, 0.2, &mut seed_rng);
        let w0 = op.init_params(&mut seed_rng);
        let mut wk = DqganWorker::new(
            w0,
            LrSchedule::constant(0.05),
            Arc::new(LinfStochastic::with_bits(4)),
        );
        let mut rng = Pcg32::new(29);
        let q = wk.produce(&mut op, 4, &mut rng).unwrap().dense.to_vec();
        let e_before = wk.error().to_vec();
        assert!(e_before.iter().any(|&x| x != 0.0), "coarse quantizer must leave residue");
        wk.absorb_skipped();
        for i in 0..q.len() {
            assert_eq!(
                wk.error()[i].to_bits(),
                (e_before[i] + q[i]).to_bits(),
                "element {i}"
            );
        }
    }

    #[test]
    fn snapshot_round_trip_continues_bit_exact() {
        // The leader-recovery contract: save (algo state, rng) at a round
        // boundary, rebuild a fresh worker from config, load the
        // snapshot, and the restored worker must emit bit-identical
        // payloads forever after — including the stochastic quantizer
        // draws, which flow through the restored rng.
        let compressor: Arc<dyn Compressor> = Arc::new(LinfStochastic::with_bits(4));
        let mut seed_rng = Pcg32::new(33);
        let mut op = QuadraticOperator::new(24, 0.3, &mut seed_rng);
        let w0 = op.init_params(&mut seed_rng);
        let mut a = DqganWorker::new(w0.clone(), LrSchedule::constant(0.05), compressor.clone());
        let mut rng = Pcg32::new(71);
        for _ in 0..10 {
            let dense = a.produce(&mut op, 4, &mut rng).unwrap().dense.to_vec();
            a.apply(&dense);
        }
        let mut snap = Vec::new();
        a.save_state(&mut snap).unwrap();
        let (state, inc) = rng.state_parts();
        let mut b = DqganWorker::new(w0, LrSchedule::constant(0.05), compressor);
        b.load_state(&snap).unwrap();
        let mut rng_b = Pcg32::from_state_parts(state, inc);
        for _ in 0..10 {
            let pa = a.produce(&mut op, 4, &mut rng).unwrap().dense.to_vec();
            let pb = b.produce(&mut op, 4, &mut rng_b).unwrap().dense.to_vec();
            for (x, y) in pa.iter().zip(&pb) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            a.apply(&pa);
            b.apply(&pb);
            assert_eq!(a.params(), b.params());
        }
    }

    #[test]
    fn error_memory_stays_bounded_lemma1() {
        // Lemma 1: E‖e_t‖² ≤ 8η²(1−δ)(G²+σ²/B)/δ². Run with a coarse
        // compressor and check the trajectory never blows past the bound
        // computed from measured G and the declared δ.
        let c = LinfStochastic::new(3); // very coarse: s=3 levels
        let delta = 0.3f64; // conservative lower bound for this setup
        let eta = 0.05f32;
        let mut seed_rng = Pcg32::new(11);
        let mut op = QuadraticOperator::new(16, 0.5, &mut seed_rng);
        let w0 = op.init_params(&mut seed_rng);
        let mut wk = DqganWorker::new(w0, LrSchedule::constant(eta), Arc::new(c));
        let mut rng = Pcg32::new(13);
        let mut g_max = 0.0f32;
        let mut max_err = 0.0f32;
        for _ in 0..400 {
            let prod = wk.produce(&mut op, 8, &mut rng).unwrap();
            g_max = g_max.max(prod.stats.grad_norm_sq);
            max_err = max_err.max(prod.stats.err_norm_sq);
            let dense = prod.dense.to_vec();
            wk.apply(&dense);
        }
        let sigma_sq_over_b = 0.5f32 * 0.5 / 8.0;
        let bound =
            8.0 * (eta * eta) as f64 * (1.0 - delta) * (g_max + sigma_sq_over_b) as f64
                / (delta * delta);
        assert!(
            (max_err as f64) <= bound,
            "max ‖e‖²={max_err} exceeded Lemma-1 bound {bound}"
        );
    }
}
