//! The paper's baselines (§4):
//!
//! - **CPOAdam** — Centralized Parallel Optimistic Adam: workers push raw
//!   f32 minibatch gradients, the server averages, every worker applies an
//!   identical Optimistic Adam update to the averaged gradient (replicated
//!   deterministic state ⇒ parameters stay in lockstep).
//! - **CPOAdam-GQ** — same, but the transmitted gradient is quantized with
//!   a δ-approximate compressor and **no error feedback** — the ablation
//!   that isolates what DQGAN's double compensation buys.

use super::{Produced, RoundStats, WorkerAlgo};
use crate::compress::{Compressor, Identity};
use crate::grad::GradientSource;
use crate::optim::{LrSchedule, OptimisticAdam, Optimizer};
use crate::util::rng::Pcg32;
use crate::util::stats::norm2_sq;
use std::sync::Arc;

/// CPOAdam / CPOAdam-GQ worker (quantizer = `None` for plain CPOAdam).
pub struct CpoAdamWorker {
    w: Vec<f32>,
    opt: OptimisticAdam,
    quantizer: Option<Arc<dyn Compressor>>,
    f: Vec<f32>,
    /// Dense quantized payload scratch (empty for plain CPOAdam, whose
    /// dense payload is `f` itself), reused every round.
    q: Vec<f32>,
    /// Wire bytes, reused every round.
    wire_buf: Vec<u8>,
}

impl CpoAdamWorker {
    pub fn new(w0: Vec<f32>, lr: LrSchedule, quantizer: Option<Arc<dyn Compressor>>) -> Self {
        let d = w0.len();
        let (q, wire_cap) = match &quantizer {
            Some(c) => (vec![0.0; d], c.encoded_size(d)),
            None => (Vec::new(), 4 * d),
        };
        Self {
            w: w0,
            opt: OptimisticAdam::new(1.0).with_betas(0.5, 0.9).with_schedule(lr),
            quantizer,
            f: vec![0.0; d],
            q,
            wire_buf: Vec::with_capacity(wire_cap),
        }
    }
}

impl WorkerAlgo for CpoAdamWorker {
    fn dim(&self) -> usize {
        self.w.len()
    }

    fn params(&self) -> &[f32] {
        &self.w
    }

    fn produce(
        &mut self,
        src: &mut dyn GradientSource,
        batch: usize,
        rng: &mut Pcg32,
    ) -> anyhow::Result<Produced<'_>> {
        let meta = src.grad(&self.w, batch, rng, &mut self.f)?;
        self.wire_buf.clear();
        let dense: &[f32] = match &self.quantizer {
            None => {
                Identity.encode(&self.f, &mut self.wire_buf);
                &self.f
            }
            Some(c) => {
                c.compress_encoded_observed(&self.f, rng, &mut self.wire_buf, &mut self.q);
                &self.q
            }
        };
        let stats = RoundStats {
            bytes_up: self.wire_buf.len(),
            grad_norm_sq: norm2_sq(&self.f),
            err_norm_sq: 0.0, // no error feedback by construction
            loss_g: meta.loss_g,
            loss_d: meta.loss_d,
        };
        Ok(Produced { wire: &self.wire_buf, dense, stats })
    }

    fn apply(&mut self, avg: &[f32]) {
        // Replicated Optimistic Adam on the averaged (possibly quantized)
        // gradient — deterministic, so replicas stay identical.
        self.opt.step(&mut self.w, avg);
    }

    fn save_state(&self, out: &mut Vec<u8>) -> anyhow::Result<()> {
        use crate::util::bytes::{put_f32_slice, put_u32};
        put_u32(out, self.w.len() as u32);
        put_f32_slice(out, &self.w);
        self.opt.save_state(out);
        Ok(())
    }

    fn load_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut r = crate::util::bytes::Reader::new(bytes);
        let d = r.u32()? as usize;
        anyhow::ensure!(
            d == self.w.len(),
            "cpoadam snapshot dim {d} != configured dim {}",
            self.w.len()
        );
        self.w = r.f32_vec(d)?;
        self.opt.load_state(&mut r)?;
        anyhow::ensure!(r.remaining() == 0, "cpoadam snapshot has trailing bytes");
        Ok(())
    }

    fn name(&self) -> String {
        match &self.quantizer {
            None => "cpoadam".to_string(),
            Some(q) => format!("cpoadam-gq[{}]", q.name()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::LinfStochastic;
    use crate::grad::QuadraticOperator;
    use crate::tensor::ops;

    fn run(
        quantizer: Option<Arc<dyn Compressor>>,
        rounds: usize,
        eta: f32,
    ) -> (Vec<f32>, Vec<f32>) {
        let m = 4;
        let mut seed_rng = Pcg32::new(21);
        let mut op = QuadraticOperator::new(12, 0.2, &mut seed_rng);
        let target = op.target.clone();
        let w0 = op.init_params(&mut seed_rng);
        let mut workers: Vec<CpoAdamWorker> = (0..m)
            .map(|_| CpoAdamWorker::new(w0.clone(), LrSchedule::constant(eta), quantizer.clone()))
            .collect();
        let mut rngs: Vec<Pcg32> = (0..m).map(|i| Pcg32::new(500 + i as u64)).collect();
        for _ in 0..rounds {
            let mut payloads = Vec::new();
            for (wk, rng) in workers.iter_mut().zip(&mut rngs) {
                payloads.push(wk.produce(&mut op, 8, rng).unwrap().dense.to_vec());
            }
            let refs: Vec<&[f32]> = payloads.iter().map(|p| p.as_slice()).collect();
            let mut avg = vec![0.0; 12];
            ops::mean_into(&refs, &mut avg);
            for wk in workers.iter_mut() {
                wk.apply(&avg);
            }
            // lockstep invariant
            for wk in &workers[1..] {
                assert_eq!(wk.params(), workers[0].params());
            }
        }
        (workers[0].params().to_vec(), target)
    }

    #[test]
    fn cpoadam_converges_on_quadratic() {
        let (w, target) = run(None, 1200, 0.02);
        for (a, b) in w.iter().zip(&target) {
            assert!((a - b).abs() < 0.1, "{a} vs {b}");
        }
    }

    #[test]
    fn cpoadam_gq_converges_with_fine_quantizer() {
        let (w, target) = run(Some(Arc::new(LinfStochastic::with_bits(8))), 1200, 0.02);
        for (a, b) in w.iter().zip(&target) {
            assert!((a - b).abs() < 0.15, "{a} vs {b}");
        }
    }

    #[test]
    fn gq_wire_is_smaller() {
        let mut seed_rng = Pcg32::new(31);
        let mut op = QuadraticOperator::new(1000, 0.1, &mut seed_rng);
        let w0 = op.init_params(&mut seed_rng);
        let mut raw = CpoAdamWorker::new(w0.clone(), LrSchedule::constant(0.01), None);
        let mut gq = CpoAdamWorker::new(
            w0,
            LrSchedule::constant(0.01),
            Some(Arc::new(LinfStochastic::with_bits(8))),
        );
        let mut rng = Pcg32::new(1);
        let b_raw = raw.produce(&mut op, 4, &mut rng).unwrap().stats.bytes_up;
        let b_gq = gq.produce(&mut op, 4, &mut rng).unwrap().stats.bytes_up;
        assert!(b_gq * 3 < b_raw, "raw={b_raw} gq={b_gq}");
    }
}
