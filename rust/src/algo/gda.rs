//! Distributed simultaneous gradient descent–ascent (GDA): the
//! "basic gradient method" of paper eq. 11, data-parallel. Included as the
//! divergence baseline for the SYN-B bilinear experiment — it cycles or
//! drifts on min–max problems where DQGAN/OMD converge (§2.2).

use super::{Produced, RoundStats, WorkerAlgo};
use crate::compress::{Compressor, Identity};
use crate::grad::GradientSource;
use crate::optim::LrSchedule;
use crate::tensor::ops;
use crate::util::rng::Pcg32;
use crate::util::stats::norm2_sq;

/// GDA worker: push raw F(w; ξ), apply `w ← w − η·ḡ`.
pub struct DistGdaWorker {
    w: Vec<f32>,
    lr: LrSchedule,
    t: u64,
    f: Vec<f32>,
    /// Wire bytes (raw f32 encoding of `f`), reused every round.
    wire_buf: Vec<u8>,
}

impl DistGdaWorker {
    pub fn new(w0: Vec<f32>, lr: LrSchedule) -> Self {
        let d = w0.len();
        Self { w: w0, lr, t: 0, f: vec![0.0; d], wire_buf: Vec::with_capacity(4 * d) }
    }
}

impl WorkerAlgo for DistGdaWorker {
    fn dim(&self) -> usize {
        self.w.len()
    }

    fn params(&self) -> &[f32] {
        &self.w
    }

    fn produce(
        &mut self,
        src: &mut dyn GradientSource,
        batch: usize,
        rng: &mut Pcg32,
    ) -> anyhow::Result<Produced<'_>> {
        let meta = src.grad(&self.w, batch, rng, &mut self.f)?;
        self.wire_buf.clear();
        Identity.encode(&self.f, &mut self.wire_buf);
        let stats = RoundStats {
            bytes_up: self.wire_buf.len(),
            grad_norm_sq: norm2_sq(&self.f),
            err_norm_sq: 0.0,
            loss_g: meta.loss_g,
            loss_d: meta.loss_d,
        };
        Ok(Produced { wire: &self.wire_buf, dense: &self.f, stats })
    }

    fn apply(&mut self, avg: &[f32]) {
        let eta = self.lr.at(self.t);
        ops::axpy(-eta, avg, &mut self.w);
        self.t += 1;
    }

    fn save_state(&self, out: &mut Vec<u8>) -> anyhow::Result<()> {
        use crate::util::bytes::{put_f32_slice, put_u32, put_u64};
        put_u64(out, self.t);
        put_u32(out, self.w.len() as u32);
        put_f32_slice(out, &self.w);
        Ok(())
    }

    fn load_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut r = crate::util::bytes::Reader::new(bytes);
        let t = r.u64()?;
        let d = r.u32()? as usize;
        anyhow::ensure!(
            d == self.w.len(),
            "gda snapshot dim {d} != configured dim {}",
            self.w.len()
        );
        self.w = r.f32_vec(d)?;
        anyhow::ensure!(r.remaining() == 0, "gda snapshot has trailing bytes");
        self.t = t;
        Ok(())
    }

    fn name(&self) -> String {
        "gda".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::{GradMeta, GradientSource};

    /// Bilinear min–max: F(x, y) = (y, −x).
    struct Bilinear;
    impl GradientSource for Bilinear {
        fn dim(&self) -> usize {
            2
        }
        fn grad(
            &mut self,
            w: &[f32],
            _batch: usize,
            _rng: &mut Pcg32,
            out: &mut [f32],
        ) -> anyhow::Result<GradMeta> {
            out[0] = w[1];
            out[1] = -w[0];
            Ok(GradMeta::default())
        }
        fn init_params(&self, _rng: &mut Pcg32) -> Vec<f32> {
            vec![1.0, 1.0]
        }
    }

    #[test]
    fn gda_spirals_out_on_bilinear() {
        let mut wk = DistGdaWorker::new(vec![1.0, 1.0], LrSchedule::constant(0.1));
        let mut rng = Pcg32::new(1);
        let mut src = Bilinear;
        for _ in 0..500 {
            let dense = wk.produce(&mut src, 1, &mut rng).unwrap().dense.to_vec();
            wk.apply(&dense);
        }
        let r = norm2_sq(wk.params()).sqrt();
        assert!(r > 5.0, "GDA should diverge on the bilinear game, r={r}");
    }
}
