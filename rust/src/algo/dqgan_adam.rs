//! DQGAN (experimental variant): the configuration the paper's §4 actually
//! benchmarks. The baselines reveal it — "CPOAdam … is our method without
//! quantization and error-feedback" — i.e. the experiments' DQGAN is
//! **Optimistic Adam + δ-approximate quantization + error feedback**:
//!
//!   worker:  p = F(w; ξ) + e;  p̂ = Q(p);  e ← p − p̂
//!   server:  q̄ = 1/M Σ p̂
//!   worker:  w ← OptimisticAdam(w, q̄)     (replicated deterministic state)
//!
//! The pure Algorithm-2 form (OMD with η-scaled payloads and the double
//! compensation, [`super::DqganWorker`]) is kept for the theory
//! experiments (LEM1/THM3) where the analysis applies literally.

use super::{Produced, RoundStats, WorkerAlgo};
use crate::compress::Compressor;
use crate::grad::GradientSource;
use crate::optim::{LrSchedule, OptimisticAdam, Optimizer};
use crate::util::rng::Pcg32;
use crate::util::stats::norm2_sq;
use std::sync::Arc;

/// DQGAN-Adam worker: EF quantization in front of a replicated
/// Optimistic Adam update.
pub struct DqganAdamWorker {
    w: Vec<f32>,
    e: Vec<f32>,
    opt: OptimisticAdam,
    compressor: Arc<dyn Compressor>,
    f: Vec<f32>,
    p: Vec<f32>,
    /// p̂ = Q(p) — dense quantized payload, reused every round.
    q: Vec<f32>,
    /// Wire bytes for p̂, reused every round.
    wire_buf: Vec<u8>,
}

impl DqganAdamWorker {
    pub fn new(w0: Vec<f32>, lr: LrSchedule, compressor: Arc<dyn Compressor>) -> Self {
        let d = w0.len();
        let wire_cap = compressor.encoded_size(d);
        Self {
            w: w0,
            e: vec![0.0; d],
            opt: OptimisticAdam::new(1.0).with_betas(0.5, 0.9).with_schedule(lr),
            compressor,
            f: vec![0.0; d],
            p: vec![0.0; d],
            q: vec![0.0; d],
            wire_buf: Vec::with_capacity(wire_cap),
        }
    }
}

impl WorkerAlgo for DqganAdamWorker {
    fn dim(&self) -> usize {
        self.w.len()
    }

    fn params(&self) -> &[f32] {
        &self.w
    }

    fn produce(
        &mut self,
        src: &mut dyn GradientSource,
        batch: usize,
        rng: &mut Pcg32,
    ) -> anyhow::Result<Produced<'_>> {
        let meta = src.grad(&self.w, batch, rng, &mut self.f)?;
        // p = F + e (no η scaling: Adam owns the step size).
        for i in 0..self.p.len() {
            self.p[i] = self.f[i] + self.e[i];
        }
        self.wire_buf.clear();
        self.compressor.compress_encoded_observed(&self.p, rng, &mut self.wire_buf, &mut self.q);
        for i in 0..self.e.len() {
            self.e[i] = self.p[i] - self.q[i];
        }
        let stats = RoundStats {
            bytes_up: self.wire_buf.len(),
            grad_norm_sq: norm2_sq(&self.f),
            err_norm_sq: norm2_sq(&self.e),
            loss_g: meta.loss_g,
            loss_d: meta.loss_d,
        };
        Ok(Produced { wire: &self.wire_buf, dense: &self.q, stats })
    }

    fn apply(&mut self, avg: &[f32]) {
        self.opt.step(&mut self.w, avg);
    }

    fn absorb_skipped(&mut self) {
        // Skipped by a partial round: e ← e + p̂ = p = F + e_prev — the
        // whole intended transmission re-enters the error memory (same
        // re-absorption as the pure Algorithm-2 worker).
        for i in 0..self.e.len() {
            self.e[i] += self.q[i];
        }
    }

    fn save_state(&self, out: &mut Vec<u8>) -> anyhow::Result<()> {
        use crate::util::bytes::{put_f32_slice, put_u32};
        put_u32(out, self.w.len() as u32);
        put_f32_slice(out, &self.w);
        put_f32_slice(out, &self.e);
        self.opt.save_state(out);
        Ok(())
    }

    fn load_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut r = crate::util::bytes::Reader::new(bytes);
        let d = r.u32()? as usize;
        anyhow::ensure!(
            d == self.w.len(),
            "dqgan-adam snapshot dim {d} != configured dim {}",
            self.w.len()
        );
        self.w = r.f32_vec(d)?;
        self.e = r.f32_vec(d)?;
        self.opt.load_state(&mut r)?;
        anyhow::ensure!(r.remaining() == 0, "dqgan-adam snapshot has trailing bytes");
        Ok(())
    }

    fn name(&self) -> String {
        format!("dqgan-adam[{}]", self.compressor.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::LinfStochastic;
    use crate::grad::QuadraticOperator;
    use crate::tensor::ops;

    #[test]
    fn converges_and_beats_no_ef_under_coarse_quantization() {
        // With a very coarse compressor, EF (this worker) must end closer
        // to the optimum than the no-EF CPOAdam-GQ baseline.
        let run = |ef: bool| {
            let m = 4;
            let mut seed_rng = Pcg32::new(77);
            let mut op = QuadraticOperator::new(64, 0.1, &mut seed_rng);
            let target = op.target.clone();
            let w0 = op.init_params(&mut seed_rng);
            let comp: Arc<dyn Compressor> = Arc::new(LinfStochastic::new(1)); // 1 level!
            let lr = LrSchedule::constant(0.02);
            let mut workers: Vec<Box<dyn WorkerAlgo>> = (0..m)
                .map(|_| -> Box<dyn WorkerAlgo> {
                    if ef {
                        Box::new(DqganAdamWorker::new(w0.clone(), lr.clone(), comp.clone()))
                    } else {
                        Box::new(crate::algo::CpoAdamWorker::new(
                            w0.clone(),
                            lr.clone(),
                            Some(comp.clone()),
                        ))
                    }
                })
                .collect();
            let mut rngs: Vec<Pcg32> = (0..m).map(|i| Pcg32::new(900 + i as u64)).collect();
            for _ in 0..800 {
                let mut payloads = Vec::new();
                for (wk, rng) in workers.iter_mut().zip(&mut rngs) {
                    payloads.push(wk.produce(&mut op, 8, rng).unwrap().dense.to_vec());
                }
                let refs: Vec<&[f32]> = payloads.iter().map(|p| p.as_slice()).collect();
                let mut avg = vec![0.0; 64];
                ops::mean_into(&refs, &mut avg);
                for wk in workers.iter_mut() {
                    wk.apply(&avg);
                }
            }
            crate::util::stats::dist2_sq(workers[0].params(), &target).sqrt()
        };
        let with_ef = run(true);
        let without_ef = run(false);
        assert!(
            with_ef < without_ef,
            "EF should help under 1-level quantization: ef={with_ef} no-ef={without_ef}"
        );
        assert!(with_ef < 1.0, "EF variant did not converge: {with_ef}");
    }
}
