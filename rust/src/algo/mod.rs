//! Training algorithms (paper §3.1, §4): the worker-side round logic of
//! DQGAN (Algorithm 2) and the paper's two baselines, behind one trait the
//! parameter-server runtime drives.
//!
//! Per synchronous round, every worker:
//! 1. [`WorkerAlgo::produce`] — local half-step (if any), minibatch
//!    gradient, compression, error feedback; emits the wire payload;
//! 2. the server averages the decoded payloads (`ps/server.rs`);
//! 3. [`WorkerAlgo::apply`] — applies the averaged vector.
//!
//! | algorithm   | transmits            | error feedback | update        |
//! |-------------|----------------------|----------------|---------------|
//! | DQGAN       | Q(η·F + e), δ-approx | double (Alg 2) | `w −= q̄`      |
//! | CPOAdam     | raw F (f32)          | —              | Optimistic Adam on ḡ |
//! | CPOAdam-GQ  | Q(F), δ-approx       | **none**       | Optimistic Adam on q̄ |
//! | DistGDA     | raw F (f32)          | —              | `w −= η·ḡ` (divergence baseline) |

mod cpoadam;
mod dqgan;
mod dqgan_adam;
mod gda;

pub use cpoadam::CpoAdamWorker;
pub use dqgan::DqganWorker;
pub use dqgan_adam::DqganAdamWorker;
pub use gda::DistGdaWorker;

use crate::compress::{Compressor as _, CompressorSpec};
use crate::grad::GradientSource;
use crate::optim::LrSchedule;
use crate::util::rng::Pcg32;
use std::sync::Arc;

/// Per-round telemetry a worker reports back to the leader.
#[derive(Debug, Clone, Default)]
pub struct RoundStats {
    /// Uplink payload bytes actually placed on the wire.
    pub bytes_up: usize,
    /// ‖F(w_{t−½}; ξ)‖² — the convergence measure of Theorem 3.
    pub grad_norm_sq: f32,
    /// ‖e_t‖² — the Lemma 1 quantity (0 for algorithms without EF).
    pub err_norm_sq: f32,
    /// Losses at the evaluation point, when the model reports them.
    pub loss_g: Option<f32>,
    pub loss_d: Option<f32>,
}

/// The message a worker hands the transport each round.
///
/// Borrows the worker's **reused** round buffers (valid until the next
/// `produce` call), so the worker hot path allocates nothing per round;
/// the one owned copy happens at the transport boundary, where
/// [`crate::comm::Message`] takes ownership of the wire bytes.
#[derive(Debug)]
pub struct Produced<'a> {
    /// Encoded payload (exact bytes a real network would carry).
    pub wire: &'a [u8],
    /// Dense decoded payload — the in-process fast path (bit-identical to
    /// `decode(wire)`; integration tests assert this).
    pub dense: &'a [f32],
    pub stats: RoundStats,
}

/// Worker-side round logic.
pub trait WorkerAlgo: Send {
    /// Flat parameter dimension.
    fn dim(&self) -> usize;

    /// Current parameters w_t (identical across workers after `apply`).
    fn params(&self) -> &[f32];

    /// Phase 1: produce this round's payload. The returned views point
    /// into the worker's reused scratch buffers and stay valid until the
    /// next `produce` call.
    fn produce(
        &mut self,
        src: &mut dyn GradientSource,
        batch: usize,
        rng: &mut Pcg32,
    ) -> anyhow::Result<Produced<'_>>;

    /// Phase 2: apply the server-averaged payload.
    fn apply(&mut self, avg: &[f32]);

    /// The leader closed this round **without** our payload (K-of-M /
    /// deadline round-completion policy): fold the entire transmitted
    /// payload back into local state so the contribution is delayed, not
    /// lost. Error-feedback algorithms re-absorb the sent p̂ into the
    /// error memory (`e ← e + p̂ = p`, exactly as if the δ-approximate
    /// compressor had returned 0 — a legal 0-approximate round the next
    /// transmission compensates, so the compressor contract is intact).
    /// Algorithms without error feedback have nothing to fold the
    /// payload into and simply drop it (the default no-op) — the same
    /// information loss CPOAdam-GQ already accepts per round.
    ///
    /// Only valid between a [`Self::produce`] and the next one (it
    /// references the round's reused payload buffer).
    fn absorb_skipped(&mut self) {}

    /// Serialize every field the next round's `produce`/`apply` depend on
    /// (parameters, error memory, optimizer moments, step counters) into
    /// `out`. Snapshots are taken at the round boundary — after `apply`,
    /// before the next `produce` — where the reused scratch buffers are
    /// dead, so they are deliberately excluded. Default: unsupported
    /// (protects test mocks from silently snapshotting nothing).
    fn save_state(&self, _out: &mut Vec<u8>) -> anyhow::Result<()> {
        anyhow::bail!("algorithm {} does not support state snapshots", self.name())
    }

    /// Restore from [`Self::save_state`] bytes. Hyperparameters (lr
    /// schedule, compressor, betas) come from config — a resume rebuilds
    /// the worker from config first, then loads the dynamic state here.
    fn load_state(&mut self, _bytes: &[u8]) -> anyhow::Result<()> {
        anyhow::bail!("algorithm {} does not support state snapshots", self.name())
    }

    /// Algorithm name for logs/reports.
    fn name(&self) -> String;
}

/// Which algorithm to run — the config-level selector.
#[derive(Debug, Clone, PartialEq)]
pub enum AlgoKind {
    /// Algorithm 2 (pure OMD form) with the given compressor.
    Dqgan { compressor: CompressorSpec },
    /// The paper's experimental DQGAN: Optimistic Adam + EF quantization.
    DqganAdam { compressor: CompressorSpec },
    /// Centralized Parallel Optimistic Adam (no quantization, no EF).
    CpoAdam,
    /// CPOAdam with quantized gradients but **no** error feedback.
    CpoAdamGq { compressor: CompressorSpec },
    /// Distributed simultaneous gradient descent (divergence baseline).
    DistGda,
}

impl AlgoKind {
    /// Parse from a CLI string: `dqgan:linf8`, `cpoadam`, `cpoadam-gq:linf8`,
    /// `gda`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let (name, arg) = match s.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (s, None),
        };
        match name {
            "dqgan" => Ok(Self::Dqgan {
                compressor: CompressorSpec::parse(arg.unwrap_or("linf8"))?,
            }),
            "dqgan-adam" | "dqganadam" => Ok(Self::DqganAdam {
                compressor: CompressorSpec::parse(arg.unwrap_or("linf8"))?,
            }),
            "cpoadam" => Ok(Self::CpoAdam),
            "cpoadam-gq" | "cpoadamgq" => Ok(Self::CpoAdamGq {
                compressor: CompressorSpec::parse(arg.unwrap_or("linf8"))?,
            }),
            "gda" => Ok(Self::DistGda),
            other => anyhow::bail!("unknown algorithm '{other}'"),
        }
    }

    pub fn label(&self) -> String {
        match self {
            Self::Dqgan { compressor } => format!("dqgan[{compressor:?}]"),
            Self::DqganAdam { compressor } => format!("dqgan-adam[{compressor:?}]"),
            Self::CpoAdam => "cpoadam".into(),
            Self::CpoAdamGq { compressor } => format!("cpoadam-gq[{compressor:?}]"),
            Self::DistGda => "gda".into(),
        }
    }

    /// Build a worker instance with initial parameters `w0` and step-size
    /// schedule `lr`.
    pub fn build_worker(&self, w0: Vec<f32>, lr: LrSchedule) -> Box<dyn WorkerAlgo> {
        match self {
            Self::Dqgan { compressor } => {
                Box::new(DqganWorker::new(w0, lr, Arc::from(compressor.build())))
            }
            Self::DqganAdam { compressor } => {
                Box::new(DqganAdamWorker::new(w0, lr, Arc::from(compressor.build())))
            }
            Self::CpoAdam => Box::new(CpoAdamWorker::new(w0, lr, None)),
            Self::CpoAdamGq { compressor } => {
                Box::new(CpoAdamWorker::new(w0, lr, Some(Arc::from(compressor.build()))))
            }
            Self::DistGda => Box::new(DistGdaWorker::new(w0, lr)),
        }
    }

    /// Server-side decoder for this algorithm's wire payloads: decodes a
    /// wire buffer *into* the caller's dense slice, so the leader's
    /// aggregation path never materializes intermediate `Vec`s (see
    /// [`crate::ps::Aggregator`]). Decode latency feeds the
    /// `codec.decode_ns` histogram when metrics are on; with metrics off
    /// the wrapper is one relaxed load.
    pub fn decoder(&self) -> crate::ps::Decoder {
        match self {
            Self::Dqgan { compressor }
            | Self::DqganAdam { compressor }
            | Self::CpoAdamGq { compressor } => {
                let c: Arc<dyn crate::compress::Compressor> = Arc::from(compressor.build());
                Arc::new(move |bytes: &[u8], out: &mut [f32]| {
                    let t0 = crate::obs::maybe_now();
                    let res = c.decode_into(bytes, out);
                    crate::obs::record_elapsed(&crate::obs::metrics::CODEC_DECODE_NS, t0);
                    res
                })
            }
            Self::CpoAdam | Self::DistGda => {
                let c = crate::compress::Identity;
                Arc::new(move |bytes: &[u8], out: &mut [f32]| {
                    let t0 = crate::obs::maybe_now();
                    let res = c.decode_into(bytes, out);
                    crate::obs::record_elapsed(&crate::obs::metrics::CODEC_DECODE_NS, t0);
                    res
                })
            }
        }
    }

    /// Uplink bytes per round for dimension `d` (used by the network cost
    /// model without running the worker).
    pub fn uplink_bytes(&self, d: usize) -> usize {
        match self {
            Self::Dqgan { compressor }
            | Self::DqganAdam { compressor }
            | Self::CpoAdamGq { compressor } => compressor.build().encoded_size(d),
            Self::CpoAdam | Self::DistGda => 4 * d,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_algo_strings() {
        assert_eq!(AlgoKind::parse("cpoadam").unwrap(), AlgoKind::CpoAdam);
        assert_eq!(AlgoKind::parse("gda").unwrap(), AlgoKind::DistGda);
        match AlgoKind::parse("dqgan:linf8").unwrap() {
            AlgoKind::Dqgan { compressor } => {
                assert_eq!(compressor, CompressorSpec::Linf { levels: 127, block: None })
            }
            other => panic!("{other:?}"),
        }
        match AlgoKind::parse("cpoadam-gq:qsgd(s=7)").unwrap() {
            AlgoKind::CpoAdamGq { compressor } => {
                assert_eq!(compressor, CompressorSpec::Qsgd { levels: 7 })
            }
            other => panic!("{other:?}"),
        }
        assert!(AlgoKind::parse("wat").is_err());
    }

    #[test]
    fn uplink_bytes_reflect_compression() {
        let d = 100_000;
        let dq = AlgoKind::parse("dqgan:linf8").unwrap();
        let cp = AlgoKind::parse("cpoadam").unwrap();
        assert!(dq.uplink_bytes(d) * 3 < cp.uplink_bytes(d));
    }
}
