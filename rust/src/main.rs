//! DQGAN CLI entrypoint (subcommands implemented in `cli/`).
fn main() {
    if let Err(e) = dqgan::cli::run(std::env::args().skip(1).collect()) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
