//! Tiny argument parser: positionals + `--key value` / `--flag` pairs,
//! with typed accessors and unused-flag warnings.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    used: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse `--key value` (value required unless the next token is another
    /// option or the end — then it's a boolean flag).
    pub fn parse(argv: Vec<String>) -> anyhow::Result<Self> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                anyhow::ensure!(!key.is_empty(), "bare '--' is not a valid option");
                match it.peek() {
                    Some(next) if !next.starts_with("--") => {
                        let val = it.next().unwrap();
                        args.options.insert(key.to_string(), val);
                    }
                    _ => args.flags.push(key.to_string()),
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    fn mark_used(&self, key: &str) {
        self.used.borrow_mut().push(key.to_string());
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<String> {
        self.mark_used(key);
        self.options.get(key).cloned()
    }

    /// String option with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or_else(|| default.to_string())
    }

    /// Typed option with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
        }
    }

    /// Boolean flag (present or `--key true/false`).
    pub fn flag(&self, key: &str) -> bool {
        self.mark_used(key);
        self.flags.iter().any(|f| f == key)
            || self
                .options
                .get(key)
                .map(|v| v == "true" || v == "1")
                .unwrap_or(false)
    }

    /// Warn about options the command never read (typo protection).
    pub fn warn_unused(&self) {
        let used = self.used.borrow();
        for key in self.options.keys().chain(self.flags.iter()) {
            if !used.iter().any(|u| u == key) {
                crate::log_warn!("unused option --{key}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from).collect()).unwrap()
    }

    #[test]
    fn positionals_and_options() {
        let a = parse("train --workers 4 --fast --lr 0.01");
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get_parse("workers", 1usize).unwrap(), 4);
        assert!((a.get_parse("lr", 0.0f32).unwrap() - 0.01).abs() < 1e-9);
        assert!(a.flag("fast"));
        assert!(!a.flag("slow"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("train");
        assert_eq!(a.get_parse("rounds", 100u64).unwrap(), 100);
        assert_eq!(a.get_or("algo", "cpoadam"), "cpoadam");
    }

    #[test]
    fn bad_value_is_error() {
        let a = parse("train --workers banana");
        assert!(a.get_parse("workers", 1usize).is_err());
    }

    #[test]
    fn negative_numbers_are_values() {
        // "--shift -3" : "-3" doesn't start with "--" so it's a value.
        let a = parse("cmd --shift -3");
        assert_eq!(a.get_parse("shift", 0i32).unwrap(), -3);
    }
}
