//! CLI command implementations.

use super::args::Args;
use crate::algo::AlgoKind;
use crate::config::{
    AggMode, AggregatorConfig, KernelMode, PolicyConfig, RecoveryConfig, ReduceMode,
    TransportMode, WorkerLossMode,
};
use crate::compress::{
    compressor_from_spec, empirical_delta, gaussian_sampler, heavy_tail_sampler,
    sparse_sampler,
};
use crate::data::{GaussianMixture2D, SynthImages};
use crate::model::{MlpGan, MlpGanConfig};
use crate::optim::LrSchedule;
use crate::ps::{run_cluster, ClusterConfig};
use crate::runtime::{artifacts_dir, Runtime, XlaGradSource};
use crate::telemetry::Table;
use crate::util::rng::Pcg32;

/// `dqgan train`: one PS training run, printing a progress table.
pub fn train(args: &mut Args) -> anyhow::Result<()> {
    let algo = AlgoKind::parse(&args.get_or("algo", "dqgan-adam:linf8"))?;
    let model = args.get_or("model", "mlp");
    let workers = args.get_parse("workers", 4usize)?;
    let rounds = args.get_parse("rounds", 200u64)?;
    let seed = args.get_parse("seed", 2020u64)?;
    let eval_every = args.get_parse("eval-every", (rounds / 10).max(1))?;
    let native = args.flag("native");
    // Hot-loop kernel arm. Both arms are bitwise-identical by contract
    // (CI A/Bs the round checksums), so this is a perf/debug knob, not a
    // numerics knob.
    let kernels = KernelMode::parse(&args.get_or("kernels", "simd"))?;
    crate::kernels::set_mode(kernels);

    let (default_batch, default_lr) = match model.as_str() {
        "mlp" => (32usize, 2e-3f32),
        "dcgan" => (16, 2e-4),
        other => anyhow::bail!("unknown model '{other}' (mlp|dcgan)"),
    };
    let batch = args.get_parse("batch", default_batch)?;
    let lr = args.get_parse("lr", default_lr)?;
    let policy = PolicyConfig::parse(&args.get_or("policy", "full"))?;
    // Partial policies need the per-arrival hook, which only the
    // streaming-engine modes have: default to streaming when --agg
    // wasn't given, and reject an explicit barrier choice early with a
    // clear message.
    let mode = match args.get("agg") {
        Some(s) => AggMode::parse(&s)?,
        None if policy != PolicyConfig::Full => AggMode::Streaming,
        None => AggMode::Sharded,
    };
    anyhow::ensure!(
        policy == PolicyConfig::Full || mode.is_streaming(),
        "--policy {} requires --agg streaming or --agg pipelined (got --agg {mode:?})",
        policy.label()
    );
    let pipeline_depth = args.get_parse("pipeline-depth", 2usize)?;
    anyhow::ensure!(
        (1..=64).contains(&pipeline_depth),
        "--pipeline-depth {pipeline_depth} needs 1 <= depth <= 64"
    );
    let liveness_rounds = args.get_parse("liveness", 0u64)?;
    anyhow::ensure!(
        liveness_rounds == 0 || policy != PolicyConfig::Full,
        "--liveness only applies to partial round policies (--policy kofm:K|deadline:MS)"
    );
    // Reduce schedule (windowed incremental vs close-time barrier) —
    // only the streaming-engine modes have per-arrival folds to
    // schedule; the batch modes reduce at close regardless, so an
    // explicit --reduce there is ignored rather than rejected.
    let reduce = ReduceMode::parse(&args.get_or("reduce", "windowed"))?;
    // Transport engine: one readiness-loop delivery thread (evloop,
    // default) vs the per-worker thread army (threads, A/B baseline).
    // Bitwise-identical broadcasts either way — CI diffs the checksums.
    let transport = TransportMode::parse(&args.get_or("transport", "evloop"))?;
    // Elastic-membership knobs (`--on-worker-loss evict` + friends):
    // eviction needs the in-band Gone/Rejoin protocol, which only the
    // readiness-loop transport speaks, and a partial policy to shrink
    // the quorum over survivors.
    let on_worker_loss = WorkerLossMode::parse(&args.get_or("on-worker-loss", "abort"))?;
    if on_worker_loss == WorkerLossMode::Evict {
        anyhow::ensure!(
            policy != PolicyConfig::Full,
            "--on-worker-loss evict requires a partial round policy \
             (--policy kofm:K|deadline:MS) so rounds can close over the survivors"
        );
        anyhow::ensure!(
            mode.is_streaming(),
            "--on-worker-loss evict requires the streaming engine \
             (--agg streaming|pipelined)"
        );
        anyhow::ensure!(
            transport == TransportMode::EvLoop,
            "--on-worker-loss evict requires --transport evloop \
             (eviction is not supported on the threaded transport)"
        );
    }
    let replay_depth = args.get_parse("replay-depth", RecoveryConfig::default().replay_depth)?;
    let ckpt_dir = args.get("ckpt-dir").map(std::path::PathBuf::from);
    let ckpt_every = args.get_parse("ckpt-every", 0u64)?;
    // `--resume DIR`: restart a checkpointed run from its RUN.json
    // manifest. DIR doubles as the checkpoint dir; when `--ckpt-dir` is
    // also given the two must agree — a run has exactly one store.
    let resume_dir = args.get("resume").map(std::path::PathBuf::from);
    let resume = resume_dir.is_some() || args.flag("resume");
    let ckpt_dir = match (ckpt_dir, resume_dir) {
        (Some(cd), Some(rd)) => {
            anyhow::ensure!(
                cd == rd,
                "--ckpt-dir {} and --resume {} disagree — a run has exactly one \
                 checkpoint store",
                cd.display(),
                rd.display()
            );
            Some(cd)
        }
        (cd, rd) => cd.or(rd),
    };
    anyhow::ensure!(
        ckpt_every == 0 || ckpt_dir.is_some(),
        "--ckpt-every needs --ckpt-dir PATH to write into"
    );
    anyhow::ensure!(
        !resume || ckpt_dir.is_some(),
        "--resume wants the checkpoint directory (--resume DIR or --ckpt-dir PATH)"
    );
    // Worker-side reconnect policy for TCP deployments (`--connect-retry
    // N,BASE_MS`): N dial attempts with exponential backoff plus
    // deterministic jitter. Parsed and carried on the config; the
    // in-process transports never dial.
    let connect_retry = match args.get("connect-retry") {
        Some(spec) => Some(crate::comm::RetryPolicy::parse(&spec)?),
        None => None,
    };
    // Fault injection for the CI chaos job: `--chaos-kill W@R` kills
    // worker W (its transport end drops, no teardown) after R rounds.
    let chaos_kill = match args.get("chaos-kill") {
        Some(spec) => {
            let (w, r) = spec.split_once('@').ok_or_else(|| {
                anyhow::anyhow!("--chaos-kill wants W@R (worker@round), got '{spec}'")
            })?;
            Some((
                w.parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("--chaos-kill worker '{w}' is not a number"))?,
                r.parse::<u64>()
                    .map_err(|_| anyhow::anyhow!("--chaos-kill round '{r}' is not a number"))?,
            ))
        }
        None => None,
    };
    // Leader fault injection (`--chaos-kill-leader R`): the serve loop
    // returns right after round R's broadcast with no Shutdown frame —
    // a simulated `kill -9` the CI chaos-leader job resumes from.
    let chaos_kill_leader = match args.get("chaos-kill-leader") {
        Some(r) => Some(r.parse::<u64>().map_err(|_| {
            anyhow::anyhow!("--chaos-kill-leader round '{r}' is not a number")
        })?),
        None => None,
    };
    let agg = AggregatorConfig {
        mode,
        threads: args.get_parse("agg-threads", 0usize)?,
        shard_elems: args.get_parse("agg-shard", AggregatorConfig::default().shard_elems)?,
        policy,
        pipeline_depth,
        reduce,
        liveness_rounds,
        recovery: RecoveryConfig { on_worker_loss, replay_depth, ckpt_dir, ckpt_every },
    };

    let cfg = ClusterConfig {
        algo,
        workers,
        batch,
        rounds,
        lr: LrSchedule::constant(lr),
        seed,
        eval_every,
        keep_stats: true,
        agg,
        transport,
        chaos_kill,
        chaos_kill_leader,
        resume,
        connect_retry,
    };

    // Observability sinks (ADR-004; the flags combine freely). The
    // global gates are flipped before the run so every hot-path check
    // is a single relaxed load; recording is counts and clock durations
    // only, so broadcast bits are unaffected either way (CI diffs the
    // round checksums between obs-on and obs-off runs).
    let metrics_json_path = args.get("metrics-json").map(std::path::PathBuf::from);
    let worker_csv_path = args.get("worker-csv").map(std::path::PathBuf::from);
    let trace_path = args.get("trace").map(std::path::PathBuf::from);
    if metrics_json_path.is_some() {
        crate::obs::enable_metrics();
    }
    if worker_csv_path.is_some() {
        crate::obs::enable_worker_rows();
    }
    if trace_path.is_some() {
        crate::obs::enable_trace();
    }
    crate::log_info!(
        "train: model={model} algo={} M={workers} B={batch} T={rounds} lr={lr} agg={:?} \
         reduce={:?} policy={} transport={} kernels={} ({})",
        cfg.algo.label(),
        cfg.agg.mode,
        cfg.agg.reduce,
        cfg.agg.policy.label(),
        cfg.transport.label(),
        kernels.label(),
        crate::kernels::simd_backend()
    );

    let report = if model == "mlp" && native {
        run_cluster(&cfg, |_m| Ok(Box::new(MlpGan::new(MlpGanConfig::default()))))?
    } else {
        let rt = Runtime::from_default_dir()?;
        match model.as_str() {
            "mlp" => run_cluster(&cfg, move |_m| {
                Ok(Box::new(XlaGradSource::mlp(
                    &rt,
                    GaussianMixture2D::ring(8, 2.0, 0.1),
                )?))
            })?,
            _ => run_cluster(&cfg, move |_m| {
                Ok(Box::new(XlaGradSource::dcgan(&rt, SynthImages::cifar_like(seed))?))
            })?,
        }
    };

    let mut table = Table::new(&["round", "loss_G", "loss_D", "‖F‖²", "‖e‖²", "bytes_up"]);
    for (i, st) in report.worker0.stats.iter().enumerate() {
        if (i as u64) % eval_every == 0 || i + 1 == report.worker0.stats.len() {
            table.row(&[
                i.to_string(),
                format!("{:.4}", st.loss_g.unwrap_or(f32::NAN)),
                format!("{:.4}", st.loss_d.unwrap_or(f32::NAN)),
                format!("{:.3e}", st.grad_norm_sq),
                format!("{:.3e}", st.err_norm_sq),
                st.bytes_up.to_string(),
            ]);
        }
    }
    table.print();
    let skipped: usize = report.records.iter().map(|r| r.workers_skipped).sum();
    println!(
        "done: {} rounds in {:.1}s ({:.1} ms/round), uplink total {}, skipped payloads {}",
        report.records.len(),
        report.wall_secs,
        report.mean_round_secs * 1e3,
        crate::util::bytes::human_bytes(report.total_bytes_up),
        skipped
    );
    if let Some(p) = args.get("round-csv") {
        let path = std::path::PathBuf::from(p);
        let written = crate::telemetry::write_round_records(&path, &report.records)?;
        println!("wrote per-round telemetry to {written}");
    }
    if let Some(path) = &metrics_json_path {
        use crate::util::json::Json;
        let mut meta = std::collections::BTreeMap::new();
        meta.insert("algo".to_string(), Json::Str(cfg.algo.label()));
        meta.insert("model".to_string(), Json::Str(model.clone()));
        meta.insert("workers".to_string(), Json::Num(workers as f64));
        meta.insert("rounds".to_string(), Json::Num(rounds as f64));
        meta.insert("batch".to_string(), Json::Num(batch as f64));
        meta.insert("seed".to_string(), Json::Num(seed as f64));
        meta.insert("transport".to_string(), Json::Str(cfg.transport.label().to_string()));
        meta.insert("kernels".to_string(), Json::Str(kernels.label().to_string()));
        crate::obs::write_metrics_json(path, meta)?;
        println!("wrote metrics dump to {}", path.display());
    }
    if let Some(path) = &worker_csv_path {
        let written = crate::obs::write_worker_csv(path)?;
        println!("wrote per-worker telemetry to {written}");
    }
    if let Some(path) = &trace_path {
        crate::obs::write_trace(path)?;
        println!(
            "wrote trace-event JSON to {} (load in Perfetto or chrome://tracing)",
            path.display()
        );
    }
    Ok(())
}

/// `dqgan ckpt-gc`: prune old rounds from a checkpoint store, keeping
/// the newest `--keep K` rounds per kind — and always the round the run
/// manifest (`RUN.json`) points at, which a resume must be able to
/// restore from. The store manifest is rewritten atomically, and the
/// run manifest's replay index is refreshed so pruned broadcast rounds
/// are no longer advertised as replayable.
pub fn ckpt_gc(args: &mut Args) -> anyhow::Result<()> {
    use crate::ckpt::{CkptStore, RunManifest};
    let dir = args.get("dir").map(std::path::PathBuf::from).ok_or_else(|| {
        anyhow::anyhow!("ckpt-gc needs --dir PATH (the checkpoint directory)")
    })?;
    let keep = args.get_parse("keep", 4usize)?;
    let run_manifest = RunManifest::load(&dir)?;
    let protect = run_manifest.as_ref().map(|man| man.round);
    let mut store = CkptStore::open(&dir)?;
    let before = store.len();
    let removed = store.gc_keep(keep, protect)?;
    if let Some(mut man) = run_manifest {
        man.replay_rounds = store.rounds("bcast");
        man.save(&dir)?;
    }
    println!(
        "ckpt-gc {}: removed {removed} of {before} blobs (keep {keep}{})",
        dir.display(),
        match protect {
            Some(r) => format!(", manifest round {r} protected"),
            None => String::new(),
        }
    );
    Ok(())
}

/// `dqgan figures --id <exp>`: regenerate a paper figure.
pub fn figures(args: &mut Args) -> anyhow::Result<()> {
    let id = args
        .get("id")
        .or_else(|| args.positional.get(1).cloned())
        .ok_or_else(|| anyhow::anyhow!("need --id (fig2|fig3|fig4|synthetic|bilinear|lemma1|thm3|all)"))?;
    let fast = args.flag("fast");
    crate::exp::run(&id, fast)
}

/// `dqgan validate-compressors`: empirical Definition-1 verification.
pub fn validate_compressors(args: &mut Args) -> anyhow::Result<()> {
    let dim = args.get_parse("dim", 4096usize)?;
    let trials = args.get_parse("trials", 20usize)?;
    let reps = args.get_parse("reps", 10usize)?;
    // Expected Definition-1 FAILURES (reported, not fatal):
    // - terngrad: never δ-approximate (see compress/ docs);
    // - qsgd at 4 bits with large d: QSGD's ‖·‖₂ scale needs s ≳ √d, so
    //   s=7 at d ≥ ~100 violates the contraction on dense inputs. This is
    //   a genuine limit of the paper's Theorem 2 as stated; the ‖·‖∞
    //   variant (Hou et al. — the one the paper's experiments use) holds
    //   in every regime we test. Recorded in EXPERIMENTS.md §THM2.
    let specs = [
        "identity", "topk(f=0.05)", "topk(f=0.25)", "qsgd8", "qsgd4", "linf8", "linf4",
        "linf(bits=8,block=128)", "sign", "terngrad",
    ];
    let expected_negative = ["terngrad", "qsgd4", "qsgd(s=7)"];
    let samplers: [(&str, fn(&mut Pcg32, usize) -> Vec<f32>); 3] = [
        ("gaussian", gaussian_sampler),
        ("heavy-tail", heavy_tail_sampler),
        ("sparse", sparse_sampler),
    ];
    let mut table = Table::new(&[
        "compressor", "input", "δ̂ (mean)", "δ̂ (worst)", "guaranteed δ", "4d/bytes", "ok",
    ]);
    let mut failures = 0;
    for spec in specs {
        let c = compressor_from_spec(spec)?;
        for (sname, sampler) in samplers {
            let mut rng = Pcg32::new(0xC0FFEE ^ dim as u64);
            let est = empirical_delta(c.as_ref(), dim, trials, reps, &mut rng, sampler);
            let ok = est.is_delta_approximate();
            if !ok && !expected_negative.contains(&spec) {
                failures += 1;
            }
            table.row(&[
                c.name(),
                sname.to_string(),
                format!("{:.4}", est.mean_delta),
                format!("{:.4}", est.worst_delta),
                c.delta(dim).map(|d| format!("{d:.4}")).unwrap_or_else(|| "—".into()),
                format!("{:.1}×", crate::compress::compression_ratio(c.as_ref(), dim)),
                if ok { "✓" } else { "✗" }.to_string(),
            ]);
        }
    }
    table.print();
    anyhow::ensure!(failures == 0, "{failures} compressor/input combos violated Definition 1");
    println!(
        "Theorems 1–2 hold empirically for every δ-approximate compressor ✓ \
         (terngrad is documented as NOT δ-approximate — comparison codec only)"
    );
    Ok(())
}

/// `dqgan bench-compare`: gate a fresh bench summary against the
/// committed trajectory (`BENCH_*.json`). Exits non-zero on any
/// calibration-normalized regression past `--threshold` or any
/// `speedup_gates` pair below `--min-speedup` — this is the CI perf
/// gate, not a reporting convenience.
pub fn bench_compare(args: &mut Args) -> anyhow::Result<()> {
    let baseline_path = args
        .get("baseline")
        .ok_or_else(|| anyhow::anyhow!("need --baseline PATH (committed BENCH_*.json)"))?;
    let fresh_path = args
        .get("fresh")
        .ok_or_else(|| anyhow::anyhow!("need --fresh PATH (this run's DQGAN_BENCH_JSON output)"))?;
    let threshold = args.get_parse("threshold", 0.15f64)?;
    anyhow::ensure!(threshold >= 0.0, "--threshold must be >= 0 (got {threshold})");
    let min_speedup = args.get_parse("min-speedup", 1.5f64)?;
    anyhow::ensure!(min_speedup >= 1.0, "--min-speedup must be >= 1 (got {min_speedup})");

    let load = |path: &str| -> anyhow::Result<crate::util::json::Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        crate::util::json::Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))
    };
    let baseline = load(&baseline_path)?;
    let fresh = load(&fresh_path)?;

    let rep = crate::benchutil::summary::compare(&baseline, &fresh, threshold, min_speedup);
    println!(
        "bench-compare: {} vs {} (threshold {:.0}%, min simd speedup {min_speedup}×)",
        baseline_path,
        fresh_path,
        threshold * 100.0
    );
    for line in &rep.lines {
        println!("{line}");
    }
    println!("compared {} cases", rep.compared);
    anyhow::ensure!(
        rep.compared > 0,
        "no overlapping cases between {baseline_path} and {fresh_path} — wrong files?"
    );
    for r in &rep.regressions {
        eprintln!("REGRESSION: {r}");
    }
    for g in &rep.gate_failures {
        eprintln!("SPEEDUP GATE: {g}");
    }
    anyhow::ensure!(
        rep.passed(),
        "{} regression(s), {} speedup-gate failure(s)",
        rep.regressions.len(),
        rep.gate_failures.len()
    );
    println!("bench trajectory ok ✓");
    Ok(())
}

/// `dqgan metrics-check`: validate a `--metrics-json` dump — schema tag
/// plus one required key per **declared** metric (the same central
/// enumeration the dump writes from). CI runs this on the seeded
/// observability run so a silently dropped metric fails the build.
pub fn metrics_check(args: &mut Args) -> anyhow::Result<()> {
    let path = args
        .get("file")
        .ok_or_else(|| anyhow::anyhow!("need --file PATH (a --metrics-json dump)"))?;
    let text =
        std::fs::read_to_string(&path).map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let doc = crate::util::json::Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
    crate::obs::check_metrics_json(&doc)?;
    println!("metrics dump ok ✓ ({path}, schema {})", crate::obs::SCHEMA);
    Ok(())
}

/// `dqgan info`: platform and manifest summary.
pub fn info(_args: &mut Args) -> anyhow::Result<()> {
    println!("dqgan {} — DQGAN reproduction (three-layer Rust+JAX+Pallas)", env!("CARGO_PKG_VERSION"));
    let dir = artifacts_dir();
    if dir.join("manifest.json").exists() {
        let rt = Runtime::new(&dir)?;
        let m = rt.manifest();
        println!("artifacts dir: {} (jax {})", dir.display(), m.jax_version);
        let mut table = Table::new(&["artifact", "file", "inputs", "outputs", "dim"]);
        for (name, spec) in &m.artifacts {
            table.row(&[
                name.clone(),
                spec.file.clone(),
                spec.inputs.len().to_string(),
                spec.outputs.len().to_string(),
                spec.meta_usize("dim").map(|d| d.to_string()).unwrap_or_else(|_| "—".into()),
            ]);
        }
        table.print();
    } else {
        println!("artifacts dir: {} — NOT BUILT (run `make artifacts`)", dir.display());
    }
    Ok(())
}
