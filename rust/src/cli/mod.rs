//! Command-line interface (hand-rolled; clap is unavailable offline).
//!
//! ```text
//! dqgan train --algo dqgan-adam:linf8 --model dcgan --workers 4 ...
//! dqgan figures --id fig2 [--fast]
//! dqgan validate-compressors [--dim 4096]
//! dqgan info
//! ```

mod args;
mod commands;

pub use args::Args;

/// Entry point used by `main.rs`.
pub fn run(argv: Vec<String>) -> crate::Result<()> {
    let mut args = Args::parse(argv)?;
    let cmd = args.positional.first().cloned().unwrap_or_else(|| "help".to_string());
    let result = match cmd.as_str() {
        "train" => commands::train(&mut args),
        "figures" | "exp" | "experiment" => commands::figures(&mut args),
        "validate-compressors" => commands::validate_compressors(&mut args),
        "ckpt-gc" => commands::ckpt_gc(&mut args),
        "bench-compare" => commands::bench_compare(&mut args),
        "metrics-check" => commands::metrics_check(&mut args),
        "info" => commands::info(&mut args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            anyhow::bail!("unknown command '{other}'")
        }
    };
    if result.is_ok() {
        args.warn_unused();
    }
    result
}

fn print_help() {
    println!(
        "dqgan — Distributed Quantized GAN training (Chen et al. 2020 reproduction)

USAGE:
  dqgan train [--algo A] [--model mlp|dcgan] [--workers N] [--batch B]
              [--rounds T] [--lr ETA] [--seed S] [--eval-every K]
              [--agg sharded|sequential|streaming|pipelined]
              [--agg-threads N] [--agg-shard E] [--pipeline-depth D]
              [--reduce windowed|barrier]
              [--policy full|kofm:K|deadline:MS[,K]] [--liveness R]
              [--transport evloop|threads]
              [--on-worker-loss abort|evict] [--replay-depth N]
              [--ckpt-dir PATH] [--ckpt-every K] [--chaos-kill W@R]
              [--resume DIR] [--chaos-kill-leader R]
              [--connect-retry N,BASE_MS]
              [--kernels simd|scalar] [--round-csv PATH]
              [--metrics-json PATH] [--worker-csv PATH] [--trace PATH]
      Train a GAN on the parameter-server runtime.
      Algorithms: dqgan[:comp] (Algorithm 2), dqgan-adam[:comp] (paper §4),
                  cpoadam, cpoadam-gq[:comp], gda
      Compressors: linf8 (paper), linfN, qsgdN, topk(f=0.1), sign,
                  terngrad, identity
      Aggregation: the leader's decode+average path. sharded (default)
      fans decode/reduce work across a thread pool; streaming decodes
      each payload as it arrives (overlapping decode with straggler
      wait); pipelined additionally queues broadcasts onto per-worker
      writer threads so a slow receiver no longer stalls the next
      round's gather (--pipeline-depth bounds the undelivered
      broadcasts per worker, default 2); sequential is the
      single-thread baseline. All four are bitwise-identical.
      --agg-threads 0 = auto; --agg-shard = f32 elements per reduction
      shard. --liveness R fails a kofm/deadline run when a skipped
      worker's late payload is more than R rounds behind (dead, not
      slow; 0 = never, default). --reduce windowed (default) folds the
      arrived worker-id prefix into the mean during the gather — and
      offloads the close-time tail to the pool under --agg pipelined —
      while barrier keeps the whole fold at close time; both are
      bitwise-identical (streaming/pipelined engines only).
      --kernels selects the hot-loop implementation: simd (default,
      8-wide lane chunks + AVX2 where it wins) or scalar (the reference
      loops). Both arms are bitwise-identical by contract — CI A/Bs the
      per-round broadcast checksums between them.
      --on-worker-loss picks what a worker death does to the run:
      abort (default) fails fast naming the worker; evict removes the
      worker from the membership — parked frames are reclaimed, the
      quorum shrinks to the survivors, and the run continues (needs
      --policy kofm/deadline, --agg streaming|pipelined and
      --transport evloop). An evicted worker may reconnect with its old
      id: the leader replays the last --replay-depth broadcast frames
      (default 8, bitwise-identical to the originals) and readmits it;
      --ckpt-dir extends that window by spilling rotated-out frames to
      a content-addressed checkpoint store, and --ckpt-every K
      additionally snapshots the model every K rounds. --chaos-kill W@R
      is the fault injector behind the CI chaos job: worker W drops
      dead (no teardown handshake) after R rounds.
      Leader recovery: with --ckpt-dir and --ckpt-every K the run is
      resumable across a leader kill — every K rounds the leader spills
      the broadcast and each worker snapshots its error memory,
      optimizer state and RNG cursor into the shared store, and a
      crash-consistent run manifest (RUN.json) advances only when a
      round's blobs are all durable. --resume DIR reloads the manifest
      (refusing loudly on a config-fingerprint mismatch), rolls every
      worker back to the manifest round, and continues under a bumped
      session epoch; the rounds after the resume are bitwise-identical
      to an undisturbed run. --chaos-kill-leader R is the matching
      fault injector: the leader dies right after round R's broadcast
      (no Shutdown), exactly like kill -9. --connect-retry N,BASE_MS
      gives TCP workers N dial attempts with exponential backoff and
      deterministic jitter while a restarted leader comes back up.
      --transport selects the frame engine: evloop (default) drives
      every worker connection from one readiness-loop leader thread and
      bounds *applied* (acked) broadcasts per worker, so leader thread
      count stays flat as workers scale; threads is the per-worker
      reader/writer baseline kept for A/B. Both transports produce
      bitwise-identical broadcasts — CI diffs the per-round checksums.
      Observability (counts and clock durations only — never numerics,
      so every bitwise A/B stays green with these on): --metrics-json
      dumps the process-global metrics registry at run end
      (schema-versioned JSON); --worker-csv writes one row per
      (worker, round) with apply latency, ack RTT, absorbed-skip flag
      and error-memory L2 norm; --trace writes Chrome trace-event JSON
      (leader spans gather/decode/reduce/close/broadcast on tid 0,
      worker i spans produce/recv/apply/ack on tid 1+i) — load it in
      Perfetto or chrome://tracing.

  dqgan figures --id fig2|fig3|fig4|synthetic|bilinear|lemma1|thm3|all [--fast]
      Regenerate a paper figure / theory validation (CSV under results/).

  dqgan validate-compressors [--dim D] [--trials N]
      Empirically verify Definition 1 (δ-approximate) for every compressor
      (Theorems 1–2).

  dqgan ckpt-gc --dir PATH [--keep K]
      Prune a checkpoint store down to the newest K rounds per kind
      (default 4). The round the run manifest points at is never
      pruned — a resume must always find its blobs — and the manifest's
      replay index is refreshed after the sweep.

  dqgan bench-compare --baseline BENCH_N.json --fresh RUN.json
                      [--threshold 0.15] [--min-speedup 1.5]
      Gate a fresh bench summary (written by the bench binaries under
      DQGAN_BENCH_JSON=PATH) against the committed trajectory file.
      Fails on any calibration-normalized median regression past the
      threshold, or any speedup_gates pair whose scalar/simd ratio in
      the fresh run is below the floor.

  dqgan metrics-check --file PATH
      Validate a --metrics-json dump: schema tag plus one required key
      per declared metric (CI's missing-keys gate for the obs registry).

  dqgan info
      Show artifact manifest, platform and configuration info.

ENVIRONMENT:
  DQGAN_LOG=LEVEL[,TARGET=LEVEL]*         log filter (default info); levels
                                          error|warn|info|debug|trace, with
                                          per-target overrides by module
                                          path segment, e.g.
                                          DQGAN_LOG=info,evloop=trace
  DQGAN_ARTIFACTS=DIR                     artifacts dir (default artifacts/)
  DQGAN_RESULTS=DIR                       results dir (default results/)
  DQGAN_BENCH_JSON=PATH                   bench binaries merge a machine-
                                          readable summary into PATH"
    );
}
