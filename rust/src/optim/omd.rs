//! One-call Optimistic Mirror Descent (paper Algorithm 1, eq. 16–18).
//!
//! Unconstrained form used throughout the paper:
//!
//!   w_{t+½} = w_t − η·F(w_{t−½})          (half step with *stored* grad)
//!   w_{t+1} = w_t − η·F(w_{t+½})          (full step with fresh grad)
//!
//! One gradient evaluation per iteration (at w_{t+½}); the previous one is
//! reused. The caller drives the two phases:
//! [`Omd::half_point`] yields w_{t+½}, the caller evaluates F there, then
//! [`Omd::full_step`] applies the update and stores the gradient.

use super::LrSchedule;

/// One-call OMD state: the stored gradient F(w_{t−½}).
#[derive(Debug, Clone)]
pub struct Omd {
    pub lr: LrSchedule,
    f_prev: Vec<f32>,
    t: u64,
}

impl Omd {
    pub fn new(lr: f32, dim: usize) -> Self {
        // F(w_{−½}) = 0 by convention (first half step is a no-op),
        // matching Algorithm 2's initialization w_{−½} = w₀, e₀ = 0.
        Self { lr: LrSchedule::constant(lr), f_prev: vec![0.0; dim], t: 0 }
    }

    pub fn with_schedule(mut self, lr: LrSchedule) -> Self {
        self.lr = lr;
        self
    }

    /// Current step size.
    pub fn eta(&self) -> f32 {
        self.lr.at(self.t)
    }

    /// The stored gradient F(w_{t−½}).
    pub fn stored_grad(&self) -> &[f32] {
        &self.f_prev
    }

    /// Compute the half point w_{t+½} = w_t − η·F(w_{t−½}) into `out`.
    pub fn half_point(&self, w: &[f32], out: &mut [f32]) {
        assert_eq!(w.len(), self.f_prev.len());
        assert_eq!(w.len(), out.len());
        let eta = self.eta();
        for i in 0..w.len() {
            out[i] = w[i] - eta * self.f_prev[i];
        }
    }

    /// Apply the full step `w ← w − η·F(w_{t+½})` and store the gradient.
    pub fn full_step(&mut self, w: &mut [f32], grad_at_half: &[f32]) {
        assert_eq!(w.len(), grad_at_half.len());
        let eta = self.eta();
        for i in 0..w.len() {
            w[i] -= eta * grad_at_half[i];
        }
        self.f_prev.copy_from_slice(grad_at_half);
        self.t += 1;
    }

    /// Convenience one-shot driver: `f` evaluates F at a given point.
    pub fn step_with(&mut self, w: &mut [f32], mut f: impl FnMut(&[f32], &mut [f32])) {
        let mut half = vec![0.0; w.len()];
        self.half_point(w, &mut half);
        let mut g = vec![0.0; w.len()];
        f(&half, &mut g);
        self.full_step(w, &g);
    }

    pub fn t(&self) -> u64 {
        self.t
    }

    pub fn reset(&mut self) {
        self.f_prev.iter_mut().for_each(|x| *x = 0.0);
        self.t = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The canonical bilinear game F(x,y) = (y, −x): GDA spirals out,
    /// OMD converges (paper §2.2's motivation).
    fn bilinear_f(w: &[f32], out: &mut [f32]) {
        out[0] = w[1];
        out[1] = -w[0];
    }

    #[test]
    fn omd_converges_on_bilinear() {
        let mut omd = Omd::new(0.1, 2);
        let mut w = vec![1.0f32, 1.0];
        for _ in 0..2000 {
            omd.step_with(&mut w, bilinear_f);
        }
        let r = (w[0] * w[0] + w[1] * w[1]).sqrt();
        assert!(r < 1e-3, "OMD did not converge: r={r}");
    }

    #[test]
    fn gda_diverges_on_bilinear_for_contrast() {
        use crate::optim::{Optimizer, Sgd};
        let mut sgd = Sgd::new(0.1);
        let mut w = vec![1.0f32, 1.0];
        for _ in 0..2000 {
            let mut g = vec![0.0; 2];
            bilinear_f(&w, &mut g);
            sgd.step(&mut w, &g);
        }
        let r = (w[0] * w[0] + w[1] * w[1]).sqrt();
        assert!(r > 10.0, "GDA unexpectedly bounded: r={r}");
    }

    #[test]
    fn first_half_step_is_identity() {
        let omd = Omd::new(0.5, 3);
        let w = vec![1.0, 2.0, 3.0];
        let mut half = vec![0.0; 3];
        omd.half_point(&w, &mut half);
        assert_eq!(half, w);
    }

    #[test]
    fn matches_one_line_form() {
        // eq. 18: w_{t+½} = w_{t−½} − 2η·F(w_{t−½}) + η·F(w_{t−3/2})
        // Verify our two-phase implementation satisfies it on a quadratic.
        let f = |w: &[f32], out: &mut [f32]| out[0] = w[0];
        let eta = 0.05f32;
        let mut omd = Omd::new(eta, 1);
        let mut w = vec![1.0f32];
        let mut halves = Vec::new();
        let mut grads = vec![0.0f32]; // F(w_{−3/2}) = 0 convention
        let mut prev_half_grad = 0.0f32;
        for _ in 0..5 {
            let mut half = vec![0.0; 1];
            omd.half_point(&w, &mut half);
            halves.push(half[0]);
            let mut g = vec![0.0; 1];
            f(&half, &mut g);
            grads.push(g[0]);
            omd.full_step(&mut w, &g);
            prev_half_grad = g[0];
        }
        let _ = prev_half_grad;
        // Check eq. 18 for t = 2..: halves[t] = halves[t-1] − 2η·F(halves[t-1]) + η·F(halves[t-2])
        for t in 2..halves.len() {
            let lhs = halves[t];
            let rhs = halves[t - 1] - 2.0 * eta * grads[t] + eta * grads[t - 1];
            assert!((lhs - rhs).abs() < 1e-6, "t={t} lhs={lhs} rhs={rhs}");
        }
    }
}
