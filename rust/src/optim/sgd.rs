//! Plain (simultaneous) gradient descent on the operator F — the baseline
//! the paper shows *fails* on min–max problems (§2.2, eq. 11).

use super::{LrSchedule, Optimizer};

/// `w ← w − η_t·F(w)` with optional momentum.
#[derive(Debug, Clone)]
pub struct Sgd {
    pub lr: LrSchedule,
    pub momentum: f32,
    velocity: Vec<f32>,
    t: u64,
}

impl Sgd {
    pub fn new(lr: f32) -> Self {
        Self { lr: LrSchedule::constant(lr), momentum: 0.0, velocity: Vec::new(), t: 0 }
    }

    pub fn with_momentum(mut self, m: f32) -> Self {
        assert!((0.0..1.0).contains(&m));
        self.momentum = m;
        self
    }

    pub fn with_schedule(mut self, lr: LrSchedule) -> Self {
        self.lr = lr;
        self
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, w: &mut [f32], grad: &[f32]) {
        assert_eq!(w.len(), grad.len());
        let eta = self.lr.at(self.t);
        if self.momentum > 0.0 {
            if self.velocity.len() != w.len() {
                self.velocity = vec![0.0; w.len()];
            }
            for i in 0..w.len() {
                self.velocity[i] = self.momentum * self.velocity[i] + grad[i];
                w[i] -= eta * self.velocity[i];
            }
        } else {
            for i in 0..w.len() {
                w[i] -= eta * grad[i];
            }
        }
        self.t += 1;
    }

    fn t(&self) -> u64 {
        self.t
    }

    fn reset(&mut self) {
        self.velocity.clear();
        self.t = 0;
    }

    fn name(&self) -> String {
        if self.momentum > 0.0 {
            format!("sgd(m={})", self.momentum)
        } else {
            "sgd".to_string()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descends_a_quadratic() {
        // min ½w² → F(w) = w; SGD converges.
        let mut opt = Sgd::new(0.1);
        let mut w = vec![10.0f32];
        for _ in 0..200 {
            let g = vec![w[0]];
            opt.step(&mut w, &g);
        }
        assert!(w[0].abs() < 1e-4, "w={}", w[0]);
    }

    #[test]
    fn momentum_accumulates() {
        let mut opt = Sgd::new(0.1).with_momentum(0.9);
        let mut w = vec![0.0f32];
        // Constant gradient 1: velocity grows toward 1/(1-0.9) = 10.
        for _ in 0..200 {
            opt.step(&mut w, &[1.0]);
        }
        // displacement per step approaches 0.1*10 = 1
        let before = w[0];
        opt.step(&mut w, &[1.0]);
        assert!((before - w[0] - 1.0).abs() < 0.05);
    }
}
