//! Optimizers for min–max training (paper §2.2).
//!
//! - [`Sgd`] / [`Adam`] — classical minimization updates (the "may cycle
//!   on min–max problems" baselines, §2.2 / SYN-B experiment);
//! - [`Omd`] — one-call Optimistic Mirror Descent (Algorithm 1 / eq. 18),
//!   the update DQGAN distributes;
//! - [`Extragradient`] — the two-call extragradient (eq. 12–13), kept for
//!   the bilinear-game comparison;
//! - [`OptimisticAdam`] — Daskalakis et al. [7]'s Adam variant used by the
//!   paper's CPOAdam baselines;
//! - [`LrSchedule`] — step-size schedules (constant / 1/√t decay).

mod adam;
mod extragradient;
mod omd;
mod optimistic_adam;
mod schedule;
mod sgd;

pub use adam::Adam;
pub use extragradient::Extragradient;
pub use omd::Omd;
pub use optimistic_adam::OptimisticAdam;
pub use schedule::LrSchedule;
pub use sgd::Sgd;

/// A stateful first-order update rule on a flat parameter vector. The
/// gradient passed in is the *operator value* F(w) (descent direction is
/// `-F`), matching the paper's convention.
pub trait Optimizer: Send {
    /// Apply one update in place given the (stochastic) gradient at the
    /// point the algorithm evaluated (see each optimizer's contract).
    fn step(&mut self, w: &mut [f32], grad: &[f32]);

    /// Step count so far.
    fn t(&self) -> u64;

    /// Reset all state.
    fn reset(&mut self);

    /// Name for logs.
    fn name(&self) -> String;
}
