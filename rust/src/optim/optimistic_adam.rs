//! Optimistic Adam (Daskalakis et al. [7], Algorithm 1 "Optimistic Adam"):
//! Adam's preconditioned direction with the optimistic ±η correction,
//!
//!   d_t   = m̂_t / (√v̂_t + ε)
//!   w_{t+1} = w_t − 2η·d_t + η·d_{t−1}
//!
//! This is the update inside the paper's CPOAdam / CPOAdam-GQ baselines:
//! every worker applies it to the *server-averaged* gradient, so all
//! replicas stay in lockstep (the state is deterministic given the
//! gradient stream).

use super::adam::Adam;
use super::{LrSchedule, Optimizer};
use crate::util::bytes::{put_f32_slice, put_u32, put_u64, Reader};

/// Optimistic Adam state: inner Adam moments + previous direction.
#[derive(Debug, Clone)]
pub struct OptimisticAdam {
    inner: Adam,
    lr: LrSchedule,
    prev_dir: Vec<f32>,
    t: u64,
}

impl OptimisticAdam {
    pub fn new(lr: f32) -> Self {
        Self {
            // Inner Adam's own lr is unused; we consume directions only.
            inner: Adam::new(1.0).with_betas(0.5, 0.9),
            lr: LrSchedule::constant(lr),
            prev_dir: Vec::new(),
            t: 0,
        }
    }

    /// GAN-typical betas (paper experiments tune via grid search; β₁=0.5
    /// is the DCGAN convention).
    pub fn with_betas(mut self, b1: f32, b2: f32) -> Self {
        self.inner = Adam::new(1.0).with_betas(b1, b2);
        self
    }

    pub fn with_schedule(mut self, lr: LrSchedule) -> Self {
        self.lr = lr;
        self
    }

    /// Serialize inner-Adam moments + the optimistic previous direction
    /// for a worker snapshot (`prev_dir` enters the next update with a
    /// full η weight, so it must survive bit-for-bit).
    pub(crate) fn save_state(&self, out: &mut Vec<u8>) {
        self.inner.save_state(out);
        put_u64(out, self.t);
        put_u32(out, self.prev_dir.len() as u32);
        put_f32_slice(out, &self.prev_dir);
    }

    /// Restore from [`Self::save_state`] bytes.
    pub(crate) fn load_state(&mut self, r: &mut Reader) -> anyhow::Result<()> {
        self.inner.load_state(r)?;
        self.t = r.u64()?;
        let n = r.u32()? as usize;
        self.prev_dir = r.f32_vec(n)?;
        Ok(())
    }
}

impl Optimizer for OptimisticAdam {
    fn step(&mut self, w: &mut [f32], grad: &[f32]) {
        assert_eq!(w.len(), grad.len());
        if self.prev_dir.len() != w.len() {
            self.prev_dir = vec![0.0; w.len()];
        }
        let eta = self.lr.at(self.t);
        let mut dir = vec![0.0; w.len()];
        self.inner.direction(grad, &mut dir);
        for i in 0..w.len() {
            w[i] -= 2.0 * eta * dir[i] - eta * self.prev_dir[i];
        }
        self.prev_dir.copy_from_slice(&dir);
        self.t += 1;
    }

    fn t(&self) -> u64 {
        self.t
    }

    fn reset(&mut self) {
        self.inner.reset();
        self.prev_dir.clear();
        self.t = 0;
    }

    fn name(&self) -> String {
        "optimistic-adam".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_on_quadratic() {
        let mut opt = OptimisticAdam::new(0.05);
        let mut w = vec![5.0f32];
        for _ in 0..2000 {
            let g = vec![w[0]];
            opt.step(&mut w, &g);
        }
        assert!(w[0].abs() < 0.05, "w={}", w[0]);
    }

    #[test]
    fn bounded_on_bilinear_where_adam_spirals() {
        // F(x,y) = (y, −x). Plain Adam (minimization update) cycles/diverges;
        // Optimistic Adam stays bounded and shrinks.
        let mut oadam = OptimisticAdam::new(0.02);
        let mut w = vec![1.0f32, 1.0];
        for _ in 0..5000 {
            let g = vec![w[1], -w[0]];
            oadam.step(&mut w, &g);
        }
        let r_opt = (w[0] * w[0] + w[1] * w[1]).sqrt();

        let mut adam = Adam::new(0.02).with_betas(0.5, 0.9);
        let mut w = vec![1.0f32, 1.0];
        for _ in 0..5000 {
            let g = vec![w[1], -w[0]];
            adam.step(&mut w, &g);
        }
        let r_adam = (w[0] * w[0] + w[1] * w[1]).sqrt();
        assert!(
            r_opt < r_adam && r_opt < 1.0,
            "optimistic={r_opt} plain={r_adam}"
        );
    }

    #[test]
    fn snapshot_round_trip_continues_bit_exact() {
        // Step an optimizer, snapshot it, restore into a fresh instance,
        // and drive both on the same gradient stream: the restored copy
        // must track the original bit-for-bit (the leader-recovery
        // contract for replicated optimizer state).
        let mut a = OptimisticAdam::new(0.01);
        let mut w = vec![1.0f32, -2.0, 3.0];
        let mut rng = crate::util::rng::Pcg32::new(5);
        for _ in 0..25 {
            let g: Vec<f32> = (0..3).map(|_| rng.normal()).collect();
            a.step(&mut w, &g);
        }
        let mut buf = Vec::new();
        a.save_state(&mut buf);
        let mut b = OptimisticAdam::new(0.01);
        let mut r = Reader::new(&buf);
        b.load_state(&mut r).unwrap();
        assert_eq!(r.remaining(), 0, "snapshot must be fully consumed");
        let mut wa = w.clone();
        let mut wb = w;
        for _ in 0..25 {
            let g: Vec<f32> = (0..3).map(|_| rng.normal()).collect();
            a.step(&mut wa, &g);
            b.step(&mut wb, &g);
        }
        for (x, y) in wa.iter().zip(&wb) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn deterministic_replicas_stay_identical() {
        // Two replicas fed the same gradient stream remain bit-identical —
        // the property CPOAdam relies on for consistency across workers.
        let mut a = OptimisticAdam::new(0.01);
        let mut b = OptimisticAdam::new(0.01);
        let mut wa = vec![1.0f32, -2.0, 3.0];
        let mut wb = wa.clone();
        let mut rng = crate::util::rng::Pcg32::new(77);
        for _ in 0..100 {
            let g: Vec<f32> = (0..3).map(|_| rng.normal()).collect();
            a.step(&mut wa, &g);
            b.step(&mut wb, &g);
        }
        assert_eq!(wa, wb);
    }
}
