//! Step-size schedules. Theorem 3's rate is proved for a constant
//! η ≤ min{1/√(BM), 1/(6√2 L)}; the 1/√t decay is the standard fallback
//! when L is unknown.

/// Learning-rate schedule η_t.
#[derive(Debug, Clone, PartialEq)]
pub enum LrSchedule {
    /// η_t = η₀
    Constant { eta0: f32 },
    /// η_t = η₀ / √(1 + t/t0)
    InvSqrt { eta0: f32, t0: f64 },
    /// Linear warmup to η₀ over `warmup` steps, then constant.
    Warmup { eta0: f32, warmup: u64 },
}

impl LrSchedule {
    pub fn constant(eta0: f32) -> Self {
        assert!(eta0 > 0.0);
        Self::Constant { eta0 }
    }

    pub fn inv_sqrt(eta0: f32, t0: f64) -> Self {
        assert!(eta0 > 0.0 && t0 > 0.0);
        Self::InvSqrt { eta0, t0 }
    }

    pub fn warmup(eta0: f32, warmup: u64) -> Self {
        assert!(eta0 > 0.0);
        Self::Warmup { eta0, warmup }
    }

    /// η at step t (0-based).
    pub fn at(&self, t: u64) -> f32 {
        match *self {
            Self::Constant { eta0 } => eta0,
            Self::InvSqrt { eta0, t0 } => (eta0 as f64 / (1.0 + t as f64 / t0).sqrt()) as f32,
            Self::Warmup { eta0, warmup } => {
                if warmup == 0 || t >= warmup {
                    eta0
                } else {
                    eta0 * (t + 1) as f32 / warmup as f32
                }
            }
        }
    }

    /// The paper's safe constant step for Theorem 3:
    /// η = min{1/√(BM), 1/(6√2·L)}.
    pub fn theorem3(batch: usize, workers: usize, lipschitz: f32) -> Self {
        let a = 1.0 / ((batch * workers) as f32).sqrt();
        let b = 1.0 / (6.0 * std::f32::consts::SQRT_2 * lipschitz);
        Self::constant(a.min(b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::constant(0.1);
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(1_000_000), 0.1);
    }

    #[test]
    fn inv_sqrt_decays() {
        let s = LrSchedule::inv_sqrt(1.0, 1.0);
        assert!((s.at(0) - 1.0).abs() < 1e-6);
        assert!((s.at(3) - 0.5).abs() < 1e-6);
        assert!(s.at(100) < s.at(10));
    }

    #[test]
    fn warmup_ramps() {
        let s = LrSchedule::warmup(1.0, 10);
        assert!((s.at(0) - 0.1).abs() < 1e-6);
        assert!((s.at(4) - 0.5).abs() < 1e-6);
        assert_eq!(s.at(10), 1.0);
        assert_eq!(s.at(99), 1.0);
    }

    #[test]
    fn theorem3_takes_the_min() {
        // Large L dominates.
        let s = LrSchedule::theorem3(4, 4, 100.0);
        let want = 1.0 / (6.0 * std::f32::consts::SQRT_2 * 100.0);
        assert!((s.at(0) - want).abs() < 1e-9);
        // Large BM dominates.
        let s = LrSchedule::theorem3(256, 64, 0.01);
        let want = 1.0 / (256.0f32 * 64.0).sqrt();
        assert!((s.at(0) - want).abs() < 1e-9);
    }
}
