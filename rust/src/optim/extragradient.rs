//! Two-call extragradient (Korpelevich [16]; paper eq. 12–13):
//!
//!   w_{t+½} = w_t − η·F(w_t)
//!   w_{t+1} = w_t − η·F(w_{t+½})
//!
//! Two gradient evaluations per iteration — the reference point for what
//! one-call OMD approximates.

use super::LrSchedule;

/// Two-call extragradient driver.
#[derive(Debug, Clone)]
pub struct Extragradient {
    pub lr: LrSchedule,
    t: u64,
}

impl Extragradient {
    pub fn new(lr: f32) -> Self {
        Self { lr: LrSchedule::constant(lr), t: 0 }
    }

    pub fn with_schedule(mut self, lr: LrSchedule) -> Self {
        self.lr = lr;
        self
    }

    /// One full iteration; `f` evaluates F at a given point.
    pub fn step_with(&mut self, w: &mut [f32], mut f: impl FnMut(&[f32], &mut [f32])) {
        let eta = self.lr.at(self.t);
        let n = w.len();
        let mut g = vec![0.0; n];
        f(w, &mut g);
        let mut half = vec![0.0; n];
        for i in 0..n {
            half[i] = w[i] - eta * g[i];
        }
        f(&half, &mut g);
        for i in 0..n {
            w[i] -= eta * g[i];
        }
        self.t += 1;
    }

    pub fn t(&self) -> u64 {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bilinear_f(w: &[f32], out: &mut [f32]) {
        out[0] = w[1];
        out[1] = -w[0];
    }

    #[test]
    fn converges_on_bilinear() {
        let mut eg = Extragradient::new(0.1);
        let mut w = vec![1.0f32, 1.0];
        for _ in 0..2000 {
            eg.step_with(&mut w, bilinear_f);
        }
        let r = (w[0] * w[0] + w[1] * w[1]).sqrt();
        assert!(r < 1e-3, "r={r}");
    }

    #[test]
    fn converges_on_quadratic() {
        let mut eg = Extragradient::new(0.2);
        let mut w = vec![4.0f32];
        for _ in 0..200 {
            eg.step_with(&mut w, |w, o| o[0] = w[0]);
        }
        assert!(w[0].abs() < 1e-4);
    }
}
