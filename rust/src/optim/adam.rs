//! Adam (Kingma & Ba [15]) on the operator F — minimization-style baseline.

use super::{LrSchedule, Optimizer};
use crate::util::bytes::{put_f32_slice, put_u32, put_u64, Reader};

/// Standard Adam with bias correction.
#[derive(Debug, Clone)]
pub struct Adam {
    pub lr: LrSchedule,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(lr: f32) -> Self {
        Self {
            lr: LrSchedule::constant(lr),
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: Vec::new(),
            v: Vec::new(),
            t: 0,
        }
    }

    pub fn with_betas(mut self, b1: f32, b2: f32) -> Self {
        assert!((0.0..1.0).contains(&b1) && (0.0..1.0).contains(&b2));
        self.beta1 = b1;
        self.beta2 = b2;
        self
    }

    pub fn with_schedule(mut self, lr: LrSchedule) -> Self {
        self.lr = lr;
        self
    }

    fn ensure(&mut self, n: usize) {
        if self.m.len() != n {
            self.m = vec![0.0; n];
            self.v = vec![0.0; n];
        }
    }

    /// Serialize the moment state for a worker snapshot. The moment
    /// vectors are lazily sized (empty until the first step) and that
    /// emptiness is part of the state, so lengths are encoded explicitly.
    pub(crate) fn save_state(&self, out: &mut Vec<u8>) {
        put_u64(out, self.t);
        put_u32(out, self.m.len() as u32);
        put_f32_slice(out, &self.m);
        put_f32_slice(out, &self.v);
    }

    /// Restore from [`Self::save_state`] bytes (hyperparameters come from
    /// config, not the snapshot).
    pub(crate) fn load_state(&mut self, r: &mut Reader) -> anyhow::Result<()> {
        self.t = r.u64()?;
        let n = r.u32()? as usize;
        self.m = r.f32_vec(n)?;
        self.v = r.f32_vec(n)?;
        Ok(())
    }

    /// The preconditioned direction m̂/(√v̂+ε) *without* applying it —
    /// shared with [`super::OptimisticAdam`].
    pub(crate) fn direction(&mut self, grad: &[f32], out: &mut [f32]) {
        self.ensure(grad.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..grad.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grad[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
            let mh = self.m[i] / b1t;
            let vh = self.v[i] / b2t;
            out[i] = mh / (vh.sqrt() + self.eps);
        }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, w: &mut [f32], grad: &[f32]) {
        assert_eq!(w.len(), grad.len());
        let eta = self.lr.at(self.t);
        let mut dir = vec![0.0; w.len()];
        self.direction(grad, &mut dir);
        for i in 0..w.len() {
            w[i] -= eta * dir[i];
        }
    }

    fn t(&self) -> u64 {
        self.t
    }

    fn reset(&mut self) {
        self.m.clear();
        self.v.clear();
        self.t = 0;
    }

    fn name(&self) -> String {
        "adam".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descends_a_quadratic() {
        let mut opt = Adam::new(0.1);
        let mut w = vec![5.0f32];
        for _ in 0..500 {
            let g = vec![w[0]];
            opt.step(&mut w, &g);
        }
        assert!(w[0].abs() < 1e-2, "w={}", w[0]);
    }

    #[test]
    fn first_step_is_lr_sized() {
        // Bias correction makes the very first step ≈ lr·sign(g).
        let mut opt = Adam::new(0.1);
        let mut w = vec![0.0f32];
        opt.step(&mut w, &[3.0]);
        assert!((w[0] + 0.1).abs() < 1e-3, "w={}", w[0]);
    }

    #[test]
    fn reset_clears_state() {
        let mut opt = Adam::new(0.1);
        let mut w = vec![1.0f32];
        opt.step(&mut w, &[1.0]);
        opt.reset();
        assert_eq!(opt.t(), 0);
    }
}
