//! Byte-level encode/decode helpers for the wire format and the codecs.
//! Everything is little-endian (the only byte order this system touches).
//!
//! The two hot entry points ([`put_f32_slice`] on the broadcast/identity
//! encode path, [`fnv1a64_f32`] on the drift-check path) dispatch between
//! a scalar baseline and a lane-chunked arm on the process-global
//! [`crate::kernels`] mode; both arms produce identical bytes/checksums.

use crate::config::KernelMode;
use crate::kernels;

/// Append a u32 (LE).
#[inline]
pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Append a u64 (LE).
#[inline]
pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Append an f32 (LE).
#[inline]
pub fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Elements per scratch block of [`put_f32_slice`] (1 KiB of bytes —
/// comfortably in L1, large enough to amortize the per-extend length
/// bookkeeping down to noise).
const F32_SCRATCH_ELEMS: usize = 256;

/// Append an entire f32 slice (LE).
///
/// This sits on the identity-codec and broadcast encode path (the leader
/// serializes the full `dim` average every round), so it avoids the
/// per-element `extend_from_slice` round trips: one up-front reserve,
/// then whole scratch blocks of serialized values appended at a time.
pub fn put_f32_slice(buf: &mut Vec<u8>, vs: &[f32]) {
    match kernels::mode() {
        KernelMode::Simd => put_f32_slice_simd(buf, vs),
        KernelMode::Scalar => put_f32_slice_scalar(buf, vs),
    }
}

/// Scalar arm of [`put_f32_slice`]: one element serialized per iteration.
pub fn put_f32_slice_scalar(buf: &mut Vec<u8>, vs: &[f32]) {
    buf.reserve(vs.len() * 4);
    let mut scratch = [0u8; 4 * F32_SCRATCH_ELEMS];
    for chunk in vs.chunks(F32_SCRATCH_ELEMS) {
        let block = &mut scratch[..4 * chunk.len()];
        for (dst, &v) in block.chunks_exact_mut(4).zip(chunk) {
            dst.copy_from_slice(&v.to_le_bytes());
        }
        buf.extend_from_slice(block);
    }
}

/// SIMD arm of [`put_f32_slice`]: 8 elements land as one fixed 32-byte
/// block store per iteration (the fixed bounds let the backend emit wide
/// stores instead of eight 4-byte copies). Byte-identical to the scalar
/// arm — serialization has no rounding sites at all.
pub fn put_f32_slice_simd(buf: &mut Vec<u8>, vs: &[f32]) {
    buf.reserve(vs.len() * 4);
    let mut scratch = [0u8; 4 * F32_SCRATCH_ELEMS];
    for chunk in vs.chunks(F32_SCRATCH_ELEMS) {
        let block = &mut scratch[..4 * chunk.len()];
        let mut bc = block.chunks_exact_mut(4 * kernels::LANES);
        let mut vc = chunk.chunks_exact(kernels::LANES);
        for (b, v) in (&mut bc).zip(&mut vc) {
            let b: &mut [u8; 4 * kernels::LANES] = b.try_into().expect("exact chunk");
            let v: &[f32; kernels::LANES] = v.try_into().expect("exact chunk");
            for i in 0..kernels::LANES {
                b[4 * i..4 * i + 4].copy_from_slice(&v[i].to_le_bytes());
            }
        }
        for (dst, &v) in bc.into_remainder().chunks_exact_mut(4).zip(vc.remainder()) {
            dst.copy_from_slice(&v.to_le_bytes());
        }
        buf.extend_from_slice(block);
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

/// FNV-1a-style 64-bit checksum over a slice of f32 **bit patterns**,
/// folding one whole u32 pattern per multiply instead of single bytes
/// (4× fewer multiplies than byte-wise FNV; still deterministic across
/// runs and platforms, which is all the broadcast drift checks need).
/// Distinguishes the NaN-payload/±0.0 cases a value comparison would
/// conflate — two checksums agree iff the f32 sequences are bit-equal
/// modulo 64-bit collisions.
pub fn fnv1a64_f32(vs: &[f32]) -> u64 {
    match kernels::mode() {
        KernelMode::Simd => fnv1a64_f32_simd(vs),
        KernelMode::Scalar => fnv1a64_f32_scalar(vs),
    }
}

/// Plain byte-wise FNV-1a 64-bit hash — the content address of the
/// checkpoint store (`crate::ckpt`), where the hashed unit is an opaque
/// serialized blob rather than an f32 sequence. Kept byte-wise (one
/// multiply per byte) so the digest is independent of any element-width
/// interpretation of the data.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Scalar arm of [`fnv1a64_f32`].
pub fn fnv1a64_f32_scalar(vs: &[f32]) -> u64 {
    let mut h = FNV_OFFSET;
    for &v in vs {
        h = (h ^ v.to_bits() as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// SIMD arm of [`fnv1a64_f32`]: the hash chain itself is a strict
/// sequential dependency (each multiply needs the previous hash), so only
/// the f32→bits conversion chunks over lanes; the fold is then an
/// unrolled walk over the lane block. Exactly the same u64 as the scalar
/// arm — integer wrapping ops have no rounding to disturb.
pub fn fnv1a64_f32_simd(vs: &[f32]) -> u64 {
    let mut h = FNV_OFFSET;
    let mut vc = vs.chunks_exact(kernels::LANES);
    for v in &mut vc {
        let v: &[f32; kernels::LANES] = v.try_into().expect("exact chunk");
        let mut bits = [0u64; kernels::LANES];
        for i in 0..kernels::LANES {
            bits[i] = v[i].to_bits() as u64;
        }
        for &b in &bits {
            h = (h ^ b).wrapping_mul(FNV_PRIME);
        }
    }
    for &v in vc.remainder() {
        h = (h ^ v.to_bits() as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Cursor for decoding (fails loudly on truncation instead of UB).
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

/// Error for truncated/malformed wire data.
///
/// (Hand-rolled `Display`/`Error` impls: `anyhow` is the crate's only
/// dependency, so no `thiserror` derive here.)
#[derive(Debug)]
pub struct Underflow {
    pub pos: usize,
    pub needed: usize,
    pub have: usize,
}

impl std::fmt::Display for Underflow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "byte reader underflow at {}: needed {}, have {}",
            self.pos, self.needed, self.have
        )
    }
}

impl std::error::Error for Underflow {}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn position(&self) -> usize {
        self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], Underflow> {
        if self.remaining() < n {
            return Err(Underflow { pos: self.pos, needed: n, have: self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, Underflow> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, Underflow> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    pub fn u64(&mut self) -> Result<u64, Underflow> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    pub fn f32(&mut self) -> Result<f32, Underflow> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>, Underflow> {
        let s = self.take(n * 4)?;
        let mut out = Vec::with_capacity(n);
        for c in s.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(out)
    }

    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8], Underflow> {
        self.take(n)
    }
}

/// Human-readable byte size.
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_scalars() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 0xDEAD_BEEF);
        put_u64(&mut buf, 0x0123_4567_89AB_CDEF);
        put_f32(&mut buf, -1.5);
        let mut r = Reader::new(&buf);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), 0x0123_4567_89AB_CDEF);
        assert_eq!(r.f32().unwrap(), -1.5);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn round_trip_f32_slice() {
        let xs = [1.0f32, -2.5, 3.25, f32::MIN_POSITIVE];
        let mut buf = Vec::new();
        put_f32_slice(&mut buf, &xs);
        let mut r = Reader::new(&buf);
        assert_eq!(r.f32_vec(4).unwrap(), xs.to_vec());
    }

    #[test]
    fn f32_slice_round_trips_across_scratch_block_boundaries() {
        // Lengths straddling the scratch block: empty, sub-block, exact
        // multiple, multiple + ragged tail. Bit patterns (not values)
        // must survive, including -0.0 and NaN payloads.
        for n in [0usize, 1, 255, 256, 512, 513, 1000] {
            let xs: Vec<f32> = (0..n)
                .map(|i| match i % 5 {
                    0 => -0.0,
                    1 => f32::from_bits(0x7FC0_1234), // NaN with payload
                    2 => f32::MIN_POSITIVE / 2.0,     // subnormal
                    3 => -(i as f32) * 0.125,
                    _ => i as f32,
                })
                .collect();
            let mut buf = vec![0xAAu8; 3]; // nonempty prefix must survive
            put_f32_slice(&mut buf, &xs);
            assert_eq!(buf.len(), 3 + 4 * n, "n={n}");
            assert_eq!(&buf[..3], &[0xAA; 3]);
            let mut r = Reader::new(&buf[3..]);
            let back = r.f32_vec(n).unwrap();
            for (i, (a, b)) in xs.iter().zip(&back).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "n={n} element {i}");
            }
            assert_eq!(r.remaining(), 0);
        }
    }

    #[test]
    fn fnv_checksum_tracks_bit_patterns() {
        let a = [1.0f32, -2.0, 0.0];
        assert_eq!(fnv1a64_f32(&a), fnv1a64_f32(&[1.0, -2.0, 0.0]));
        // Value-equal but bit-different (+0.0 vs -0.0) must differ.
        assert_ne!(fnv1a64_f32(&a), fnv1a64_f32(&[1.0, -2.0, -0.0]));
        assert_ne!(fnv1a64_f32(&a), fnv1a64_f32(&[1.0, -2.0]));
        assert_ne!(fnv1a64_f32(&a), fnv1a64_f32(&[-2.0, 1.0, 0.0]), "order-sensitive");
        // Stable across calls (the CI drift check diffs these across runs).
        assert_eq!(fnv1a64_f32(&[]), fnv1a64_f32(&[]));
    }

    #[test]
    fn scalar_and_simd_arms_agree_bytewise() {
        // Lane-boundary lengths with -0.0 / NaN-payload / subnormal
        // entries: both serialization arms and both checksum arms must
        // produce identical output.
        for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 255, 256, 257, 1000] {
            let xs: Vec<f32> = (0..n)
                .map(|i| match i % 5 {
                    0 => -0.0,
                    1 => f32::from_bits(0x7FC0_1234),
                    2 => f32::MIN_POSITIVE / 2.0,
                    3 => -(i as f32) * 0.125,
                    _ => i as f32,
                })
                .collect();
            let mut a = vec![0x55u8; 2];
            let mut b = vec![0x55u8; 2];
            put_f32_slice_scalar(&mut a, &xs);
            put_f32_slice_simd(&mut b, &xs);
            assert_eq!(a, b, "put_f32_slice n={n}");
            assert_eq!(fnv1a64_f32_scalar(&xs), fnv1a64_f32_simd(&xs), "fnv n={n}");
        }
    }

    #[test]
    fn byte_fnv_is_stable_and_content_sensitive() {
        assert_eq!(fnv1a64(b"abc"), fnv1a64(b"abc"));
        assert_ne!(fnv1a64(b"abc"), fnv1a64(b"abd"));
        assert_ne!(fnv1a64(b"abc"), fnv1a64(b"ab"));
        // Known FNV-1a 64 vector: empty input hashes to the offset basis.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
    }

    #[test]
    fn underflow_is_an_error() {
        let buf = [0u8, 1];
        let mut r = Reader::new(&buf);
        assert!(r.u32().is_err());
    }

    #[test]
    fn human_readable() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }
}
