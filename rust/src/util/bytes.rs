//! Byte-level encode/decode helpers for the wire format and the codecs.
//! Everything is little-endian (the only byte order this system touches).

/// Append a u32 (LE).
#[inline]
pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Append a u64 (LE).
#[inline]
pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Append an f32 (LE).
#[inline]
pub fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Append an entire f32 slice (LE).
pub fn put_f32_slice(buf: &mut Vec<u8>, vs: &[f32]) {
    buf.reserve(vs.len() * 4);
    for &v in vs {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Cursor for decoding (fails loudly on truncation instead of UB).
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

/// Error for truncated/malformed wire data.
///
/// (Hand-rolled `Display`/`Error` impls: `anyhow` is the crate's only
/// dependency, so no `thiserror` derive here.)
#[derive(Debug)]
pub struct Underflow {
    pub pos: usize,
    pub needed: usize,
    pub have: usize,
}

impl std::fmt::Display for Underflow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "byte reader underflow at {}: needed {}, have {}",
            self.pos, self.needed, self.have
        )
    }
}

impl std::error::Error for Underflow {}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn position(&self) -> usize {
        self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], Underflow> {
        if self.remaining() < n {
            return Err(Underflow { pos: self.pos, needed: n, have: self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, Underflow> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, Underflow> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    pub fn u64(&mut self) -> Result<u64, Underflow> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    pub fn f32(&mut self) -> Result<f32, Underflow> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>, Underflow> {
        let s = self.take(n * 4)?;
        let mut out = Vec::with_capacity(n);
        for c in s.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(out)
    }

    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8], Underflow> {
        self.take(n)
    }
}

/// Human-readable byte size.
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_scalars() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 0xDEAD_BEEF);
        put_u64(&mut buf, 0x0123_4567_89AB_CDEF);
        put_f32(&mut buf, -1.5);
        let mut r = Reader::new(&buf);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), 0x0123_4567_89AB_CDEF);
        assert_eq!(r.f32().unwrap(), -1.5);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn round_trip_f32_slice() {
        let xs = [1.0f32, -2.5, 3.25, f32::MIN_POSITIVE];
        let mut buf = Vec::new();
        put_f32_slice(&mut buf, &xs);
        let mut r = Reader::new(&buf);
        assert_eq!(r.f32_vec(4).unwrap(), xs.to_vec());
    }

    #[test]
    fn underflow_is_an_error() {
        let buf = [0u8, 1];
        let mut r = Reader::new(&buf);
        assert!(r.u32().is_err());
    }

    #[test]
    fn human_readable() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }
}
