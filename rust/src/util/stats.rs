//! Small statistics helpers shared by metrics, benches and experiments.

/// Mean of a slice (0.0 for empty input). The division happens in f64 —
/// casting the sum to f32 first would throw away the extra accumulator
/// precision exactly where it matters (large slices).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64) as f32
}

/// Population variance.
pub fn variance(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs) as f64;
    (xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64) as f32
}

/// Population standard deviation.
pub fn stddev(xs: &[f32]) -> f32 {
    variance(xs).sqrt()
}

/// Squared L2 norm.
pub fn norm2_sq(xs: &[f32]) -> f32 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() as f32
}

/// L2 norm.
pub fn norm2(xs: &[f32]) -> f32 {
    norm2_sq(xs).sqrt()
}

/// L∞ norm.
pub fn norm_inf(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |acc, &x| acc.max(x.abs()))
}

/// Dot product (f64 accumulation).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum::<f64>() as f32
}

/// Squared Euclidean distance between two vectors.
pub fn dist2_sq(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum::<f64>() as f32
}

/// p-quantile (linear interpolation) of an unsorted slice; p in [0,1].
/// NaN-safe: `total_cmp` gives NaNs a defined order (by IEEE total
/// ordering — negative NaNs before −∞, positive NaNs after +∞) instead
/// of panicking mid-sort the way `partial_cmp().unwrap()` did. With NaN
/// input the result is well-defined but may itself be NaN.
pub fn quantile(xs: &[f32], p: f64) -> f32 {
    assert!(!xs.is_empty());
    assert!((0.0..=1.0).contains(&p));
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(f32::total_cmp);
    let idx = p * (v.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = (idx - lo as f64) as f32;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Exponential moving average.
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Self { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn value(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-6);
        assert!((variance(&xs) - 1.25).abs() < 1e-6);
    }

    #[test]
    fn norms() {
        let xs = [3.0, -4.0];
        assert!((norm2(&xs) - 5.0).abs() < 1e-6);
        assert!((norm_inf(&xs) - 4.0).abs() < 1e-6);
        assert!((norm2_sq(&xs) - 25.0).abs() < 1e-6);
    }

    #[test]
    fn quantiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert!((quantile(&xs, 0.0) - 1.0).abs() < 1e-6);
        assert!((quantile(&xs, 1.0) - 4.0).abs() < 1e-6);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-6);
    }

    #[test]
    fn quantile_does_not_panic_on_nan() {
        // Regression: partial_cmp().unwrap() used to panic mid-sort.
        let xs = [2.0, f32::NAN, 1.0, 3.0];
        // f32::NAN is a positive NaN, which total_cmp sorts after +∞: the
        // finite prefix stays ordered. (A sign-bit-set NaN would sort
        // first instead — either way the sort is total and panic-free.)
        assert!((quantile(&xs, 0.0) - 1.0).abs() < 1e-6);
        assert!(quantile(&xs, 1.0).is_nan());
        // Negative NaN sorts before the finite values — still no panic.
        let neg_nan = f32::from_bits(f32::NAN.to_bits() | 0x8000_0000);
        let ys = [2.0, neg_nan, 1.0];
        assert!(quantile(&ys, 0.0).is_nan());
        assert!((quantile(&ys, 1.0) - 2.0).abs() < 1e-6);
        let all_nan = [f32::NAN, f32::NAN];
        assert!(quantile(&all_nan, 0.5).is_nan());
    }

    #[test]
    fn mean_divides_in_f64_matches_kahan_reference() {
        // Property check against a Kahan-compensated f64 oracle over
        // adversarial inputs: large slices of values whose f32-rounded
        // running sum drifts.
        fn kahan_mean(xs: &[f32]) -> f64 {
            let (mut sum, mut c) = (0.0f64, 0.0f64);
            for &x in xs {
                let y = x as f64 - c;
                let t = sum + y;
                c = (t - sum) - y;
                sum = t;
            }
            sum / xs.len() as f64
        }
        let mut rng = crate::util::rng::Pcg32::new(0x5EED);
        for &(n, offset) in &[(10usize, 0.0f32), (100_000, 1.0e4), (250_000, -3.0e3)] {
            let xs: Vec<f32> = (0..n).map(|_| offset + rng.uniform() * 0.125).collect();
            let want = kahan_mean(&xs);
            let got = mean(&xs) as f64;
            // Dividing in f64 keeps the result within rounding distance of
            // the compensated oracle (the cast-to-f32-then-divide path
            // stacked two extra f32 roundings on top).
            let ulp = (want as f32).abs().max(f32::MIN_POSITIVE) as f64 * f32::EPSILON as f64;
            assert!(
                (got - want).abs() <= 2.0 * ulp,
                "n={n} offset={offset}: mean {got} vs kahan {want}"
            );
        }
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [2.0f32, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x as f64);
        }
        assert!((w.mean() as f32 - mean(&xs)).abs() < 1e-5);
        assert!((w.variance() as f32 - variance(&xs)).abs() < 1e-4);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..64 {
            e.push(10.0);
        }
        assert!((e.value().unwrap() - 10.0).abs() < 1e-6);
    }
}
