//! Tiny leveled logger (the `log`/`env_logger` crates are unavailable
//! offline). Controlled by `DQGAN_LOG` or programmatically via
//! [`set_level`]/[`set_filter`]. Output goes to stderr with a monotonic
//! timestamp so training progress is greppable.
//!
//! `DQGAN_LOG` takes a filter spec, `env_logger`-style: a bare default
//! level plus comma-separated per-target overrides —
//! `DQGAN_LOG=info,evloop=trace` logs Info everywhere except targets
//! whose `module_path!()` contains an `evloop` path segment, which log
//! at Trace. Override keys match whole `::`-delimited segments (also
//! multi-segment keys like `comm::tcp`), never substrings, so `evloop`
//! does not capture an `evloop_sim` module. With no overrides installed
//! the per-message cost is unchanged: one relaxed atomic load.

use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// A parsed `DQGAN_LOG` filter: an optional default level plus ordered
/// per-target overrides (first matching key wins).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Spec {
    pub default: Option<Level>,
    pub overrides: Vec<(String, Level)>,
}

impl Spec {
    /// Parse `LEVEL[,TARGET=LEVEL]*` (clauses in any order; a bare
    /// `TARGET=LEVEL` spec without a default is fine). Malformed
    /// clauses are skipped, not fatal — a logging knob must never take
    /// a run down.
    pub fn parse(s: &str) -> Spec {
        let mut default = None;
        let mut overrides = Vec::new();
        for clause in s.split(',') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            match clause.split_once('=') {
                None => {
                    if let Some(l) = Level::from_str(clause) {
                        default = Some(l);
                    }
                }
                Some((target, level)) => {
                    let target = target.trim();
                    if target.is_empty() {
                        continue;
                    }
                    if let Some(l) = Level::from_str(level.trim()) {
                        overrides.push((target.to_string(), l));
                    }
                }
            }
        }
        Spec { default, overrides }
    }
}

/// Whether override key `key` selects `target` (a `module_path!()`
/// string): the key must cover whole `::`-delimited segments —
/// `evloop` matches `dqgan::comm::evloop` but not `dqgan::evloop_sim`;
/// multi-segment keys (`comm::tcp`) match at any segment boundary.
fn target_matches(key: &str, target: &str) -> bool {
    if key == target {
        return true;
    }
    for (pos, _) in target.match_indices(key) {
        let end = pos + key.len();
        let left_ok = pos == 0 || target[..pos].ends_with("::");
        let right_ok = end == target.len() || target[end..].starts_with("::");
        if left_ok && right_ok {
            return true;
        }
    }
    false
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // default Info
// Per-target overrides, gated by the flag so the no-override hot path
// stays a single relaxed load (no lock touched).
static HAS_OVERRIDES: AtomicBool = AtomicBool::new(false);
static OVERRIDES: Mutex<Vec<(String, Level)>> = Mutex::new(Vec::new());
static INIT: std::sync::Once = std::sync::Once::new();
static mut START: Option<Instant> = None;

fn install_spec(spec: Spec) {
    if let Some(l) = spec.default {
        LEVEL.store(l as u8, Ordering::Relaxed);
    }
    let has = !spec.overrides.is_empty();
    *OVERRIDES.lock().expect("log overrides lock") = spec.overrides;
    HAS_OVERRIDES.store(has, Ordering::Relaxed);
}

fn start_instant() -> Instant {
    unsafe {
        INIT.call_once(|| {
            START = Some(Instant::now());
            if let Ok(v) = std::env::var("DQGAN_LOG") {
                install_spec(Spec::parse(&v));
            }
        });
        #[allow(static_mut_refs)]
        START.unwrap()
    }
}

/// Set the global log level.
pub fn set_level(l: Level) {
    start_instant();
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Current global log level.
pub fn level() -> Level {
    start_instant();
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Install a filter spec (the `DQGAN_LOG` syntax): default level plus
/// per-target overrides, e.g. `set_filter("info,evloop=trace")`.
pub fn set_filter(spec: &str) {
    start_instant();
    install_spec(Spec::parse(spec));
}

/// Whether `l` is currently enabled at the **default** level (ignores
/// per-target overrides — use [`enabled_for`] with a target).
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Whether `l` is enabled for `target`, honoring per-target overrides
/// (first matching override key wins; no match falls back to the
/// default level).
pub fn enabled_for(l: Level, target: &str) -> bool {
    if HAS_OVERRIDES.load(Ordering::Relaxed) {
        let overrides = OVERRIDES.lock().expect("log overrides lock");
        if let Some((_, ol)) = overrides.iter().find(|(k, _)| target_matches(k, target)) {
            return l <= *ol;
        }
    }
    enabled(l)
}

/// Core log entry point (prefer the macros).
pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled_for(l, target) {
        return;
    }
    let t = start_instant().elapsed().as_secs_f64();
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:9.3}s {} {target}] {msg}", l.tag());
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("info"), Some(Level::Info));
        assert_eq!(Level::from_str("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str("bogus"), None);
    }

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Debug));
        set_level(Level::Info);
    }

    #[test]
    fn spec_parsing_splits_default_and_target_clauses() {
        let s = Spec::parse("info,evloop=trace");
        assert_eq!(s.default, Some(Level::Info));
        assert_eq!(s.overrides, vec![("evloop".to_string(), Level::Trace)]);
        // A bare TARGET=LEVEL spec needs no leading default.
        let s = Spec::parse("comm::tcp=debug");
        assert_eq!(s.default, None);
        assert_eq!(s.overrides, vec![("comm::tcp".to_string(), Level::Debug)]);
        // Malformed clauses are dropped, surviving ones still apply.
        let s = Spec::parse("bogus,=debug,evloop=nope, ,warn");
        assert_eq!(s.default, Some(Level::Warn));
        assert!(s.overrides.is_empty());
    }

    #[test]
    fn target_matching_is_segment_exact() {
        assert!(target_matches("evloop", "dqgan::comm::evloop"));
        assert!(target_matches("comm", "dqgan::comm::evloop"));
        assert!(target_matches("comm::tcp", "dqgan::comm::tcp"));
        assert!(target_matches("dqgan::comm::tcp", "dqgan::comm::tcp"));
        assert!(!target_matches("evloop", "dqgan::evloop_sim"));
        assert!(!target_matches("loop", "dqgan::comm::evloop"));
        assert!(!target_matches("comm::udp", "dqgan::comm::tcp"));
    }

    #[test]
    fn per_target_overrides_gate_independently_of_the_default() {
        // Override-path assertions only (deterministic under parallel
        // tests: the matching branch never consults the global level,
        // and Error is enabled at every default level).
        set_filter("info,evloop=trace,ps::server=error");
        assert!(enabled_for(Level::Trace, "dqgan::comm::evloop"));
        assert!(!enabled_for(Level::Warn, "dqgan::ps::server"));
        assert!(enabled_for(Level::Error, "dqgan::ps::server"));
        assert!(enabled_for(Level::Error, "dqgan::compress"), "non-matching target falls back");
        // Clear the overrides so other tests see pristine global state.
        set_filter("info");
        assert!(enabled_for(Level::Error, "dqgan::comm::evloop"));
    }
}
