//! Tiny leveled logger (the `log`/`env_logger` crates are unavailable
//! offline). Controlled by `DQGAN_LOG` (error|warn|info|debug|trace) or
//! programmatically via [`set_level`]. Output goes to stderr with a
//! monotonic timestamp so training progress is greppable.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // default Info
static INIT: std::sync::Once = std::sync::Once::new();
static mut START: Option<Instant> = None;

fn start_instant() -> Instant {
    unsafe {
        INIT.call_once(|| {
            START = Some(Instant::now());
            if let Ok(v) = std::env::var("DQGAN_LOG") {
                if let Some(l) = Level::from_str(&v) {
                    LEVEL.store(l as u8, Ordering::Relaxed);
                }
            }
        });
        #[allow(static_mut_refs)]
        START.unwrap()
    }
}

/// Set the global log level.
pub fn set_level(l: Level) {
    start_instant();
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Current global log level.
pub fn level() -> Level {
    start_instant();
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Whether `l` is currently enabled.
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Core log entry point (prefer the macros).
pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = start_instant().elapsed().as_secs_f64();
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:9.3}s {} {target}] {msg}", l.tag());
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("info"), Some(Level::Info));
        assert_eq!(Level::from_str("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str("bogus"), None);
    }

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Debug));
        set_level(Level::Info);
    }
}
