//! Wall-clock timing utilities and a hierarchical phase profiler used by the
//! coordinator to attribute round time to compute / quantize / encode /
//! transport / aggregate phases (EXPERIMENTS.md §Perf).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Accumulated timing for one named phase.
#[derive(Debug, Clone, Default)]
pub struct PhaseStat {
    pub total: Duration,
    pub count: u64,
    pub max: Duration,
}

impl PhaseStat {
    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            Duration::ZERO
        } else {
            self.total / self.count as u32
        }
    }
}

/// Thread-safe phase profiler: `profiler.time("grad", || ...)` accumulates
/// per-phase totals; `report()` renders a breakdown table.
#[derive(Debug, Default)]
pub struct PhaseProfiler {
    phases: Mutex<BTreeMap<String, PhaseStat>>,
}

impl PhaseProfiler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record an externally measured duration against a phase.
    pub fn record(&self, phase: &str, d: Duration) {
        let mut map = self.phases.lock().unwrap();
        let e = map.entry(phase.to_string()).or_default();
        e.total += d;
        e.count += 1;
        if d > e.max {
            e.max = d;
        }
    }

    /// Time a closure under a phase name.
    pub fn time<T>(&self, phase: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.record(phase, t.elapsed());
        out
    }

    /// Snapshot of all phases.
    pub fn snapshot(&self) -> BTreeMap<String, PhaseStat> {
        self.phases.lock().unwrap().clone()
    }

    /// Total time across phases.
    pub fn grand_total(&self) -> Duration {
        self.phases.lock().unwrap().values().map(|p| p.total).sum()
    }

    /// Human-readable breakdown, sorted by total descending.
    pub fn report(&self) -> String {
        let snap = self.snapshot();
        let total: f64 = snap.values().map(|p| p.total.as_secs_f64()).sum();
        let mut rows: Vec<_> = snap.into_iter().collect();
        rows.sort_by(|a, b| b.1.total.cmp(&a.1.total));
        let mut out = String::new();
        out.push_str(&format!(
            "{:<20} {:>10} {:>8} {:>10} {:>10} {:>6}\n",
            "phase", "total_ms", "count", "mean_us", "max_us", "pct"
        ));
        for (name, st) in rows {
            out.push_str(&format!(
                "{:<20} {:>10.2} {:>8} {:>10.1} {:>10.1} {:>5.1}%\n",
                name,
                st.total.as_secs_f64() * 1e3,
                st.count,
                st.mean().as_secs_f64() * 1e6,
                st.max.as_secs_f64() * 1e6,
                if total > 0.0 { 100.0 * st.total.as_secs_f64() / total } else { 0.0 },
            ));
        }
        out
    }

    /// Clear all accumulated phases.
    pub fn reset(&self) {
        self.phases.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_advances() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.elapsed() >= Duration::from_millis(1));
    }

    #[test]
    fn profiler_accumulates() {
        let p = PhaseProfiler::new();
        p.time("a", || std::thread::sleep(Duration::from_millis(1)));
        p.time("a", || std::thread::sleep(Duration::from_millis(1)));
        p.record("b", Duration::from_millis(5));
        let snap = p.snapshot();
        assert_eq!(snap["a"].count, 2);
        assert_eq!(snap["b"].count, 1);
        assert!(snap["a"].total >= Duration::from_millis(2));
        let report = p.report();
        assert!(report.contains("a"));
        assert!(report.contains("b"));
    }

    #[test]
    fn profiler_reset() {
        let p = PhaseProfiler::new();
        p.record("x", Duration::from_millis(1));
        p.reset();
        assert!(p.snapshot().is_empty());
    }
}
