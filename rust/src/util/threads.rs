//! Process thread-count observation — the telemetry hook behind the
//! readiness-loop transport's O(1)-threads claim (`threads_peak` in
//! `RoundRecord` / `--round-csv`).

/// Number of live OS threads in this process, read from
/// `/proc/self/task`. On non-Linux platforms (no procfs) this degrades
/// to 0, which callers treat as "unknown" — telemetry only, never a
/// correctness input.
pub fn live_threads() -> usize {
    #[cfg(target_os = "linux")]
    {
        std::fs::read_dir("/proc/self/task").map(|d| d.count()).unwrap_or(0)
    }
    #[cfg(not(target_os = "linux"))]
    {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg(target_os = "linux")]
    fn counts_spawned_threads() {
        let base = live_threads();
        assert!(base >= 1, "at least the calling thread");
        // Park two threads on a channel; the count must rise by exactly 2
        // while they live.
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let rx = std::sync::Arc::new(std::sync::Mutex::new(rx));
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let rx = std::sync::Arc::clone(&rx);
                std::thread::spawn(move || {
                    let _ = rx.lock().unwrap().recv();
                })
            })
            .collect();
        // The spawned threads are live the moment spawn returns (the
        // parent observes them in /proc/self/task even before they park).
        assert!(live_threads() >= base + 2);
        drop(tx);
        for h in handles {
            h.join().unwrap();
        }
    }
}
