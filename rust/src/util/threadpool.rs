//! A minimal fixed-size thread pool with scoped parallel-for, used by the
//! server aggregation path and the experiment sweeps (no `rayon` offline).
//!
//! Design: N long-lived workers pull boxed jobs from a shared channel.
//! `parallel_for` / `parallel_for_mut` split the work into chunks, enqueue
//! all but the first on the pool's persistent workers (no per-call thread
//! spawning), run the first chunk on the caller thread, and block on a
//! [`CountdownLatch`] until every chunk completes. Panics inside jobs are
//! caught and re-raised on the caller thread.
//!
//! Scoped borrows across the `'static` job channel are handled by
//! [`ThreadPool::run_scoped`], whose latch-before-return discipline is
//! the safety argument for its one lifetime transmute. Do not call the
//! scoped entry points from *inside* a pool job: with every worker busy
//! waiting, the inner call's chunks could never be picked up.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed pool of worker threads.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Create a pool with `size` threads (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("dqgan-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            // A panicking job must not kill the worker:
                            // pool width is an invariant (`run_scoped`'s
                            // safety argument needs `execute` to keep
                            // succeeding while the pool is alive).
                            Ok(job) => {
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn pool worker"),
            );
        }
        Self { tx: Some(tx), workers, size }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget job submission. A panic inside `job` is caught
    /// and swallowed on the worker (wrap your own reporting if you need
    /// it); the scoped entry points layer their panic propagation on top.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx.as_ref().expect("pool alive").send(Box::new(job)).expect("pool send");
    }

    /// Submit a **detached** job and get a completion handle back — the
    /// offloaded-reduce entry point: the leader fires the close-time fold
    /// here and joins the [`TaskDone`] latch later. The latch is opened
    /// by a drop guard, so it opens even if the job panics (the waiter
    /// distinguishes "completed" from "panicked" by whether the job
    /// deposited its result, not by the latch).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) -> TaskDone {
        let latch = Arc::new(CountdownLatch::new(1));
        let guard = OpenOnDrop(Arc::clone(&latch));
        self.execute(move || {
            let _guard = guard; // counts down when the job ends, panic or not
            job();
        });
        TaskDone { latch }
    }

    /// Run a batch of borrowed jobs: all but the first are enqueued on
    /// the pool's persistent workers, the first runs on the caller
    /// thread, and the latch blocks until every job has completed.
    /// Returns whether any job panicked.
    ///
    /// SAFETY argument for the lifetime transmute below: the job channel
    /// requires `'static`, but every enqueued job counts the latch down
    /// *after* running (the panic guard counts down too), and this
    /// function does not return — not even by panic — before
    /// `latch.wait()` observes all of them. The borrowed environment
    /// therefore strictly outlives every use of the jobs. Two pool
    /// invariants uphold "does not return by panic": workers never die
    /// (the worker loop catches job panics, so pool width is constant
    /// while the pool is alive), hence `execute`'s channel send cannot
    /// fail mid-enqueue, and the only code between the first transmute
    /// and `latch.wait()` is that non-panicking enqueue loop plus the
    /// caller job, which is wrapped in `catch_unwind`.
    fn run_scoped<'env>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) -> bool {
        let total = jobs.len();
        if total == 0 {
            return false;
        }
        let mut it = jobs.into_iter();
        let first = it.next().expect("total > 0");
        let latch = Arc::new(CountdownLatch::new(total - 1));
        let panicked = Arc::new(AtomicBool::new(false));
        for job in it {
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 'env>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(job)
            };
            let latch = Arc::clone(&latch);
            let panicked = Arc::clone(&panicked);
            self.execute(move || {
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    panicked.store(true, Ordering::SeqCst);
                }
                latch.count_down();
            });
        }
        // The caller contributes its own core instead of idling.
        let caller_panicked = catch_unwind(AssertUnwindSafe(first)).is_err();
        latch.wait();
        caller_panicked || panicked.load(Ordering::SeqCst)
    }

    /// Run `f(i)` for every `i in 0..n`, blocking until all complete.
    /// `f` must be `Sync` since chunks run concurrently.
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Send + Sync,
    {
        if n == 0 {
            return;
        }
        let chunks = self.size.min(n);
        let chunk_len = n.div_ceil(chunks);
        if chunks == 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let f = &f;
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..chunks)
            .map(|c| {
                let lo = c * chunk_len;
                let hi = ((c + 1) * chunk_len).min(n);
                Box::new(move || {
                    for i in lo..hi {
                        f(i);
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        if self.run_scoped(jobs) {
            panic!("parallel_for: a worker panicked");
        }
    }

    /// Run `f(i, &mut items[i])` for every item, blocking until all
    /// complete. Items are split into at most `size()` contiguous chunks,
    /// one per pool width, so disjoint `&mut` access needs no locking —
    /// this is the entry point the PS aggregation shards use (each shard
    /// owns a disjoint `&mut [f32]` of the output vector).
    pub fn parallel_for_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Send + Sync,
    {
        self.parallel_for_mut_min_chunk(items, 1, f)
    }

    /// [`Self::parallel_for_mut`] with an explicit floor on items per
    /// dispatched job: the item count per chunk is at least
    /// `min_per_job`, so callers whose per-item work is tiny (e.g. the
    /// aggregator folding many small shards) can batch enough consecutive
    /// items into each job to amortize the dispatch + latch round trip —
    /// and to keep the lane kernels on long contiguous runs. Scheduling
    /// only: items still run exactly once, in index order within a chunk.
    pub fn parallel_for_mut_min_chunk<T, F>(&self, items: &mut [T], min_per_job: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Send + Sync,
    {
        let n = items.len();
        if n == 0 {
            return;
        }
        let chunks = self.size.min(n.div_ceil(min_per_job.max(1))).max(1);
        let chunk_len = n.div_ceil(chunks);
        if chunks == 1 {
            // Single-threaded fast path: no dispatch overhead.
            for (i, item) in items.iter_mut().enumerate() {
                f(i, item);
            }
            return;
        }
        let f = &f;
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = items
            .chunks_mut(chunk_len)
            .enumerate()
            .map(|(c, chunk)| {
                Box::new(move || {
                    for (j, item) in chunk.iter_mut().enumerate() {
                        f(c * chunk_len + j, item);
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        if self.run_scoped(jobs) {
            panic!("parallel_for_mut: a worker panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Close the channel so workers exit, then join.
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Completion handle of one [`ThreadPool::submit`] job.
pub struct TaskDone {
    latch: Arc<CountdownLatch>,
}

impl TaskDone {
    /// Block until the job has ended (normally or by panic).
    pub fn wait(&self) {
        self.latch.wait();
    }

    /// Bounded wait; `true` iff the job ended within the budget.
    pub fn wait_timeout(&self, timeout: std::time::Duration) -> bool {
        self.latch.wait_timeout(timeout)
    }
}

/// Opens the wrapped latch on drop — the anti-hang guard `submit` wraps
/// around every detached job.
struct OpenOnDrop(Arc<CountdownLatch>);

impl Drop for OpenOnDrop {
    fn drop(&mut self) {
        self.0.count_down();
    }
}

/// A latch that waits for `n` completions (used by the PS barrier tests).
pub struct CountdownLatch {
    remaining: AtomicUsize,
    lock: Mutex<()>,
    cv: Condvar,
}

impl CountdownLatch {
    pub fn new(n: usize) -> Self {
        Self { remaining: AtomicUsize::new(n), lock: Mutex::new(()), cv: Condvar::new() }
    }

    /// Signal one completion.
    pub fn count_down(&self) {
        let prev = self.remaining.fetch_sub(1, Ordering::SeqCst);
        assert!(prev > 0, "count_down below zero");
        if prev == 1 {
            let _g = self.lock.lock().unwrap();
            self.cv.notify_all();
        }
    }

    /// Block until the count reaches zero.
    pub fn wait(&self) {
        let mut g = self.lock.lock().unwrap();
        while self.remaining.load(Ordering::SeqCst) > 0 {
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Block until the count reaches zero or `timeout` elapses. Returns
    /// `true` if the latch opened — the bounded-wait variant the
    /// streaming-arrival tests use so a broken engine fails an assertion
    /// instead of deadlocking CI.
    pub fn wait_timeout(&self, timeout: std::time::Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        let mut g = self.lock.lock().unwrap();
        while self.remaining.load(Ordering::SeqCst) > 0 {
            let now = std::time::Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _res) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn execute_runs_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        let latch = Arc::new(CountdownLatch::new(8));
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            let l = Arc::clone(&latch);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                l.count_down();
            });
        }
        latch.wait();
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn submit_returns_a_joinable_completion_handle() {
        use std::time::Duration;
        let pool = ThreadPool::new(2);
        let flag = Arc::new(AtomicU64::new(0));
        let f2 = Arc::clone(&flag);
        let done = pool.submit(move || {
            f2.store(7, Ordering::SeqCst);
        });
        done.wait();
        assert_eq!(flag.load(Ordering::SeqCst), 7);
        assert!(done.wait_timeout(Duration::from_millis(1)), "already open");
        // A panicking detached job must still open the latch (the drop
        // guard), never hang the joiner.
        let boom = pool.submit(|| panic!("detached boom"));
        assert!(boom.wait_timeout(Duration::from_secs(10)));
        // The pool survives the panic and keeps executing.
        let after = pool.submit(|| {});
        after.wait();
    }

    #[test]
    fn parallel_for_covers_all_indices() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(100, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::SeqCst), 1);
        }
    }

    #[test]
    fn parallel_for_mut_gives_each_index_exclusive_access() {
        let pool = ThreadPool::new(4);
        let mut items: Vec<u64> = vec![0; 97]; // deliberately un-even chunking
        pool.parallel_for_mut(&mut items, |i, item| {
            *item = i as u64 * 3 + 1;
        });
        for (i, &v) in items.iter().enumerate() {
            assert_eq!(v, i as u64 * 3 + 1);
        }
        // Empty input is a no-op.
        let mut empty: Vec<u64> = Vec::new();
        pool.parallel_for_mut(&mut empty, |_, _| unreachable!());
        // Single item takes the inline fast path.
        let mut one = vec![7u64];
        pool.parallel_for_mut(&mut one, |i, item| *item += i as u64 + 1);
        assert_eq!(one[0], 8);
    }

    #[test]
    fn parallel_for_mut_min_chunk_batches_but_covers_everything() {
        let pool = ThreadPool::new(4);
        // Any floor — including one larger than the input — still visits
        // every index exactly once with the right value.
        for min_per_job in [1usize, 3, 7, 50, 1000] {
            let mut items: Vec<u64> = vec![0; 97];
            pool.parallel_for_mut_min_chunk(&mut items, min_per_job, |i, item| {
                *item = i as u64 * 5 + 2;
            });
            for (i, &v) in items.iter().enumerate() {
                assert_eq!(v, i as u64 * 5 + 2, "min_per_job={min_per_job}");
            }
        }
        // min_per_job = 0 is treated as 1 (no division by zero).
        let mut items: Vec<u64> = vec![0; 5];
        pool.parallel_for_mut_min_chunk(&mut items, 0, |i, item| *item = i as u64);
        assert_eq!(items, vec![0, 1, 2, 3, 4]);
        // A floor that swallows the whole input runs inline (observable
        // as: still correct, even from within a pool worker's context).
        let mut one = vec![1u64];
        pool.parallel_for_mut_min_chunk(&mut one, usize::MAX, |_, item| *item += 1);
        assert_eq!(one[0], 2);
    }

    #[test]
    #[should_panic(expected = "parallel_for_mut: a worker panicked")]
    fn parallel_for_mut_propagates_panic() {
        let pool = ThreadPool::new(2);
        let mut items = vec![0u8; 8];
        pool.parallel_for_mut(&mut items, |i, _| {
            if i == 5 {
                panic!("boom");
            }
        });
    }

    #[test]
    #[should_panic(expected = "a worker panicked")]
    fn parallel_for_propagates_panic() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(4, |i| {
            if i == 3 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn latch_blocks_until_zero() {
        let latch = Arc::new(CountdownLatch::new(3));
        let l2 = Arc::clone(&latch);
        let t = std::thread::spawn(move || {
            for _ in 0..3 {
                l2.count_down();
            }
        });
        latch.wait();
        t.join().unwrap();
    }

    #[test]
    fn latch_wait_timeout_reports_outcome() {
        use std::time::Duration;
        // Never opened: times out and reports false.
        let stuck = CountdownLatch::new(1);
        assert!(!stuck.wait_timeout(Duration::from_millis(10)));
        // Already open: returns true immediately.
        let open = CountdownLatch::new(0);
        assert!(open.wait_timeout(Duration::from_millis(1)));
        // Opened concurrently: returns true within the budget.
        let latch = Arc::new(CountdownLatch::new(1));
        let l2 = Arc::clone(&latch);
        let t = std::thread::spawn(move || l2.count_down());
        assert!(latch.wait_timeout(Duration::from_secs(10)));
        t.join().unwrap();
    }
}
