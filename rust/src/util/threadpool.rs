//! A minimal fixed-size thread pool with scoped parallel-for, used by the
//! server aggregation path and the experiment sweeps (no `rayon` offline).
//!
//! Design: N long-lived workers pull boxed jobs from a shared channel; a
//! [`ThreadPool::scope`]-style `parallel_for` splits an index range into
//! chunks and blocks until all chunks complete. Panics inside jobs are
//! caught and re-raised on the caller thread.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed pool of worker threads.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Create a pool with `size` threads (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("dqgan-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn pool worker"),
            );
        }
        Self { tx: Some(tx), workers, size }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget job submission.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx.as_ref().expect("pool alive").send(Box::new(job)).expect("pool send");
    }

    /// Run `f(i)` for every `i in 0..n`, blocking until all complete.
    /// `f` must be `Sync` since chunks run concurrently.
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Send + Sync,
    {
        if n == 0 {
            return;
        }
        let chunks = self.size.min(n);
        let chunk_len = n.div_ceil(chunks);
        let done = Arc::new((Mutex::new(0usize), Condvar::new()));
        let panicked = Arc::new(AtomicBool::new(false));
        // SAFETY-free approach: we only pass the closure by Arc and join
        // before returning, so borrows must be 'static — callers wrap state
        // in Arc. For the common slice case use `parallel_for_chunks`.
        let f = Arc::new(f);
        std::thread::scope(|scope| {
            for c in 0..chunks {
                let lo = c * chunk_len;
                let hi = ((c + 1) * chunk_len).min(n);
                if lo >= hi {
                    continue;
                }
                let f = Arc::clone(&f);
                let panicked = Arc::clone(&panicked);
                scope.spawn(move || {
                    let r = catch_unwind(AssertUnwindSafe(|| {
                        for i in lo..hi {
                            f(i);
                        }
                    }));
                    if r.is_err() {
                        panicked.store(true, Ordering::SeqCst);
                    }
                });
            }
            let _ = &done; // reserved for future non-scoped impl
        });
        if panicked.load(Ordering::SeqCst) {
            panic!("parallel_for: a worker panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Close the channel so workers exit, then join.
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A latch that waits for `n` completions (used by the PS barrier tests).
pub struct CountdownLatch {
    remaining: AtomicUsize,
    lock: Mutex<()>,
    cv: Condvar,
}

impl CountdownLatch {
    pub fn new(n: usize) -> Self {
        Self { remaining: AtomicUsize::new(n), lock: Mutex::new(()), cv: Condvar::new() }
    }

    /// Signal one completion.
    pub fn count_down(&self) {
        let prev = self.remaining.fetch_sub(1, Ordering::SeqCst);
        assert!(prev > 0, "count_down below zero");
        if prev == 1 {
            let _g = self.lock.lock().unwrap();
            self.cv.notify_all();
        }
    }

    /// Block until the count reaches zero.
    pub fn wait(&self) {
        let mut g = self.lock.lock().unwrap();
        while self.remaining.load(Ordering::SeqCst) > 0 {
            g = self.cv.wait(g).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn execute_runs_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        let latch = Arc::new(CountdownLatch::new(8));
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            let l = Arc::clone(&latch);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                l.count_down();
            });
        }
        latch.wait();
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn parallel_for_covers_all_indices() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(100, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::SeqCst), 1);
        }
    }

    #[test]
    #[should_panic(expected = "a worker panicked")]
    fn parallel_for_propagates_panic() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(4, |i| {
            if i == 3 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn latch_blocks_until_zero() {
        let latch = Arc::new(CountdownLatch::new(3));
        let l2 = Arc::clone(&latch);
        let t = std::thread::spawn(move || {
            for _ in 0..3 {
                l2.count_down();
            }
        });
        latch.wait();
        t.join().unwrap();
    }
}
