//! Minimal JSON parser + writer.
//!
//! The artifact manifest written by `python/compile/aot.py` is JSON; with no
//! `serde`/`serde_json` available offline, this module implements the small
//! recursive-descent parser the runtime needs (full JSON value model, UTF-8
//! strings with escapes, numbers as f64) plus a compact serializer used by
//! telemetry reports.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
///
/// (Hand-rolled `Display`/`Error` impls: `anyhow` is the crate's only
/// dependency, so no `thiserror` derive here.)
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // ------------------------------------------------ typed accessors ----

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            let v = self.value()?;
            items.push(v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Note: surrogate pairs outside BMP are not needed
                            // by the manifest; map unpaired surrogates to U+FFFD.
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let rest = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let doc = r#"{"a": [1, 2, {"b": "x"}], "c": null, "d": true}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
        assert_eq!(v.get("d").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn round_trips() {
        let doc = r#"{"arr":[1,2.5,"s"],"flag":false,"n":null}"#;
        let v = Json::parse(doc).unwrap();
        let re = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
        assert_eq!(Json::parse("7.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
    }
}
