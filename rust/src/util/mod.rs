//! Infrastructure substrate the offline environment lacks: deterministic
//! RNG, JSON, statistics, timing/profiling, logging, a thread pool, and
//! byte-level wire helpers. See DESIGN.md §7 for why these are in-tree.

pub mod bytes;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod threads;
pub mod timer;
