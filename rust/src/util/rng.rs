//! Deterministic pseudo-random number generation.
//!
//! The offline environment has no `rand` crate, so this module provides the
//! randomness substrate for the whole system: a [`SplitMix64`] seeder, a
//! [`Pcg32`] core generator (O'Neill's PCG-XSH-RR 64/32), uniform floats,
//! Box–Muller Gaussians, and a few sampling helpers used by the data
//! generators and the stochastic quantizers.
//!
//! All generators are deterministic given a seed; every experiment in
//! `exp/` threads explicit seeds so figures are exactly reproducible.

/// SplitMix64 — used to expand a single `u64` seed into high-quality stream
/// seeds (recommended seeding procedure for PCG-family generators).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new seeder from an arbitrary `u64`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32: small, fast, statistically solid 32-bit generator.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;

impl Pcg32 {
    /// Seed with a `u64`; the stream id is derived via SplitMix64 so two
    /// `Pcg32::new(s)` with different `s` are decorrelated.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let initstate = sm.next_u64();
        let initseq = sm.next_u64();
        Self::with_streams(initstate, initseq)
    }

    /// Explicit (state, stream) construction.
    pub fn with_streams(initstate: u64, initseq: u64) -> Self {
        let mut rng = Self { state: 0, inc: (initseq << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(initstate);
        rng.next_u32();
        rng
    }

    /// Raw `(state, inc)` for bit-exact snapshots. Unlike
    /// [`Self::with_streams`] (which advances the state while seeding),
    /// the pair round-trips through [`Self::from_state_parts`] without
    /// consuming any output, so a restored generator continues the exact
    /// stream — the property worker checkpoints depend on.
    pub fn state_parts(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`Self::state_parts`] verbatim.
    pub fn from_state_parts(state: u64, inc: u64) -> Self {
        Self { state, inc }
    }

    /// Derive a child generator (for per-worker / per-shard streams).
    pub fn split(&mut self) -> Pcg32 {
        let s = ((self.next_u32() as u64) << 32) | self.next_u32() as u64;
        Pcg32::new(s)
    }

    /// Next 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit output (two 32-bit draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform float in [0, 1) with 24 bits of mantissa entropy.
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform float in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform f64 in [0, 1) with 53 bits of entropy.
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Unbiased integer in [0, bound) via Lemire-style rejection.
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "below(0) is meaningless");
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            let m = (r as u64) * (bound as u64);
            if (m as u32) >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    /// Standard normal via Box–Muller (polar-free, two uniforms).
    pub fn normal(&mut self) -> f32 {
        // Avoid u == 0 (log(0)); uniform() excludes 1.0 already.
        let mut u1 = self.uniform();
        while u1 <= f32::EPSILON {
            u1 = self.uniform();
        }
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f32::consts::PI * u2;
        r * theta.cos()
    }

    /// Normal with given mean / std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Fill a slice with uniforms in [0,1).
    pub fn fill_uniform(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.uniform();
        }
    }

    /// A fresh Vec of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.fill_normal(&mut v);
        v
    }

    /// A fresh Vec of uniforms in [0,1).
    pub fn uniform_vec(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.fill_uniform(&mut v);
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, data: &mut [T]) {
        for i in (1..data.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            data.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        assert!(total > 0.0, "categorical() needs positive mass");
        let mut u = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            if u < w {
                return i;
            }
            u -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..16 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg_reference_stream_changes_with_seed() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let sa: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let sb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(sa, sb);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Pcg32::new(7);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut rng = Pcg32::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.uniform() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg32::new(17);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = rng.below(10) as usize;
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Pcg32::new(19);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Pcg32::new(23);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[rng.categorical(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn state_parts_round_trip_continues_the_stream() {
        let mut a = Pcg32::new(101);
        for _ in 0..17 {
            a.next_u32();
        }
        let (state, inc) = a.state_parts();
        let mut b = Pcg32::from_state_parts(state, inc);
        let sa: Vec<u32> = (0..32).map(|_| a.next_u32()).collect();
        let sb: Vec<u32> = (0..32).map(|_| b.next_u32()).collect();
        assert_eq!(sa, sb, "restored generator must continue the exact stream");
    }

    #[test]
    fn split_decorrelates() {
        let mut parent = Pcg32::new(29);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let s1: Vec<u32> = (0..8).map(|_| c1.next_u32()).collect();
        let s2: Vec<u32> = (0..8).map(|_| c2.next_u32()).collect();
        assert_ne!(s1, s2);
    }
}
