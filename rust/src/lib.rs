//! # DQGAN — Distributed Training of GANs with Quantized Gradients
//!
//! A from-scratch reproduction of *"A Distributed Training Algorithm of
//! Generative Adversarial Networks with Quantized Gradients"* (Chen, Yang,
//! Shen, Pang — 2020) as a three-layer Rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)**: the parameter-server coordinator — workers,
//!   leader, δ-approximate gradient compression with double error feedback
//!   (Algorithm 2), transports (in-process / TCP / simulated network),
//!   baselines (CPOAdam, CPOAdam-GQ), metrics (proxy IS/FID), and every
//!   figure harness from the paper's evaluation.
//! - **Layer 2 (`python/compile/`)**: the GAN forward/backward written in
//!   JAX, AOT-lowered to HLO text once at build time.
//! - **Layer 1 (`python/compile/kernels/`)**: Pallas kernels (fused
//!   quantize+error-feedback, tiled matmul, fused OMD update) lowered with
//!   `interpret=True` into the same HLO modules.
//!
//! Python never runs on the training path: the Rust binary loads
//! `artifacts/*.hlo.txt` through PJRT (`runtime/`) and owns the event loop.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod benchutil;
pub mod kernels;
pub mod linalg;
pub mod tensor;
pub mod testutil;
pub mod util;

pub mod obs;

pub mod ckpt;
pub mod compress;
pub mod comm;
pub mod optim;
pub mod algo;
pub mod grad;
pub mod model;
pub mod data;
pub mod metrics;
pub mod ps;
pub mod runtime;
pub mod config;
pub mod telemetry;
pub mod exp;
pub mod cli;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
