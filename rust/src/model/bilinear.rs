//! The bilinear saddle-point game `min_θ max_φ  θᵀ A φ + bᵀθ − cᵀφ`
//! (paper §2.2's motivating example [23]): the operator
//!
//!   F(θ, φ) = [A·φ + b,  −(Aᵀ·θ − c)]
//!
//! has a unique stationary point and *purely rotational* dynamics around
//! it — simultaneous GDA provably spirals out, OMD/extragradient converge.
//! This is SYN-B's workload.

use crate::grad::{GradMeta, GradientSource};
use crate::util::rng::Pcg32;

/// Bilinear game over θ, φ ∈ R^n.
pub struct BilinearGame {
    pub n: usize,
    /// Row-major n×n coupling matrix A.
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    pub c: Vec<f32>,
    /// Gradient noise std (σ), divided by √batch.
    pub noise: f32,
}

impl BilinearGame {
    /// The 1-D classic `θ·φ` (A = I₁, b = c = 0).
    pub fn scalar() -> Self {
        Self { n: 1, a: vec![1.0], b: vec![0.0], c: vec![0.0], noise: 0.0 }
    }

    /// Random well-conditioned instance.
    pub fn random(n: usize, noise: f32, rng: &mut Pcg32) -> Self {
        // A = I + 0.5·G/√n keeps the spectrum away from zero.
        let mut a = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                a[i * n + j] =
                    if i == j { 1.0 } else { 0.0 } + 0.5 * rng.normal() / (n as f32).sqrt();
            }
        }
        Self { n, a, b: rng.normal_vec(n), c: rng.normal_vec(n), noise }
    }

    /// The stationary point (θ*, φ*): A·φ* = −b, Aᵀ·θ* = c.
    /// Solved by Gaussian elimination (n is small in experiments).
    pub fn stationary_point(&self) -> Vec<f32> {
        let n = self.n;
        let neg_b: Vec<f32> = self.b.iter().map(|x| -x).collect();
        let phi = solve(&self.a, &neg_b, n);
        let at = crate::linalg::transpose(&self.a, n, n);
        let theta = solve(&at, &self.c, n);
        let mut w = theta;
        w.extend(phi);
        w
    }

    /// Distance to the stationary point.
    pub fn dist_to_solution(&self, w: &[f32]) -> f32 {
        let star = self.stationary_point();
        crate::util::stats::dist2_sq(w, &star).sqrt()
    }
}

/// Dense LU-free solve via Gauss–Jordan with partial pivoting (small n).
fn solve(a: &[f32], rhs: &[f32], n: usize) -> Vec<f32> {
    let mut m = vec![0.0f64; n * (n + 1)];
    for i in 0..n {
        for j in 0..n {
            m[i * (n + 1) + j] = a[i * n + j] as f64;
        }
        m[i * (n + 1) + n] = rhs[i] as f64;
    }
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in col + 1..n {
            if m[r * (n + 1) + col].abs() > m[piv * (n + 1) + col].abs() {
                piv = r;
            }
        }
        if piv != col {
            for j in 0..=n {
                m.swap(col * (n + 1) + j, piv * (n + 1) + j);
            }
        }
        let d = m[col * (n + 1) + col];
        assert!(d.abs() > 1e-12, "singular system");
        for j in 0..=n {
            m[col * (n + 1) + j] /= d;
        }
        for r in 0..n {
            if r != col {
                let f = m[r * (n + 1) + col];
                for j in 0..=n {
                    m[r * (n + 1) + j] -= f * m[col * (n + 1) + j];
                }
            }
        }
    }
    (0..n).map(|i| m[i * (n + 1) + n] as f32).collect()
}

impl GradientSource for BilinearGame {
    fn dim(&self) -> usize {
        2 * self.n
    }

    fn grad(
        &mut self,
        w: &[f32],
        batch: usize,
        rng: &mut Pcg32,
        out: &mut [f32],
    ) -> anyhow::Result<GradMeta> {
        let n = self.n;
        let (theta, phi) = w.split_at(n);
        let eff = self.noise / (batch.max(1) as f32).sqrt();
        // ∇θ = A·φ + b
        for i in 0..n {
            let mut acc = self.b[i];
            for j in 0..n {
                acc += self.a[i * n + j] * phi[j];
            }
            out[i] = acc + eff * rng.normal();
        }
        // ∇φ of the *descent* formulation: −(Aᵀ·θ − c)
        for j in 0..n {
            let mut acc = -self.c[j];
            for i in 0..n {
                acc += self.a[i * n + j] * theta[i];
            }
            out[n + j] = -acc + eff * rng.normal();
        }
        Ok(GradMeta::default())
    }

    fn init_params(&self, rng: &mut Pcg32) -> Vec<f32> {
        // Start on a circle of radius ~2 around the origin.
        let mut w = rng.normal_vec(2 * self.n);
        let norm = crate::util::stats::norm2(&w).max(1e-6);
        for v in w.iter_mut() {
            *v *= 2.0 / norm;
        }
        w
    }

    fn name(&self) -> String {
        format!("bilinear(n={})", self.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::Omd;

    #[test]
    fn gradient_is_rotational_for_scalar_game() {
        let mut g = BilinearGame::scalar();
        let mut out = vec![0.0; 2];
        let mut rng = Pcg32::new(1);
        g.grad(&[1.0, 0.5], 1, &mut rng, &mut out).unwrap();
        assert_eq!(out, vec![0.5, -1.0]); // F(θ,φ) = (φ, −θ)
    }

    #[test]
    fn stationary_point_zeroes_gradient() {
        let mut rng = Pcg32::new(2);
        let mut g = BilinearGame::random(4, 0.0, &mut rng);
        let star = g.stationary_point();
        let mut out = vec![0.0; 8];
        g.grad(&star, 1, &mut rng, &mut out).unwrap();
        for &x in &out {
            assert!(x.abs() < 1e-4, "F(w*)={out:?}");
        }
    }

    #[test]
    fn omd_converges_on_random_instance() {
        let mut rng = Pcg32::new(3);
        let mut g = BilinearGame::random(4, 0.0, &mut rng);
        let mut w = g.init_params(&mut rng);
        let mut omd = Omd::new(0.3, 8);
        for _ in 0..6000 {
            let mut grng = Pcg32::new(0);
            omd.step_with(&mut w, |p, o| {
                g.grad(p, 1, &mut grng, o).unwrap();
            });
        }
        assert!(g.dist_to_solution(&w) < 1e-2, "dist={}", g.dist_to_solution(&w));
    }
}
