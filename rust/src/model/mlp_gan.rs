//! Native-Rust WGAN on 2-D Gaussian mixtures with exact analytic backprop
//! (the SYN-A workload, and the fast path for theory sweeps).
//!
//! Architecture (one hidden layer each, tanh):
//!
//! ```text
//! G: z ∈ R^nz → h = tanh(Wg1·z + bg1) → x = Wg2·h + bg2 ∈ R²
//! D: x ∈ R²  → h = tanh(Wd1·x + bd1) → y = wd2·h + bd2 ∈ R
//! ```
//!
//! WGAN losses (paper eq. 6–7):
//!   L_G(θ,φ) = −E_z[D(G(z))]
//!   L_D(θ,φ) = −E_x[D(x)] + E_z[D(G(z))] + (λ/2)‖φ‖²
//! The λ-term is a soft critic regularizer standing in for WGAN's weight
//! clipping (keeps the critic bounded; applied to all of φ).
//!
//! F(w) = [∇θ L_G; ∇φ L_D] over the stacked vector w = [θ; φ]. The
//! analytic gradients are verified against finite differences in tests.

use crate::data::GaussianMixture2D;
use crate::grad::{GradMeta, GradientSource};
use crate::tensor::ParamLayout;
use crate::util::rng::Pcg32;

const DATA_DIM: usize = 2;

/// Sizes + hyperparameters.
#[derive(Debug, Clone)]
pub struct MlpGanConfig {
    pub noise_dim: usize,
    pub gen_hidden: usize,
    pub disc_hidden: usize,
    /// Critic L2 coefficient λ (Lipschitz surrogate).
    pub critic_l2: f32,
    /// Data distribution.
    pub mixture_modes: usize,
    pub mixture_radius: f32,
    pub mixture_std: f32,
}

impl Default for MlpGanConfig {
    fn default() -> Self {
        Self {
            noise_dim: 4,
            gen_hidden: 32,
            disc_hidden: 32,
            critic_l2: 1e-2,
            mixture_modes: 8,
            mixture_radius: 2.0,
            mixture_std: 0.1,
        }
    }
}

/// The model: parameter layout + data generator. Parameters themselves
/// live in the flat vector owned by the training algorithm.
pub struct MlpGan {
    pub cfg: MlpGanConfig,
    pub layout: ParamLayout,
    pub data: GaussianMixture2D,
    off: Offsets,
}

/// Flat offsets of each parameter block.
#[derive(Debug, Clone, Copy)]
struct Offsets {
    wg1: usize,
    bg1: usize,
    wg2: usize,
    bg2: usize,
    wd1: usize,
    bd1: usize,
    wd2: usize,
    bd2: usize,
    /// Start of the φ (discriminator) block.
    phi_start: usize,
    total: usize,
}

impl MlpGan {
    pub fn new(cfg: MlpGanConfig) -> Self {
        let (nz, hg, hd) = (cfg.noise_dim, cfg.gen_hidden, cfg.disc_hidden);
        let mut layout = ParamLayout::new();
        layout.push("gen.w1", &[hg, nz]);
        layout.push("gen.b1", &[hg]);
        layout.push("gen.w2", &[DATA_DIM, hg]);
        layout.push("gen.b2", &[DATA_DIM]);
        layout.push("disc.w1", &[hd, DATA_DIM]);
        layout.push("disc.b1", &[hd]);
        layout.push("disc.w2", &[hd]);
        layout.push("disc.b2", &[1]);
        let o = |name: &str| layout.spec(layout.index_of(name).unwrap()).offset;
        let off = Offsets {
            wg1: o("gen.w1"),
            bg1: o("gen.b1"),
            wg2: o("gen.w2"),
            bg2: o("gen.b2"),
            wd1: o("disc.w1"),
            bd1: o("disc.b1"),
            wd2: o("disc.w2"),
            bd2: o("disc.b2"),
            phi_start: o("disc.w1"),
            total: layout.total_len(),
        };
        let data =
            GaussianMixture2D::ring(cfg.mixture_modes, cfg.mixture_radius, cfg.mixture_std);
        Self { cfg, layout, data, off }
    }

    /// Generator forward: x = G(z), also returning the hidden activations.
    fn gen_forward(&self, w: &[f32], z: &[f32]) -> ([f32; DATA_DIM], Vec<f32>) {
        let (nz, hg) = (self.cfg.noise_dim, self.cfg.gen_hidden);
        let o = self.off;
        let mut h = vec![0.0f32; hg];
        for i in 0..hg {
            let mut a = w[o.bg1 + i];
            for j in 0..nz {
                a += w[o.wg1 + i * nz + j] * z[j];
            }
            h[i] = a.tanh();
        }
        let mut x = [0.0f32; DATA_DIM];
        for k in 0..DATA_DIM {
            let mut a = w[o.bg2 + k];
            for i in 0..hg {
                a += w[o.wg2 + k * hg + i] * h[i];
            }
            x[k] = a;
        }
        (x, h)
    }

    /// Public generator forward.
    pub fn generate(&self, w: &[f32], z: &[f32]) -> [f32; DATA_DIM] {
        self.gen_forward(w, z).0
    }

    /// Sample `n` generator outputs (metrics/plots).
    pub fn sample_generator(&self, w: &[f32], n: usize, rng: &mut Pcg32) -> Vec<[f32; 2]> {
        (0..n)
            .map(|_| {
                let z = rng.normal_vec(self.cfg.noise_dim);
                self.generate(w, &z)
            })
            .collect()
    }

    /// Critic forward: (D(x), hidden activations).
    fn critic_forward(&self, w: &[f32], x: &[f32; DATA_DIM]) -> (f32, Vec<f32>) {
        let hd = self.cfg.disc_hidden;
        let o = self.off;
        let mut h = vec![0.0f32; hd];
        let mut y = w[o.bd2];
        for i in 0..hd {
            let a = w[o.bd1 + i]
                + w[o.wd1 + i * DATA_DIM] * x[0]
                + w[o.wd1 + i * DATA_DIM + 1] * x[1];
            h[i] = a.tanh();
            y += w[o.wd2 + i] * h[i];
        }
        (y, h)
    }

    /// Public critic forward.
    pub fn criticize(&self, w: &[f32], x: &[f32; DATA_DIM]) -> f32 {
        self.critic_forward(w, x).0
    }

    /// ∇_x D(x) given the critic's hidden activations.
    fn critic_input_grad(&self, w: &[f32], h: &[f32]) -> [f32; DATA_DIM] {
        let hd = self.cfg.disc_hidden;
        let o = self.off;
        let mut gx = [0.0f32; DATA_DIM];
        for i in 0..hd {
            let gi = w[o.wd2 + i] * (1.0 - h[i] * h[i]);
            gx[0] += gi * w[o.wd1 + i * DATA_DIM];
            gx[1] += gi * w[o.wd1 + i * DATA_DIM + 1];
        }
        gx
    }

    /// Accumulate ∇φ of `coef·D(x)` into `out` (given forward h).
    fn critic_param_grad(
        &self,
        w: &[f32],
        x: &[f32; DATA_DIM],
        h: &[f32],
        coef: f32,
        out: &mut [f32],
    ) {
        let hd = self.cfg.disc_hidden;
        let o = self.off;
        out[o.bd2] += coef;
        for i in 0..hd {
            out[o.wd2 + i] += coef * h[i];
            let ga = coef * w[o.wd2 + i] * (1.0 - h[i] * h[i]);
            out[o.bd1 + i] += ga;
            out[o.wd1 + i * DATA_DIM] += ga * x[0];
            out[o.wd1 + i * DATA_DIM + 1] += ga * x[1];
        }
    }

    /// Accumulate ∇θ of `gx·G(z)` into `out` (given forward h): backprop
    /// the 2-vector `gx = dL/dx` through the generator.
    fn gen_param_grad(
        &self,
        w: &[f32],
        z: &[f32],
        h: &[f32],
        gx: &[f32; DATA_DIM],
        out: &mut [f32],
    ) {
        let (nz, hg) = (self.cfg.noise_dim, self.cfg.gen_hidden);
        let o = self.off;
        let mut gh = vec![0.0f32; hg];
        for k in 0..DATA_DIM {
            out[o.bg2 + k] += gx[k];
            for i in 0..hg {
                out[o.wg2 + k * hg + i] += gx[k] * h[i];
                gh[i] += w[o.wg2 + k * hg + i] * gx[k];
            }
        }
        for i in 0..hg {
            let ga = gh[i] * (1.0 - h[i] * h[i]);
            out[o.bg1 + i] += ga;
            for j in 0..nz {
                out[o.wg1 + i * nz + j] += ga * z[j];
            }
        }
    }

    /// Gradient for a fixed minibatch of noise vectors `zs` (B×nz) and
    /// real samples `xs` (B×2) — the deterministic core shared by `grad`
    /// and the finite-difference tests.
    pub fn grad_with_samples(
        &self,
        w: &[f32],
        zs: &[Vec<f32>],
        xs: &[[f32; DATA_DIM]],
        out: &mut [f32],
    ) -> (f32, f32) {
        assert_eq!(zs.len(), xs.len());
        assert_eq!(w.len(), self.off.total);
        assert_eq!(out.len(), self.off.total);
        let b = zs.len();
        let inv_b = 1.0 / b as f32;
        out.iter_mut().for_each(|v| *v = 0.0);
        let mut loss_g = 0.0f32;
        let mut loss_d = 0.0f32;
        for (z, xr) in zs.iter().zip(xs) {
            // fake
            let (xg, hg) = self.gen_forward(w, z);
            let (yf, hdf) = self.critic_forward(w, &xg);
            // real
            let (yr, hdr) = self.critic_forward(w, xr);
            loss_g += -yf * inv_b;
            loss_d += (-yr + yf) * inv_b;
            // ∇θ L_G: dL_G/dxg = −(1/B)·∇_x D(xg)
            let gxd = self.critic_input_grad(w, &hdf);
            let gx = [-inv_b * gxd[0], -inv_b * gxd[1]];
            self.gen_param_grad(w, z, &hg, &gx, out);
            // ∇φ L_D: −(1/B)·D(real) + (1/B)·D(fake)
            self.critic_param_grad(w, xr, &hdr, -inv_b, out);
            self.critic_param_grad(w, &xg, &hdf, inv_b, out);
        }
        // critic L2: λ·φ
        if self.cfg.critic_l2 > 0.0 {
            for i in self.off.phi_start..self.off.total {
                out[i] += self.cfg.critic_l2 * w[i];
                loss_d += 0.5 * self.cfg.critic_l2 * w[i] * w[i];
            }
        }
        (loss_g, loss_d)
    }

    /// Losses on a fixed minibatch (for the finite-difference tests).
    pub fn loss_with_samples(
        &self,
        w: &[f32],
        zs: &[Vec<f32>],
        xs: &[[f32; DATA_DIM]],
    ) -> (f32, f32) {
        let b = zs.len() as f32;
        let mut lg = 0.0f32;
        let mut ld = 0.0f32;
        for (z, xr) in zs.iter().zip(xs) {
            let (xg, _) = self.gen_forward(w, z);
            let yf = self.criticize(w, &xg);
            let yr = self.criticize(w, xr);
            lg += -yf / b;
            ld += (-yr + yf) / b;
        }
        if self.cfg.critic_l2 > 0.0 {
            for i in self.off.phi_start..self.off.total {
                ld += 0.5 * self.cfg.critic_l2 * w[i] * w[i];
            }
        }
        (lg, ld)
    }
}

impl GradientSource for MlpGan {
    fn dim(&self) -> usize {
        self.off.total
    }

    fn grad(
        &mut self,
        w: &[f32],
        batch: usize,
        rng: &mut Pcg32,
        out: &mut [f32],
    ) -> anyhow::Result<GradMeta> {
        let zs: Vec<Vec<f32>> = (0..batch).map(|_| rng.normal_vec(self.cfg.noise_dim)).collect();
        let xs: Vec<[f32; 2]> = (0..batch).map(|_| self.data.sample(rng)).collect();
        let (lg, ld) = self.grad_with_samples(w, &zs, &xs, out);
        Ok(GradMeta { loss_g: Some(lg), loss_d: Some(ld) })
    }

    fn init_params(&self, rng: &mut Pcg32) -> Vec<f32> {
        let mut w = vec![0.0f32; self.off.total];
        for spec in self.layout.specs() {
            let fan_in = if spec.shape.len() == 2 { spec.shape[1] } else { spec.shape[0] };
            let std = if spec.name.ends_with(".b1") || spec.name.ends_with(".b2") {
                0.0
            } else {
                1.0 / (fan_in as f32).sqrt()
            };
            for i in 0..spec.numel() {
                w[spec.offset + i] = std * rng.normal();
            }
        }
        w
    }

    fn name(&self) -> String {
        format!("mlp-gan(d={})", self.off.total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixed_batch(gan: &MlpGan, b: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<[f32; 2]>) {
        let mut rng = Pcg32::new(seed);
        let zs = (0..b).map(|_| rng.normal_vec(gan.cfg.noise_dim)).collect();
        let xs = (0..b).map(|_| gan.data.sample(&mut rng)).collect();
        (zs, xs)
    }

    #[test]
    fn analytic_gradient_matches_finite_differences() {
        let gan = MlpGan::new(MlpGanConfig {
            noise_dim: 3,
            gen_hidden: 5,
            disc_hidden: 4,
            critic_l2: 0.01,
            ..Default::default()
        });
        let mut rng = Pcg32::new(11);
        let w = gan.init_params(&mut rng);
        let (zs, xs) = fixed_batch(&gan, 3, 42);
        let mut g = vec![0.0; w.len()];
        gan.grad_with_samples(&w, &zs, &xs, &mut g);
        // F = [∇θ L_G; ∇φ L_D]: check each coordinate by central difference
        // of the appropriate loss.
        let phi_start = gan.off.phi_start;
        let eps = 3e-3f32;
        for i in (0..w.len()).step_by(7) {
            let mut wp = w.clone();
            let mut wm = w.clone();
            wp[i] += eps;
            wm[i] -= eps;
            let (lgp, ldp) = gan.loss_with_samples(&wp, &zs, &xs);
            let (lgm, ldm) = gan.loss_with_samples(&wm, &zs, &xs);
            let fd = if i < phi_start { (lgp - lgm) / (2.0 * eps) } else { (ldp - ldm) / (2.0 * eps) };
            assert!(
                (fd - g[i]).abs() < 2e-2 * fd.abs().max(1.0),
                "param {i}: fd={fd} analytic={}",
                g[i]
            );
        }
    }

    #[test]
    fn generator_output_is_finite_and_2d() {
        let gan = MlpGan::new(MlpGanConfig::default());
        let mut rng = Pcg32::new(13);
        let w = gan.init_params(&mut rng);
        let pts = gan.sample_generator(&w, 32, &mut rng);
        assert_eq!(pts.len(), 32);
        assert!(pts.iter().all(|p| p[0].is_finite() && p[1].is_finite()));
    }

    #[test]
    fn grad_source_contract() {
        let mut gan = MlpGan::new(MlpGanConfig::default());
        let mut rng = Pcg32::new(17);
        let w = gan.init_params(&mut rng);
        assert_eq!(w.len(), gan.dim());
        let mut out = vec![0.0; gan.dim()];
        let meta = gan.grad(&w, 8, &mut rng, &mut out).unwrap();
        assert!(meta.loss_g.is_some() && meta.loss_d.is_some());
        assert!(out.iter().any(|&x| x != 0.0));
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn single_machine_omd_training_improves_quality() {
        use crate::optim::Omd;
        let mut gan = MlpGan::new(MlpGanConfig {
            gen_hidden: 24,
            disc_hidden: 24,
            mixture_modes: 4,
            ..Default::default()
        });
        let mut rng = Pcg32::new(19);
        let mut w = gan.init_params(&mut rng);
        let q0 = {
            let pts = gan.sample_generator(&w, 256, &mut rng);
            gan.data.quality_score(&pts)
        };
        let mut omd = Omd::new(0.02, w.len());
        let mut grng = Pcg32::new(23);
        for _ in 0..4000 {
            let mut half = vec![0.0; w.len()];
            omd.half_point(&w, &mut half);
            let mut g = vec![0.0; w.len()];
            gan.grad(&half, 32, &mut grng, &mut g).unwrap();
            omd.full_step(&mut w, &g);
        }
        let q1 = {
            let pts = gan.sample_generator(&w, 256, &mut rng);
            gan.data.quality_score(&pts)
        };
        assert!(
            q1 < q0 * 0.8,
            "training did not improve quality: before={q0} after={q1}"
        );
    }
}
