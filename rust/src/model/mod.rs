//! Native-Rust models implementing [`crate::grad::GradientSource`].
//!
//! The production gradient path is the JAX/Pallas model compiled to XLA
//! (`runtime::XlaGradSource`); these native models exist because the
//! theory experiments (SYN-A/B, LEM1, THM3) sweep thousands of
//! configurations where analytic gradients are both faster and an
//! independent check on the XLA path (integration tests compare the two).
//!
//! - [`BilinearGame`] — the canonical min–max toy `L(θ,φ) = θᵀAφ`;
//! - [`MlpGan`] — a WGAN on 2-D Gaussian mixtures with one-hidden-layer
//!   generator and discriminator, exact backprop.

mod bilinear;
mod mlp_gan;

pub use bilinear::BilinearGame;
pub use mlp_gan::{MlpGan, MlpGanConfig};
