//! Hot-path f32 kernels with a process-global scalar/SIMD switch.
//!
//! Every kernel here has two arms:
//!
//! - a **scalar** arm — the original element-at-a-time loop, kept verbatim
//!   as the baseline;
//! - a **simd** arm — the same per-element expressions chunked 8 lanes at
//!   a time (portable `chunks_exact` unrolling that the backend turns into
//!   vector code, plus a runtime-detected AVX2 `std::arch` path on x86-64
//!   for the pure add/scale kernels where 256-bit lanes beat what
//!   autovectorization does at the baseline target).
//!
//! The contract is **bitwise identity**: both arms perform the identical
//! IEEE-754 operations per element, in the same order, at the same
//! rounding sites, so `broadcast_fnv` checksums must match across
//! `--kernels scalar` and `--kernels simd` forever (CI diffs them). That
//! is why the AVX2 arm only covers lane-wise `+`, `*` and `/` (exact,
//! correctly-rounded single operations with the same NaN propagation as
//! their scalar forms on x86) and never `min`/`max`-style ops whose vector
//! NaN semantics differ from Rust's scalar methods — those stay in the
//! portable chunked form where each lane is literally the scalar
//! expression.
//!
//! The mode is process-global (one `--kernels` knob per run, set once by
//! the CLI before any worker threads start). Tests and benches that need
//! both arms in one process use [`scoped_mode`], which serializes flips
//! behind a lock and restores the previous mode on drop — safe even if
//! unrelated threads race a dispatch, because both arms return identical
//! bits.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

pub use crate::config::KernelMode;

/// Lane width of the portable chunked kernels (8 × f32 = one 256-bit
/// vector register; also a whole-number multiple of the 128-bit lanes the
/// baseline x86-64 target autovectorizes to).
pub const LANES: usize = 8;

const MODE_SIMD: u8 = 0;
const MODE_SCALAR: u8 = 1;

/// Process-global kernel mode. SIMD is the default: the fast path is on
/// unless a run opts out with `--kernels scalar`.
static MODE: AtomicU8 = AtomicU8::new(MODE_SIMD);

/// Serializes [`scoped_mode`] users (tests / benches that A/B both arms).
static MODE_LOCK: Mutex<()> = Mutex::new(());

/// Set the process-global kernel mode. Called once by the CLI at startup;
/// tests should prefer [`scoped_mode`].
pub fn set_mode(mode: KernelMode) {
    let v = match mode {
        KernelMode::Simd => MODE_SIMD,
        KernelMode::Scalar => MODE_SCALAR,
    };
    MODE.store(v, Ordering::Relaxed);
}

/// The current process-global kernel mode.
pub fn mode() -> KernelMode {
    match MODE.load(Ordering::Relaxed) {
        MODE_SCALAR => KernelMode::Scalar,
        _ => KernelMode::Simd,
    }
}

/// Backend the SIMD arm will actually use on this machine, for run logs:
/// `"avx2"` when runtime detection found it, else `"portable"`.
pub fn simd_backend() -> &'static str {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        return "avx2";
    }
    "portable"
}

/// RAII guard holding the kernel mode at a fixed value; restores the
/// previous mode on drop. Guards serialize behind a process-wide lock so
/// concurrent A/B tests can't interleave flips.
pub struct ScopedMode {
    prev: KernelMode,
    _serial: MutexGuard<'static, ()>,
}

/// Pin the global kernel mode for the lifetime of the returned guard.
pub fn scoped_mode(mode: KernelMode) -> ScopedMode {
    let serial = MODE_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
    let prev = self::mode();
    set_mode(mode);
    ScopedMode { prev, _serial: serial }
}

impl Drop for ScopedMode {
    fn drop(&mut self) {
        set_mode(self.prev);
    }
}

// ---------------------------------------------------------------------------
// AVX2 runtime detection (cached; `std::arch` paths are x86-64 only).
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    // 0 = unknown, 1 = yes, 2 = no.
    static AVX2: AtomicU8 = AtomicU8::new(0);
    match AVX2.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            let yes = std::arch::is_x86_feature_detected!("avx2");
            AVX2.store(if yes { 1 } else { 2 }, Ordering::Relaxed);
            yes
        }
    }
}

// ---------------------------------------------------------------------------
// acc[i] += src[i]  (the fold_shard / reduce inner loop)
// ---------------------------------------------------------------------------

/// `acc[i] += src[i]`, dispatching on the global mode.
#[inline]
pub fn add_assign(acc: &mut [f32], src: &[f32]) {
    match mode() {
        KernelMode::Simd => add_assign_simd(acc, src),
        KernelMode::Scalar => add_assign_scalar(acc, src),
    }
}

/// Scalar baseline: one element per iteration.
pub fn add_assign_scalar(acc: &mut [f32], src: &[f32]) {
    debug_assert_eq!(acc.len(), src.len());
    for (a, &b) in acc.iter_mut().zip(src) {
        *a += b;
    }
}

/// SIMD arm: 8 lanes per iteration (AVX2 when available).
pub fn add_assign_simd(acc: &mut [f32], src: &[f32]) {
    debug_assert_eq!(acc.len(), src.len());
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // Safety: AVX2 presence was runtime-checked just above.
        unsafe { add_assign_avx2(acc, src) };
        return;
    }
    let mut ac = acc.chunks_exact_mut(LANES);
    let mut sc = src.chunks_exact(LANES);
    for (a, b) in (&mut ac).zip(&mut sc) {
        let a: &mut [f32; LANES] = a.try_into().expect("exact chunk");
        let b: &[f32; LANES] = b.try_into().expect("exact chunk");
        for i in 0..LANES {
            a[i] += b[i];
        }
    }
    for (a, &b) in ac.into_remainder().iter_mut().zip(sc.remainder()) {
        *a += b;
    }
}

/// Safety: caller must have verified AVX2 support. Lane-wise `vaddps` is
/// the same correctly-rounded IEEE add (and same NaN propagation) as the
/// scalar `+` on x86, so this stays bitwise-identical to the scalar arm.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn add_assign_avx2(acc: &mut [f32], src: &[f32]) {
    use std::arch::x86_64::{_mm256_add_ps, _mm256_loadu_ps, _mm256_storeu_ps};
    let n = acc.len();
    let mut i = 0;
    while i + LANES <= n {
        let a = _mm256_loadu_ps(acc.as_ptr().add(i));
        let b = _mm256_loadu_ps(src.as_ptr().add(i));
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_add_ps(a, b));
        i += LANES;
    }
    while i < n {
        *acc.get_unchecked_mut(i) += *src.get_unchecked(i);
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// out[i] = src[i] * k  /  buf[i] *= k  (the 1/M close-time scale)
// ---------------------------------------------------------------------------

/// `out[i] = src[i] * k`, dispatching on the global mode.
#[inline]
pub fn scale_into(out: &mut [f32], src: &[f32], k: f32) {
    match mode() {
        KernelMode::Simd => scale_into_simd(out, src, k),
        KernelMode::Scalar => scale_into_scalar(out, src, k),
    }
}

/// Scalar baseline: one element per iteration.
pub fn scale_into_scalar(out: &mut [f32], src: &[f32], k: f32) {
    debug_assert_eq!(out.len(), src.len());
    for (o, &a) in out.iter_mut().zip(src) {
        *o = a * k;
    }
}

/// SIMD arm: 8 lanes per iteration (AVX2 when available).
pub fn scale_into_simd(out: &mut [f32], src: &[f32], k: f32) {
    debug_assert_eq!(out.len(), src.len());
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // Safety: AVX2 presence was runtime-checked just above.
        unsafe { scale_into_avx2(out, src, k) };
        return;
    }
    let mut oc = out.chunks_exact_mut(LANES);
    let mut sc = src.chunks_exact(LANES);
    for (o, a) in (&mut oc).zip(&mut sc) {
        let o: &mut [f32; LANES] = o.try_into().expect("exact chunk");
        let a: &[f32; LANES] = a.try_into().expect("exact chunk");
        for i in 0..LANES {
            o[i] = a[i] * k;
        }
    }
    for (o, &a) in oc.into_remainder().iter_mut().zip(sc.remainder()) {
        *o = a * k;
    }
}

/// Safety: caller must have verified AVX2 support. Lane-wise `vmulps` is
/// the same correctly-rounded IEEE multiply as the scalar `*` on x86.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn scale_into_avx2(out: &mut [f32], src: &[f32], k: f32) {
    use std::arch::x86_64::{_mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps};
    let n = out.len();
    let kv = _mm256_set1_ps(k);
    let mut i = 0;
    while i + LANES <= n {
        let a = _mm256_loadu_ps(src.as_ptr().add(i));
        _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(a, kv));
        i += LANES;
    }
    while i < n {
        *out.get_unchecked_mut(i) = *src.get_unchecked(i) * k;
        i += 1;
    }
}

/// `buf[i] *= k` in place, dispatching on the global mode.
#[inline]
pub fn scale_in_place(buf: &mut [f32], k: f32) {
    match mode() {
        KernelMode::Simd => scale_in_place_simd(buf, k),
        KernelMode::Scalar => scale_in_place_scalar(buf, k),
    }
}

/// Scalar baseline: one element per iteration.
pub fn scale_in_place_scalar(buf: &mut [f32], k: f32) {
    for x in buf.iter_mut() {
        *x *= k;
    }
}

/// SIMD arm: 8 lanes per iteration (AVX2 when available).
pub fn scale_in_place_simd(buf: &mut [f32], k: f32) {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // Safety: AVX2 presence was runtime-checked just above. In-place
        // scale is `scale_into` with aliased src/out, expressed through
        // the same vmulps — identical rounding.
        unsafe { scale_in_place_avx2(buf, k) };
        return;
    }
    let mut bc = buf.chunks_exact_mut(LANES);
    for b in &mut bc {
        let b: &mut [f32; LANES] = b.try_into().expect("exact chunk");
        for x in b.iter_mut() {
            *x *= k;
        }
    }
    for x in bc.into_remainder().iter_mut() {
        *x *= k;
    }
}

/// Safety: caller must have verified AVX2 support.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn scale_in_place_avx2(buf: &mut [f32], k: f32) {
    use std::arch::x86_64::{_mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps};
    let n = buf.len();
    let kv = _mm256_set1_ps(k);
    let mut i = 0;
    while i + LANES <= n {
        let a = _mm256_loadu_ps(buf.as_ptr().add(i));
        _mm256_storeu_ps(buf.as_mut_ptr().add(i), _mm256_mul_ps(a, kv));
        i += LANES;
    }
    while i < n {
        *buf.get_unchecked_mut(i) *= k;
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// out[i] = scale * (levels[i] as f32 / s)   (qsgd / linf grid reconstruct)
// ---------------------------------------------------------------------------

/// Grid reconstruction `out[i] = scale * (levels[i] as f32 / s)`,
/// dispatching on the global mode. This is the shared dequantization
/// expression of the qsgd and linf codecs; the division must stay a
/// division (not a reciprocal multiply) to preserve the scalar rounding.
#[inline]
pub fn grid_reconstruct(out: &mut [f32], levels: &[i32], scale: f32, s: f32) {
    match mode() {
        KernelMode::Simd => grid_reconstruct_simd(out, levels, scale, s),
        KernelMode::Scalar => grid_reconstruct_scalar(out, levels, scale, s),
    }
}

/// Scalar baseline: one element per iteration.
pub fn grid_reconstruct_scalar(out: &mut [f32], levels: &[i32], scale: f32, s: f32) {
    debug_assert_eq!(out.len(), levels.len());
    for (o, &l) in out.iter_mut().zip(levels) {
        *o = scale * (l as f32 / s);
    }
}

/// SIMD arm: 8 lanes per iteration (AVX2 when available; `vcvtdq2ps`,
/// `vdivps` and `vmulps` are all exact/correctly-rounded per lane, so the
/// bits match the scalar expression).
pub fn grid_reconstruct_simd(out: &mut [f32], levels: &[i32], scale: f32, s: f32) {
    debug_assert_eq!(out.len(), levels.len());
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // Safety: AVX2 presence was runtime-checked just above.
        unsafe { grid_reconstruct_avx2(out, levels, scale, s) };
        return;
    }
    let mut oc = out.chunks_exact_mut(LANES);
    let mut lc = levels.chunks_exact(LANES);
    for (o, l) in (&mut oc).zip(&mut lc) {
        let o: &mut [f32; LANES] = o.try_into().expect("exact chunk");
        let l: &[i32; LANES] = l.try_into().expect("exact chunk");
        for i in 0..LANES {
            o[i] = scale * (l[i] as f32 / s);
        }
    }
    for (o, &l) in oc.into_remainder().iter_mut().zip(lc.remainder()) {
        *o = scale * (l as f32 / s);
    }
}

/// Safety: caller must have verified AVX2 support.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn grid_reconstruct_avx2(out: &mut [f32], levels: &[i32], scale: f32, s: f32) {
    use std::arch::x86_64::{
        __m256i, _mm256_cvtepi32_ps, _mm256_div_ps, _mm256_loadu_si256, _mm256_mul_ps,
        _mm256_set1_ps, _mm256_storeu_ps,
    };
    let n = out.len();
    let sv = _mm256_set1_ps(s);
    let kv = _mm256_set1_ps(scale);
    let mut i = 0;
    while i + LANES <= n {
        let l = _mm256_loadu_si256(levels.as_ptr().add(i) as *const __m256i);
        let q = _mm256_div_ps(_mm256_cvtepi32_ps(l), sv);
        _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(q, kv));
        i += LANES;
    }
    while i < n {
        *out.get_unchecked_mut(i) = scale * (*levels.get_unchecked(i) as f32 / s);
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Inputs that stress lane tails and special bit patterns: -0.0, a
    /// NaN with payload, subnormals, plus ordinary values.
    fn special_vec(n: usize, salt: u32) -> Vec<f32> {
        (0..n)
            .map(|i| match (i as u32 + salt) % 6 {
                0 => -0.0,
                1 => f32::from_bits(0x7FC0_1234), // NaN payload
                2 => f32::MIN_POSITIVE / 4.0,     // subnormal
                3 => -(i as f32) * 0.37,
                4 => 1.0 + i as f32 * 1e-3,
                _ => (i as f32).sin() * 100.0,
            })
            .collect()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    const DIMS: [usize; 10] = [0, 1, 7, 8, 9, 15, 16, 17, 63, 130];

    #[test]
    fn add_assign_arms_are_bitwise_identical() {
        for &n in &DIMS {
            let src = special_vec(n, 1);
            let base = special_vec(n, 9);
            let mut a = base.clone();
            let mut b = base.clone();
            add_assign_scalar(&mut a, &src);
            add_assign_simd(&mut b, &src);
            assert_eq!(bits(&a), bits(&b), "n={n}");
        }
    }

    #[test]
    fn scale_arms_are_bitwise_identical() {
        for &n in &DIMS {
            let src = special_vec(n, 3);
            for k in [0.25f32, 1.0 / 3.0, -7.5e-3] {
                let mut a = vec![0.0f32; n];
                let mut b = vec![0.0f32; n];
                scale_into_scalar(&mut a, &src, k);
                scale_into_simd(&mut b, &src, k);
                assert_eq!(bits(&a), bits(&b), "scale_into n={n} k={k}");
                let mut c = src.clone();
                let mut d = src.clone();
                scale_in_place_scalar(&mut c, k);
                scale_in_place_simd(&mut d, k);
                assert_eq!(bits(&c), bits(&d), "scale_in_place n={n} k={k}");
            }
        }
    }

    #[test]
    fn grid_reconstruct_arms_are_bitwise_identical() {
        for &n in &DIMS {
            let levels: Vec<i32> = (0..n).map(|i| (i as i32 * 37 % 255) - 127).collect();
            for (scale, s) in [(1.5f32, 255.0f32), (1e-4, 7.0), (-3.25, 15.0)] {
                let mut a = vec![0.0f32; n];
                let mut b = vec![0.0f32; n];
                grid_reconstruct_scalar(&mut a, &levels, scale, s);
                grid_reconstruct_simd(&mut b, &levels, scale, s);
                assert_eq!(bits(&a), bits(&b), "n={n} scale={scale} s={s}");
            }
        }
    }

    #[test]
    fn dispatch_follows_scoped_mode() {
        // Whatever the ambient mode, a scoped pin dispatches that arm and
        // restores on drop. (Outputs are identical either way; this just
        // checks the guard mechanics.)
        let ambient = mode();
        {
            let _g = scoped_mode(KernelMode::Scalar);
            assert_eq!(mode(), KernelMode::Scalar);
            // add_assign through the dispatcher still works.
            let mut a = [1.0f32, 2.0];
            add_assign(&mut a, &[0.5, 0.5]);
            assert_eq!(a, [1.5, 2.5]);
        }
        assert_eq!(mode(), ambient);
        {
            let _g = scoped_mode(KernelMode::Simd);
            assert_eq!(mode(), KernelMode::Simd);
        }
        assert_eq!(mode(), ambient);
    }

    #[test]
    fn simd_backend_label_is_stable() {
        let l = simd_backend();
        assert!(l == "avx2" || l == "portable");
        assert_eq!(l, simd_backend(), "detection is cached");
    }
}
