//! The production gradient path: [`crate::grad::GradientSource`] backed by
//! the `*_grad` XLA artifacts, plus the sampler and feature-net wrappers
//! used by the metric loop.

use super::client::Runtime;
use super::client::Executable;
use crate::data::{GaussianMixture2D, SynthImages, IMG_LEN};
use crate::grad::{GradMeta, GradientSource};
use crate::metrics::FeatureNet;
use crate::util::rng::Pcg32;

/// Which dataset feeds the real-data input of the grad artifact.
pub enum DataSource {
    Mixture(GaussianMixture2D),
    Images(SynthImages),
}

impl DataSource {
    /// Flat per-sample length.
    fn sample_len(&self) -> usize {
        match self {
            DataSource::Mixture(_) => 2,
            DataSource::Images(_) => IMG_LEN,
        }
    }

    fn fill_batch(&self, n: usize, rng: &mut Pcg32, out: &mut Vec<f32>) {
        out.clear();
        match self {
            DataSource::Mixture(gm) => {
                for _ in 0..n {
                    let s = gm.sample(rng);
                    out.push(s[0]);
                    out.push(s[1]);
                }
            }
            DataSource::Images(ds) => {
                out.resize(n * IMG_LEN, 0.0);
                for i in 0..n {
                    let label = rng.below(ds.classes as u32) as usize;
                    ds.render(label, rng, &mut out[i * IMG_LEN..(i + 1) * IMG_LEN]);
                }
            }
        }
    }
}

/// GradientSource over a `<model>_grad` artifact.
pub struct XlaGradSource {
    exe: Executable,
    data: DataSource,
    dim: usize,
    theta_dim: usize,
    batch: usize,
    noise_dim: usize,
    init: InitKind,
    // scratch
    z_buf: Vec<f32>,
    x_buf: Vec<f32>,
}

enum InitKind {
    /// Mirror the native MLP-GAN init (layouts match).
    Mlp,
    /// DCGAN init (N(0,0.02) convs, He dense, zero bias).
    Dcgan(DcganInit),
}

/// Parameter-block table for the DCGAN init (mirrors
/// `python/compile/models/dcgan.py::DcganSpec.shapes()`).
pub struct DcganInit {
    /// (numel, kind) per block, in flat order.
    blocks: Vec<(usize, BlockKind)>,
}

enum BlockKind {
    Bias,
    Dense { fan_in: usize },
    Conv,
}

impl DcganInit {
    /// Build from the artifact metadata (noise_dim + base are fixed by the
    /// export; shapes are reproduced here).
    pub fn new(noise_dim: usize, base: usize) -> Self {
        let (g4, g2, g1) = (4 * base, 2 * base, base);
        let c = 3usize; // IMG_C
        let blocks = vec![
            (g4 * 16 * noise_dim, BlockKind::Dense { fan_in: noise_dim }),
            (g4 * 16, BlockKind::Bias),
            (g4 * g2 * 16, BlockKind::Conv),
            (g2, BlockKind::Bias),
            (g2 * g1 * 16, BlockKind::Conv),
            (g1, BlockKind::Bias),
            (g1 * c * 16, BlockKind::Conv),
            (c, BlockKind::Bias),
            (g1 * c * 16, BlockKind::Conv),
            (g1, BlockKind::Bias),
            (g2 * g1 * 16, BlockKind::Conv),
            (g2, BlockKind::Bias),
            (g4 * g2 * 16, BlockKind::Conv),
            (g4, BlockKind::Bias),
            (g4 * 16, BlockKind::Dense { fan_in: g4 * 16 }),
            (1, BlockKind::Bias),
        ];
        Self { blocks }
    }

    fn total(&self) -> usize {
        self.blocks.iter().map(|(n, _)| n).sum()
    }

    fn init(&self, rng: &mut Pcg32) -> Vec<f32> {
        let mut w = Vec::with_capacity(self.total());
        for (n, kind) in &self.blocks {
            match kind {
                BlockKind::Bias => w.extend(std::iter::repeat_n(0.0, *n)),
                BlockKind::Dense { fan_in } => {
                    let std = 1.0 / (*fan_in as f32).sqrt();
                    for _ in 0..*n {
                        w.push(std * rng.normal());
                    }
                }
                BlockKind::Conv => {
                    for _ in 0..*n {
                        w.push(0.02 * rng.normal());
                    }
                }
            }
        }
        w
    }
}

impl XlaGradSource {
    /// Build for the MLP GAN (2-D mixture data).
    pub fn mlp(rt: &Runtime, mixture: GaussianMixture2D) -> anyhow::Result<Self> {
        let exe = rt.load("mlp_gan_grad")?;
        let spec = &exe.spec;
        Ok(Self {
            dim: spec.meta_usize("dim")?,
            theta_dim: spec.meta_usize("theta_dim")?,
            batch: spec.meta_usize("batch")?,
            noise_dim: spec.meta_usize("noise_dim")?,
            data: DataSource::Mixture(mixture),
            init: InitKind::Mlp,
            exe,
            z_buf: Vec::new(),
            x_buf: Vec::new(),
        })
    }

    /// Build for the DCGAN (synthetic image data).
    pub fn dcgan(rt: &Runtime, images: SynthImages) -> anyhow::Result<Self> {
        let exe = rt.load("dcgan_grad")?;
        let spec = &exe.spec;
        let dim = spec.meta_usize("dim")?;
        let noise_dim = spec.meta_usize("noise_dim")?;
        // base is recoverable from dim? Export uses base=32; assert.
        let init = DcganInit::new(noise_dim, 32);
        anyhow::ensure!(
            init.total() == dim,
            "DCGAN init table total {} ≠ artifact dim {dim}",
            init.total()
        );
        Ok(Self {
            dim,
            theta_dim: spec.meta_usize("theta_dim")?,
            batch: spec.meta_usize("batch")?,
            noise_dim,
            data: DataSource::Images(images),
            init: InitKind::Dcgan(init),
            exe,
            z_buf: Vec::new(),
            x_buf: Vec::new(),
        })
    }

    /// The artifact's fixed batch size (callers must request exactly it).
    pub fn artifact_batch(&self) -> usize {
        self.batch
    }

    pub fn theta_dim(&self) -> usize {
        self.theta_dim
    }
}

impl GradientSource for XlaGradSource {
    fn dim(&self) -> usize {
        self.dim
    }

    fn grad(
        &mut self,
        w: &[f32],
        batch: usize,
        rng: &mut Pcg32,
        out: &mut [f32],
    ) -> anyhow::Result<GradMeta> {
        anyhow::ensure!(
            batch == self.batch,
            "XLA grad artifact was exported for batch {}, got {batch} \
             (set --batch accordingly)",
            self.batch
        );
        self.z_buf.clear();
        self.z_buf.reserve(self.batch * self.noise_dim);
        for _ in 0..self.batch * self.noise_dim {
            self.z_buf.push(rng.normal());
        }
        self.data.fill_batch(self.batch, rng, &mut self.x_buf);
        debug_assert_eq!(self.x_buf.len(), self.batch * self.data.sample_len());
        let outputs = self.exe.run_f32(&[w, &self.z_buf, &self.x_buf])?;
        out.copy_from_slice(&outputs[0]);
        Ok(GradMeta { loss_g: Some(outputs[1][0]), loss_d: Some(outputs[2][0]) })
    }

    fn init_params(&self, rng: &mut Pcg32) -> Vec<f32> {
        let mut rng = rng.clone();
        match &self.init {
            InitKind::Mlp => {
                let native = crate::model::MlpGan::new(crate::model::MlpGanConfig::default());
                let w = native.init_params(&mut rng);
                assert_eq!(w.len(), self.dim, "native/artifact layout mismatch");
                w
            }
            InitKind::Dcgan(init) => init.init(&mut rng),
        }
    }

    fn name(&self) -> String {
        format!("xla[{}]", self.exe.spec.name)
    }
}

/// Generator sampling through the `<model>_sample` artifact.
pub struct XlaSampler {
    exe: Executable,
    pub sample_n: usize,
    pub noise_dim: usize,
}

impl XlaSampler {
    pub fn new(rt: &Runtime, artifact: &str) -> anyhow::Result<Self> {
        let exe = rt.load(artifact)?;
        Ok(Self {
            sample_n: exe.spec.meta_usize("sample_n")?,
            noise_dim: exe.spec.meta_usize("noise_dim")?,
            exe,
        })
    }

    /// Draw one artifact-batch of generator samples (flat output).
    pub fn sample(&self, w: &[f32], rng: &mut Pcg32) -> anyhow::Result<Vec<f32>> {
        let z: Vec<f32> = (0..self.sample_n * self.noise_dim).map(|_| rng.normal()).collect();
        Ok(self.exe.run_f32(&[w, &z])?.remove(0))
    }
}

/// Metric scoring through the `feature_net` artifact, fed with the Rust
/// [`FeatureNet`]'s weights (identical embedding in both languages).
pub struct XlaFeatureNet {
    exe: Executable,
    weights: FeatureNet,
    pub batch: usize,
}

impl XlaFeatureNet {
    pub fn new(rt: &Runtime) -> anyhow::Result<Self> {
        let exe = rt.load("feature_net")?;
        Ok(Self { batch: exe.spec.meta_usize("batch")?, weights: FeatureNet::new(), exe })
    }

    /// Features + logits for exactly `batch` images (flat CHW).
    pub fn score(&self, imgs: &[f32]) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(
            imgs.len() == self.batch * IMG_LEN,
            "feature_net artifact takes exactly {} images",
            self.batch
        );
        let (w1, b1, w2, b2, wh, bh) = self.weights.weights();
        let mut out = self.exe.run_f32(&[w1, b1, w2, b2, wh, bh, imgs])?;
        let logits = out.remove(1);
        let feats = out.remove(0);
        Ok((feats, logits))
    }
}
